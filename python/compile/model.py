"""L2: the paper's numeric inner loops as JAX functions, built on the L1
Pallas kernel.

Three exported computations, each AOT-lowered per shape bucket by aot.py and
executed from the rust hot path (rust/src/runtime/):

  assign     (points, centers, pmask, cmask) -> (min_sqdist[B], argmin[B])
      The inner loop of Iterative-Sample's pruning step (d(x, S) vs pivot)
      and of MapReduce-kMedian's weight phase.

  lloyd_step (points, centers, pmask, cmask)
      -> (sums[K, D], counts[K], cost_median[], cost_means[])
      One Lloyd accumulation over a point block: nearest-center assignment
      plus masked per-cluster sums/counts and both clustering objectives.
      Rust aggregates blocks across "machines" and recomputes means —
      exactly the paper's Parallel-Lloyd round structure.

  weight_histogram (points, centers, pmask, cmask) -> (counts[K], cost_median[])
      MapReduce-kMedian step 4: per-reducer w^i(y) = |{x : x^C = y}|,
      plus the partial k-median cost (used for evaluation).

All shapes are static per bucket; padding rows are killed by pmask/cmask.
Every function here must agree with kernels/ref.py (enforced by
python/tests/), and the semantics are mirrored by rust/src/runtime/native.rs.
"""

import jax
import jax.numpy as jnp

from .kernels.distance import assign_pallas


def assign(points, centers, pmask, cmask):
    """Nearest-valid-center assignment for a point block.

    min_sqdist of padded points is forced to 0 so downstream sums can ignore
    pmask; argmin of padded points is whatever the kernel computed (rust
    discards those rows).
    """
    md, am = assign_pallas(points, centers, cmask)
    return md * pmask, am


def lloyd_step(points, centers, pmask, cmask):
    """One Lloyd accumulation step over a point block (see module doc)."""
    k = centers.shape[0]
    md, am = assign(points, centers, pmask, cmask)
    w = pmask
    # Scatter-add via one-hot matmul: keeps the whole step MXU-shaped and
    # avoids data-dependent scatters, which lower poorly on TPU.
    onehot = (jnp.arange(k, dtype=jnp.int32)[None, :] == am[:, None])
    onehot = onehot.astype(jnp.float32) * w[:, None]
    sums = jax.lax.dot_general(
        onehot, points, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (K, D)
    counts = jnp.sum(onehot, axis=0)  # (K,)
    cost_median = jnp.sum(jnp.sqrt(md))  # md already 0 on padded rows
    cost_means = jnp.sum(md)
    return sums, counts, cost_median, cost_means


def weight_histogram(points, centers, pmask, cmask):
    """Per-block center weights (MapReduce-kMedian step 4) + partial cost."""
    k = centers.shape[0]
    md, am = assign(points, centers, pmask, cmask)
    onehot = (jnp.arange(k, dtype=jnp.int32)[None, :] == am[:, None])
    counts = jnp.sum(onehot.astype(jnp.float32) * pmask[:, None], axis=0)
    return counts, jnp.sum(jnp.sqrt(md))


def example_args(b, k, d):
    """ShapeDtypeStructs for lowering at bucket (B=b, K=k, D=d)."""
    return (
        jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((k, d), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
    )


# Registry consumed by aot.py: name -> (callable, n_outputs).
EXPORTS = {
    "assign": (assign, 2),
    "lloyd_step": (lloyd_step, 4),
    "weight_histogram": (weight_histogram, 2),
}
