"""L1 Pallas kernel: blocked nearest-center assignment.

This is the compute hot-spot of every algorithm in the paper (Lloyd
iterations, Iterative-Sample's d(x, S) pruning, MapReduce-kMedian's weight
phase): for a block of points X (B, D) and a center set C (K, D), compute for
each point the squared distance to — and index of — its nearest *valid*
center.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): instead of the naive
(B, K, D) difference tensor, we use the expansion

    D2[b, k] = |x_b|^2 - 2 * (X @ C^T)[b, k] + |c_k|^2

whose dominant term is a (B, D) x (D, K) matmul — an MXU-shaped contraction.
The Pallas grid tiles the point axis: each grid step holds one (BLOCK_B, D)
point tile plus the full center set in VMEM (K <= 512, D <= 64 fits easily in
16 MiB) and writes one (BLOCK_B,) min/argmin pair. The HBM<->VMEM schedule
that the paper's cluster expressed with per-machine partitioning is expressed
here with the BlockSpec index maps.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel into plain HLO ops so the AOT
artifact runs on the rust CPU client. Real-TPU perf is estimated in
DESIGN.md / EXPERIMENTS.md §Perf from the VMEM footprint and MXU utilization
of this same tiling.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Penalty added to masked-out centers. Large enough to exceed any real
# squared distance in our workloads (unit-cube data => d2 <= D * 4), small
# enough that f32 arithmetic on it stays finite.
MASK_PENALTY = 1e30

# Default point-tile height. 512 rows x (D + K) f32 columns keeps the tile
# plus the distance block well under VMEM budget for every bucket we ship.
DEFAULT_BLOCK_B = 512


def _assign_kernel(x_ref, c_ref, cm_ref, md_ref, am_ref):
    """One grid step: nearest valid center for a (BLOCK_B, D) point tile."""
    x = x_ref[...]  # (bb, D) f32, VMEM
    c = c_ref[...]  # (K, D) f32, VMEM (replicated across grid steps)
    cm = cm_ref[...]  # (K,)  f32

    # |x|^2 - 2 x.c + |c|^2 ; the dot_general is the MXU-eligible term.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (bb, 1)
    c2 = jnp.sum(c * c, axis=1)  # (K,)
    xc = jax.lax.dot_general(
        x, c, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bb, K)
    d2 = x2 - 2.0 * xc + c2[None, :]
    # Cancellation can push tiny true-zero distances slightly negative.
    d2 = jnp.maximum(d2, 0.0)
    # Invalid centers must lose every argmin: add a huge penalty.
    d2 = d2 + (1.0 - cm[None, :]) * MASK_PENALTY

    md_ref[...] = jnp.min(d2, axis=1)
    am_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def assign_pallas(points, centers, cmask, *, block_b=DEFAULT_BLOCK_B):
    """Nearest-valid-center assignment via the Pallas kernel.

    Args:
      points:  f32[B, D]; B must be a multiple of ``block_b`` (the AOT
               buckets guarantee this; rust pads to the bucket shape).
      centers: f32[K, D]
      cmask:   f32[K] (1 = valid center, 0 = padding)
      block_b: point-tile height (static).

    Returns:
      (min_sqdist f32[B], argmin i32[B]).
    """
    b, d = points.shape
    k, d2 = centers.shape
    if d != d2:
        raise ValueError(f"dim mismatch: points D={d} centers D={d2}")
    block_b = min(block_b, b)  # small buckets use a single tile
    if b % block_b != 0:
        raise ValueError(f"B={b} not a multiple of block_b={block_b}")

    grid = (b // block_b,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(points, centers, cmask)
