"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the reference semantics that every kernel in this package must
match bit-for-bit (up to float tolerance). They are deliberately written in
the most naive way possible — O(B*K*D) dense broadcasting — so they are easy
to audit against the paper's definitions.

Conventions (shared with distance.py / model.py):
  points : f32[B, D]   point block (rows may be padding)
  centers: f32[K, D]   center set (rows may be padding)
  pmask  : f32[B]      1.0 for valid points, 0.0 for padding
  cmask  : f32[K]      1.0 for valid centers, 0.0 for padding

Padded centers must never be selected as the argmin; padded points produce
zero contribution to any aggregate (sums / counts / costs).
"""

import jax.numpy as jnp

_BIG = jnp.float32(3.4e38)  # stand-in for +inf that survives f32 arithmetic


def sq_distances_ref(points, centers):
    """Dense squared Euclidean distances, f32[B, K]."""
    diff = points[:, None, :] - centers[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def assign_ref(points, centers, cmask):
    """(min_sqdist f32[B], argmin i32[B]) over *valid* centers only."""
    d2 = sq_distances_ref(points, centers)
    d2 = jnp.where(cmask[None, :] > 0.5, d2, _BIG)
    return jnp.min(d2, axis=1), jnp.argmin(d2, axis=1).astype(jnp.int32)


def lloyd_step_ref(points, centers, pmask, cmask):
    """One Lloyd accumulation step (assignment + masked cluster stats).

    Returns (sums f32[K, D], counts f32[K], cost_median f32, cost_means f32):
      sums[j]     = sum of valid points assigned to center j
      counts[j]   = number of valid points assigned to center j
      cost_median = sum over valid points of  d(x, nearest center)
      cost_means  = sum over valid points of  d(x, nearest center)^2
    """
    k = centers.shape[0]
    d2, idx = assign_ref(points, centers, cmask)
    w = pmask
    onehot = (jnp.arange(k)[None, :] == idx[:, None]).astype(jnp.float32)
    onehot = onehot * w[:, None]
    sums = onehot.T @ points
    counts = jnp.sum(onehot, axis=0)
    d2v = jnp.maximum(d2, 0.0)
    cost_median = jnp.sum(jnp.sqrt(d2v) * w)
    cost_means = jnp.sum(d2v * w)
    return sums, counts, cost_median, cost_means


def min_dist_to_set_ref(points, sample, pmask, smask):
    """d(x, S) for every point: f32[B] (0 for padded points)."""
    d2, _ = assign_ref(points, sample, smask)
    return jnp.sqrt(jnp.maximum(d2, 0.0)) * pmask
