"""AOT exporter: lower the L2 model functions to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Each export is specialized to a shape bucket (B, K, D) — XLA executables are
shape-monomorphic, so the rust runtime pads every real workload up to the
nearest bucket (rust/src/runtime/bucket.rs) and masks the padding.

Outputs:
  artifacts/<func>_b<B>_k<K>_d<D>.hlo.txt
  artifacts/manifest.json     — consumed by rust/src/runtime/manifest.rs

Usage: (cd python && python -m compile.aot --out-dir ../artifacts)
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import EXPORTS, example_args

# Shape buckets shipped by default. D=3 matches the paper's experiments
# (points in R^3, Section 4.2); K covers the paper's k=25 (bucket 32), large
# k sweeps (128/512), and Iterative-Sample's returned sample used as a
# "center set" in the weight phase (2048). D=8 exercises a non-trivial
# feature dimension for the library use-case.
DEFAULT_BUCKETS = [
    # (B, K, D)
    (2048, 32, 3),
    (2048, 128, 3),
    (2048, 512, 3),
    (2048, 2048, 3),
    (2048, 64, 8),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_bucket(func_name, fn, b, k, d, out_dir):
    lowered = jax.jit(fn).lower(*example_args(b, k, d))
    text = to_hlo_text(lowered)
    fname = f"{func_name}_b{b}_k{k}_d{d}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    return {
        "func": func_name,
        "b": b,
        "k": k,
        "d": d,
        "file": fname,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "bytes": len(text),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default=None,
        help="comma-separated B:K:D triples, e.g. 2048:32:3,2048:128:3",
    )
    ap.add_argument(
        "--funcs", default=None, help="comma-separated subset of funcs to export"
    )
    args = ap.parse_args()

    buckets = DEFAULT_BUCKETS
    if args.buckets:
        buckets = [
            tuple(int(x) for x in spec.split(":")) for spec in args.buckets.split(",")
        ]
    funcs = list(EXPORTS)
    if args.funcs:
        funcs = args.funcs.split(",")

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for func_name in funcs:
        fn, n_out = EXPORTS[func_name]
        for b, k, d in buckets:
            e = export_bucket(func_name, fn, b, k, d, args.out_dir)
            e["n_outputs"] = n_out
            entries.append(e)
            print(f"  {e['file']}: {e['bytes']} bytes")

    manifest = {
        "version": 1,
        "format": "hlo-text",
        "jax_version": jax.__version__,
        "entries": entries,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
