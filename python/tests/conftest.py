"""Collection guard: the python side (AOT artifact pipeline) is optional
tooling, not tier-1. When its heavyweight dependencies are absent the test
modules must be skipped at collection time — importing them would otherwise
error before pytest's own skip machinery can run."""

import importlib.util


def _missing(*mods):
    return [m for m in mods if importlib.util.find_spec(m) is None]


collect_ignore = []

# Everything here needs jax + numpy (the AOT exporter's substrate).
if _missing("jax", "numpy"):
    collect_ignore += ["test_aot.py", "test_kernel.py", "test_model.py"]
else:
    # The property sweeps additionally need hypothesis.
    if _missing("hypothesis"):
        collect_ignore += ["test_kernel.py", "test_model.py"]
