"""L1 correctness: Pallas assign kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, masks, and degenerate geometries;
every case asserts allclose (distances) and exact match (argmin indices,
modulo distance ties, which we compare through the distance value).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.distance import assign_pallas, MASK_PENALTY
from compile.kernels.ref import assign_ref, sq_distances_ref

RNG = np.random.RandomState


def _mk(b, k, d, seed=0, scale=1.0, cvalid=None):
    r = RNG(seed)
    x = (r.rand(b, d).astype(np.float32) * scale)
    c = (r.rand(k, d).astype(np.float32) * scale)
    cm = np.ones((k,), np.float32)
    if cvalid is not None:
        cm[cvalid:] = 0.0
    return jnp.asarray(x), jnp.asarray(c), jnp.asarray(cm)


def _check(x, c, cm, block_b):
    # The kernel's |x|^2 - 2xc + |c|^2 expansion loses ~1e-4 relative
    # precision to cancellation vs the oracle's (x-c)^2 at large coordinate
    # scales; 1e-3 relative is the contract the rust runtime assumes.
    md, am = assign_pallas(x, c, cm, block_b=block_b)
    rmd, ram = assign_ref(x, c, cm)
    # Absolute error of the expansion scales with the squared data magnitude
    # (cancellation), so the tolerance floor is relative to max |d2|.
    d2 = np.asarray(sq_distances_ref(x, c))
    atol = 1e-6 * (float(d2.max()) + 1.0)
    np.testing.assert_allclose(np.asarray(md), np.asarray(rmd), rtol=1e-3, atol=atol)
    # Argmin may differ only on (near-)distance ties: compare through d2.
    b = x.shape[0]
    got = d2[np.arange(b), np.asarray(am)]
    want = d2[np.arange(b), np.asarray(ram)]
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=atol)
    # The argmin must always be a valid center.
    assert np.all(np.asarray(cm)[np.asarray(am)] > 0.5)


class TestAssignBasic:
    def test_single_block(self):
        x, c, cm = _mk(512, 32, 3)
        _check(x, c, cm, 512)

    def test_multi_block(self):
        x, c, cm = _mk(2048, 32, 3)
        _check(x, c, cm, 512)

    def test_masked_centers(self):
        x, c, cm = _mk(512, 32, 3, cvalid=25)
        _check(x, c, cm, 512)

    def test_single_valid_center(self):
        x, c, cm = _mk(512, 32, 3, cvalid=1)
        md, am = assign_pallas(x, c, cm, block_b=512)
        assert np.all(np.asarray(am) == 0)

    def test_point_equals_center(self):
        x, c, cm = _mk(512, 16, 3)
        x = x.at[7].set(c[3])
        md, am = assign_pallas(x, c, cm, block_b=512)
        assert np.asarray(md)[7] <= 1e-6
        assert np.asarray(am)[7] == 3

    def test_all_points_identical(self):
        x, c, cm = _mk(512, 8, 3)
        x = jnp.broadcast_to(x[0], x.shape)
        _check(x, c, cm, 512)

    def test_all_centers_identical(self):
        x, c, cm = _mk(512, 8, 3)
        c = jnp.broadcast_to(c[0], c.shape)
        md, am = assign_pallas(x, c, cm, block_b=512)
        rmd, _ = assign_ref(x, c, cm)
        np.testing.assert_allclose(np.asarray(md), np.asarray(rmd), rtol=1e-4, atol=1e-5)

    def test_min_dist_nonnegative(self):
        x, c, cm = _mk(1024, 64, 3, scale=1e-3)
        md, _ = assign_pallas(x, c, cm, block_b=512)
        assert np.all(np.asarray(md) >= 0.0)

    def test_high_dim(self):
        x, c, cm = _mk(512, 64, 8)
        _check(x, c, cm, 512)

    def test_large_coordinates(self):
        # Distances stay far below MASK_PENALTY even at large scale.
        x, c, cm = _mk(512, 16, 3, scale=1e3, cvalid=10)
        _check(x, c, cm, 512)
        md, _ = assign_pallas(x, c, cm, block_b=512)
        assert np.asarray(md).max() < MASK_PENALTY / 2


class TestAssignValidation:
    def test_dim_mismatch_raises(self):
        x, _, _ = _mk(512, 8, 3)
        _, c, cm = _mk(512, 8, 4, seed=1)
        with pytest.raises(ValueError, match="dim mismatch"):
            assign_pallas(x, c, cm)

    def test_block_divisibility_raises(self):
        x, c, cm = _mk(100, 8, 3)
        with pytest.raises(ValueError, match="not a multiple"):
            assign_pallas(x, c, cm, block_b=64)

    def test_small_input_clamps_block(self):
        # B smaller than the default tile height uses a single tile.
        x, c, cm = _mk(256, 8, 3)
        _check(x, c, cm, 512)


@settings(max_examples=30, deadline=None)
@given(
    b_blocks=st.integers(1, 4),
    block_b=st.sampled_from([128, 256, 512]),
    k=st.integers(1, 96),
    d=st.integers(1, 10),
    cvalid_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
)
def test_assign_hypothesis(b_blocks, block_b, k, d, cvalid_frac, seed, scale):
    b = b_blocks * block_b
    cvalid = max(1, int(k * cvalid_frac))
    x, c, cm = _mk(b, k, d, seed=seed, scale=scale, cvalid=cvalid)
    _check(x, c, cm, block_b)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_assign_block_size_invariance(seed):
    """The result must not depend on the tile height."""
    x, c, cm = _mk(1024, 24, 3, seed=seed, cvalid=20)
    md1, am1 = assign_pallas(x, c, cm, block_b=128)
    md2, am2 = assign_pallas(x, c, cm, block_b=1024)
    np.testing.assert_allclose(np.asarray(md1), np.asarray(md2), rtol=1e-5)
    assert np.array_equal(np.asarray(am1), np.asarray(am2))
