"""L2 correctness: model.py functions vs the jnp oracle, including the
masked-padding semantics the rust runtime relies on."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.RandomState


def _mk(b, k, d, seed=0, pvalid=None, cvalid=None):
    r = RNG(seed)
    x = jnp.asarray(r.rand(b, d).astype(np.float32))
    c = jnp.asarray(r.rand(k, d).astype(np.float32))
    pm = np.ones((b,), np.float32)
    cm = np.ones((k,), np.float32)
    if pvalid is not None:
        pm[pvalid:] = 0.0
    if cvalid is not None:
        cm[cvalid:] = 0.0
    return x, c, jnp.asarray(pm), jnp.asarray(cm)


class TestLloydStep:
    def test_matches_ref(self):
        x, c, pm, cm = _mk(512, 32, 3, pvalid=400, cvalid=25)
        got = model.lloyd_step(x, c, pm, cm)
        want = ref.lloyd_step_ref(x, c, pm, cm)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-4)

    def test_counts_sum_to_valid_points(self):
        x, c, pm, cm = _mk(1024, 16, 3, pvalid=700)
        _, counts, _, _ = model.lloyd_step(x, c, pm, cm)
        assert abs(float(jnp.sum(counts)) - 700.0) < 1e-3

    def test_padded_points_no_contribution(self):
        x, c, pm, cm = _mk(512, 16, 3, pvalid=256)
        # Poison the padded rows with huge values; results must not change.
        x2 = x.at[256:].set(1e6)
        a = model.lloyd_step(x, c, pm, cm)
        b = model.lloyd_step(x2, c, pm, cm)
        for g, w in zip(a, b):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4)

    def test_sums_recover_means(self):
        # Points exactly at two centers: means must reproduce the centers.
        k, d = 4, 3
        c = jnp.asarray(RNG(3).rand(k, d).astype(np.float32))
        x = jnp.concatenate([jnp.tile(c[0], (256, 1)), jnp.tile(c[1], (256, 1))])
        pm = jnp.ones((512,), jnp.float32)
        cm = jnp.ones((k,), jnp.float32)
        sums, counts, cm_cost, _ = model.lloyd_step(x, c, pm, cm)
        means = np.asarray(sums) / np.maximum(np.asarray(counts)[:, None], 1.0)
        np.testing.assert_allclose(means[0], np.asarray(c[0]), rtol=1e-5)
        np.testing.assert_allclose(means[1], np.asarray(c[1]), rtol=1e-5)
        assert float(cm_cost) < 1e-3

    def test_cost_zero_when_points_are_centers(self):
        x, c, pm, cm = _mk(512, 8, 3)
        x = jnp.tile(c[2], (512, 1))
        _, _, cost_median, cost_means = model.lloyd_step(x, c, pm, cm)
        assert float(cost_median) < 1e-2
        assert float(cost_means) < 1e-4


class TestWeightHistogram:
    def test_matches_lloyd_counts(self):
        x, c, pm, cm = _mk(512, 32, 3, pvalid=300, cvalid=20)
        wh, cost = model.weight_histogram(x, c, pm, cm)
        _, counts, cost_median, _ = model.lloyd_step(x, c, pm, cm)
        np.testing.assert_allclose(np.asarray(wh), np.asarray(counts), rtol=1e-5)
        np.testing.assert_allclose(float(cost), float(cost_median), rtol=1e-4)

    def test_weights_nonnegative_integers(self):
        x, c, pm, cm = _mk(1024, 16, 3)
        wh, _ = model.weight_histogram(x, c, pm, cm)
        w = np.asarray(wh)
        assert np.all(w >= 0)
        np.testing.assert_allclose(w, np.round(w), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 48),
    d=st.integers(1, 8),
    pfrac=st.floats(0.05, 1.0),
    cfrac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_lloyd_step_hypothesis(k, d, pfrac, cfrac, seed):
    b = 512
    x, c, pm, cm = _mk(
        b, k, d, seed=seed,
        pvalid=max(1, int(b * pfrac)), cvalid=max(1, int(k * cfrac)),
    )
    got = model.lloyd_step(x, c, pm, cm)
    want = ref.lloyd_step_ref(x, c, pm, cm)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4)
