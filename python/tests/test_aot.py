"""AOT pipeline: exported HLO text must round-trip through the XLA parser
and execute (via jax's own CPU client) with the same numerics as the source
functions. This is the python-side half of the contract the rust runtime
relies on; the rust side is covered by rust/tests/integration_runtime.rs."""

import json
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

from compile import aot, model

OUT = Path("/tmp/mrcluster_aot_test")


@pytest.fixture(scope="module")
def exported():
    OUT.mkdir(exist_ok=True)
    entries = []
    for func in ("assign", "lloyd_step", "weight_histogram"):
        fn, n_out = aot.EXPORTS[func] if hasattr(aot, "EXPORTS") else model.EXPORTS[func]
        e = aot.export_bucket(func, fn, 512, 32, 3, str(OUT))
        e["n_outputs"] = n_out
        entries.append(e)
    return entries


def test_export_produces_parseable_hlo(exported):
    for e in exported:
        text = (OUT / e["file"]).read_text()
        assert text.startswith("HloModule"), e["file"]
        assert "ENTRY" in text


def test_entry_layout_matches_bucket(exported):
    for e in exported:
        text = (OUT / e["file"]).read_text()
        first = text.splitlines()[0]
        assert f"f32[{e['b']},{e['d']}]" in first  # points
        assert f"f32[{e['k']},{e['d']}]" in first  # centers


def test_manifest_cli_roundtrip(tmp_path):
    # Run the module CLI exactly as the Makefile does, for a tiny bucket.
    res = subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out-dir", str(tmp_path),
            "--buckets", "256:16:3",
            "--funcs", "assign",
        ],
        cwd=str(Path(__file__).resolve().parents[1]),
        capture_output=True, text=True,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["entries"]) == 1
    e = manifest["entries"][0]
    assert (tmp_path / e["file"]).exists()
    assert e["n_outputs"] == 2


def test_exported_hlo_numerics_match_source(exported):
    """Compile the HLO text back with jax's CPU client and compare."""
    from jax._src.lib import xla_client as xc
    import jax

    backend = jax.devices("cpu")[0].client
    r = np.random.RandomState(7)
    x = r.rand(512, 3).astype(np.float32)
    c = r.rand(32, 3).astype(np.float32)
    pm = np.ones((512,), np.float32)
    pm[400:] = 0.0
    cm = np.ones((32,), np.float32)
    cm[25:] = 0.0

    for e in exported:
        text = (OUT / e["file"]).read_text()
        mod = xc._xla.hlo_module_from_text(text)
        # Rebuild an XlaComputation from the parsed module proto — this is
        # exactly the id-reassignment round-trip the rust loader depends on.
        comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
        mlir_module = xc._xla.mlir.xla_computation_to_mlir_module(comp)
        if hasattr(backend, "compile_and_load"):
            # jaxlib >= 0.5 splits compile from load.
            devices = xc.DeviceList(tuple(jax.devices("cpu")))
            exe = backend.compile_and_load(mlir_module, devices)
        else:
            exe = backend.compile(mlir_module)
        outs = exe.execute([backend.buffer_from_pyval(a) for a in (x, c, pm, cm)])
        got = [np.asarray(o) for o in outs]
        fn = model.EXPORTS[e["func"]][0]
        want = fn(x, c, pm, cm)
        want = [np.asarray(w) for w in (want if isinstance(want, tuple) else (want,))]
        assert len(got) == len(want) == e["n_outputs"]
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)
