//! Shared test helpers: exact brute-force clustering oracles.
//!
//! Not a test target itself (no `main.rs`); included by
//! `integration_algorithms.rs` (`mod common;`) and by the scenario harness
//! (`#[path = "../common/mod.rs"] mod common;`) so both targets check
//! against the *same* oracle.

use mrcluster::geometry::{MetricKind, PointSet};
use mrcluster::metrics::{
    kcenter_cost, kcenter_cost_metric, kcenter_cost_with_outliers,
    kcenter_cost_with_outliers_metric, kmedian_cost, kmedian_cost_metric,
};

/// Visit every k-combination of `[0, n)` in lexicographic order: supports
/// the exact oracles up to n = 64 (a 2^n bitmask enumeration caps out at
/// n ~ 16; with k <= 3 the combination count stays in the thousands).
pub fn for_each_combination(n: usize, k: usize, mut f: impl FnMut(&[usize])) {
    assert!((1..=n).contains(&k));
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        f(&idx);
        // Find the rightmost index that can still advance.
        let mut i = k;
        while i > 0 && idx[i - 1] == n - k + (i - 1) {
            i -= 1;
        }
        if i == 0 {
            return;
        }
        idx[i - 1] += 1;
        for j in i..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// Exact discrete k-median optimum (centers restricted to input points).
/// (The allows on these oracles cover including targets that only use a
/// subset — each test binary compiles its own copy of this module.)
#[allow(dead_code)]
pub fn exact_kmedian(points: &PointSet, k: usize) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kmedian_cost(points, &points.gather(idx)));
    });
    best
}

/// Exact discrete k-center optimum.
#[allow(dead_code)]
pub fn exact_kcenter(points: &PointSet, k: usize) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost(points, &points.gather(idx)));
    });
    best
}

/// Exact discrete k-center-with-outliers optimum: over every k-subset of
/// center candidates, the best cost after the `z` farthest points are
/// dropped (the best-z-drop bound the robust pipeline is checked against).
/// Only the scenario harness consumes this one, hence the allow for the
/// other including target.
#[allow(dead_code)]
pub fn exact_kcenter_outliers(points: &PointSet, k: usize, z: usize) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost_with_outliers(points, &points.gather(idx), z));
    });
    best
}

/// Exact discrete k-median optimum under an explicit metric (the oracle
/// the general-metric pipelines are bounded against). The `#[allow]`s on
/// the metric oracles cover the including target that doesn't use them.
#[allow(dead_code)]
pub fn exact_kmedian_metric(points: &PointSet, k: usize, metric: MetricKind) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kmedian_cost_metric(points, &points.gather(idx), metric));
    });
    best
}

/// Exact discrete k-center optimum under an explicit metric.
#[allow(dead_code)]
pub fn exact_kcenter_metric(points: &PointSet, k: usize, metric: MetricKind) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost_metric(points, &points.gather(idx), metric));
    });
    best
}

/// Exact discrete k-center-with-outliers optimum under an explicit metric.
#[allow(dead_code)]
pub fn exact_kcenter_outliers_metric(
    points: &PointSet,
    k: usize,
    z: usize,
    metric: MetricKind,
) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost_with_outliers_metric(
            points,
            &points.gather(idx),
            z,
            metric,
        ));
    });
    best
}
