//! Shared test helpers: exact brute-force clustering oracles.
//!
//! Not a test target itself (no `main.rs`); included by
//! `integration_algorithms.rs` (`mod common;`) and by the scenario harness
//! (`#[path = "../common/mod.rs"] mod common;`) so both targets check
//! against the *same* oracle.

use mrcluster::geometry::{MetricKind, PointSet};
use mrcluster::metrics::{
    kcenter_cost, kcenter_cost_metric, kcenter_cost_with_outliers,
    kcenter_cost_with_outliers_metric, kmedian_cost, kmedian_cost_metric,
};

/// Visit every k-combination of `[0, n)` in lexicographic order: supports
/// the exact oracles up to n = 64 (a 2^n bitmask enumeration caps out at
/// n ~ 16; with k <= 3 the combination count stays in the thousands).
pub fn for_each_combination(n: usize, k: usize, mut f: impl FnMut(&[usize])) {
    assert!((1..=n).contains(&k));
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        f(&idx);
        // Find the rightmost index that can still advance.
        let mut i = k;
        while i > 0 && idx[i - 1] == n - k + (i - 1) {
            i -= 1;
        }
        if i == 0 {
            return;
        }
        idx[i - 1] += 1;
        for j in i..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// Exact discrete k-median optimum (centers restricted to input points).
/// (The allows on these oracles cover including targets that only use a
/// subset — each test binary compiles its own copy of this module.)
#[allow(dead_code)]
pub fn exact_kmedian(points: &PointSet, k: usize) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kmedian_cost(points, &points.gather(idx)));
    });
    best
}

/// Exact discrete k-center optimum.
#[allow(dead_code)]
pub fn exact_kcenter(points: &PointSet, k: usize) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost(points, &points.gather(idx)));
    });
    best
}

/// Exact discrete k-center-with-outliers optimum: over every k-subset of
/// center candidates, the best cost after the `z` farthest points are
/// dropped (the best-z-drop bound the robust pipeline is checked against).
/// Only the scenario harness consumes this one, hence the allow for the
/// other including target.
#[allow(dead_code)]
pub fn exact_kcenter_outliers(points: &PointSet, k: usize, z: usize) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost_with_outliers(points, &points.gather(idx), z));
    });
    best
}

/// Exact discrete k-median optimum under an explicit metric (the oracle
/// the general-metric pipelines are bounded against). The `#[allow]`s on
/// the metric oracles cover the including target that doesn't use them.
#[allow(dead_code)]
pub fn exact_kmedian_metric(points: &PointSet, k: usize, metric: MetricKind) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kmedian_cost_metric(points, &points.gather(idx), metric));
    });
    best
}

/// Exact discrete k-center optimum under an explicit metric.
#[allow(dead_code)]
pub fn exact_kcenter_metric(points: &PointSet, k: usize, metric: MetricKind) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost_metric(points, &points.gather(idx), metric));
    });
    best
}

/// Exact discrete k-center-with-outliers optimum under an explicit metric.
#[allow(dead_code)]
pub fn exact_kcenter_outliers_metric(
    points: &PointSet,
    k: usize,
    z: usize,
    metric: MetricKind,
) -> f64 {
    assert!(points.len() <= 64, "exact search is exponential");
    let mut best = f64::INFINITY;
    for_each_combination(points.len(), k, |idx| {
        best = best.min(kcenter_cost_with_outliers_metric(
            points,
            &points.gather(idx),
            z,
            metric,
        ));
    });
    best
}

/// One row of the arena approximation table: which objective a pipeline is
/// held to, and the documented envelope factor it must stay under against
/// the brute-force oracle. (`#[allow]`s as above: each including target
/// compiles its own copy and may use a subset.)
#[allow(dead_code)]
pub struct ArenaBound {
    /// The registered pipeline this row gates.
    pub algo: mrcluster::coordinator::Algorithm,
    /// True: gate the max-distance objective against the exact k-center
    /// optimum. False: gate the summed-distance objective against the
    /// exact k-median optimum.
    pub kcenter_objective: bool,
    /// The documented approximation envelope (ratio vs the exact OPT).
    pub factor: f64,
}

/// The full arena table: every registered pipeline with its documented
/// envelope — 12x the exact k-center OPT for the k-center pipelines
/// (MapReduce-kCenter's Theorem-3.7 factor plus summary slack; Ceccarello
/// et al.'s skeleton greedy sits under the same envelope), 15x the exact
/// k-median OPT for everything else (the weakest pipeline's constant with
/// slack; Mazzetto et al.'s accuracy-oriented coreset sits far under it).
/// Ratios compare true-distance objectives, so the factors are
/// metric-uniform (under `l2sq` the reported costs are real Euclidean
/// distances, not squared surrogates).
#[allow(dead_code)]
pub fn arena_bounds() -> Vec<ArenaBound> {
    use mrcluster::coordinator::Algorithm;
    Algorithm::all()
        .into_iter()
        .map(|algo| {
            let kcenter_objective = matches!(
                algo,
                Algorithm::MrKCenter | Algorithm::RobustKCenter | Algorithm::CeccarelloKCenter
            );
            ArenaBound {
                algo,
                kcenter_objective,
                factor: if kcenter_objective { 12.0 } else { 15.0 },
            }
        })
        .collect()
}

/// Table-driven arena assertion: run every registered pipeline on
/// `points` under `metric`, verify replay bit-identity, and assert each
/// lands within its [`arena_bounds`] envelope of the exact brute-force
/// optimum — one pass instead of per-pipeline test copies. `cfg` supplies
/// the shared knobs; `k` and `metric` override it per call.
#[allow(dead_code)]
pub fn assert_arena_bounds(
    points: &PointSet,
    k: usize,
    metric: MetricKind,
    cfg: &mrcluster::config::ClusterConfig,
) {
    use mrcluster::coordinator::run_algorithm_with;
    use mrcluster::runtime::NativeBackend;
    let opt_median = exact_kmedian_metric(points, k, metric);
    let opt_center = exact_kcenter_metric(points, k, metric);
    assert!(
        opt_median.is_finite() && opt_median > 0.0 && opt_center > 0.0,
        "{metric}: degenerate oracle instance"
    );
    let cfg = mrcluster::config::ClusterConfig {
        k,
        metric,
        ..cfg.clone()
    };
    for b in arena_bounds() {
        let out = run_algorithm_with(b.algo, points, &cfg, &NativeBackend).unwrap();
        let replay = run_algorithm_with(b.algo, points, &cfg, &NativeBackend).unwrap();
        assert_eq!(
            out.centers,
            replay.centers,
            "{} under {metric} is nondeterministic",
            b.algo.name()
        );
        let (objective, cost, opt) = if b.kcenter_objective {
            ("kcenter", kcenter_cost_metric(points, &out.centers, metric), opt_center)
        } else {
            ("kmedian", kmedian_cost_metric(points, &out.centers, metric), opt_median)
        };
        assert!(
            cost <= opt * b.factor + 1e-6,
            "{} under {metric}: {objective} cost {cost} vs exact OPT {opt} \
             (documented envelope {}x)",
            b.algo.name(),
            b.factor
        );
    }
}
