//! Brute-force oracle: on tiny instances the MapReduce pipelines' cost
//! must stay within the paper's constant factors of the *exact* optimum —
//! and they are exercised here under the hostile fault regime, so the
//! approximation claims are checked on the recovered outputs.

use crate::common::{exact_kcenter, exact_kcenter_outliers, exact_kmedian};
use crate::hostile_cfg;
use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::geometry::PointSet;
use mrcluster::metrics::{kcenter_cost, kcenter_cost_with_outliers, kmedian_cost};

fn tiny_blobs(n: usize, k: usize, seed: u64) -> PointSet {
    DataGenConfig {
        n,
        k,
        dim: 3,
        sigma: 0.02,
        alpha: 0.0,
        contamination: 0.0,
        seed,
    }
    .generate()
    .points
}

fn oracle_cluster_cfg(k: usize, seed: u64) -> ClusterConfig {
    // Hostile regime on purpose: the bound must hold on recovered outputs.
    hostile_cfg(k, 4, seed)
}

#[test]
fn kmedian_pipelines_within_constant_of_exact_optimum() {
    // Lloyd means can even beat the discrete optimum, so only the upper
    // bound is asserted. 10x is far below a degenerate solution (~16x for
    // one-center collapse on this geometry) while holding slack over the
    // paper's constants and Lloyd's seeding luck on 30 points.
    const FACTOR: f64 = 10.0;
    for seed in [5u64, 6] {
        let points = tiny_blobs(30, 3, seed);
        let opt = exact_kmedian(&points, 3);
        assert!(opt.is_finite() && opt > 0.0);
        for algo in [
            Algorithm::ParallelLloyd,
            Algorithm::DivideLloyd,
            Algorithm::SamplingLloyd,
            Algorithm::SamplingLocalSearch,
        ] {
            let out = run_algorithm(algo, &points, &oracle_cluster_cfg(3, seed)).unwrap();
            let cost = kmedian_cost(&points, &out.centers);
            assert!(
                cost <= opt * FACTOR + 1e-6,
                "seed {seed} {}: cost {cost} vs exact OPT {opt}",
                algo.name()
            );
        }
    }
}

#[test]
fn kcenter_pipeline_within_theorem_bound_of_exact_optimum() {
    // Theorem 3.7: (4a + 2) with Gonzalez (a = 2) is a 10-approximation;
    // on a tiny instance the sample is essentially the whole input, so the
    // observed ratio is far below the bound.
    for seed in [7u64, 8] {
        let points = tiny_blobs(28, 3, seed);
        let opt = exact_kcenter(&points, 3);
        assert!(opt.is_finite() && opt > 0.0);
        let out = run_algorithm(Algorithm::MrKCenter, &points, &oracle_cluster_cfg(3, seed))
            .unwrap();
        let radius = kcenter_cost(&points, &out.centers);
        assert!(
            radius <= opt * 10.0 + 1e-6,
            "seed {seed}: radius {radius} vs exact OPT {opt}"
        );
    }
}

#[test]
fn robust_kcenter_within_constant_of_exact_best_z_drop_optimum() {
    // n ≤ 48 contaminated instances: the robust pipeline (summaries built
    // per machine, composed in a reduce step, Charikar greedy with the z
    // budget at the leader — run under the hostile fault regime) must stay
    // within a constant factor of the exact best-z-drop optimum. The
    // greedy's certified factor is 3; the summary layer adds its coverage
    // radius on both sides, so 6x is the safe envelope (on these tiny
    // instances the summary is nearly lossless and the observed ratio is
    // far smaller).
    for (seed, z_extra) in [(13u64, 2usize), (14, 3)] {
        let mut points = tiny_blobs(48 - z_extra, 3, seed);
        // Plant unambiguous outliers so the budget matters.
        for i in 0..z_extra {
            points.push(&[40.0 + 10.0 * i as f32, -25.0, 60.0]);
        }
        let z = z_extra;
        let opt = exact_kcenter_outliers(&points, 3, z);
        assert!(opt.is_finite() && opt > 0.0);
        let mut cfg = oracle_cluster_cfg(3, seed);
        cfg.z = z;
        let out = run_algorithm(Algorithm::RobustKCenter, &points, &cfg).unwrap();
        let cost = kcenter_cost_with_outliers(&points, &out.centers, z);
        assert!(
            cost <= opt * 6.0 + 1e-6,
            "seed {seed}: robust cost {cost} vs exact best-z-drop OPT {opt}"
        );
    }
}

#[test]
fn rival_coordinators_within_envelope_of_exact_optimum() {
    // The arena's rival pipelines under the hostile fault regime: the
    // Mazzetto coreset k-median must land within the same 10x envelope as
    // the paper's k-median pipelines (its coreset is near-lossless at this
    // scale, so the observed ratio tracks weighted local search), and the
    // Ceccarello skeleton k-center within the 6x envelope the robust
    // pipeline is held to (greedy factor 3 plus skeleton radius slack).
    for seed in [15u64, 16] {
        let points = tiny_blobs(42, 3, seed);
        let opt_median = exact_kmedian(&points, 3);
        let opt_center = exact_kcenter(&points, 3);
        assert!(opt_median > 0.0 && opt_center > 0.0);
        let out =
            run_algorithm(Algorithm::MazzettoKMedian, &points, &oracle_cluster_cfg(3, seed))
                .unwrap();
        let cost = kmedian_cost(&points, &out.centers);
        assert!(
            cost <= opt_median * 10.0 + 1e-6,
            "seed {seed} Mazzetto: cost {cost} vs exact OPT {opt_median}"
        );
        let out =
            run_algorithm(Algorithm::CeccarelloKCenter, &points, &oracle_cluster_cfg(3, seed))
                .unwrap();
        let radius = kcenter_cost(&points, &out.centers);
        assert!(
            radius <= opt_center * 6.0 + 1e-6,
            "seed {seed} Ceccarello: radius {radius} vs exact OPT {opt_center}"
        );
    }
}

#[test]
fn outlier_oracle_agrees_with_hand_computation() {
    // Points {0, 1, 2, 50} on a line, k = 1, z = 1: drop 50, put the
    // center at 1 (cost 1) — any other choice pays more.
    let points = PointSet::from_flat(1, vec![0.0, 1.0, 2.0, 50.0]);
    let opt = exact_kcenter_outliers(&points, 1, 1);
    assert!((opt - 1.0).abs() < 1e-6, "outlier oracle {opt}");
    // And with no budget the plain oracle is recovered.
    assert!((exact_kcenter_outliers(&points, 1, 0) - exact_kcenter(&points, 1)).abs() < 1e-9);
}

#[test]
fn oracle_agrees_with_hand_computation_on_a_known_instance() {
    // Points {0, 1, 5} on a line, k = 2: any optimal discrete pair covers
    // two points exactly and pays 1.0 for the remaining one (as distance
    // sum and as max radius alike).
    let points = PointSet::from_flat(1, vec![0.0, 1.0, 5.0]);
    let med = exact_kmedian(&points, 2);
    assert!((med - 1.0).abs() < 1e-6, "kmedian {med}");
    let cen = exact_kcenter(&points, 2);
    assert!((cen - 1.0).abs() < 1e-6, "kcenter {cen}");
}
