//! Scenario datasets: the geometry regimes the harness drives every
//! coordinator through.
//!
//! * `clustered` — the paper's §4.2 workload (well-separated blobs,
//!   uniform sizes): the happy path every approximation bound assumes.
//! * `skewed` — Zipf-1.5 cluster sizes: one giant cluster dominates, so
//!   per-machine load and the sampling probabilities are unbalanced.
//! * `adversarial` — a huge near-duplicate mass (zero-distance stress for
//!   pivot selection and seeding), a thin collinear filament, and a few
//!   extreme outliers (the k-center-style worst case for sampling).

use mrcluster::data::DataGenConfig;
use mrcluster::geometry::PointSet;
use mrcluster::util::rng::Rng;

pub struct Scenario {
    pub name: &'static str,
    pub points: PointSet,
}

pub fn all(n: usize, k: usize, seed: u64) -> Vec<Scenario> {
    vec![
        Scenario { name: "clustered", points: clustered(n, k, seed) },
        Scenario { name: "skewed", points: skewed(n, k, seed) },
        Scenario { name: "adversarial", points: adversarial(n, seed) },
    ]
}

pub fn clustered(n: usize, k: usize, seed: u64) -> PointSet {
    DataGenConfig {
        n,
        k,
        dim: 3,
        sigma: 0.05,
        alpha: 0.0,
        contamination: 0.0,
        seed,
    }
    .generate()
    .points
}

pub fn skewed(n: usize, k: usize, seed: u64) -> PointSet {
    DataGenConfig {
        n,
        k,
        dim: 3,
        sigma: 0.05,
        alpha: 1.5,
        contamination: 0.0,
        seed: seed ^ 1,
    }
    .generate()
    .points
}

pub fn adversarial(n: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed ^ 0xAD5A);
    let mut flat = Vec::with_capacity(n * 3);
    let heavy = n * 7 / 10;
    let line = n * 2 / 10;
    // 70%: distinct points packed within 1e-4 of one location.
    for _ in 0..heavy {
        for _ in 0..3 {
            flat.push(0.5 + (rng.f32() - 0.5) * 1e-4);
        }
    }
    // 20%: a collinear filament through the cube.
    for i in 0..line {
        let t = i as f32 / line.max(1) as f32;
        let c = t * 2.0 - 1.0;
        flat.extend_from_slice(&[c, c, c]);
    }
    // Remainder: extreme outliers marching away from everything.
    let rest = n - heavy - line;
    for i in 0..rest {
        let s = (i + 1) as f32;
        flat.extend_from_slice(&[50.0 * s, -30.0 * s, 80.0]);
    }
    PointSet::from_flat(3, flat)
}
