//! Deterministic scenario harness: drive every coordinator pipeline
//! through an adversity matrix — {datasets} x {machine counts} x
//! {fault/straggler regimes} x {thread modes} — and assert the recovery
//! layer's contract end to end:
//!
//! 1. outputs are **bit-identical** to the zero-fault run at any thread
//!    count (lineage replay reconstructs exactly what failures destroyed);
//! 2. the round structure (count, shuffle bytes) is unchanged — recovery
//!    happens *inside* rounds, never by adding rounds;
//! 3. the `MRC^0` bounds still hold under adversity, including the
//!    recovery-memory audit (`Mrc0Report::recovery_ok`), with the slack
//!    calibrated from the zero-fault run so the assertion is scale-free;
//! 4. hostile regimes really do inject work (the retries accounting is
//!    non-trivial).
//!
//! A second axis drives the same pipelines under the discrete-event
//! timing simulation ({no-sim, flat shared fabric, oversubscribed racks
//! with heterogeneous hosts} × fault regimes) and asserts the sim is a
//! *pure observer*: outputs, rounds, and shuffle bytes stay bit-identical
//! to the no-sim rows, and only `sim_wallclock` differs.
//!
//! Costs-vs-oracle assertions on tiny instances live in `oracle.rs`.
//! Default scale is CI-sized; set `SCENARIO_FULL=1` for the larger matrix
//! (more machine counts, larger n).

#[path = "../common/mod.rs"]
mod common;
mod datasets;
mod oracle;

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm, Algorithm, Outcome};
use mrcluster::mapreduce::check_mrc0;
use mrcluster::sim::{Heterogeneity, NetworkKind, Placement, SimConfig};
use std::time::Duration;

/// One fault/straggler regime of the matrix.
pub struct Regime {
    pub name: &'static str,
    pub fail_prob: f64,
    pub straggler_prob: f64,
    pub straggler_factor: f64,
    pub speculative: bool,
}

/// The adversity levels beyond the zero-fault baseline.
pub const REGIMES: &[Regime] = &[
    Regime {
        name: "lossy",
        fail_prob: 0.05,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        speculative: false,
    },
    Regime {
        name: "hostile",
        fail_prob: 0.3,
        straggler_prob: 0.2,
        straggler_factor: 4.0,
        speculative: true,
    },
];

const EPS: f64 = 0.2;
const SEED: u64 = 97;

fn full_matrix() -> bool {
    std::env::var("SCENARIO_FULL").map(|v| v == "1").unwrap_or(false)
}

fn machine_counts() -> Vec<usize> {
    if full_matrix() {
        vec![4, 16]
    } else {
        vec![8]
    }
}

fn scenario_n() -> usize {
    if full_matrix() {
        6000
    } else {
        1500
    }
}

fn scenario_cfg(
    k: usize,
    machines: usize,
    seed: u64,
    regime: Option<&Regime>,
    parallel: bool,
) -> ClusterConfig {
    let mut cfg = ClusterConfig {
        k,
        epsilon: EPS,
        machines,
        seed,
        parallel,
        threads: 4,
        ..Default::default()
    };
    if let Some(r) = regime {
        cfg.fail_prob = r.fail_prob;
        cfg.straggler_prob = r.straggler_prob;
        cfg.straggler_factor = r.straggler_factor;
        cfg.speculative = r.speculative;
    }
    cfg
}

/// The hostile regime as a ready-made config (shared with `oracle.rs`).
pub fn hostile_cfg(k: usize, machines: usize, seed: u64) -> ClusterConfig {
    scenario_cfg(k, machines, seed, Some(&REGIMES[1]), true)
}

/// Slack that puts the zero-fault run at a 2x margin inside the
/// `N^{1-eps}` envelope: the fault runs must then fit the *same* envelope,
/// which bounds recovery overhead (checkpointed mutable blocks at most
/// double a machine's residency) without hand-picked absolute numbers.
fn calibrated_slack(baseline: &Outcome, input_bytes: usize) -> f64 {
    let bound = (input_bytes as f64).powf(1.0 - EPS);
    let peak = baseline
        .stats
        .peak_machines()
        .max(baseline.stats.peak_machine_mem()) as f64;
    (2.0 * peak / bound).max(1.0)
}

fn run_matrix(algo: Algorithm) {
    let k = 5;
    let n = scenario_n();
    for ds in datasets::all(n, k, 0xACE) {
        for machines in machine_counts() {
            let baseline =
                run_algorithm(algo, &ds.points, &scenario_cfg(k, machines, SEED, None, true))
                    .unwrap();
            assert_eq!(baseline.stats.total_retries(), 0);
            assert_eq!(baseline.stats.peak_replay_mem(), 0);
            let input_bytes = ds.points.mem_bytes();
            let slack = calibrated_slack(&baseline, input_bytes);
            let round_bound = baseline.rounds;
            let base_report =
                check_mrc0(&baseline.stats, input_bytes, EPS, slack, round_bound);
            assert!(
                base_report.ok(),
                "{} / {} baseline out of its own envelope: {base_report}",
                algo.name(),
                ds.name
            );

            for regime in REGIMES {
                for parallel in [true, false] {
                    let out = run_algorithm(
                        algo,
                        &ds.points,
                        &scenario_cfg(k, machines, SEED, Some(regime), parallel),
                    )
                    .unwrap();
                    let tag = format!(
                        "{} / {} / {} machines / {} / parallel={parallel}",
                        algo.name(),
                        ds.name,
                        machines,
                        regime.name
                    );

                    // 1. Bit-identical output at any thread count.
                    assert_eq!(out.centers, baseline.centers, "{tag}: centers diverged");
                    assert_eq!(
                        out.cost.median.to_bits(),
                        baseline.cost.median.to_bits(),
                        "{tag}: cost diverged"
                    );

                    // 2. Recovery never changes the round structure.
                    assert_eq!(out.rounds, baseline.rounds, "{tag}: round count changed");
                    assert_eq!(
                        out.stats.shuffle_bytes(),
                        baseline.stats.shuffle_bytes(),
                        "{tag}: shuffle changed"
                    );

                    // 3. MRC^0 bounds, including the recovery-memory audit.
                    let report = check_mrc0(&out.stats, input_bytes, EPS, slack, round_bound);
                    assert!(report.ok(), "{tag}: {report}");
                    assert!(
                        out.stats.peak_machine_mem() <= 2 * baseline.stats.peak_machine_mem(),
                        "{tag}: recovery more than doubled a machine's residency"
                    );

                    // 4. Hostile regimes must actually inject failures into
                    //    multi-round pipelines (single-round pipelines draw
                    //    too few fates for a guarantee).
                    if regime.fail_prob >= 0.3 && baseline.rounds > 2 {
                        assert!(
                            out.stats.total_retries() > 0,
                            "{tag}: no failures injected"
                        );
                    }
                }
            }
        }
    }
}

// The seven matrix tests are `#[ignore]`d so the debug tier-1 `cargo test`
// stays fast; the CI `scenario-matrix` job runs them in release with
// `--include-ignored` (and locally: `cargo test --release --test scenario
// -- --include-ignored`, optionally with SCENARIO_FULL=1).

#[test]
#[ignore = "run via the scenario-matrix CI job (release mode)"]
fn scenario_parallel_lloyd() {
    run_matrix(Algorithm::ParallelLloyd);
}

#[test]
#[ignore = "run via the scenario-matrix CI job (release mode)"]
fn scenario_sampling_kmedian() {
    run_matrix(Algorithm::SamplingLloyd);
}

#[test]
#[ignore = "run via the scenario-matrix CI job (release mode)"]
fn scenario_divide_kmedian() {
    run_matrix(Algorithm::DivideLloyd);
}

#[test]
#[ignore = "run via the scenario-matrix CI job (release mode)"]
fn scenario_mr_kcenter() {
    run_matrix(Algorithm::MrKCenter);
}

#[test]
#[ignore = "run via the scenario-matrix CI job (release mode)"]
fn scenario_streaming() {
    run_matrix(Algorithm::StreamingGuha);
}

#[test]
#[ignore = "run via the scenario-matrix CI job (release mode)"]
fn scenario_mazzetto_kmedian() {
    run_matrix(Algorithm::MazzettoKMedian);
}

#[test]
#[ignore = "run via the scenario-matrix CI job (release mode)"]
fn scenario_ceccarello_kcenter() {
    run_matrix(Algorithm::CeccarelloKCenter);
}

/// The simulation axis of the matrix: no-sim, a flat shared fabric, and
/// an oversubscribed rack topology with a bimodal (10% of hosts 4x slow)
/// fleet — the harshest timing environment the models offer.
fn sim_axes() -> [(&'static str, SimConfig); 3] {
    [
        ("no-sim", SimConfig::default()),
        (
            "flat-network",
            SimConfig { enabled: true, network: NetworkKind::Shared, ..SimConfig::default() },
        ),
        (
            "oversubscribed-hetero",
            SimConfig {
                enabled: true,
                network: NetworkKind::Topology,
                racks: 3,
                oversub: 8.0,
                hetero: Heterogeneity::Bimodal { slow_frac: 0.1, slow_factor: 4.0 },
                placement: Placement::RackAware,
                ..SimConfig::default()
            },
        ),
    ]
}

/// Drive `algo` through {sim axes} × {fault regimes} and hold the sim to
/// its pure-observer contract: every simulated row reproduces the no-sim
/// row bit for bit (centers, cost bits, rounds, shuffle bytes), zero
/// wall-clock with sim off, nonzero and repeat-deterministic wall-clock
/// with sim on.
fn run_sim_matrix(algo: Algorithm, n: usize) {
    let k = 5;
    let points = datasets::clustered(n, k, 0xACE);
    for regime in [None, Some(&REGIMES[0]), Some(&REGIMES[1])] {
        let base_cfg = scenario_cfg(k, 8, SEED, regime, true);
        let baseline = run_algorithm(algo, &points, &base_cfg).unwrap();
        assert_eq!(
            baseline.sim_wallclock,
            Duration::ZERO,
            "{}: sim off must report zero wall-clock",
            algo.name()
        );
        for (axis, sim) in sim_axes() {
            if !sim.enabled {
                continue;
            }
            let cfg = ClusterConfig { sim: sim.clone(), ..base_cfg.clone() };
            let out = run_algorithm(algo, &points, &cfg).unwrap();
            let tag = format!(
                "{} / {axis} / regime {}",
                algo.name(),
                regime.map(|r| r.name).unwrap_or("none")
            );
            assert_eq!(out.centers, baseline.centers, "{tag}: centers diverged");
            assert_eq!(
                out.cost.median.to_bits(),
                baseline.cost.median.to_bits(),
                "{tag}: cost diverged"
            );
            assert_eq!(out.rounds, baseline.rounds, "{tag}: round count changed");
            assert_eq!(
                out.stats.shuffle_bytes(),
                baseline.stats.shuffle_bytes(),
                "{tag}: shuffle changed"
            );
            assert!(out.sim_wallclock > Duration::ZERO, "{tag}: sim recorded nothing");
            // The wall-clock itself is deterministic: replaying the very
            // same configuration reproduces it bit for bit.
            let again = run_algorithm(algo, &points, &cfg).unwrap();
            assert_eq!(again.sim_wallclock, out.sim_wallclock, "{tag}: wall-clock replay");
        }
    }
}

#[test]
#[ignore = "run via the sim-matrix CI job (release mode)"]
fn scenario_sim_parallel_lloyd() {
    run_sim_matrix(Algorithm::ParallelLloyd, scenario_n());
}

#[test]
#[ignore = "run via the sim-matrix CI job (release mode)"]
fn scenario_sim_sampling_kmedian() {
    run_sim_matrix(Algorithm::SamplingLloyd, scenario_n());
}

#[test]
#[ignore = "run via the sim-matrix CI job (release mode)"]
fn scenario_sim_mr_kcenter() {
    run_sim_matrix(Algorithm::MrKCenter, scenario_n());
}

/// Always-on (non-ignored) slice of the sim axis: one pipeline at small
/// n, so the pure-observer contract is exercised by plain `cargo test`
/// on every push, not just by the release matrix job.
#[test]
fn sim_axis_is_pure_observation_small() {
    run_sim_matrix(Algorithm::SamplingLloyd, 600);
}

/// Satellite: the report's memory-violation path on a *real* run — an
/// over-tight epsilon makes the sub-linear envelope impossible, and the
/// report must flag it rather than pass vacuously.
#[test]
fn mrc0_flags_deliberately_over_budget_run() {
    let points = datasets::clustered(1500, 5, 0xACE);
    let out =
        run_algorithm(Algorithm::SamplingLloyd, &points, &scenario_cfg(5, 8, SEED, None, true))
            .unwrap();
    let report = check_mrc0(&out.stats, points.mem_bytes(), 0.9, 1.0, out.rounds);
    assert!(!report.memory_ok, "{report}");
    assert!(!report.ok());
    assert!(format!("{report}").contains("VIOLATED"));
}

/// Outlier-robustness acceptance scenario: on a contaminated dataset the
/// robust k-center pipeline must beat plain MapReduce-kCenter by the
/// harness's calibrated margin, and its recovery under the lossy fault
/// regime must stay bit-identical to the clean run.
///
/// Calibration: the reference cost is the *planted* centers' radius with
/// the true z outliers dropped — a data-derived yardstick, not a magic
/// number. The robust pipeline must land within 4x of it (3x greedy +
/// summary radius); plain k-center, whose farthest-first `A` burns centers
/// on the outliers, must be at least 2x worse than the robust run.
#[test]
fn robust_kcenter_beats_plain_on_contaminated_data_and_recovers() {
    let data = mrcluster::data::DataGenConfig {
        n: 1500,
        k: 5,
        dim: 3,
        sigma: 0.05,
        alpha: 0.0,
        contamination: 0.02,
        seed: 0xACE2,
    }
    .generate();
    let z = data.n_outliers();
    assert!(z > 0, "contamination must have planted outliers");

    let mut clean_cfg = scenario_cfg(5, 8, SEED, None, true);
    clean_cfg.z = z;
    let mut lossy_cfg = scenario_cfg(5, 8, SEED, Some(&REGIMES[0]), true);
    lossy_cfg.z = z;

    let plain = run_algorithm(Algorithm::MrKCenter, &data.points, &clean_cfg).unwrap();
    let robust = run_algorithm(Algorithm::RobustKCenter, &data.points, &clean_cfg).unwrap();
    let plain_z = mrcluster::metrics::kcenter_cost_with_outliers(&data.points, &plain.centers, z);
    let robust_z =
        mrcluster::metrics::kcenter_cost_with_outliers(&data.points, &robust.centers, z);

    // Calibrated quality: within 4x of the planted-centers reference.
    let reference =
        mrcluster::metrics::kcenter_cost_with_outliers(&data.points, &data.planted_centers, z);
    assert!(
        robust_z <= reference * 4.0 + 1e-6,
        "robust {robust_z} vs planted reference {reference}"
    );
    // Calibrated margin: robust beats plain by at least 2x.
    assert!(
        robust_z * 2.0 <= plain_z + 1e-6,
        "robust {robust_z} should beat plain {plain_z} by 2x (z = {z})"
    );

    // Recovery: the lossy regime must reproduce the clean run bit-for-bit.
    let lossy = run_algorithm(Algorithm::RobustKCenter, &data.points, &lossy_cfg).unwrap();
    assert_eq!(lossy.centers, robust.centers, "lossy recovery diverged");
    assert_eq!(lossy.rounds, robust.rounds);
}

/// Satellite: recovery replay must not inflate per-machine memory past the
/// checkpoint bound — replays hold at most twice the fault-free peak, and
/// the recovery audit passes at the baseline-calibrated slack.
#[test]
fn recovery_replay_respects_memory_bound() {
    let points = datasets::clustered(1500, 5, 0xACE);
    let clean =
        run_algorithm(Algorithm::SamplingLloyd, &points, &scenario_cfg(5, 8, SEED, None, true))
            .unwrap();
    let out = run_algorithm(Algorithm::SamplingLloyd, &points, &hostile_cfg(5, 8, SEED)).unwrap();
    assert!(out.stats.total_retries() > 0);
    let replay_peak = out.stats.peak_replay_mem();
    assert!(replay_peak > 0, "replays must be charged to a machine");
    assert!(
        replay_peak <= 2 * clean.stats.peak_machine_mem(),
        "replay peak {replay_peak} vs clean peak {}",
        clean.stats.peak_machine_mem()
    );
    let slack = calibrated_slack(&clean, points.mem_bytes());
    let report = check_mrc0(&out.stats, points.mem_bytes(), EPS, slack, clean.rounds);
    assert!(report.recovery_ok, "{report}");
}
