//! Property tests of the recovery layer: for random seeds and
//! fail_prob in {0, 0.05, 0.3}, a recovered run's final centers and costs
//! are bit-identical to the fault-free run, and the engine's
//! `total_retries()` accounting matches an independent replay of the
//! planned fate stream.

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::mapreduce::{plan_fates, FaultModel, MrCluster, MrConfig};
use mrcluster::util::rng::Rng;

const FAIL_PROBS: [f64; 3] = [0.0, 0.05, 0.3];

/// Engine accounting vs a pure replay of the fate stream: drive identical
/// machine rounds and recompute the expected injected-failure count from a
/// fresh `Rng` seeded with the same `fault_seed`. `plan_fates` is a pure
/// function, so any divergence (extra draws, reordering, double-counting)
/// shows up as a mismatch here.
#[test]
fn prop_total_retries_match_planned_failures() {
    const ROUNDS: usize = 6;
    const PARTS: usize = 24;
    for seed in [1u64, 2, 3] {
        for fail_prob in FAIL_PROBS {
            let mut c = MrCluster::new(MrConfig {
                n_machines: 8,
                parallel: false,
                threads: 1,
                fail_prob,
                fault_seed: seed,
                ..Default::default()
            });
            let parts: Vec<Vec<u64>> = (0..PARTS).map(|i| vec![i as u64; 32]).collect();
            for _ in 0..ROUNDS {
                c.run_machine_round("round", &parts, 0, |_i, p: &Vec<u64>| {
                    p.iter().sum::<u64>()
                })
                .unwrap();
            }

            let model = FaultModel {
                fail_prob,
                straggler_prob: 0.0,
                straggler_factor: 1.0,
                max_task_retries: MrConfig::default().max_task_retries,
                speculative: false,
            };
            let mut rng = Rng::new(seed);
            let mut expected_total = 0usize;
            for round in 0..ROUNDS {
                let planned: usize = plan_fates(&mut rng, PARTS, &model)
                    .iter()
                    .map(|f| f.failures)
                    .sum();
                assert_eq!(
                    c.stats.rounds[round].recovery.replayed_tasks, planned,
                    "seed {seed} p {fail_prob} round {round}"
                );
                expected_total += planned;
            }
            assert_eq!(
                c.stats.total_retries(),
                expected_total,
                "seed {seed} p {fail_prob}: engine vs planned stream"
            );
            if fail_prob == 0.0 {
                assert_eq!(expected_total, 0);
            }
        }
    }
}

/// End-to-end: a full sampling-k-median pipeline under every fault level
/// produces bit-identical centers and costs, and its retry count replays
/// deterministically.
#[test]
fn prop_recovered_pipeline_bit_identical_to_fault_free() {
    for seed in [11u64, 12] {
        let data = DataGenConfig {
            n: 2500,
            k: 5,
            sigma: 0.05,
            seed,
            ..Default::default()
        }
        .generate();
        let run = |fail_prob: f64| {
            let cfg = ClusterConfig {
                k: 5,
                epsilon: 0.2,
                machines: 8,
                seed,
                fail_prob,
                straggler_prob: 0.1,
                straggler_factor: 3.0,
                speculative: true,
                ..Default::default()
            };
            run_algorithm(Algorithm::SamplingLloyd, &data.points, &cfg).unwrap()
        };
        let clean = run(0.0);
        assert_eq!(clean.stats.total_retries(), 0);
        for fail_prob in FAIL_PROBS {
            let faulty = run(fail_prob);
            assert_eq!(
                faulty.centers, clean.centers,
                "seed {seed} p {fail_prob}: centers diverged"
            );
            assert_eq!(
                faulty.cost.median.to_bits(),
                clean.cost.median.to_bits(),
                "seed {seed} p {fail_prob}: cost diverged"
            );
            assert_eq!(faulty.rounds, clean.rounds);
            // Same seed + config => the fault stream replays identically.
            let again = run(fail_prob);
            assert_eq!(again.stats.total_retries(), faulty.stats.total_retries());
            if fail_prob >= 0.3 {
                assert!(
                    faulty.stats.total_retries() > 0,
                    "seed {seed}: p=0.3 over a multi-round run must inject"
                );
            }
        }
    }
}

/// The fault stream and its recovery are independent of host parallelism:
/// sequential and pooled execution agree on outputs *and* accounting.
#[test]
fn prop_recovery_thread_invariant() {
    let data = DataGenConfig {
        n: 2000,
        k: 4,
        sigma: 0.05,
        seed: 21,
        ..Default::default()
    }
    .generate();
    let run = |parallel: bool| {
        let cfg = ClusterConfig {
            k: 4,
            epsilon: 0.2,
            machines: 8,
            seed: 21,
            parallel,
            threads: 4,
            fail_prob: 0.3,
            straggler_prob: 0.2,
            straggler_factor: 4.0,
            speculative: true,
            ..Default::default()
        };
        run_algorithm(Algorithm::SamplingLloyd, &data.points, &cfg).unwrap()
    };
    let seq = run(false);
    let par = run(true);
    assert_eq!(seq.centers, par.centers);
    assert_eq!(seq.cost.median.to_bits(), par.cost.median.to_bits());
    assert_eq!(seq.stats.total_retries(), par.stats.total_retries());
    assert_eq!(
        seq.stats.total_recomputed_bytes(),
        par.stats.total_recomputed_bytes()
    );
    assert_eq!(seq.stats.peak_replay_mem(), par.stats.peak_replay_mem());
    assert!(seq.stats.total_retries() > 0);
}
