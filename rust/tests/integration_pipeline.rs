//! End-to-end integration: every paper algorithm over the full stack
//! (data gen → MapReduce engine → coordinator → metrics), checking the
//! relationships the paper's evaluation relies on.

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::mapreduce::check_mrc0;
use mrcluster::metrics::kmedian_cost;

fn dataset(n: usize, k: usize, seed: u64) -> mrcluster::data::Dataset {
    DataGenConfig {
        n,
        k,
        sigma: 0.05,
        seed,
        ..Default::default()
    }
    .generate()
}

fn cfg(k: usize, seed: u64) -> ClusterConfig {
    ClusterConfig {
        k,
        epsilon: 0.2,
        machines: 16,
        seed,
        ls_max_swaps: 40,
        ..Default::default()
    }
}

#[test]
fn figure1_cost_relationships_hold() {
    // On well-separated blobs every constant-factor algorithm should land
    // within ~50% of Parallel-Lloyd — the paper's cost table shows all
    // algorithms within 17% of each other.
    let data = dataset(20_000, 10, 1);
    let c = cfg(10, 1);
    let base = run_algorithm(Algorithm::ParallelLloyd, &data.points, &c).unwrap();
    for algo in [
        Algorithm::DivideLloyd,
        Algorithm::SamplingLloyd,
        Algorithm::SamplingLocalSearch,
    ] {
        let out = run_algorithm(algo, &data.points, &c).unwrap();
        let ratio = out.cost.median / base.cost.median;
        assert!(
            ratio < 1.5 && ratio > 0.5,
            "{}: cost ratio {ratio:.3} out of band",
            algo.name()
        );
    }
}

#[test]
fn sampling_beats_parallel_lloyd_on_time_at_scale() {
    // The headline speedup claim, scaled down: at n = 400k under the
    // paper's Figure-1 parameters (eps = 0.1, k = 25, 100 machines) the
    // sampling algorithm's simulated time must beat Parallel-Lloyd's.
    let data = dataset(400_000, 25, 2);
    let c = ClusterConfig {
        k: 25,
        machines: 100,
        epsilon: 0.1,
        seed: 2,
        // Sequential engine: timing must not depend on how many other test
        // binaries are fighting for cores right now.
        parallel: false,
        ..Default::default()
    };
    // Best-of-3 per algorithm to shed scheduler noise.
    let best = |algo| {
        (0..3)
            .map(|_| run_algorithm(algo, &data.points, &c).unwrap().sim_time)
            .min()
            .unwrap()
    };
    let base = best(Algorithm::ParallelLloyd);
    let fast = best(Algorithm::SamplingLloyd);
    assert!(
        fast < base,
        "Sampling-Lloyd {fast:?} not faster than Parallel-Lloyd {base:?}"
    );
}

#[test]
fn rounds_are_constant_in_n() {
    // Theorems 1.1/1.2: rounds depend on ε, not on n.
    let c = cfg(10, 3);
    let mut rounds = Vec::new();
    for n in [5_000usize, 20_000, 80_000] {
        let data = dataset(n, 10, 3);
        let out = run_algorithm(Algorithm::SamplingLloyd, &data.points, &c).unwrap();
        rounds.push(out.rounds);
    }
    let max = *rounds.iter().max().unwrap();
    let min = *rounds.iter().min().unwrap();
    assert!(
        max <= min + 4,
        "rounds grew with n: {rounds:?} (must be ~constant)"
    );
}

#[test]
fn mrc0_bounds_hold_for_sampling_kmedian() {
    // Empirical check of Theorem 1.2's resource claims.
    let data = dataset(50_000, 10, 4);
    let c = ClusterConfig {
        machines: 50,
        ..cfg(10, 4)
    };
    let out = run_algorithm(Algorithm::SamplingLloyd, &data.points, &c).unwrap();
    let report = check_mrc0(
        &out.stats,
        data.points.mem_bytes(),
        c.epsilon,
        16.0,
        3 * (1.0 / c.epsilon).ceil() as usize + 4,
    );
    assert!(report.ok(), "{report}");
}

#[test]
fn memory_limit_kills_hoggish_configs() {
    // With a tiny per-machine budget the engine must hard-error rather
    // than silently exceed MRC^0 memory.
    let data = dataset(20_000, 10, 5);
    let c = ClusterConfig {
        mem_limit: Some(1024), // 1 KiB per machine: absurd on purpose
        ..cfg(10, 5)
    };
    let err = run_algorithm(Algorithm::ParallelLloyd, &data.points, &c);
    assert!(err.is_err(), "1KiB budget must be exceeded");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("memory budget"), "unexpected error: {msg}");
}

#[test]
fn deterministic_given_seed() {
    let data = dataset(10_000, 8, 6);
    let c = cfg(8, 6);
    let a = run_algorithm(Algorithm::SamplingLloyd, &data.points, &c).unwrap();
    let b = run_algorithm(Algorithm::SamplingLloyd, &data.points, &c).unwrap();
    assert_eq!(a.cost.median, b.cost.median);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.centers, b.centers);
}

#[test]
fn skewed_data_still_clusters_well() {
    // E7: alpha = 1.5 (heavily skewed cluster sizes).
    let data = DataGenConfig {
        n: 30_000,
        k: 10,
        sigma: 0.05,
        alpha: 1.5,
        seed: 7,
        ..Default::default()
    }
    .generate();
    let c = cfg(10, 7);
    let out = run_algorithm(Algorithm::SamplingLocalSearch, &data.points, &c).unwrap();
    let planted = data.planted_cost_median();
    assert!(
        out.cost.median < planted * 2.0,
        "skewed: cost {} vs planted {planted}",
        out.cost.median
    );
}

#[test]
fn works_on_loaded_csv_roundtrip() {
    // data I/O integrates with the pipeline.
    let data = dataset(2_000, 5, 8);
    let dir = std::env::temp_dir().join("mrcluster_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pts.csv");
    mrcluster::data::save_csv(&path, &data.points).unwrap();
    let loaded = mrcluster::data::load_csv(&path).unwrap();
    let c = cfg(5, 8);
    let out = run_algorithm(Algorithm::SamplingLloyd, &loaded, &c).unwrap();
    assert_eq!(out.centers.len(), 5);
    assert!(kmedian_cost(&loaded, &out.centers) > 0.0);
}
