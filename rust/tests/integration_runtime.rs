//! XLA/PJRT backend vs native backend: the two implementations of the
//! compute surface must agree to float tolerance on every function and
//! shape (including padding paths). The whole file is gated on the `xla`
//! cargo feature (without it the executor is not compiled), and each test
//! additionally skips cleanly when `make artifacts` has not been run.

#![cfg(feature = "xla")]

use mrcluster::geometry::PointSet;
use mrcluster::runtime::{ComputeBackend, NativeBackend, XlaBackend};
use mrcluster::util::rng::Rng;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: no artifacts/manifest.json (run `make artifacts`)");
        None
    }
}

fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed);
    PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
}

fn check_assign(xla: &XlaBackend, n: usize, k: usize, d: usize, seed: u64) {
    let p = random_ps(n, d, seed);
    let c = random_ps(k, d, seed + 1);
    let got = xla.assign(&p, &c);
    let want = NativeBackend.assign(&p, &c);
    assert_eq!(got.sqdist.len(), n);
    assert_eq!(got.idx.len(), n);
    for i in 0..n {
        assert!(
            (got.sqdist[i] - want.sqdist[i]).abs() < 1e-4,
            "n={n} k={k} d={d} i={i}: {} vs {}",
            got.sqdist[i],
            want.sqdist[i]
        );
        // Indices may differ on exact ties only; compare through distance.
        if got.idx[i] != want.idx[i] {
            let a = mrcluster::geometry::metric::sq_dist(p.row(i), c.row(got.idx[i] as usize));
            let b = mrcluster::geometry::metric::sq_dist(p.row(i), c.row(want.idx[i] as usize));
            assert!((a - b).abs() < 1e-4, "tie mismatch at {i}");
        }
    }
}

#[test]
fn assign_agrees_across_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(dir).unwrap();
    // Exact bucket size, sub-bucket (padding), multi-block, k-padding.
    check_assign(&xla, 2048, 32, 3, 1);
    check_assign(&xla, 100, 25, 3, 2);
    check_assign(&xla, 5000, 25, 3, 3);
    check_assign(&xla, 513, 100, 3, 4);
    check_assign(&xla, 64, 5, 8, 5);
}

#[test]
fn lloyd_step_agrees() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(dir).unwrap();
    let shapes = [(2048usize, 32usize, 3usize, 10u64), (700, 25, 3, 11), (4100, 25, 3, 12)];
    for (n, k, d, seed) in shapes {
        let p = random_ps(n, d, seed);
        let c = random_ps(k, d, seed + 1);
        let got = xla.lloyd_step(&p, &c);
        let want = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(got.sums.len(), k * d);
        assert_eq!(got.counts.len(), k);
        for j in 0..k {
            assert!(
                (got.counts[j] - want.counts[j]).abs() < 0.5,
                "counts[{j}]: {} vs {}",
                got.counts[j],
                want.counts[j]
            );
        }
        for j in 0..k * d {
            assert!(
                (got.sums[j] - want.sums[j]).abs() < 0.05 * (1.0 + want.sums[j].abs()),
                "sums[{j}]: {} vs {}",
                got.sums[j],
                want.sums[j]
            );
        }
        let rel = (got.cost_median - want.cost_median).abs() / want.cost_median.max(1e-9);
        assert!(rel < 1e-3, "cost {} vs {}", got.cost_median, want.cost_median);
    }
}

#[test]
fn weight_histogram_agrees() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(dir).unwrap();
    let p = random_ps(3000, 3, 20);
    let c = random_ps(25, 3, 21);
    let (gw, gc) = xla.weight_histogram(&p, &c);
    let (ww, wc) = NativeBackend.weight_histogram(&p, &c);
    let g_total: f64 = gw.iter().sum();
    assert!((g_total - 3000.0).abs() < 0.5, "weights must sum to n: {g_total}");
    for j in 0..25 {
        assert!((gw[j] - ww[j]).abs() < 0.5, "w[{j}]: {} vs {}", gw[j], ww[j]);
    }
    assert!((gc - wc).abs() / wc.max(1e-9) < 1e-3);
}

#[test]
fn full_pipeline_on_xla_backend_matches_native_cost() {
    let Some(dir) = artifacts_dir() else { return };
    use mrcluster::config::{ClusterConfig, RuntimeBackendKind};
    use mrcluster::coordinator::{run_algorithm, Algorithm};
    let data = mrcluster::data::DataGenConfig {
        n: 20_000,
        k: 10,
        sigma: 0.05,
        seed: 30,
        ..Default::default()
    }
    .generate();
    let mk = |backend| ClusterConfig {
        k: 10,
        epsilon: 0.2,
        machines: 8,
        seed: 30,
        backend,
        artifact_dir: dir.to_path_buf(),
        ..Default::default()
    };
    let nat_cfg = mk(RuntimeBackendKind::Native);
    let xla_cfg = mk(RuntimeBackendKind::Xla);
    let nat = run_algorithm(Algorithm::SamplingLloyd, &data.points, &nat_cfg).unwrap();
    let xla = run_algorithm(Algorithm::SamplingLloyd, &data.points, &xla_cfg).unwrap();
    // Same seeds drive the same sampling decisions; distances only differ
    // by float noise, so the costs must be near-identical.
    let rel = (nat.cost.median - xla.cost.median).abs() / nat.cost.median;
    assert!(rel < 0.05, "native {} vs xla {}", nat.cost.median, xla.cost.median);
}

#[test]
fn unsupported_shape_errors_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(dir).unwrap();
    // d=5 has no artifact; supports() must say no.
    assert!(!xla.supports("assign", 10, 5));
    assert!(xla.supports("assign", 25, 3));
}
