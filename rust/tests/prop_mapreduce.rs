//! Property-based tests of the MapReduce engine's semantics: the shuffle
//! contract (all values of a key meet exactly once), conservation laws,
//! parallel/sequential equivalence, and memory accounting monotonicity.

use mrcluster::mapreduce::{MrCluster, MrConfig};
use mrcluster::util::rng::Rng;

fn cluster(nm: usize, parallel: bool) -> MrCluster {
    MrCluster::new(MrConfig {
        n_machines: nm,
        mem_limit: None,
        parallel,
        threads: 4,
        ..Default::default()
    })
}

/// Random multiset histogram via MapReduce == direct histogram.
#[test]
fn prop_histogram_conservation() {
    let mut rng = Rng::new(1);
    for case in 0..10 {
        let n = 100 + rng.below(5000);
        let buckets = 1 + rng.below(50);
        let nm = 1 + rng.below(32);
        let values: Vec<usize> = (0..n).map(|_| rng.below(buckets)).collect();
        let mut direct = vec![0usize; buckets];
        for &v in &values {
            direct[v] += 1;
        }
        let mut c = cluster(nm, case % 2 == 0);
        let out = c
            .run_round(
                "hist",
                values.into_iter().enumerate().collect(),
                |_k, v: &usize, emit| emit(*v, 1usize),
                |k: &usize, vs: &[usize], emit| emit(*k, vs.len()),
            )
            .unwrap();
        let mut got = vec![0usize; buckets];
        for (k, count) in out {
            assert_eq!(got[k], 0, "case {case}: key {k} reduced twice");
            got[k] = count;
        }
        assert_eq!(got, direct, "case {case} (n={n}, buckets={buckets}, nm={nm})");
    }
}

/// Sum over machine-round outputs == direct sum (conservation through the
/// resident-data path), for parts counts above and below machine counts.
#[test]
fn prop_machine_round_conservation() {
    let mut rng = Rng::new(2);
    for case in 0..10 {
        let n_parts = 1 + rng.below(40);
        let nm = 1 + rng.below(16);
        let parts: Vec<Vec<u64>> = (0..n_parts)
            .map(|_| (0..1 + rng.below(200)).map(|_| rng.below(1000) as u64).collect())
            .collect();
        let direct: u64 = parts.iter().flatten().sum();
        let mut c = cluster(nm, case % 2 == 1);
        let sums = c
            .run_machine_round("sum", &parts, 0, |_i, p: &Vec<u64>| p.iter().sum::<u64>())
            .unwrap();
        assert_eq!(sums.len(), n_parts, "one output per block");
        assert_eq!(sums.iter().sum::<u64>(), direct, "case {case}");
        assert_eq!(c.stats.rounds[0].machines_used, n_parts.min(nm));
    }
}

/// Parallel and sequential execution produce identical outputs.
#[test]
fn prop_parallel_equals_sequential() {
    let mut rng = Rng::new(3);
    for _case in 0..6 {
        let n = 500 + rng.below(2000);
        let input: Vec<(usize, u64)> = (0..n).map(|i| (i, rng.next_u64() % 997)).collect();
        let run = |parallel: bool| {
            let mut c = cluster(8, parallel);
            let mut out = c
                .run_round(
                    "mod-sum",
                    input.clone(),
                    |_k, v: &u64, emit| emit(v % 13, *v),
                    |k: &u64, vs: &[u64], emit| {
                        emit(*k, vs.iter().sum::<u64>())
                    },
                )
                .unwrap();
            out.sort();
            out
        };
        assert_eq!(run(true), run(false));
    }
}

/// Memory accounting: a round's max-machine memory never exceeds the total
/// shuffled bytes plus keys, and is positive whenever data moved.
#[test]
fn prop_memory_accounting_sane() {
    let mut rng = Rng::new(4);
    for _ in 0..6 {
        let n = 100 + rng.below(1000);
        let input: Vec<(usize, u64)> = (0..n).map(|i| (i, i as u64)).collect();
        let mut c = cluster(4, false);
        c.run_round(
            "acct",
            input,
            |_k, v: &u64, emit| emit(v % 7, *v),
            |k: &u64, vs: &[u64], emit| emit(*k, vs.len() as u64),
        )
        .unwrap();
        let r = &c.stats.rounds[0];
        assert!(r.max_machine_mem > 0);
        // keys + values both counted: per-pair 8 bytes key + 8 value.
        assert!(r.shuffle_bytes >= n * 16);
        assert!(r.max_machine_mem <= r.shuffle_bytes + n * 8);
    }
}

/// The memory limit is a sharp threshold: a budget above the observed peak
/// passes, a budget just below it fails.
#[test]
fn prop_memory_limit_threshold() {
    let input: Vec<(usize, u64)> = (0..1000).map(|i| (i, i as u64)).collect();
    // Dry run to learn the peak.
    let mut probe = cluster(4, false);
    probe
        .run_round(
            "probe",
            input.clone(),
            |_k, v: &u64, emit| emit(v % 3, *v),
            |k: &u64, vs: &[u64], emit| emit(*k, vs.len() as u64),
        )
        .unwrap();
    let peak = probe.stats.peak_machine_mem();
    assert!(peak > 0);

    let run_with = |limit: usize| {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 4,
            mem_limit: Some(limit),
            parallel: false,
            threads: 1,
            ..Default::default()
        });
        c.run_round(
            "limit",
            input.clone(),
            |_k, v: &u64, emit| emit(v % 3, *v),
            |k: &u64, vs: &[u64], emit| emit(*k, vs.len() as u64),
        )
        .map(|_| ())
    };
    assert!(run_with(peak).is_ok(), "budget == peak must pass");
    assert!(run_with(peak - 1).is_err(), "budget < peak must fail");
}

/// Round stats accumulate monotonically across jobs on one cluster.
#[test]
fn prop_stats_accumulate() {
    let mut c = cluster(4, false);
    let mut last_rounds = 0;
    for j in 0..5 {
        let parts: Vec<Vec<u32>> = vec![vec![j as u32; 100]; 4];
        c.run_machine_round("acc", &parts, 0, |_i, p: &Vec<u32>| p.len()).unwrap();
        assert_eq!(c.stats.n_rounds(), last_rounds + 1);
        last_rounds += 1;
    }
    let total: std::time::Duration = c.stats.rounds.iter().map(|r| r.sim_time()).sum();
    assert_eq!(total, c.stats.sim_time());
}

/// Fault injection: a failing task *loses its output partition* and the
/// round recovers by actually replaying it from the retained inputs, so
/// failures inflate simulated time and the recovery accounting while the
/// computation's *outputs* stay bit-identical.
#[test]
fn prop_fault_injection_inflates_time_not_results() {
    let parts: Vec<Vec<u64>> = (0..64).map(|i| vec![i as u64; 2000]).collect();
    let run = |fail_prob: f64| {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 16,
            parallel: false,
            threads: 1,
            fail_prob,
            // p = 0.5 chains can run long; keep the abort path out of this
            // test's way (it has its own coverage in cluster.rs).
            max_task_retries: 1000,
            fault_seed: 7,
            ..Default::default()
        });
        let out = c
            .run_machine_round("faulty", &parts, 0, |_i, p: &Vec<u64>| {
                p.iter().map(|&x| x.wrapping_mul(2654435761)).sum::<u64>()
            })
            .unwrap();
        (out, c.stats.total_retries(), c.stats.total_recomputed_bytes())
    };
    let (clean_out, clean_retries, clean_bytes) = run(0.0);
    let (faulty_out, faulty_retries, faulty_bytes) = run(0.5);
    assert_eq!(clean_retries, 0);
    assert_eq!(clean_bytes, 0);
    assert!(
        faulty_retries > 10,
        "expected ~64 replays at p=0.5, got {faulty_retries}"
    );
    assert!(faulty_bytes > 0, "replays must account recomputed bytes");
    assert_eq!(clean_out, faulty_out, "results must be fault-transparent");
}

/// Stragglers: a 10x straggler factor must increase the round's simulated
/// time when stragglers are certain.
#[test]
fn prop_straggler_model_slows_round() {
    let parts: Vec<Vec<u64>> = (0..8).map(|i| vec![i as u64; 50_000]).collect();
    let run = |straggler_prob: f64| {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 8,
            parallel: false,
            threads: 1,
            straggler_prob,
            straggler_factor: 10.0,
            fault_seed: 11,
            ..Default::default()
        });
        c.run_machine_round("straggle", &parts, 0, |_i, p: &Vec<u64>| {
            p.iter().map(|&x| x.wrapping_mul(0x9E3779B9)).sum::<u64>()
        })
        .unwrap();
        c.stats.sim_time()
    };
    let normal = run(0.0);
    let straggly = run(1.0);
    assert!(
        straggly.as_secs_f64() > normal.as_secs_f64() * 3.0,
        "straggler run {straggly:?} should be >>3x the normal {normal:?}"
    );
}

/// The cluster's worker pool is persistent: many rounds on one cluster
/// execute on the same fixed set of OS threads (a per-round scoped-spawn
/// regression would show ~rounds × threads distinct thread ids here).
#[test]
fn prop_worker_threads_reused_across_rounds() {
    let mut c = cluster(8, true); // threads: 4
    let mut ids = std::collections::HashSet::new();
    for round in 0..10u64 {
        let parts: Vec<Vec<u64>> = (0..8).map(|i| vec![i + round; 500]).collect();
        let tids = c
            .run_machine_round("tids", &parts, 0, |_i, _p: &Vec<u64>| {
                format!("{:?}", std::thread::current().id())
            })
            .unwrap();
        for t in tids {
            ids.insert(t);
        }
    }
    assert!(
        ids.len() <= 4,
        "rounds must reuse the persistent pool workers, saw {} distinct threads",
        ids.len()
    );
}

/// The fault stream is deterministic: same fault_seed => same retries.
#[test]
fn prop_fault_stream_deterministic() {
    let parts: Vec<Vec<u64>> = (0..32).map(|i| vec![i as u64; 100]).collect();
    let run = || {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 8,
            parallel: false,
            threads: 1,
            fail_prob: 0.3,
            fault_seed: 99,
            ..Default::default()
        });
        c.run_machine_round("det", &parts, 0, |_i, p: &Vec<u64>| p.len()).unwrap();
        c.stats.total_retries()
    };
    assert_eq!(run(), run());
}
