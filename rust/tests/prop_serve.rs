//! The serving-mode consistency test wall (seeded sweeps, same style as
//! the other prop_* targets).
//!
//! Three contracts are pinned here, all at the bit level:
//!
//! 1. **Ingest invariance** — in lossless mode (`serve.tau = 0`) any
//!    partition, permutation, or regrouping of a point stream into ingest
//!    batches produces a bit-identical epoch sketch, and the closed
//!    epoch's centers are bit-identical to the one-shot batch pipeline
//!    (`Algorithm::CoresetKMedian`) on the same data's canonical
//!    arrangement. Compressed mode (`tau > 0`) keeps batch-*order*
//!    invariance bitwise.
//! 2. **Fold-depth pinning** — `CoverageSummary::compose_all` and every
//!    pairwise-compose tree shape produce the same sketch bytes, and an
//!    `IngestLog`'s deferred canonicalization means observing the sketch
//!    mid-stream never perturbs the final bytes.
//! 3. **Snapshot isolation** — query threads hammering a `ServeEngine`
//!    while epochs close underneath only ever see whole published models:
//!    every answer replays bit-identically against the single model its
//!    epoch id names, and that epoch sits inside the window the thread
//!    observed around the call.

mod common;

use mrcluster::config::{ClusterConfig, ServeConfig};
use mrcluster::coordinator::{run_algorithm_with, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::geometry::{MetricKind, PointSet};
use mrcluster::metrics::kmedian_cost_metric;
use mrcluster::runtime::{ComputeBackend, NativeBackend};
use mrcluster::serve::{IngestLog, Model, QueryEngine, ServeEngine};
use mrcluster::summaries::{Coreset, CoverageSummary, WeightedSet};
use mrcluster::util::rng::Rng;
use std::sync::Arc;

fn stream(n: usize, dim: usize, seed: u64) -> PointSet {
    DataGenConfig {
        n,
        k: 3,
        dim,
        sigma: 0.1,
        alpha: 0.0,
        contamination: 0.0,
        seed,
    }
    .generate()
    .points
}

fn small_cfg(metric: MetricKind, seed: u64) -> ClusterConfig {
    ClusterConfig {
        k: 3,
        metric,
        machines: 4,
        ls_max_swaps: 20,
        seed,
        ..Default::default()
    }
}

/// Fisher–Yates permutation of `[0, n)`.
fn permutation(n: usize, rng: &mut Rng) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.below(i + 1);
        order.swap(i, j);
    }
    order
}

/// Split `points` into randomly sized batches (1..=max_batch points each).
fn random_batches(points: &PointSet, max_batch: usize, rng: &mut Rng) -> Vec<PointSet> {
    let mut batches = Vec::new();
    let mut lo = 0usize;
    while lo < points.len() {
        let hi = (lo + 1 + rng.below(max_batch)).min(points.len());
        batches.push(points.view(lo, hi));
        lo = hi;
    }
    batches
}

/// Strict bit-level sketch equality (coords, weights, radius by bits).
fn sketch_bits_equal(a: &CoverageSummary, b: &CoverageSummary) -> bool {
    let (ra, rb) = (a.reps(), b.reps());
    ra.len() == rb.len()
        && a.radius().to_bits() == b.radius().to_bits()
        && ra
            .points()
            .flat()
            .iter()
            .zip(rb.points().flat())
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && ra
            .weights()
            .iter()
            .zip(rb.weights())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Strict bit-level center equality.
fn centers_bits_equal(a: &PointSet, b: &PointSet) -> bool {
    a.len() == b.len()
        && a.dim() == b.dim()
        && a.flat().iter().zip(b.flat()).all(|(x, y)| x.to_bits() == y.to_bits())
}

// ---------------------------------------------------------------------------
// 1. Ingest invariance (lossless + compressed)
// ---------------------------------------------------------------------------

#[test]
fn lossless_sketch_is_invariant_to_partition_permutation_and_regrouping() {
    let backend = NativeBackend;
    for seed in 0..6u64 {
        let data = stream(300, 3, 4000 + seed);
        // Baseline: the whole stream as one batch.
        let mut base = IngestLog::new(3, MetricKind::L2Sq, 0, 77);
        base.ingest(&data, &backend);
        let baseline = base.sketch();
        let mut rng = Rng::new(seed ^ 0x5Eed);
        for round in 0..4 {
            // A fresh permutation of the points, re-split into fresh
            // random batch sizes every round.
            let order = permutation(data.len(), &mut rng);
            let shuffled = data.gather(&order);
            let mut log = IngestLog::new(3, MetricKind::L2Sq, 0, 77);
            for batch in random_batches(&shuffled, 40, &mut rng) {
                log.ingest(&batch, &backend);
            }
            let sketch = log.sketch();
            assert!(
                sketch_bits_equal(&baseline, &sketch),
                "seed {seed} round {round}: re-partitioned/permuted ingest changed \
                 the epoch sketch bytes"
            );
        }
    }
}

#[test]
fn lossless_epoch_centers_match_the_one_shot_pipeline_bitwise() {
    for metric in [MetricKind::L2Sq, MetricKind::L1, MetricKind::Cosine] {
        for seed in 0..2u64 {
            let data = stream(240, 3, 5000 + seed);
            let cfg = small_cfg(metric, 9 + seed);
            let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
            // Serve path: a shuffled stream in uneven batches.
            let mut rng = Rng::new(seed ^ 0xA11CE);
            let order = permutation(data.len(), &mut rng);
            let shuffled = data.gather(&order);
            let engine = ServeEngine::with_backend(
                3,
                &cfg,
                &ServeConfig::default(),
                Arc::clone(&backend),
            );
            for batch in random_batches(&shuffled, 50, &mut rng) {
                engine.ingest(&batch).unwrap();
            }
            let close = engine.close_epoch().unwrap();
            // One-shot path: the batch pipeline on the canonical
            // arrangement of the very same multiset of points.
            let canonical = WeightedSet::unit(data.clone()).canonicalize();
            let oneshot = run_algorithm_with(
                Algorithm::CoresetKMedian,
                canonical.points(),
                &cfg,
                &NativeBackend,
            )
            .unwrap();
            assert!(
                centers_bits_equal(&close.model.centers, &oneshot.centers),
                "{metric:?} seed {seed}: serve epoch centers diverged from the \
                 one-shot batch pipeline"
            );
        }
    }
}

#[test]
fn lossless_epoch_cost_is_bounded_against_the_exact_oracle() {
    // Small n so the brute-force oracle is feasible; the served model must
    // stay within a constant factor of the exact discrete optimum.
    for metric in [MetricKind::L2Sq, MetricKind::L1] {
        let data = stream(48, 2, 8123);
        let cfg = small_cfg(metric, 13);
        let engine = ServeEngine::new(2, &cfg, &ServeConfig::default());
        for batch in data.chunks(6) {
            engine.ingest(&batch).unwrap();
        }
        let close = engine.close_epoch().unwrap();
        let served = kmedian_cost_metric(&data, &close.model.centers, metric);
        let opt = common::exact_kmedian_metric(&data, cfg.k, metric);
        assert!(
            served <= 5.0 * opt + 1e-9,
            "{metric:?}: served cost {served} vs exact optimum {opt}"
        );
    }
}

#[test]
fn compressed_sketch_is_invariant_to_batch_arrival_order() {
    let backend = NativeBackend;
    for seed in 0..4u64 {
        let data = stream(320, 3, 6000 + seed);
        let batches = data.chunks(8);
        let feed = |order: &[usize]| {
            let mut log = IngestLog::new(3, MetricKind::L1, 12, 321);
            for &i in order {
                log.ingest(&batches[i], &backend);
            }
            log.sketch()
        };
        let baseline = feed(&(0..batches.len()).collect::<Vec<_>>());
        let mut rng = Rng::new(seed ^ 0xBee5);
        for round in 0..4 {
            let order = permutation(batches.len(), &mut rng);
            let sketch = feed(&order);
            assert!(
                sketch_bits_equal(&baseline, &sketch),
                "seed {seed} round {round}: batch order {order:?} changed the \
                 compressed sketch bytes"
            );
        }
    }
}

#[test]
fn compressed_epoch_centers_are_invariant_to_batch_arrival_order() {
    let cfg = small_cfg(MetricKind::L2Sq, 21);
    let serve = ServeConfig {
        tau: 10,
        ..Default::default()
    };
    let data = stream(400, 3, 7001);
    let batches = data.chunks(10);
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
    let run = |order: &[usize]| {
        let engine = ServeEngine::with_backend(3, &cfg, &serve, Arc::clone(&backend));
        for &i in order {
            engine.ingest(&batches[i]).unwrap();
        }
        engine.close_epoch().unwrap()
    };
    let forward = run(&(0..batches.len()).collect::<Vec<_>>());
    let reverse = run(&(0..batches.len()).rev().collect::<Vec<_>>());
    assert!(
        centers_bits_equal(&forward.model.centers, &reverse.model.centers),
        "compressed-mode centers changed with batch arrival order"
    );
    assert_eq!(forward.sketch_len, reverse.sketch_len);
    assert_eq!(forward.trimmed, reverse.trimmed);
}

// ---------------------------------------------------------------------------
// 2. Fold-depth pinning (the canonicalize-once-per-publish fix)
// ---------------------------------------------------------------------------

#[test]
fn compose_all_matches_every_pairwise_fold_shape_bitwise() {
    for seed in 0..4u64 {
        let data = stream(360, 3, 9000 + seed);
        let summaries: Vec<CoverageSummary> = data
            .chunks(6)
            .into_iter()
            .enumerate()
            .map(|(m, chunk)| CoverageSummary::build(&chunk, 9, seed ^ m as u64, &NativeBackend))
            .collect();
        let flat = CoverageSummary::compose_all(summaries.iter().cloned()).unwrap();
        let left = summaries.iter().cloned().reduce(Coreset::compose).unwrap();
        let right = summaries
            .iter()
            .cloned()
            .rev()
            .reduce(|acc, s| Coreset::compose(s, acc))
            .unwrap();
        let mid = summaries.len() / 2;
        let tree = Coreset::compose(
            CoverageSummary::compose_all(summaries[..mid].iter().cloned()).unwrap(),
            CoverageSummary::compose_all(summaries[mid..].iter().cloned()).unwrap(),
        );
        for (name, other) in [("left", &left), ("right", &right), ("tree", &tree)] {
            assert!(
                sketch_bits_equal(&flat, other),
                "seed {seed}: compose_all diverged from the {name} fold"
            );
        }
    }
}

#[test]
fn observing_the_sketch_mid_stream_never_perturbs_the_final_bytes() {
    // The ingest log canonicalizes once per publish; `sketch()` is a pure
    // observer, so sampling it after every batch (any fold depth) must
    // leave the final epoch sketch byte-identical.
    let backend = NativeBackend;
    for &tau in &[0usize, 8] {
        let data = stream(280, 3, 10_500);
        let mut plain = IngestLog::new(3, MetricKind::L2Sq, tau, 55);
        let mut observed = IngestLog::new(3, MetricKind::L2Sq, tau, 55);
        for batch in data.chunks(7) {
            plain.ingest(&batch, &backend);
            observed.ingest(&batch, &backend);
            let _ = observed.sketch(); // mid-stream observation
        }
        assert!(
            sketch_bits_equal(&plain.sketch(), &observed.sketch()),
            "tau {tau}: mid-stream sketch() calls changed the published bytes"
        );
        let (a, ea, ..) = plain.take_epoch();
        let (b, eb, ..) = observed.take_epoch();
        assert_eq!(ea, eb);
        assert!(sketch_bits_equal(&a, &b), "tau {tau}: take_epoch diverged");
    }
}

// ---------------------------------------------------------------------------
// 3. Concurrent snapshot consistency
// ---------------------------------------------------------------------------

/// One recorded concurrent query: the epoch window the thread observed
/// around the call, the view it asked about, and the full response.
struct Obs {
    pre: u64,
    post: u64,
    lo: usize,
    hi: usize,
    epoch: u64,
    assign: Vec<u32>,
    dist_bits: Vec<u32>,
    cost_bits: u64,
}

/// Hammer a [`ServeEngine`] from `threads` query threads while a writer
/// closes `epochs` epochs underneath, then serially replay every recorded
/// answer against the single published model its epoch id names.
fn stress_snapshot_consistency(threads: usize, queries_per_thread: usize, epochs: u64) {
    let cfg = small_cfg(MetricKind::L2Sq, 31);
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
    let engine =
        ServeEngine::with_backend(3, &cfg, &ServeConfig::default(), Arc::clone(&backend));
    let per_epoch = 80usize;
    let feed = stream(per_epoch * epochs as usize, 3, 12_000);
    let queries = stream(64, 3, 13_000);
    let qb = 16usize;

    // Publish epoch 1 before any query thread starts, so queries always
    // have a model.
    let mut models: Vec<Arc<Model>> = Vec::new();
    engine.ingest(&feed.view(0, per_epoch)).unwrap();
    models.push(engine.close_epoch().unwrap().model);

    let observations: Vec<Vec<Obs>> = std::thread::scope(|s| {
        // Writer: keep closing epochs 2..=epochs while the queriers run.
        let writer = s.spawn(|| {
            let mut published = Vec::new();
            for e in 1..epochs as usize {
                let lo = e * per_epoch;
                engine.ingest(&feed.view(lo, lo + per_epoch)).unwrap();
                published.push(engine.close_epoch().unwrap().model);
            }
            published
        });
        let handles: Vec<_> = (0..threads)
            .map(|ti| {
                let q = engine.query_engine();
                let queries = &queries;
                s.spawn(move || {
                    let mut obs = Vec::with_capacity(queries_per_thread);
                    for j in 0..queries_per_thread {
                        let lo = ((ti * queries_per_thread + j) * qb)
                            % (queries.len() - qb + 1);
                        let view = queries.view(lo, lo + qb);
                        let pre = q.current_epoch().expect("epoch 1 pre-published");
                        let r = q.query(&view).expect("epoch 1 pre-published");
                        let post = q.current_epoch().unwrap();
                        obs.push(Obs {
                            pre,
                            post,
                            lo,
                            hi: lo + qb,
                            epoch: r.epoch,
                            assign: r.assign,
                            dist_bits: r.dist.iter().map(|d| d.to_bits()).collect(),
                            cost_bits: r.cost.to_bits(),
                        });
                    }
                    obs
                })
            })
            .collect();
        let per_thread: Vec<Vec<Obs>> =
            handles.into_iter().map(|h| h.join().expect("query thread")).collect();
        models.extend(writer.join().expect("writer thread"));
        per_thread
    });

    for (i, m) in models.iter().enumerate() {
        assert_eq!(m.epoch, i as u64 + 1, "publication log must be dense");
    }
    for (ti, obs) in observations.iter().enumerate() {
        for (j, o) in obs.iter().enumerate() {
            // The captured snapshot must be one whole published epoch
            // inside the window observed around the call — no torn or
            // mixed-epoch reads.
            assert!(
                o.pre <= o.epoch && o.epoch <= o.post,
                "thread {ti} query {j}: epoch {} outside window [{}, {}]",
                o.epoch,
                o.pre,
                o.post
            );
            let model = &models[o.epoch as usize - 1];
            let replay =
                QueryEngine::answer(model, backend.as_ref(), &queries.view(o.lo, o.hi));
            assert_eq!(replay.epoch, o.epoch);
            assert_eq!(replay.assign, o.assign, "thread {ti} query {j}: assignment tore");
            let replay_bits: Vec<u32> = replay.dist.iter().map(|d| d.to_bits()).collect();
            assert_eq!(replay_bits, o.dist_bits, "thread {ti} query {j}: distance bits tore");
            assert_eq!(
                replay.cost.to_bits(),
                o.cost_bits,
                "thread {ti} query {j}: cost bits tore"
            );
        }
    }
}

#[test]
fn concurrent_queries_are_snapshot_consistent_while_epochs_close() {
    stress_snapshot_consistency(4, 60, 6);
}

/// High-contention variant for release-mode CI (`--include-ignored`): more
/// threads and epochs than the debug-tier run, same invariant.
#[test]
#[ignore = "high-contention stress; run in release CI via --include-ignored"]
fn concurrent_queries_survive_high_contention() {
    stress_snapshot_consistency(8, 300, 20);
}
