//! Launcher (CLI) integration: drive the actual `mrcluster` binary the way
//! a user would — argument parsing, config layering, dataset round-trips,
//! and experiment commands on tiny workloads.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_mrcluster"))
}

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join("mrcluster_cli_tests");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_commands_and_keys() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in [
        "fig1",
        "fig2",
        "mrc-check",
        "cluster.epsilon",
        "Sampling-LocalSearch",
        "ooc-sweep",
        "ooc-check",
        "data.backing",
        "arena",
        "Mazzetto-kMedian",
        "Ceccarello-kCenter",
    ] {
        assert!(text.contains(needle), "help missing {needle:?}");
    }
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown command"), "{err}");
}

#[test]
fn bad_override_fails() {
    let out = bin()
        .args(["cluster", "--algo", "Sampling-Lloyd", "--set", "cluster.nope=1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown config key"));
}

#[test]
fn generate_then_cluster_roundtrip() {
    let path = tmpdir().join("cli_pts.csv");
    let out = bin()
        .args([
            "generate",
            "--out",
            path.to_str().unwrap(),
            "--set",
            "data.n=2000",
            "--set",
            "data.k=5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(path.exists());

    let out = bin()
        .args([
            "cluster",
            "--algo",
            "Sampling-Lloyd",
            "--input",
            path.to_str().unwrap(),
            "--set",
            "cluster.k=5",
            "--set",
            "cluster.machines=4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("k-median cost"), "{text}");
    assert!(text.contains("rounds"), "{text}");
}

#[test]
fn generate_mrc_then_file_backed_cluster_matches_mem() {
    let path = tmpdir().join("cli_pts.mrc");
    let out = bin()
        .args([
            "generate",
            "--out",
            path.to_str().unwrap(),
            "--set",
            "data.n=2000",
            "--set",
            "data.k=5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(path.exists());

    let run = |backing: &str| {
        let set_backing = format!("data.backing={backing}");
        let out = bin()
            .args([
                "cluster",
                "--algo",
                "MrKCenter",
                "--input",
                path.to_str().unwrap(),
                "--set",
                &set_backing,
                "--set",
                "cluster.k=5",
                "--set",
                "cluster.machines=4",
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{backing}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let file_text = run("file");
    let mem_text = run("mem");
    assert!(file_text.contains("backing        : file"), "{file_text}");
    assert!(file_text.contains("peak resident"), "{file_text}");
    // The printed objectives must agree exactly across backings.
    let cost = |t: &str| t.lines().find(|l| l.starts_with("k-median cost")).map(String::from);
    assert!(cost(&file_text).is_some(), "{file_text}");
    assert_eq!(cost(&file_text), cost(&mem_text));
}

#[test]
fn cluster_all_algorithms_tiny() {
    for algo in [
        "Parallel-Lloyd",
        "Divide-Lloyd",
        "Sampling-Lloyd",
        "Sampling-LocalSearch",
        "Streaming-Guha",
        "MrKCenter",
        "Robust-kCenter",
        "Coreset-kMedian",
        "Mazzetto-kMedian",
        "Ceccarello-kCenter",
    ] {
        let out = bin()
            .args([
                "cluster",
                "--algo",
                algo,
                "--set",
                "data.n=1500",
                "--set",
                "data.k=4",
                "--set",
                "cluster.k=4",
                "--set",
                "cluster.machines=4",
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{algo}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
}

#[test]
fn config_file_layering() {
    let cfg = tmpdir().join("cli_cfg.toml");
    std::fs::write(
        &cfg,
        "[data]\nn = 1200\nk = 3\n\n[cluster]\nk = 3\nmachines = 2\n",
    )
    .unwrap();
    let out = bin()
        .args([
            "cluster",
            "--algo",
            "Sampling-Lloyd",
            "--config",
            cfg.to_str().unwrap(),
            "--set",
            "cluster.machines=5", // override wins
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("points         : 1200"));
}

#[test]
fn sample_stats_table_renders() {
    let out = bin()
        .args(["sample-stats", "--ns", "3000", "--eps", "0.2,0.3", "--set", "cluster.k=5"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("iterations"), "{text}");
    assert_eq!(text.lines().filter(|l| l.starts_with("3000")).count(), 2);
}

#[test]
fn fault_sweep_reports_identical_outputs() {
    let out = bin()
        .args([
            "fault-sweep",
            "--n",
            "1200",
            "--regimes",
            "0.3:0.2",
            "--set",
            "data.k=4",
            "--set",
            "cluster.k=4",
            "--set",
            "cluster.machines=4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("replays"), "{text}");
    // Every row must report bit-identical recovery ("yes", never "NO").
    assert!(!text.contains("NO"), "{text}");
}

#[test]
fn outlier_compare_reports_margin_and_recovery() {
    let out = bin()
        .args([
            "outlier-compare",
            "--n",
            "1200",
            "--contamination",
            "0.02",
            "--set",
            "data.k=4",
            "--set",
            "data.sigma=0.05",
            "--set",
            "cluster.k=4",
            "--set",
            "cluster.machines=4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Robust-kCenter"), "{text}");
    assert!(text.contains("robustness margin"), "{text}");
    // Lossy-regime recovery must be bit-identical for both pipelines.
    assert!(!text.contains("NO"), "{text}");
}

#[test]
fn cluster_metric_flag_and_key_work() {
    // --metric shorthand.
    let out = bin()
        .args([
            "cluster",
            "--algo",
            "Sampling-Lloyd",
            "--metric",
            "l1",
            "--set",
            "data.n=1200",
            "--set",
            "data.k=4",
            "--set",
            "cluster.k=4",
            "--set",
            "cluster.machines=4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("metric         : l1"), "{text}");

    // The dotted key spells the same thing; a bad name fails loudly.
    let out = bin()
        .args([
            "cluster",
            "--algo",
            "Sampling-Lloyd",
            "--set",
            "cluster.metric=hamming",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown metric"));
}

#[test]
fn metric_compare_reports_deterministic_cells() {
    let out = bin()
        .args([
            "metric-compare",
            "--n",
            "1200",
            "--metrics",
            "l2sq,l1,cosine",
            "--set",
            "data.k=4",
            "--set",
            "cluster.k=4",
            "--set",
            "cluster.machines=4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["l2sq", "l1", "cosine", "deterministic"] {
        assert!(text.contains(needle), "{text}");
    }
    // Every cell must replay bit-identically ("yes", never "NO").
    assert!(!text.contains("NO"), "{text}");
}

#[test]
fn serve_bench_json_is_schema_v2_with_reproducible_counters() {
    // Latencies vary run to run; the schema tag, the row structure, and
    // the operation counters must not. Run the same tiny bench twice and
    // compare everything deterministic.
    let run = |tag: &str| {
        let path = tmpdir().join(format!("serve_bench_{tag}.json"));
        let out = bin()
            .args([
                "serve-bench",
                "--n",
                "1500",
                "--batches",
                "128,512",
                "--threads",
                "1,2",
                "--queries",
                "4",
                "--json",
                path.to_str().unwrap(),
                "--set",
                "data.k=4",
                "--set",
                "cluster.k=4",
                "--set",
                "cluster.machines=4",
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("oracle gate passed"), "{text}");
        mrcluster::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap()
    };
    let (a, b) = (run("a"), run("b"));
    for doc in [&a, &b] {
        assert_eq!(
            doc.get("schema").and_then(|s| s.as_str()),
            Some("mrcluster-serve-bench-v2")
        );
        assert!(doc.get("oracle_checked").is_some());
        let rows = doc.get("rows").and_then(|r| r.as_arr()).expect("rows array");
        // 2 ingest rows + 1 epoch_close row + 2x2 query cells.
        assert_eq!(rows.len(), 7);
        for row in rows {
            for key in ["variant", "threads", "batch", "count", "p50_us", "p99_us", "per_sec"] {
                assert!(row.get(key).is_some(), "row missing {key}");
            }
        }
        let variant = |i: usize| rows[i].get("variant").unwrap().as_str().unwrap().to_string();
        assert_eq!(variant(0), "ingest");
        assert_eq!(variant(2), "epoch_close");
        assert_eq!(variant(3), "query");
    }
    // The deterministic counters must agree exactly across the two runs.
    for key in ["n", "dim", "k", "tau", "epochs", "batches", "queries"] {
        assert_eq!(
            a.get(key).and_then(|v| v.as_usize()),
            b.get(key).and_then(|v| v.as_usize()),
            "counter {key} not reproducible"
        );
    }
    let row_counts = |doc: &mrcluster::util::json::Json| -> Vec<(String, usize, usize, usize)> {
        doc.get("rows")
            .and_then(|r| r.as_arr())
            .unwrap()
            .iter()
            .map(|r| {
                (
                    r.get("variant").unwrap().as_str().unwrap().to_string(),
                    r.get("threads").unwrap().as_usize().unwrap(),
                    r.get("batch").unwrap().as_usize().unwrap(),
                    r.get("count").unwrap().as_usize().unwrap(),
                )
            })
            .collect()
    };
    assert_eq!(row_counts(&a), row_counts(&b), "per-row counters not reproducible");
}

#[test]
fn arena_runs_every_pipeline_and_gates_pass() {
    // Tiny arena through the real binary: the command itself bails if a
    // cell diverges on replay, the sim perturbs a run, or a pipeline blows
    // its oracle envelope — success already certifies the gates. On top,
    // the JSON artifact must carry every registered pipeline and the three
    // top-level verdicts as true.
    let path = tmpdir().join("arena.json");
    let out = bin()
        .args([
            "arena",
            "--n",
            "300",
            "--contamination",
            "0.0",
            "--metrics",
            "l2sq",
            "--json",
            path.to_str().unwrap(),
            "--set",
            "data.k=4",
            "--set",
            "cluster.k=4",
            "--set",
            "cluster.machines=4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["E17", "Mazzetto-kMedian", "Ceccarello-kCenter", "sim-pure", "oracle"] {
        assert!(text.contains(needle), "stdout missing {needle:?}: {text}");
    }
    assert!(!text.contains("NO"), "{text}");
    let doc =
        mrcluster::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    for key in ["all_deterministic", "all_match_baseline", "oracle_ok"] {
        assert_eq!(
            doc.get(key).and_then(|v| v.as_bool()),
            Some(true),
            "verdict {key} must be true"
        );
    }
    // 3 datasets x 12 pipelines (n = 300 keeps LocalSearch under the cap).
    assert_eq!(doc.get("rows").and_then(|r| r.as_arr()).unwrap().len(), 36);
    assert_eq!(doc.get("oracle").and_then(|r| r.as_arr()).unwrap().len(), 12);
}

#[test]
fn mrc_check_passes_on_defaults() {
    let out = bin()
        .args(["mrc-check", "--set", "data.n=30000", "--set", "cluster.machines=16"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("OK"), "{text}");
    assert!(!text.contains("VIOLATED"), "{text}");
}

#[test]
fn info_runs() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("paper: Fast Clustering"));
}
