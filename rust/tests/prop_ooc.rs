//! Property tests of the out-of-core data plane, through the public crate
//! surface: a file-backed (`PointStore::File`) run of every streaming
//! coordinator must be bit-identical to the in-memory run on the same
//! generated dataset, a serial file-backed run must never hold more than
//! one O(chunk) window of coordinates resident, and the v2 dataset format
//! must round-trip through `generate_stream` → `FileStore::open`.

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm_store_with, run_algorithm_with, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::geometry::{FileStore, PointStore};
use mrcluster::runtime::NativeBackend;
use std::path::PathBuf;

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("mrcluster_prop_ooc");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

const STREAMING: [Algorithm; 6] = [
    Algorithm::MrKCenter,
    Algorithm::RobustKCenter,
    Algorithm::CoresetKMedian,
    Algorithm::DivideLloyd,
    Algorithm::MazzettoKMedian,
    Algorithm::CeccarelloKCenter,
];

/// Every streaming coordinator, several seeds: the file-backed run must
/// reproduce the in-memory run bit for bit — centers, round count,
/// reduced size, and the exact cost bits (f64 summation order included).
#[test]
fn prop_file_backed_runs_are_bit_identical() {
    for seed in [11u64, 12, 13] {
        let gen = DataGenConfig {
            n: 6000,
            k: 6,
            seed,
            contamination: 0.02,
            ..Default::default()
        };
        let path = tmpfile(&format!("ident_{seed}.mrc"));
        let store = PointStore::from(gen.generate_stream(&path).unwrap());
        let points = gen.generate().points;
        let cfg = ClusterConfig {
            k: 6,
            machines: 8,
            seed,
            ..Default::default()
        };
        for algo in STREAMING {
            let a = run_algorithm_store_with(algo, &store, &cfg, 64 * 1024, &NativeBackend)
                .unwrap();
            let b = run_algorithm_with(algo, &points, &cfg, &NativeBackend).unwrap();
            assert_eq!(
                a.centers.flat(),
                b.centers.flat(),
                "{}: centers diverged (seed {seed})",
                algo.name()
            );
            assert_eq!(a.rounds, b.rounds, "{}: rounds diverged", algo.name());
            assert_eq!(a.reduced_size, b.reduced_size, "{}: reduced size", algo.name());
            assert_eq!(
                a.cost.median.to_bits(),
                b.cost.median.to_bits(),
                "{}: k-median cost bits diverged",
                algo.name()
            );
            assert_eq!(
                a.cost.center.to_bits(),
                b.cost.center.to_bits(),
                "{}: k-center cost bits diverged",
                algo.name()
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

/// The E14 hard check through the public experiments API: with serial
/// machines and a serial cost sweep, the peak resident bytes of every
/// streaming pipeline stay under one legitimate window — which itself is
/// a strict fraction of the dataset, so the run genuinely spilled.
#[test]
fn prop_serial_file_runs_stay_within_one_window() {
    use mrcluster::experiments::{ooc_check, ExperimentParams};
    let params = ExperimentParams {
        k: 5,
        sigma: 0.05,
        alpha: 0.0,
        contamination: 0.0,
        seed: 21,
        repeats: 1,
        cluster: ClusterConfig {
            k: 5,
            machines: 8,
            epsilon: 0.2,
            ls_max_swaps: 20,
            seed: 21,
            ..Default::default()
        },
    };
    let dir = std::env::temp_dir().join("mrcluster_prop_ooc_check");
    let report = ooc_check(&params, 40_000, 1024, &dir, &NativeBackend).unwrap();
    assert!(
        report.peak_resident_bytes <= report.resident_bound_bytes,
        "peak {} exceeded the O(chunk) ceiling {}",
        report.peak_resident_bytes,
        report.resident_bound_bytes
    );
    assert!(
        report.resident_bound_bytes < report.total_bytes,
        "the check must exercise a genuine spill"
    );
    assert!(report.verdicts.iter().all(|(_, ok)| *ok));
}

/// Algorithms that hold the full input on one machine refuse file backing
/// with an actionable error instead of silently loading everything.
#[test]
fn prop_non_streaming_algorithms_report_a_clear_error() {
    let gen = DataGenConfig {
        n: 500,
        k: 4,
        seed: 31,
        ..Default::default()
    };
    let path = tmpfile("refuse.mrc");
    let store = PointStore::from(gen.generate_stream(&path).unwrap());
    let cfg = ClusterConfig {
        k: 4,
        seed: 31,
        ..Default::default()
    };
    let err = run_algorithm_store_with(Algorithm::SamplingLloyd, &store, &cfg, 4096, &NativeBackend)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("no out-of-core path"),
        "unexpected error: {err:#}"
    );
    std::fs::remove_file(&path).ok();
}

/// v2 dataset format round trip: stream to disk, re-open cold, read back
/// — header provenance and every coordinate bit must survive.
#[test]
fn prop_stream_open_round_trip() {
    for seed in [41u64, 42] {
        let gen = DataGenConfig {
            n: 3000,
            k: 5,
            seed,
            ..Default::default()
        };
        let path = tmpfile(&format!("rt_{seed}.mrc"));
        gen.generate_stream(&path).unwrap();
        let fs = FileStore::open(&path).unwrap();
        assert_eq!(fs.header().seed, seed, "header must carry the generator seed");
        assert_eq!(fs.len(), 3000);
        let back = fs.read_rows(0, fs.len()).unwrap();
        assert_eq!(back, gen.generate().points, "payload must be bit-identical");
        std::fs::remove_file(&path).ok();
    }
}
