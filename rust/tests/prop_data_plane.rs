//! Property tests of the zero-copy data plane and the persistent-pool
//! execution model: view aliasing, chunk/extend round-trips, zero-copy
//! accounting, and bit-identical parallel vs sequential cluster runs.

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::parallel_lloyd::parallel_lloyd;
use mrcluster::data::DataGenConfig;
use mrcluster::geometry::PointSet;
use mrcluster::mapreduce::{MrCluster, MrConfig};
use mrcluster::runtime::NativeBackend;
use mrcluster::util::rng::Rng;

fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed);
    PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
}

/// `chunks` performs zero coordinate copies: every chunk aliases the
/// parent allocation and owns no bytes of its own, while the *logical*
/// (simulated-machine) accounting still sees every byte.
#[test]
fn prop_chunks_are_zero_copy() {
    let mut rng = Rng::new(1);
    for _ in 0..10 {
        let n = 100 + rng.below(3000);
        let d = 1 + rng.below(6);
        let parts = 1 + rng.below(40);
        let p = random_ps(n, d, rng.next_u64());
        let chunks = p.chunks(parts);
        let mut logical = 0usize;
        for c in &chunks {
            assert!(c.shares_storage(&p), "chunk must alias parent storage");
            assert_eq!(c.owned_bytes(), 0, "chunk must own zero bytes");
            logical += c.mem_bytes();
        }
        assert_eq!(logical, p.mem_bytes(), "logical accounting must not shrink");
        assert_eq!(
            chunks.iter().map(PointSet::len).sum::<usize>(),
            p.len(),
            "chunks must cover every point"
        );
    }
}

/// Mutating an owned set never changes a previously-taken view, and
/// mutating a chunk never changes the parent or sibling chunks.
#[test]
fn prop_view_aliasing_is_safe() {
    let mut rng = Rng::new(2);
    for _ in 0..10 {
        let n = 50 + rng.below(500);
        let d = 1 + rng.below(4);
        let mut p = random_ps(n, d, rng.next_u64());
        let before = p.flat().to_vec();
        let lo = rng.below(n / 2);
        let hi = lo + 1 + rng.below(n - lo);
        let view = p.view(lo, hi);
        let view_before = view.flat().to_vec();

        // Mutate the parent: push and shuffle.
        p.push(&vec![7.0f32; d]);
        p.shuffle(&mut Rng::new(9));
        assert_eq!(view.flat(), &view_before[..], "view changed by parent");

        // Mutate a chunk: the parent and its siblings must be unaffected.
        let mut chunks = random_ps(n, d, 5).chunks(4);
        let sibling_before = chunks[1].flat().to_vec();
        let mut first = chunks.remove(0);
        first.push(&vec![3.0f32; d]);
        assert_eq!(chunks[0].flat(), &sibling_before[..]);
    }
}

/// chunks + extend round-trips to the exact original contents — the old
/// deep-copying semantics, observable difference zero.
#[test]
fn prop_chunks_extend_round_trip() {
    let mut rng = Rng::new(3);
    for _ in 0..10 {
        let n = 1 + rng.below(2000);
        let d = 1 + rng.below(5);
        let parts = 1 + rng.below(30);
        let p = random_ps(n, d, rng.next_u64());
        let mut rebuilt = PointSet::with_capacity(d, n);
        for c in p.chunks(parts) {
            rebuilt.extend(&c);
        }
        assert_eq!(rebuilt, p, "round-trip must reproduce the set");
        assert_eq!(rebuilt.flat(), p.flat(), "bit-exact coordinates");
    }
}

/// Contiguous gathers are views; scattered gathers copy but preserve
/// contents.
#[test]
fn prop_gather_fast_path_equivalence() {
    let mut rng = Rng::new(4);
    for _ in 0..10 {
        let n = 20 + rng.below(500);
        let p = random_ps(n, 2, rng.next_u64());
        let lo = rng.below(n / 2);
        let len = 1 + rng.below(n - lo);
        let run: Vec<usize> = (lo..lo + len).collect();
        let g = p.gather(&run);
        assert!(g.shares_storage(&p), "contiguous gather must be a view");
        for (pos, &i) in run.iter().enumerate() {
            assert_eq!(g.row(pos), p.row(i));
        }
        // Every-other-point gather: must copy, same contents.
        let scattered: Vec<usize> = (0..n).step_by(2).collect();
        let s = p.gather(&scattered);
        assert!(!s.shares_storage(&p) || scattered.len() == n);
        for (pos, &i) in scattered.iter().enumerate() {
            assert_eq!(s.row(pos), p.row(i));
        }
    }
}

/// Degenerate shapes behave: empty views, full-range views, more chunk
/// parts than points, and gathers taken *from* a view (indices are
/// view-relative, contents match the parent rows they alias).
#[test]
fn prop_view_and_chunk_edge_cases() {
    let mut rng = Rng::new(10);
    for _ in 0..10 {
        let n = 2 + rng.below(300);
        let d = 1 + rng.below(4);
        let p = random_ps(n, d, rng.next_u64());

        // Empty view: no rows, no logical bytes, dim preserved.
        let lo = rng.below(n);
        let empty = p.view(lo, lo);
        assert!(empty.is_empty());
        assert_eq!(empty.dim(), d);
        assert_eq!(empty.mem_bytes(), 0);
        assert_eq!(empty.chunks(3).len(), 0, "an empty set splits into no chunks");

        // Full-range view: indistinguishable from (and aliasing) the parent.
        let full = p.view(0, n);
        assert_eq!(full, p);
        assert!(full.shares_storage(&p));

        // More parts than points: per-chunk size rounds up to one point,
        // so exactly n single-point chunks come back, in order.
        let chunks = p.chunks(n + 1 + rng.below(50));
        assert_eq!(chunks.len(), n);
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.len(), 1);
            assert_eq!(c.row(0), p.row(i));
        }

        // Gather from a mid-range view.
        let vlo = rng.below(n / 2);
        let vhi = vlo + 1 + rng.below(n - vlo);
        let view = p.view(vlo, vhi);
        let idx: Vec<usize> = (0..view.len()).step_by(2).collect();
        let g = view.gather(&idx);
        for (pos, &i) in idx.iter().enumerate() {
            assert_eq!(g.row(pos), p.row(vlo + i), "gather indices must be view-relative");
        }
    }
}

fn run_lloyd(parallel: bool, n: usize, seed: u64) -> (PointSet, Vec<f64>, usize) {
    let data = DataGenConfig {
        n,
        k: 8,
        sigma: 0.05,
        seed,
        ..Default::default()
    }
    .generate();
    let cfg = ClusterConfig {
        k: 8,
        machines: 16,
        seed,
        ..Default::default()
    };
    let mut cluster = MrCluster::new(MrConfig {
        n_machines: 16,
        parallel,
        threads: 4,
        ..Default::default()
    });
    let res = parallel_lloyd(&mut cluster, &data.points, &cfg, &NativeBackend).unwrap();
    (res.centers, res.history, cluster.stats.n_rounds())
}

/// The determinism contract of the persistent pool: `parallel = true` and
/// `parallel = false` cluster runs produce *bit-identical* outputs,
/// because work is decomposed into fixed blocks merged in index order
/// regardless of the worker schedule.
#[test]
fn prop_parallel_sequential_bit_identical() {
    for seed in [5u64, 6, 7] {
        let (pc, ph, pr) = run_lloyd(true, 4000, seed);
        let (sc, sh, sr) = run_lloyd(false, 4000, seed);
        assert_eq!(pc.flat(), sc.flat(), "centers must match bit-for-bit");
        assert_eq!(ph.len(), sh.len());
        for (a, b) in ph.iter().zip(&sh) {
            assert_eq!(a.to_bits(), b.to_bits(), "objective history must match");
        }
        assert_eq!(pr, sr);
    }
}

/// Same contract through the full sampling pipeline (Iterative-Sample has
/// per-machine RNG state and pruning): identical sample, indices, and
/// round count either way.
#[test]
fn prop_sampling_parallel_sequential_identical() {
    use mrcluster::coordinator::mr_iterative_sample::mr_iterative_sample;
    let data = DataGenConfig {
        n: 20_000,
        k: 10,
        seed: 8,
        ..Default::default()
    }
    .generate();
    let cfg = ClusterConfig {
        k: 10,
        epsilon: 0.2,
        machines: 16,
        seed: 8,
        ..Default::default()
    };
    let run = |parallel: bool| {
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 16,
            parallel,
            threads: 4,
            ..Default::default()
        });
        let res = mr_iterative_sample(&mut cluster, &data.points, &cfg, &NativeBackend).unwrap();
        (res.indices, res.sample, res.iterations)
    };
    let (pi, ps, pit) = run(true);
    let (si, ss, sit) = run(false);
    assert_eq!(pi, si, "sample indices must be identical");
    assert_eq!(ps.flat(), ss.flat(), "sample coordinates must be identical");
    assert_eq!(pit, sit);
}
