//! Cross-algorithm quality comparisons on controlled geometry — the
//! approximation-factor relationships the paper's analysis predicts.

use mrcluster::algorithms::gonzalez::gonzalez;
use mrcluster::algorithms::lloyd::{lloyd, LloydConfig};
use mrcluster::algorithms::local_search::{local_search, LocalSearchConfig};
use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::geometry::PointSet;
use mrcluster::metrics::kmedian_cost;
use mrcluster::runtime::NativeBackend;
use mrcluster::util::rng::Rng;

mod common;
use common::{exact_kcenter, exact_kmedian};

#[test]
fn local_search_within_5x_of_exact_optimum() {
    // Theory: 3+2/c approximation with exact swaps. Our first-improvement
    // variant should stay well within 5x on small instances.
    let mut rng = Rng::new(1);
    for trial in 0..5 {
        let n = 20;
        let p = PointSet::from_flat(2, (0..n * 2).map(|_| rng.f32() * 10.0).collect());
        let opt = exact_kmedian(&p, 3);
        let res = local_search(
            &p,
            None,
            &LocalSearchConfig {
                k: 3,
                seed: trial,
                ..Default::default()
            },
        );
        let cost = kmedian_cost(&p, &res.centers);
        assert!(
            cost <= opt * 5.0 + 1e-6,
            "trial {trial}: LS {cost} vs OPT {opt}"
        );
    }
}

#[test]
fn gonzalez_within_2x_of_exact_kcenter() {
    // Gonzalez is provably 2-approx; verify against brute force.
    let mut rng = Rng::new(2);
    for trial in 0..5 {
        let n = 20;
        let p = PointSet::from_flat(2, (0..n * 2).map(|_| rng.f32() * 10.0).collect());
        let opt = exact_kcenter(&p, 3);
        let res = gonzalez(&p, 3, &mut Rng::new(trial));
        assert!(
            res.radius <= 2.0 * opt + 1e-6,
            "trial {trial}: gonzalez {} vs OPT {opt}",
            res.radius
        );
    }
}

#[test]
fn sampling_pipeline_within_constant_of_exact_optimum() {
    // The full MapReduce pipeline against the exact discrete optimum at
    // n = 48 — far beyond the old bitmask oracle's n <= 16 reach. On two
    // well-separated blobs a constant-factor algorithm sits near 1x; 8x
    // holds comfortable slack under Theorem 3.11's (10a + 3) constant.
    let data = DataGenConfig {
        n: 48,
        k: 2,
        dim: 3,
        sigma: 0.02,
        alpha: 0.0,
        contamination: 0.0,
        seed: 33,
    }
    .generate();
    let opt = exact_kmedian(&data.points, 2);
    assert!(opt.is_finite() && opt > 0.0);
    let cfg = ClusterConfig {
        k: 2,
        epsilon: 0.2,
        machines: 4,
        seed: 33,
        ..Default::default()
    };
    let out = run_algorithm(Algorithm::SamplingLocalSearch, &data.points, &cfg).unwrap();
    let cost = kmedian_cost(&data.points, &out.centers);
    assert!(cost <= opt * 8.0 + 1e-6, "cost {cost} vs exact OPT {opt}");
}

#[test]
fn local_search_beats_or_matches_lloyd_on_kmedian() {
    // The paper's cost tables show LocalSearch <= Lloyd on the k-median
    // objective (Figure 1, LocalSearch row ~0.95). Aggregate comparison
    // across seeds to tolerate per-seed noise.
    let mut ls_total = 0.0;
    let mut lloyd_total = 0.0;
    for seed in 0..3u64 {
        let data = DataGenConfig {
            n: 2000,
            k: 8,
            sigma: 0.15,
            seed,
            ..Default::default()
        }
        .generate();
        let ls = local_search(
            &data.points,
            None,
            &LocalSearchConfig {
                k: 8,
                seed,
                ..Default::default()
            },
        );
        let ll = lloyd(
            &data.points,
            None,
            &LloydConfig {
                k: 8,
                seed,
                ..Default::default()
            },
            &NativeBackend,
        );
        ls_total += kmedian_cost(&data.points, &ls.centers);
        lloyd_total += kmedian_cost(&data.points, &ll.centers);
    }
    assert!(
        ls_total <= lloyd_total * 1.1,
        "LS {ls_total} should be competitive with Lloyd {lloyd_total}"
    );
}

#[test]
fn graph_metric_and_coordinate_metric_agree_on_embedded_data() {
    // DistanceMatrix::from_points must induce the same clustering costs as
    // the coordinate path.
    let data = DataGenConfig {
        n: 60,
        k: 3,
        sigma: 0.05,
        seed: 9,
        ..Default::default()
    }
    .generate();
    let matrix = mrcluster::geometry::DistanceMatrix::from_points(&data.points);
    let centers_idx = vec![0usize, 20, 40];
    let via_matrix = matrix.kmedian_cost(&centers_idx);
    let via_coords = kmedian_cost(&data.points, &data.points.gather(&centers_idx));
    assert!(
        (via_matrix - via_coords).abs() / via_coords < 1e-4,
        "{via_matrix} vs {via_coords}"
    );
}

#[test]
fn weighted_algorithms_scale_invariantly() {
    // Doubling every weight must not change the argmin centers (cost
    // doubles). Checks the weighted plumbing end to end.
    let data = DataGenConfig {
        n: 500,
        k: 5,
        sigma: 0.1,
        seed: 10,
        ..Default::default()
    }
    .generate();
    let w1 = vec![1.0f32; 500];
    let w2 = vec![2.0f32; 500];
    let mk = |seed| LocalSearchConfig {
        k: 5,
        seed,
        ..Default::default()
    };
    let a = local_search(&data.points, Some(&w1), &mk(3));
    let b = local_search(&data.points, Some(&w2), &mk(3));
    assert_eq!(a.center_indices, b.center_indices);
    assert!((b.cost_median - 2.0 * a.cost_median).abs() / b.cost_median < 1e-6);
}
