//! The simulation determinism wall (E15 tentpole, satellite 1).
//!
//! The discrete-event simulation in `src/sim/` promises to be a *pure
//! function* of `(round inputs, sim.* config, sim.seed)`: bit-identical
//! across repeats and executors, totally ordered in time, and free of
//! every ambient-nondeterminism source (`Instant`, wall clock, hash-order
//! iteration). These tests hold it to that promise:
//!
//! * same seed ⇒ bit-identical event traces and wall-clocks across
//!   repeated cluster constructions and across {pooled, sequential}
//!   engine executors;
//! * simulated time is monotone within a trace and conserved — every
//!   round's wall-clock sits between the critical-path lower bound and
//!   the serial upper bound;
//! * the `sim/` sources contain no `HashMap`/`HashSet`/`Instant`
//!   (checked textually via `include_str!` so a regression cannot hide
//!   behind a lucky iteration order);
//! * a 2-rack × 2-hosts-per-rack analytic oracle whose completion times
//!   are derived by hand below and asserted exactly.

use mrcluster::mapreduce::{MrCluster, MrConfig};
use mrcluster::sim::{
    ClusterSim, Heterogeneity, NetworkKind, Placement, SimConfig, TaskSpec, TraceEvent,
};
use std::time::Duration;

/// A contended, heterogeneous config that exercises every model at once.
fn stress_cfg() -> SimConfig {
    SimConfig {
        enabled: true,
        network: NetworkKind::Topology,
        racks: 3,
        oversub: 4.0,
        hetero: Heterogeneity::LogNormal(0.5),
        placement: Placement::RackAware,
        record_trace: true,
        ..SimConfig::default()
    }
}

fn mixed_tasks(n: usize) -> Vec<TaskSpec> {
    (0..n).map(|i| TaskSpec::new(10_000 + i * 997, 1_000 + i * 131, 1 + i % 3)).collect()
}

/// Bit-identical replay: constructing the same simulated cluster twice
/// and replaying the same rounds yields byte-for-byte equal traces and
/// wall-clocks — the foundation every other guarantee rests on.
#[test]
fn prop_same_seed_same_trace() {
    for seed in [1u64, 0x51D0, 0xDEAD_BEEF] {
        let cfg = SimConfig { seed, ..stress_cfg() };
        let mk = || ClusterSim::new(&cfg, 13);
        let (a, b) = (mk(), mk());
        assert_eq!(a.speeds(), b.speeds(), "seed {seed}: speed draw diverged");
        let tasks = mixed_tasks(29);
        let reduce = mixed_tasks(13);
        let (ra, rb) = (a.machine_round(&tasks, 4096), b.machine_round(&tasks, 4096));
        assert_eq!(ra.wallclock, rb.wallclock, "seed {seed}: machine wallclock");
        assert_eq!(ra.trace, rb.trace, "seed {seed}: machine trace");
        let (sa, sb) = (a.shuffle_round(&tasks, &reduce), b.shuffle_round(&tasks, &reduce));
        assert_eq!(sa.wallclock, sb.wallclock, "seed {seed}: shuffle wallclock");
        assert_eq!(sa.trace, sb.trace, "seed {seed}: shuffle trace");
        // A different seed must actually change something (the speeds),
        // or the heterogeneity model is a no-op.
        let other = ClusterSim::new(&SimConfig { seed: seed ^ 1, ..cfg.clone() }, 13);
        assert_ne!(a.speeds(), other.speeds(), "seed is ignored");
    }
}

/// The engine-level contract: `sim_wallclock` recorded by a real
/// `MrCluster` run is identical whether machines execute on the worker
/// pool or sequentially, and across repeats — the simulation only ever
/// sees deterministic per-round aggregates, never thread timing.
#[test]
fn prop_wallclock_identical_across_executors_and_repeats() {
    let run = |parallel: bool| {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 6,
            parallel,
            threads: 3,
            fail_prob: 0.2,
            fault_seed: 7,
            sim: SimConfig { enabled: true, ..stress_cfg() },
            ..Default::default()
        });
        // One shuffle round (word count) + one machine round + a leader
        // round: all three sim surfaces in a single run.
        let docs: Vec<(usize, String)> =
            (0..18).map(|i| (i, format!("a{} b{} c", i % 4, i % 7))).collect();
        c.run_round(
            "count",
            docs,
            |_k, text: &String, emit| {
                for w in text.split_whitespace() {
                    emit(w.to_string(), 1u64);
                }
            },
            |k: &String, vs: &[u64], out| out(k.clone(), vs.iter().sum::<u64>()),
        )
        .unwrap();
        let parts: Vec<Vec<u64>> = (0..12).map(|i| vec![i as u64; 16 + i]).collect();
        c.run_machine_round("local", &parts, 128, |_i, p: &Vec<u64>| p.iter().sum::<u64>())
            .unwrap();
        c.run_leader_round("finish", 4096, || 42u64).unwrap();
        let per_round: Vec<Duration> =
            c.stats.rounds.iter().map(|r| r.sim_wallclock).collect();
        (per_round, c.stats.sim_wallclock())
    };
    let (rounds_seq, total_seq) = run(false);
    let (rounds_pool, total_pool) = run(true);
    let (rounds_again, total_again) = run(false);
    assert!(total_seq > Duration::ZERO, "sim recorded nothing");
    assert!(rounds_seq.iter().all(|d| *d > Duration::ZERO));
    assert_eq!(rounds_seq, rounds_pool, "pooled vs sequential executor");
    assert_eq!(total_seq, total_pool);
    assert_eq!(rounds_seq, rounds_again, "repeat of the same run");
    assert_eq!(total_seq, total_again);
}

/// Time is monotone and conserved: within every trace, event timestamps
/// never decrease (the `(time, seq)` order is total), and the round's
/// wall-clock lies between the critical-path lower bound (no schedule
/// beats the slowest host chain / slowest uncontended flow) and the
/// serial upper bound (fair sharing is work-conserving).
#[test]
fn prop_time_monotone_and_conserved() {
    let heteros = [
        Heterogeneity::None,
        Heterogeneity::LogNormal(0.7),
        Heterogeneity::Bimodal { slow_frac: 0.25, slow_factor: 5.0 },
    ];
    let monotone = |trace: &[TraceEvent]| trace.windows(2).all(|w| w[0].time <= w[1].time);
    for kind in [NetworkKind::Constant, NetworkKind::Shared, NetworkKind::Topology] {
        for hetero in heteros {
            for hosts in [1usize, 5, 16] {
                let cfg = SimConfig {
                    network: kind,
                    racks: hosts.div_ceil(4),
                    oversub: 2.5,
                    hetero,
                    ..stress_cfg()
                };
                let sim = ClusterSim::new(&cfg, hosts);
                let tasks = mixed_tasks(hosts * 2 + 3);
                let r = sim.machine_round(&tasks, 2048);
                assert!(monotone(&r.trace), "{kind} {hetero:?} {hosts}: machine trace");
                assert!(
                    r.lower_bound <= r.wallclock && r.wallclock <= r.upper_bound,
                    "{kind} {hetero:?} {hosts}: machine {:?} outside [{:?}, {:?}]",
                    r.wallclock,
                    r.lower_bound,
                    r.upper_bound
                );
                let s = sim.shuffle_round(&tasks, &mixed_tasks(hosts));
                assert!(monotone(&s.trace), "{kind} {hetero:?} {hosts}: shuffle trace");
                assert!(
                    s.lower_bound <= s.wallclock && s.wallclock <= s.upper_bound,
                    "{kind} {hetero:?} {hosts}: shuffle {:?} outside [{:?}, {:?}]",
                    s.wallclock,
                    s.lower_bound,
                    s.upper_bound
                );
            }
        }
    }
}

/// Textual guarantee behind the tie-breaking contract: nothing under
/// `src/sim/` may iterate a `HashMap`/`HashSet` (randomized order) or
/// read the wall clock (`Instant`/`SystemTime`). Doc-comment mentions
/// are allowed — only code lines count.
#[test]
fn prop_sim_sources_are_hash_and_clock_free() {
    let sources = [
        ("mod.rs", include_str!("../src/sim/mod.rs")),
        ("engine.rs", include_str!("../src/sim/engine.rs")),
        ("host.rs", include_str!("../src/sim/host.rs")),
        ("network.rs", include_str!("../src/sim/network.rs")),
        ("placement.rs", include_str!("../src/sim/placement.rs")),
    ];
    for (name, src) in sources {
        let code: Vec<&str> =
            src.lines().filter(|l| !l.trim_start().starts_with("//")).collect();
        for forbidden in ["HashMap", "HashSet", "Instant", "SystemTime", "thread_rng"] {
            let hit = code.iter().find(|l| l.contains(forbidden));
            assert!(
                hit.is_none(),
                "src/sim/{name} contains `{forbidden}` in code: {:?}",
                hit.unwrap()
            );
        }
    }
}

/// Analytic oracle: 2 racks × 2 hosts (hosts 0,1 in rack 0; 2,3 in rack
/// 1), NIC 800 Mbit/s = 1e8 B/s, compute 100 MB/s = 1e8 B/s at speed
/// 1.0, zero latency, no oversubscription, round-robin placement (task i
/// on host i), host speeds [1.0, 1.0, 0.5, 1.0].
fn oracle_sim() -> ClusterSim {
    let cfg = SimConfig {
        enabled: true,
        network: NetworkKind::Topology,
        racks: 2,
        oversub: 1.0,
        nic_mbps: 800.0,
        compute_mbps: 100.0,
        latency_us: 0.0,
        record_trace: true,
        ..SimConfig::default()
    };
    ClusterSim::with_speeds(&cfg, vec![1.0, 1.0, 0.5, 1.0])
}

/// Machine round, 4 tasks of (work 1e8, out 4e7, 1 attempt), no
/// broadcast. Hand derivation:
///
/// * Compute: hosts 0, 1, 3 run 1e8 B at 1e8 B/s → done at t = 1.0 s.
///   Host 2 runs at speed 0.5 → done at t = 2.0 s (the emergent
///   straggler).
/// * Host 0 is the leader: its output needs no network.
/// * t = 1.0 s: hosts 1 and 3 each start a 4e7 B gather. Both routes
///   end at the leader's ingress link (cap 1e8 B/s), so fair sharing
///   gives each 5e7 B/s → both land at 1.0 + 4e7/5e7 = 1.8 s.
/// * t = 2.0 s: host 2's gather has the ingress link to itself:
///   4e7/1e8 = 0.4 s → lands at **2.4 s**, which is the round.
#[test]
fn prop_machine_round_oracle_exact() {
    let sim = oracle_sim();
    let tasks = vec![TaskSpec::new(100_000_000, 40_000_000, 1); 4];
    let r = sim.machine_round(&tasks, 0);
    assert_eq!(r.wallclock, Duration::from_nanos(2_400_000_000));
    // Conservation around the exact value: the slowest chain (host 2:
    // 2.0 s compute + 0.4 s solo gather is not a single lower-bound
    // term, but its compute alone is) bounds below; the serial sum
    // (1+1+2+1 compute + 3 × 0.4 gathers = 6.2 s) bounds above.
    assert!(r.lower_bound >= Duration::from_nanos(2_000_000_000 - 1_000_000));
    assert!(r.upper_bound <= Duration::from_nanos(6_200_000_000 + 1_000_000));
    assert!(r.lower_bound <= r.wallclock && r.wallclock <= r.upper_bound);
}

/// Same oracle plus a 2e7 B broadcast and a doubled attempt on host 2's
/// task. Hand derivation:
///
/// * Broadcast: hosts 1, 2, 3 each pull 2e7 B from the leader's egress
///   link (cap 1e8 B/s, 3-way fair share ~3.33e7 B/s each) → all gates
///   open at 3 × 2e7 / 1e8 = **0.6 s**. (The leader starts at 0.)
/// * Host 2's task now carries `attempts = 2`: 2 × 1e8 B at 5e7 B/s =
///   4.0 s of compute, starting at 0.6 s → done at 4.6 s.
/// * Its 4e7 B gather then crosses an idle ingress link in 0.4 s
///   (hosts 1 and 3 finished theirs long before) → round = **5.0 s**.
#[test]
fn prop_machine_round_oracle_with_broadcast_and_replay() {
    let sim = oracle_sim();
    let mut tasks = vec![TaskSpec::new(100_000_000, 40_000_000, 1); 4];
    tasks[2].attempts = 2;
    let r = sim.machine_round(&tasks, 20_000_000);
    assert_eq!(r.wallclock, Duration::from_nanos(5_000_000_000));
}

/// Shuffle round under oversubscription 2.0 (rack uplink cap drops to
/// 2 hosts × 1e8 / 2 = 1e8 B/s), all speeds 1.0. Hand derivation:
///
/// * 4 maps of (work 1e8, out 5e7): compute ends at 1.0 s everywhere.
/// * Egress: the 2 flows per rack share their rack uplink (1e8 B/s) at
///   5e7 B/s each → 1.0 s → the shuffle barrier fires at **2.0 s**.
/// * 4 reduces of work 6e7: ingress is symmetric (2 flows per rack
///   downlink at 5e7 B/s each) → 1.2 s → inputs land at 3.2 s.
/// * Reduce compute 6e7 / 1e8 = 0.6 s → round = **3.8 s**.
#[test]
fn prop_shuffle_round_oracle_exact() {
    let cfg = SimConfig {
        enabled: true,
        network: NetworkKind::Topology,
        racks: 2,
        oversub: 2.0,
        nic_mbps: 800.0,
        compute_mbps: 100.0,
        latency_us: 0.0,
        record_trace: true,
        ..SimConfig::default()
    };
    let sim = ClusterSim::with_speeds(&cfg, vec![1.0; 4]);
    let map = vec![TaskSpec::new(100_000_000, 50_000_000, 1); 4];
    let reduce = vec![TaskSpec::new(60_000_000, 0, 1); 4];
    let r = sim.shuffle_round(&map, &reduce);
    assert_eq!(r.wallclock, Duration::from_nanos(3_800_000_000));
    assert!(r.lower_bound <= r.wallclock && r.wallclock <= r.upper_bound);
}

/// Leader round: 1e8 B × 3 attempts on a speed-2.0 leader (2e8 B/s) =
/// 1.5 s of pure compute, no network terms at all.
#[test]
fn prop_leader_round_oracle_exact() {
    let cfg = SimConfig {
        enabled: true,
        nic_mbps: 800.0,
        compute_mbps: 100.0,
        latency_us: 0.0,
        record_trace: true,
        ..SimConfig::default()
    };
    let sim = ClusterSim::with_speeds(&cfg, vec![2.0, 1.0]);
    let r = sim.leader_round(100_000_000, 3);
    assert_eq!(r.wallclock, Duration::from_nanos(1_500_000_000));
}

/// Contention sanity: the same bytes over a *more* constrained fabric
/// can never finish sooner. Flat shared fabric vs an 8× oversubscribed
/// topology, identical tasks and speeds.
#[test]
fn prop_oversubscription_never_speeds_a_round_up() {
    let base = SimConfig {
        enabled: true,
        hetero: Heterogeneity::None,
        record_trace: false,
        ..SimConfig::default()
    };
    let flat = ClusterSim::new(&SimConfig { network: NetworkKind::Shared, ..base.clone() }, 12);
    let tight = ClusterSim::new(
        &SimConfig { network: NetworkKind::Topology, racks: 3, oversub: 8.0, ..base },
        12,
    );
    let tasks = mixed_tasks(24);
    let reduce = mixed_tasks(12);
    assert!(
        tight.machine_round(&tasks, 8192).wallclock >= flat.machine_round(&tasks, 8192).wallclock
    );
    assert!(
        tight.shuffle_round(&tasks, &reduce).wallclock
            >= flat.shuffle_round(&tasks, &reduce).wallclock
    );
}
