//! Property tests for the summary layer's composition contract
//! (seeded sweeps, same style as the other prop_* targets): folding
//! [`Coreset::compose`] over per-machine summaries must give **bit-identical**
//! results under any permutation and any grouping of the summaries — the
//! property that makes the robust pipelines' reduce step immune to shuffle
//! order, thread count, and lineage replay.

use mrcluster::data::DataGenConfig;
use mrcluster::geometry::PointSet;
use mrcluster::runtime::NativeBackend;
use mrcluster::summaries::{Coreset, CoverageSummary, WeightedSet};
use mrcluster::util::rng::Rng;

/// Summaries of the chunks of a contaminated dataset — the exact shape the
/// robust coordinators produce in round 1.
fn machine_summaries(n: usize, machines: usize, tau: usize, seed: u64) -> Vec<CoverageSummary> {
    let data = DataGenConfig {
        n,
        k: 4,
        dim: 3,
        sigma: 0.05,
        alpha: 0.0,
        contamination: 0.03,
        seed,
    }
    .generate();
    data.points
        .chunks(machines)
        .into_iter()
        .enumerate()
        .map(|(m, chunk)| {
            CoverageSummary::build(&chunk, tau.min(chunk.len()), seed ^ m as u64, &NativeBackend)
        })
        .collect()
}

/// Strict bit-level equality: coordinates and weights compared by bit
/// pattern, radius by bit pattern.
fn bit_identical(a: &CoverageSummary, b: &CoverageSummary) -> bool {
    let (ra, rb) = (a.reps(), b.reps());
    ra.len() == rb.len()
        && a.radius().to_bits() == b.radius().to_bits()
        && ra
            .points()
            .flat()
            .iter()
            .zip(rb.points().flat())
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && ra
            .weights()
            .iter()
            .zip(rb.weights())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn fold(summaries: &[CoverageSummary]) -> CoverageSummary {
    summaries
        .iter()
        .cloned()
        .reduce(Coreset::compose)
        .expect("non-empty")
}

#[test]
fn compose_is_permutation_insensitive_bitwise() {
    for seed in 0..8u64 {
        let summaries = machine_summaries(600, 7, 9, 1000 + seed);
        let baseline = fold(&summaries);
        let mut order: Vec<usize> = (0..summaries.len()).collect();
        let mut rng = Rng::new(seed ^ 0x5Eed);
        for _ in 0..6 {
            // Fisher–Yates shuffle of the fold order.
            for i in (1..order.len()).rev() {
                let j = rng.below(i + 1);
                order.swap(i, j);
            }
            let permuted: Vec<CoverageSummary> =
                order.iter().map(|&i| summaries[i].clone()).collect();
            let merged = fold(&permuted);
            assert!(
                bit_identical(&baseline, &merged),
                "seed {seed}: permutation {order:?} changed the merged bytes"
            );
        }
    }
}

#[test]
fn compose_is_grouping_insensitive_bitwise() {
    // Associativity at the byte level: a left fold, a right fold, and a
    // balanced tree over the same summaries must agree exactly — this is
    // what lets the reduce step pre-merge arbitrary subgroups.
    for seed in 0..4u64 {
        let summaries = machine_summaries(500, 6, 8, 2000 + seed);
        let left = fold(&summaries);
        let right = summaries
            .iter()
            .cloned()
            .rev()
            .reduce(|acc, s| Coreset::compose(s, acc))
            .unwrap();
        let mid = summaries.len() / 2;
        let tree = Coreset::compose(fold(&summaries[..mid]), fold(&summaries[mid..]));
        assert!(bit_identical(&left, &right), "seed {seed}: right fold diverged");
        assert!(bit_identical(&left, &tree), "seed {seed}: tree fold diverged");
    }
}

#[test]
fn compose_preserves_weight_and_radius_invariants() {
    for seed in 0..4u64 {
        let summaries = machine_summaries(400, 5, 7, 3000 + seed);
        let merged = fold(&summaries);
        // Total weight is conserved exactly: every weight is an integral
        // count (f64 sums of small integers are exact).
        let total: f64 = summaries.iter().map(Coreset::total_weight).sum();
        assert_eq!(merged.total_weight(), total, "seed {seed}");
        assert_eq!(merged.total_weight(), 400.0, "every point represented");
        // Radius is the max of the parts.
        let want = summaries.iter().map(CoverageSummary::radius).fold(0.0, f64::max);
        assert_eq!(merged.radius().to_bits(), want.to_bits(), "seed {seed}");
        // Canonical form: the merged rep set is sorted.
        assert!(merged.reps().is_canonical(), "seed {seed}");
    }
}

#[test]
fn unit_weighted_set_composes_like_concatenation() {
    // Composing summaries wrapped from raw weighted sets is the canonical
    // multiset union: same entries as concatenating and canonicalizing.
    let a_pts = PointSet::from_flat(1, vec![3.0, 1.0]);
    let b_pts = PointSet::from_flat(1, vec![2.0]);
    let a = CoverageSummary::from_weighted(WeightedSet::unit(a_pts.clone()), 0.5);
    let b = CoverageSummary::from_weighted(WeightedSet::unit(b_pts.clone()), 0.25);
    let ab = Coreset::compose(a, b);
    let mut both = WeightedSet::unit(a_pts);
    both.extend(&WeightedSet::unit(b_pts));
    assert_eq!(ab.reps(), &both.canonicalize());
    assert_eq!(ab.radius(), 0.5);
}
