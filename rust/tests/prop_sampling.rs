//! Property-based tests of Iterative-Sample's invariants (Propositions
//! 2.1/2.2 and the structural guarantees Theorem 3.4's proof relies on).
//!
//! No proptest crate offline — properties are checked over seeded random
//! configuration sweeps (shrinking is traded for a fixed, replayable case
//! list; every failure prints its case tuple).

use mrcluster::data::DataGenConfig;
use mrcluster::runtime::{ComputeBackend, NativeBackend};
use mrcluster::sampling::{iterative_sample, IterativeSampleConfig, SampleConstants};
use mrcluster::util::rng::Rng;

struct Case {
    n: usize,
    k: usize,
    eps: f64,
    alpha: f64,
    seed: u64,
}

fn cases(count: usize, master_seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(master_seed);
    (0..count)
        .map(|_| Case {
            n: 2000 + rng.below(20_000),
            k: 2 + rng.below(20),
            eps: 0.15 + rng.f64() * 0.3,
            alpha: rng.f64() * 1.5,
            seed: rng.next_u64(),
        })
        .collect()
}

fn run_case(
    c: &Case,
    constants: SampleConstants,
) -> (mrcluster::sampling::SampleResult, DataGenConfig) {
    let dc = DataGenConfig {
        n: c.n,
        k: c.k,
        alpha: c.alpha,
        seed: c.seed,
        ..Default::default()
    };
    let data = dc.generate();
    let cfg = IterativeSampleConfig {
        k: c.k,
        epsilon: c.eps,
        constants,
        seed: c.seed ^ 0xF00,
        max_iters: 500,
        ..Default::default()
    };
    (iterative_sample(&data.points, &cfg, &NativeBackend), dc)
}

#[test]
fn prop_sample_indices_valid_and_distinct() {
    for (i, c) in cases(12, 100).iter().enumerate() {
        let (res, _) = run_case(c, SampleConstants::practical());
        let mut idx = res.indices.clone();
        idx.sort_unstable();
        let before = idx.len();
        idx.dedup();
        assert_eq!(idx.len(), before, "case {i}: duplicated indices (n={})", c.n);
        assert!(
            idx.iter().all(|&x| x < c.n),
            "case {i}: out-of-range index"
        );
    }
}

#[test]
fn prop_iterations_bounded() {
    // Proposition 2.1: O(1/eps) iterations. Constant 6 absorbs the w.h.p.
    // slack at these small n.
    for (i, c) in cases(10, 200).iter().enumerate() {
        let (res, _) = run_case(c, SampleConstants::theory());
        let bound = (6.0 / c.eps).ceil() as usize + 2;
        assert!(
            res.iterations <= bound,
            "case {i} (n={}, eps={:.2}): {} iters > {bound}",
            c.n,
            c.eps,
            res.iterations
        );
    }
}

#[test]
fn prop_sample_size_bounded_theory() {
    // Proposition 2.2: |C| = O(k n^eps log n / eps).
    for (i, c) in cases(10, 300).iter().enumerate() {
        let (res, _) = run_case(c, SampleConstants::theory());
        let bound =
            10.0 / c.eps * c.k as f64 * (c.n as f64).powf(c.eps) * (c.n as f64).ln();
        assert!(
            (res.sample.len() as f64) <= bound.min(c.n as f64),
            "case {i} (n={}, k={}, eps={:.2}): |C|={} > {bound:.0}",
            c.n,
            c.k,
            c.eps,
            res.sample.len()
        );
    }
}

#[test]
fn prop_remaining_set_shrinks_monotonically() {
    for (i, c) in cases(8, 400).iter().enumerate() {
        let (res, _) = run_case(c, SampleConstants::practical());
        for w in res.iter_stats.windows(2) {
            assert!(
                w[1].remaining_before <= w[0].remaining_before,
                "case {i}: R grew between iterations"
            );
        }
    }
}

#[test]
fn prop_coverage_every_point_close_to_sample() {
    // The guarantee behind Proposition 3.5/3.8: the sample represents all
    // points — max_x d(x, C) must be within a constant factor of the
    // planted radius (sigma-scale), not the diameter.
    for (i, c) in cases(6, 500).iter().enumerate() {
        let (res, dc) = run_case(c, SampleConstants::theory());
        let data = dc.generate();
        let md = NativeBackend.min_dist(&data.points, &res.sample);
        let worst = md.iter().cloned().fold(0.0f32, f32::max);
        // Points live in clusters of spread sigma=0.1 inside the unit cube;
        // a representative sample leaves no point stranded further than a
        // small multiple of the typical nearest-neighbour scale. sqrt(3) is
        // the cube diameter — we demand 10x better.
        assert!(
            worst < 3f32.sqrt() / 10.0,
            "case {i} (n={}): worst d(x, C) = {worst}",
            c.n
        );
    }
}

#[test]
fn prop_seed_determinism() {
    for (i, c) in cases(5, 600).iter().enumerate() {
        let (a, _) = run_case(c, SampleConstants::practical());
        let (b, _) = run_case(c, SampleConstants::practical());
        assert_eq!(a.indices, b.indices, "case {i}: nondeterministic");
    }
}

#[test]
fn prop_practical_no_bigger_than_theory() {
    // The practical profile exists to shrink samples; verify it does.
    let mut practical_total = 0usize;
    let mut theory_total = 0usize;
    for c in cases(6, 700) {
        let (p, _) = run_case(&c, SampleConstants::practical());
        let (t, _) = run_case(&c, SampleConstants::theory());
        practical_total += p.sample.len();
        theory_total += t.sample.len();
    }
    assert!(
        practical_total < theory_total,
        "practical {practical_total} >= theory {theory_total}"
    );
}
