//! E17 arena properties, end to end:
//!
//! 1. **Table-driven approximation wall.** Every registered pipeline —
//!    including the rival Mazzetto and Ceccarello coordinators — is held
//!    to its documented approximation envelope against the brute-force
//!    oracle on a 48-point instance, across `l2sq`, `l1`, and
//!    `chebyshev`, through the shared `tests/common` arena table instead
//!    of per-pipeline test copies.
//! 2. **Executor-independent replay.** Every arena cell (dataset regime x
//!    algorithm) is bit-identical across the pooled and sequential
//!    executors and across repeated runs — the engine's determinism
//!    contract extended to the full shootout matrix.
//! 3. **Lossy-regime recovery.** Both rival coordinators reproduce their
//!    fault-free outputs bit-for-bit under injected failures
//!    (`fail_prob = 0.05`, the scenario harness's lossy regime).

mod common;

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm_with, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::geometry::{MetricKind, PointSet};
use mrcluster::runtime::NativeBackend;
use mrcluster::util::rng::Rng;

/// Three tight 2-D blobs, 16 points each: small enough for the exact
/// combination oracle, separated widely enough that the envelopes hold by
/// margin (the `prop_metrics.rs` tri-blob construction at n = 48).
fn tri_blobs_48() -> PointSet {
    let centers = [[1.0f32, 0.2], [0.2, 1.0], [1.5, 1.5]];
    let mut rng = Rng::new(0xB10B);
    let mut p = PointSet::with_capacity(2, 48);
    for c in &centers {
        for _ in 0..16 {
            p.push(&[
                c[0] + (rng.f32() - 0.5) * 0.2,
                c[1] + (rng.f32() - 0.5) * 0.2,
            ]);
        }
    }
    p
}

/// The arena's adversarial regime (mirrors `tests/scenario/datasets.rs`):
/// a near-duplicate mass, a collinear filament, extreme outliers.
fn adversarial(n: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed ^ 0xAD5A);
    let mut flat = Vec::with_capacity(n * 3);
    let heavy = n * 7 / 10;
    let line = n * 2 / 10;
    for _ in 0..heavy {
        for _ in 0..3 {
            flat.push(0.5 + (rng.f32() - 0.5) * 1e-4);
        }
    }
    for i in 0..line {
        let t = i as f32 / line.max(1) as f32;
        let c = t * 2.0 - 1.0;
        flat.extend_from_slice(&[c, c, c]);
    }
    let rest = n - heavy - line;
    for i in 0..rest {
        let s = (i + 1) as f32;
        flat.extend_from_slice(&[50.0 * s, -30.0 * s, 80.0]);
    }
    PointSet::from_flat(3, flat)
}

fn arena_cfg(k: usize, machines: usize, seed: u64) -> ClusterConfig {
    ClusterConfig {
        k,
        epsilon: 0.2,
        machines,
        seed,
        ls_max_swaps: 40,
        ..Default::default()
    }
}

#[test]
fn every_pipeline_beats_its_documented_envelope_on_the_oracle() {
    let points = tri_blobs_48();
    let cfg = arena_cfg(3, 3, 81);
    for metric in [MetricKind::L2Sq, MetricKind::L1, MetricKind::Chebyshev] {
        common::assert_arena_bounds(&points, 3, metric, &cfg);
    }
}

#[test]
fn every_arena_cell_replays_identically_across_executors_and_runs() {
    let n = 300;
    let seed = 82u64;
    let datasets: Vec<(&str, PointSet, usize)> = vec![
        (
            "clustered",
            DataGenConfig { n, k: 4, dim: 3, sigma: 0.05, seed, ..Default::default() }
                .generate()
                .points,
            0,
        ),
        (
            "skewed",
            DataGenConfig {
                n,
                k: 4,
                dim: 3,
                sigma: 0.05,
                alpha: 1.2,
                seed: seed ^ 1,
                ..Default::default()
            }
            .generate()
            .points,
            0,
        ),
        ("adversarial", adversarial(n, seed ^ 2), n / 10),
    ];
    for (name, points, z) in &datasets {
        for algo in Algorithm::all() {
            let pooled = ClusterConfig {
                z: *z,
                parallel: true,
                ..arena_cfg(4, 6, seed)
            };
            let sequential = ClusterConfig {
                parallel: false,
                threads: 1,
                ..pooled.clone()
            };
            let a = run_algorithm_with(algo, points, &pooled, &NativeBackend).unwrap();
            let b = run_algorithm_with(algo, points, &pooled, &NativeBackend).unwrap();
            let c = run_algorithm_with(algo, points, &sequential, &NativeBackend).unwrap();
            let d = run_algorithm_with(algo, points, &sequential, &NativeBackend).unwrap();
            for (tag, other) in [("pooled repeat", &b), ("sequential", &c), ("sequential repeat", &d)]
            {
                assert_eq!(
                    a.centers,
                    other.centers,
                    "{name}/{}: {tag} centers diverged",
                    algo.name()
                );
                assert_eq!(
                    a.cost.median.to_bits(),
                    other.cost.median.to_bits(),
                    "{name}/{}: {tag} cost diverged",
                    algo.name()
                );
                assert_eq!(a.rounds, other.rounds, "{name}/{}: {tag}", algo.name());
            }
        }
    }
}

#[test]
fn rival_coordinators_recover_bit_identically_under_lossy_faults() {
    let gen = DataGenConfig {
        n: 800,
        k: 4,
        dim: 3,
        sigma: 0.05,
        contamination: 0.01,
        seed: 83,
        ..Default::default()
    };
    let data = gen.generate();
    let z = data.n_outliers();
    for algo in [Algorithm::MazzettoKMedian, Algorithm::CeccarelloKCenter] {
        let clean_cfg = ClusterConfig {
            z,
            fail_prob: 0.0,
            straggler_prob: 0.0,
            ..arena_cfg(4, 6, 83)
        };
        let lossy_cfg = ClusterConfig {
            fail_prob: 0.05,
            ..clean_cfg.clone()
        };
        let clean = run_algorithm_with(algo, &data.points, &clean_cfg, &NativeBackend).unwrap();
        let lossy = run_algorithm_with(algo, &data.points, &lossy_cfg, &NativeBackend).unwrap();
        assert_eq!(
            clean.centers,
            lossy.centers,
            "{}: lossy recovery changed the centers",
            algo.name()
        );
        assert_eq!(
            clean.cost.median.to_bits(),
            lossy.cost.median.to_bits(),
            "{}: lossy recovery changed the cost",
            algo.name()
        );
        assert_eq!(clean.rounds, lossy.rounds, "{}", algo.name());
    }
}
