//! Metric-space properties, end to end:
//!
//! 1. **Generic vs specialized bit-identity.** The generic metric kernels
//!    instantiated at `l2sq` are bit-identical to the specialized
//!    squared-Euclidean fast path — not just at the kernel level (covered
//!    by `runtime::native` unit tests) but through *entire coordinator
//!    pipelines*: a backend that forces every call through the generic
//!    path must reproduce the fast path's centers and costs exactly. This
//!    is the license for dispatching `metric = "l2sq"` to the legacy code,
//!    which in turn is what keeps the whole scenario matrix bit-identical
//!    to its pre-metric outputs.
//! 2. **Metric invariants.** Identity, symmetry, and the triangle
//!    inequality hold for every registered [`MetricKind`] on randomized
//!    higher-dimensional inputs (the paper's analysis assumes exactly
//!    these properties and nothing more).
//! 3. **General metrics end to end.** Every registered coordinator —
//!    including the robust pipelines — runs under `l1`, `cosine`, and
//!    `chebyshev` on tiny instances, deterministically, with costs bounded
//!    against the exact brute-force optimum *under that metric*.

#[path = "common/mod.rs"]
mod common;

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm_with, Algorithm};
use mrcluster::geometry::{MetricKind, PointSet};
use mrcluster::metrics::{kcenter_cost_metric, kmedian_cost_metric};
use mrcluster::runtime::native::{assign_metric_generic, lloyd_step_metric_generic};
use mrcluster::runtime::{
    weights_from_assign, AssignOut, ComputeBackend, LloydStepOut, NativeBackend,
};
use mrcluster::util::rng::Rng;

/// A backend that routes every kernel call through the generic metric path
/// at `l2sq` — no specialized fast-path code ever runs.
struct ForceGenericL2Sq;

impl ComputeBackend for ForceGenericL2Sq {
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut {
        assign_metric_generic(points, centers, MetricKind::L2Sq)
    }

    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut {
        lloyd_step_metric_generic(points, centers, MetricKind::L2Sq)
    }

    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64) {
        let a = self.assign(points, centers);
        weights_from_assign(&a, centers.len())
    }

    fn name(&self) -> &'static str {
        "generic-l2sq"
    }
}

fn tiny_cfg(k: usize, machines: usize, seed: u64) -> ClusterConfig {
    ClusterConfig {
        k,
        epsilon: 0.2,
        machines,
        seed,
        ls_max_swaps: 40,
        ..Default::default()
    }
}

#[test]
fn generic_path_reproduces_fast_path_through_whole_pipelines() {
    let data = mrcluster::data::DataGenConfig {
        n: 1500,
        k: 4,
        dim: 3,
        sigma: 0.05,
        alpha: 0.0,
        contamination: 0.0,
        seed: 0xBEEF,
    }
    .generate();
    let cfg = tiny_cfg(4, 4, 11);
    for algo in [
        Algorithm::ParallelLloyd,
        Algorithm::DivideLloyd,
        Algorithm::SamplingLloyd,
        Algorithm::MrKCenter,
        Algorithm::RobustKCenter,
        Algorithm::CoresetKMedian,
    ] {
        let fast = run_algorithm_with(algo, &data.points, &cfg, &NativeBackend).unwrap();
        let gen = run_algorithm_with(algo, &data.points, &cfg, &ForceGenericL2Sq).unwrap();
        assert_eq!(fast.centers, gen.centers, "{}: centers diverged", algo.name());
        assert_eq!(
            fast.cost.median.to_bits(),
            gen.cost.median.to_bits(),
            "{}: cost diverged",
            algo.name()
        );
        assert_eq!(fast.rounds, gen.rounds, "{}", algo.name());
    }
}

#[test]
fn metric_invariants_hold_randomized_high_dim() {
    let mut rng = Rng::new(0xD1CE);
    for d in [2usize, 5, 9] {
        for _ in 0..100 {
            // Offset away from the origin so cosine never sees a zero row.
            let p: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..d).map(|_| rng.f32() * 4.0 + 0.5).collect())
                .collect();
            for m in MetricKind::ALL {
                assert!(m.dist(&p[0], &p[0]).abs() < 1e-5, "{m}: identity");
                let ab = m.dist(&p[0], &p[1]);
                let ba = m.dist(&p[1], &p[0]);
                assert!((ab - ba).abs() < 1e-5, "{m}: symmetry");
                let bc = m.dist(&p[1], &p[2]);
                let ac = m.dist(&p[0], &p[2]);
                assert!(ac <= ab + bc + 1e-4, "{m}: triangle (d={d})");
            }
        }
    }
}

/// Three tight blobs, separated both in Euclidean position and in angle
/// from the origin, away from the axes: every registered metric sees the
/// same 3-cluster structure (with different numbers), and no row is the
/// zero vector.
fn tri_blobs() -> PointSet {
    let centers = [[1.0f32, 0.2], [0.2, 1.0], [1.5, 1.5]];
    let mut rng = Rng::new(0xB10B);
    let mut p = PointSet::with_capacity(2, 42);
    for c in &centers {
        for _ in 0..14 {
            // Jitter wide enough that OPT is a solid fraction of the
            // blob separation: the oracle factors then hold even through
            // an unlucky-seeding local optimum, keeping the test
            // deterministic-by-margin rather than seed-lottery.
            p.push(&[
                c[0] + (rng.f32() - 0.5) * 0.2,
                c[1] + (rng.f32() - 0.5) * 0.2,
            ]);
        }
    }
    p
}

#[test]
fn every_coordinator_runs_under_general_metrics_with_oracle_bounds() {
    let points = tri_blobs();
    let k = 3;
    let kmedian_algos = [
        Algorithm::ParallelLloyd,
        Algorithm::DivideLloyd,
        Algorithm::DivideLocalSearch,
        Algorithm::SamplingLloyd,
        Algorithm::SamplingLocalSearch,
        Algorithm::LocalSearch,
        Algorithm::StreamingGuha,
        Algorithm::CoresetKMedian,
    ];
    let kcenter_algos = [Algorithm::MrKCenter, Algorithm::RobustKCenter];

    for metric in [MetricKind::L1, MetricKind::Cosine, MetricKind::Chebyshev] {
        let opt_median = common::exact_kmedian_metric(&points, k, metric);
        let opt_center = common::exact_kcenter_metric(&points, k, metric);
        assert!(opt_median.is_finite() && opt_median > 0.0, "{metric}");
        assert!(opt_center.is_finite() && opt_center > 0.0, "{metric}");

        let cfg = ClusterConfig {
            metric,
            ..tiny_cfg(k, 3, 21)
        };
        for algo in kmedian_algos {
            let out = run_algorithm_with(algo, &points, &cfg, &NativeBackend).unwrap();
            let replay = run_algorithm_with(algo, &points, &cfg, &NativeBackend).unwrap();
            assert_eq!(
                out.centers,
                replay.centers,
                "{} under {metric} is nondeterministic",
                algo.name()
            );
            assert_eq!(out.centers.len(), k, "{} under {metric}", algo.name());
            let cost = kmedian_cost_metric(&points, &out.centers, metric);
            // 15x is far above any sane run on three tight blobs (a
            // one-cluster collapse lands near 30x here) while leaving
            // slack over the constants of the weaker pipelines.
            assert!(
                cost <= opt_median * 15.0 + 1e-6,
                "{} under {metric}: cost {cost} vs exact OPT {opt_median}",
                algo.name()
            );
        }
        for algo in kcenter_algos {
            let out = run_algorithm_with(algo, &points, &cfg, &NativeBackend).unwrap();
            let replay = run_algorithm_with(algo, &points, &cfg, &NativeBackend).unwrap();
            assert_eq!(
                out.centers,
                replay.centers,
                "{} under {metric} is nondeterministic",
                algo.name()
            );
            let radius = kcenter_cost_metric(&points, &out.centers, metric);
            // MapReduce-kCenter is a 10-approximation (Thm 3.7); the
            // robust pipeline adds the summary radius on top — 12x covers
            // both with slack on these tiny instances.
            assert!(
                radius <= opt_center * 12.0 + 1e-6,
                "{} under {metric}: radius {radius} vs exact OPT {opt_center}",
                algo.name()
            );
        }
    }
}

#[test]
fn robust_pipeline_drops_metric_outliers_under_each_metric() {
    // The tri-blob instance plus two unambiguous far outliers: with z = 2
    // the robust pipeline's z-dropped radius must stay within a constant
    // of the exact best-z-drop optimum under the active metric.
    let mut points = tri_blobs();
    points.push(&[30.0, -20.0]);
    points.push(&[-25.0, 35.0]);
    let z = 2;
    for metric in [MetricKind::L1, MetricKind::Cosine, MetricKind::Chebyshev] {
        let opt = common::exact_kcenter_outliers_metric(&points, 3, z, metric);
        assert!(opt.is_finite() && opt > 0.0, "{metric}");
        let mut cfg = ClusterConfig {
            metric,
            ..tiny_cfg(3, 3, 31)
        };
        cfg.z = z;
        let out =
            run_algorithm_with(Algorithm::RobustKCenter, &points, &cfg, &NativeBackend).unwrap();
        let cost = mrcluster::metrics::kcenter_cost_with_outliers_metric(
            &points,
            &out.centers,
            z,
            metric,
        );
        assert!(
            cost <= opt * 12.0 + 1e-6,
            "{metric}: robust z-dropped cost {cost} vs exact OPT {opt}"
        );
    }
}

#[test]
fn explicit_l2sq_config_matches_default_config_bitwise() {
    // The config plumbing itself must be inert: `metric = "l2sq"` set
    // explicitly (as the TOML/CLI path does) reproduces the default
    // config's run bit-for-bit.
    let data = mrcluster::data::DataGenConfig {
        n: 1200,
        k: 4,
        dim: 3,
        sigma: 0.05,
        alpha: 0.0,
        contamination: 0.0,
        seed: 0xFADE,
    }
    .generate();
    let default_cfg = tiny_cfg(4, 4, 17);
    let mut explicit = mrcluster::config::AppConfig::default();
    explicit
        .apply("cluster", "metric", "l2sq")
        .expect("l2sq parses");
    assert_eq!(explicit.cluster.metric, default_cfg.metric);
    let explicit_cfg = ClusterConfig {
        metric: explicit.cluster.metric,
        ..default_cfg.clone()
    };
    for algo in [Algorithm::SamplingLloyd, Algorithm::MrKCenter] {
        let a = run_algorithm_with(algo, &data.points, &default_cfg, &NativeBackend).unwrap();
        let b = run_algorithm_with(algo, &data.points, &explicit_cfg, &NativeBackend).unwrap();
        assert_eq!(a.centers, b.centers, "{}", algo.name());
        assert_eq!(a.cost.median.to_bits(), b.cost.median.to_bits(), "{}", algo.name());
    }
}
