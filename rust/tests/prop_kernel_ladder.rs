//! Kernel-ladder properties (ARCHITECTURE.md §Kernel ladder), end to end:
//!
//! 1. **GEMM ε-equivalence.** The norm-expanded GEMM-form assign agrees
//!    with a scalar per-point oracle on every argmin except inside an
//!    exact-tie neighborhood (relative best/second gap ≤ 1e-4), across
//!    dimensions and both Euclidean metrics, and its surrogate distances
//!    are ε-close.
//! 2. **Non-Euclidean fall-through.** A GEMM-configured backend serves
//!    `l1`/`cosine`/`chebyshev` through the *same* generic kernels as the
//!    default backend — bit-for-bit, not approximately.
//! 3. **Strict identity on separated data.** Away from ties (any real
//!    clustering geometry), GEMM assignments are *identical* to the exact
//!    path, and the `(Exact, F64)` fast backend reproduces
//!    [`NativeBackend`] bit-for-bit — the "fast path off" contract.
//! 4. **f32 ε-equivalence.** The f32 Lloyd reduction keeps counts exact
//!    and sums/costs within float noise on well-separated data.
//! 5. **Hamerly identity across the parallel threshold.** The pruned
//!    Lloyd is bit-identical to the unpruned run at `n > PAR_MIN`, where
//!    the accumulation takes the pooled multi-block path.
//! 6. **Opt-in routing.** `make_backend` returns the exact backend for
//!    the default config and the fast backend exactly when a ladder knob
//!    is set.

use mrcluster::algorithms::lloyd::{lloyd, LloydConfig, PruneKind};
use mrcluster::config::ClusterConfig;
use mrcluster::experiments::make_backend;
use mrcluster::geometry::{MetricKind, PointSet};
use mrcluster::runtime::native::PAR_MIN;
use mrcluster::runtime::{AssignPath, ComputeBackend, FastNativeBackend, NativeBackend, Precision};
use mrcluster::util::rng::Rng;

fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed);
    PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
}

/// Two well-separated blobs in `d` dimensions (no near-ties anywhere).
fn blobs(n_each: usize, d: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed);
    let mut p = PointSet::with_capacity(d, n_each * 2);
    let mut row = vec![0.0f32; d];
    for b in 0..2 {
        let off = b as f32 * 10.0;
        for _ in 0..n_each {
            for v in row.iter_mut() {
                *v = off + rng.f32() * 0.1;
            }
            p.push(&row);
        }
    }
    p
}

/// Scalar per-point oracle: (argmin, best surrogate, second surrogate)
/// under strict-`<` first-index-wins scanning — the kernel tie rule.
fn oracle(row: &[f32], centers: &PointSet, metric: MetricKind) -> (usize, f32, f32) {
    let (mut bi, mut best, mut second) = (0usize, f32::INFINITY, f32::INFINITY);
    for c in 0..centers.len() {
        let s = metric.surrogate(row, centers.row(c));
        if s < best {
            second = best;
            best = s;
            bi = c;
        } else if s < second {
            second = s;
        }
    }
    (bi, best, second)
}

const GEMM: FastNativeBackend = FastNativeBackend {
    assign_path: AssignPath::Gemm,
    precision: Precision::F64,
};

#[test]
fn gemm_matches_scalar_oracle_across_dims_and_euclidean_metrics() {
    for metric in [MetricKind::L2Sq, MetricKind::L2] {
        for d in [1usize, 2, 3, 5, 8, 16] {
            let p = random_ps(3000, d, 100 + d as u64);
            let c = random_ps(19, d, 200 + d as u64);
            let out = GEMM.assign_metric(&p, &c, metric);
            for i in 0..p.len() {
                let (bi, best, second) = oracle(p.row(i), &c, metric);
                if out.idx[i] as usize != bi {
                    // ε-equivalence: disagreement is legal only at near-ties.
                    let gap = (second - best) / best.max(1e-12);
                    assert!(
                        gap <= 1e-4,
                        "{metric} d={d} point {i}: gemm {} vs oracle {bi}, gap {gap:e}",
                        out.idx[i]
                    );
                }
                // GEMM cancellation error is absolute in the norm scale
                // (~d·eps), so bound relative to max(best, 1): tiny true
                // distances legitimately carry norm-sized rounding.
                let rel = (out.sqdist[i] - best).abs() / best.max(1.0);
                assert!(rel < 1e-3, "{metric} d={d} point {i}: surrogate off by {rel:e}");
            }
        }
    }
}

#[test]
fn gemm_backend_serves_non_euclidean_metrics_bitwise() {
    let p = random_ps(2000, 4, 7);
    let c = random_ps(11, 4, 8);
    for metric in [MetricKind::L1, MetricKind::Chebyshev, MetricKind::Cosine] {
        let fast = GEMM.assign_metric(&p, &c, metric);
        let exact = NativeBackend.assign_metric(&p, &c, metric);
        assert_eq!(fast.idx, exact.idx, "{metric}");
        let same_bits = fast
            .sqdist
            .iter()
            .zip(&exact.sqdist)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same_bits, "{metric}: non-Euclidean path must not change at all");
    }
}

#[test]
fn gemm_identical_on_separated_data_and_exact_knobs_reproduce_native() {
    let p = blobs(1500, 3, 21);
    let c = random_ps(6, 3, 22);
    let exact = NativeBackend.assign(&p, &c);
    assert_eq!(GEMM.assign(&p, &c).idx, exact.idx);

    // (Exact, F64) is NativeBackend, bit for bit.
    let off = FastNativeBackend {
        assign_path: AssignPath::Exact,
        precision: Precision::F64,
    };
    let a = off.assign(&p, &c);
    assert_eq!(a.idx, exact.idx);
    assert_eq!(a.sqdist, exact.sqdist);
    let s1 = off.lloyd_step(&p, &c);
    let s2 = NativeBackend.lloyd_step(&p, &c);
    assert_eq!(s1.sums, s2.sums);
    assert_eq!(s1.counts, s2.counts);
    assert_eq!(s1.cost_median.to_bits(), s2.cost_median.to_bits());
    assert_eq!(s1.cost_means.to_bits(), s2.cost_means.to_bits());
}

#[test]
fn f32_step_keeps_counts_exact_and_sums_within_noise() {
    let p = blobs(4000, 3, 31);
    let c = random_ps(5, 3, 32);
    let f32b = FastNativeBackend {
        assign_path: AssignPath::Exact,
        precision: Precision::F32,
    };
    let exact = NativeBackend.lloyd_step(&p, &c);
    let fast = f32b.lloyd_step(&p, &c);
    assert_eq!(fast.counts, exact.counts, "counts are whole numbers — exact");
    for (a, b) in fast.sums.iter().zip(&exact.sums) {
        let rel = (a - b).abs() / b.abs().max(1.0);
        assert!(rel < 1e-4, "sum {a} vs {b}");
    }
    let rel = (fast.cost_median - exact.cost_median).abs() / exact.cost_median.max(1.0);
    assert!(rel < 1e-4, "cost {} vs {}", fast.cost_median, exact.cost_median);
}

#[test]
fn hamerly_bit_identical_above_parallel_threshold() {
    // Cross PAR_MIN so the pruned path's accumulation exercises the pooled
    // multi-block merge, not just the inline path the unit tests cover.
    let n_each = PAR_MIN / 2 + 600;
    let p = blobs(n_each, 2, 41);
    assert!(p.len() > PAR_MIN);
    let run = |prune| {
        lloyd(
            &p,
            None,
            &LloydConfig {
                k: 4,
                max_iters: 3,
                tol: 0.0,
                prune,
                seed: 5,
                ..Default::default()
            },
            &NativeBackend,
        )
    };
    let a = run(PruneKind::None);
    let b = run(PruneKind::Hamerly);
    assert_eq!(a.iters, b.iters);
    assert_eq!(a.centers.flat(), b.centers.flat());
    assert_eq!(a.final_counts, b.final_counts);
    let hist_bits: Vec<u64> = a.history.iter().map(|h| h.to_bits()).collect();
    let hist_bits_b: Vec<u64> = b.history.iter().map(|h| h.to_bits()).collect();
    assert_eq!(hist_bits, hist_bits_b);
    assert_eq!(a.cost_median.to_bits(), b.cost_median.to_bits());
    let stats = b.prune.expect("pruned path must report stats");
    assert!(a.prune.is_none());
    assert!(stats.evaluated < stats.possible, "{stats:?}");
}

#[test]
fn make_backend_routes_ladder_knobs() {
    let base = ClusterConfig::default();
    assert_eq!(make_backend(&base).name(), "native");
    assert_eq!(
        make_backend(&ClusterConfig {
            kernel: AssignPath::Gemm,
            ..base.clone()
        })
        .name(),
        "native+gemm"
    );
    assert_eq!(
        make_backend(&ClusterConfig {
            precision: Precision::F32,
            ..base.clone()
        })
        .name(),
        "native+f32"
    );
    assert_eq!(
        make_backend(&ClusterConfig {
            kernel: AssignPath::Gemm,
            precision: Precision::F32,
            ..base
        })
        .name(),
        "native+gemm+f32"
    );
}
