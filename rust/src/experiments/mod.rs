//! Experiment drivers — one per paper table/figure (see EXPERIMENTS.md).
//!
//! Each driver generates the paper's workload, runs the paper's algorithm
//! set, and returns a [`FigureReport`] that renders the same rows the paper
//! prints (costs normalized to Parallel-Lloyd; times in seconds). The CLI
//! (`mrcluster fig1 …`) and the bench harness (`cargo bench`) both call
//! these.

use crate::config::ClusterConfig;
use crate::coordinator::{run_algorithm_store_with, run_algorithm_with, Algorithm};
use crate::data::DataGenConfig;
use crate::geometry::PointStore;
use crate::metrics::report::{FigureReport, RunRecord};
use crate::runtime::ComputeBackend;
use crate::sim::{Heterogeneity, NetworkKind, Placement, SimConfig};
use anyhow::Result;

pub use crate::coordinator::driver::make_backend;

/// Shared experiment parameters (the paper's §4.2 setting).
#[derive(Clone, Debug)]
pub struct ExperimentParams {
    /// Number of centers / planted clusters.
    pub k: usize,
    /// Point spread around the planted centers.
    pub sigma: f64,
    /// Zipf skew of cluster sizes.
    pub alpha: f64,
    /// Fraction of points replaced by far outliers (E12; 0 elsewhere).
    pub contamination: f64,
    /// Base PRNG seed (per-repetition seeds derive from it).
    pub seed: u64,
    /// Repetitions averaged per cell (paper: 3).
    pub repeats: usize,
    /// The cluster/driver configuration shared by every cell.
    pub cluster: ClusterConfig,
}

impl Default for ExperimentParams {
    fn default() -> Self {
        ExperimentParams {
            k: 25,
            sigma: 0.1,
            alpha: 0.0,
            contamination: 0.0,
            seed: 42,
            repeats: 1,
            cluster: ClusterConfig::default(),
        }
    }
}

impl ExperimentParams {
    fn data_config(&self, n: usize, rep: usize) -> DataGenConfig {
        DataGenConfig {
            n,
            k: self.k,
            dim: 3,
            sigma: self.sigma,
            alpha: self.alpha,
            contamination: self.contamination,
            seed: self.seed + rep as u64 * 1000,
        }
    }

    fn cluster_config(&self, rep: usize) -> ClusterConfig {
        ClusterConfig {
            k: self.k,
            seed: self.seed + rep as u64 * 7919,
            ..self.cluster.clone()
        }
    }
}

/// Run one (algorithm, n) cell, averaging `repeats` runs.
pub fn run_cell(
    params: &ExperimentParams,
    algo: Algorithm,
    n: usize,
    backend: &dyn ComputeBackend,
) -> Result<RunRecord> {
    let mut cost = 0.0f64;
    let mut sim = std::time::Duration::ZERO;
    let mut wall = std::time::Duration::ZERO;
    let mut rounds = 0usize;
    for rep in 0..params.repeats.max(1) {
        let data = params.data_config(n, rep).generate();
        let cfg = params.cluster_config(rep);
        let out = run_algorithm_with(algo, &data.points, &cfg, backend)?;
        cost += out.cost_median;
        sim += out.sim_time;
        wall += out.wall_time;
        rounds = rounds.max(out.rounds);
        log::info!(
            "{} n={} rep={}: cost {:.2}, sim {:.3}s, rounds {}, reduced {:?}",
            algo.name(),
            n,
            rep,
            out.cost_median,
            out.sim_time.as_secs_f64(),
            out.rounds,
            out.reduced_size
        );
    }
    let reps = params.repeats.max(1) as u32;
    Ok(RunRecord {
        algo: algo.name().to_string(),
        n,
        cost_median: cost / reps as f64,
        sim_time: sim / reps,
        wall_time: wall / reps,
        rounds,
    })
}

/// E1 — Figure 1: all six algorithms over moderate n.
///
/// `ns` defaults to the paper's sweep scaled to what the host can run;
/// LocalSearch only runs while `n <= ls_cap` (the paper stops at 40k).
pub fn figure1(
    params: &ExperimentParams,
    ns: &[usize],
    ls_cap: usize,
    backend: &dyn ComputeBackend,
) -> Result<FigureReport> {
    let mut report = FigureReport::default();
    for &n in ns {
        for algo in Algorithm::figure1() {
            if algo == Algorithm::LocalSearch && n > ls_cap {
                continue; // the paper's N/A cells
            }
            report.add(run_cell(params, algo, n, backend)?);
        }
    }
    Ok(report)
}

/// E2 — Figure 2: the scalable subset over large n.
pub fn figure2(
    params: &ExperimentParams,
    ns: &[usize],
    backend: &dyn ComputeBackend,
) -> Result<FigureReport> {
    let mut report = FigureReport::default();
    for &n in ns {
        for algo in Algorithm::figure2() {
            report.add(run_cell(params, algo, n, backend)?);
        }
    }
    Ok(report)
}

/// E3 — k-center: MapReduce-kCenter vs full-data Gonzalez; returns
/// (sampled radius, full radius) per n.
pub fn kcenter_compare(
    params: &ExperimentParams,
    ns: &[usize],
    backend: &dyn ComputeBackend,
) -> Result<Vec<(usize, f64, f64)>> {
    let mut rows = Vec::new();
    for &n in ns {
        let data = params.data_config(n, 0).generate();
        let cfg = params.cluster_config(0);
        let out = run_algorithm_with(Algorithm::MrKCenter, &data.points, &cfg, backend)?;
        let mut rng = crate::util::rng::Rng::new(params.seed ^ 0xF00D);
        // Reference in the same metric as the pipeline, or the columns
        // would compare radii from different geometries.
        let full = crate::algorithms::gonzalez::gonzalez_metric(
            &data.points,
            params.k,
            &mut rng,
            cfg.metric,
        );
        rows.push((n, out.cost.center, full.radius));
    }
    Ok(rows)
}

/// E4 — Iterative-Sample statistics across n and ε (Propositions 2.1/2.2).
pub struct SampleStatsRow {
    /// Input size of this row.
    pub n: usize,
    /// Iterative-Sample ε of this row.
    pub epsilon: f64,
    /// While-loop iterations the sampler ran.
    pub iterations: usize,
    /// Final sample size |C|.
    pub sample_size: usize,
    /// The proposition's size bound for these parameters.
    pub bound: f64,
}

/// Run the E4 sweep: sampler statistics for every (n, ε) pair.
pub fn sample_stats(
    params: &ExperimentParams,
    ns: &[usize],
    epsilons: &[f64],
) -> Result<Vec<SampleStatsRow>> {
    use crate::sampling::{iterative_sample, IterativeSampleConfig};
    let backend = crate::runtime::NativeBackend;
    let mut rows = Vec::new();
    for &n in ns {
        for &eps in epsilons {
            let data = params.data_config(n, 0).generate();
            let cfg = IterativeSampleConfig {
                k: params.k,
                epsilon: eps,
                constants: params.cluster.profile.constants(),
                metric: params.cluster.metric,
                seed: params.seed,
                max_iters: 200,
            };
            let res = iterative_sample(&data.points, &cfg, &backend);
            let bound =
                cfg.constants.threshold(n, params.k, eps) as f64 * 2.0; // |C| <= 2*threshold-ish
            rows.push(SampleStatsRow {
                n,
                epsilon: eps,
                iterations: res.iterations,
                sample_size: res.sample.len(),
                bound,
            });
        }
    }
    Ok(rows)
}

/// E9 — the conclusion's k-means claim ("our analysis also gives a
/// MapReduce algorithm ... for the k-means problem"): run Sampling-Lloyd
/// and Parallel-Lloyd and compare the *k-means* objective (Σ d²) ratio.
pub fn kmeans_check(
    params: &ExperimentParams,
    n: usize,
    backend: &dyn ComputeBackend,
) -> Result<(f64, f64)> {
    let data = params.data_config(n, 0).generate();
    let cfg = params.cluster_config(0);
    let base = run_algorithm_with(Algorithm::ParallelLloyd, &data.points, &cfg, backend)?;
    let samp = run_algorithm_with(Algorithm::SamplingLloyd, &data.points, &cfg, backend)?;
    Ok((samp.cost.means / base.cost.means, samp.cost.median / base.cost.median))
}

/// E10 — streaming baseline (Guha et al. [20]) vs the paper's sampling
/// algorithm: cost ratio + timing per n. Returns (n, streaming record,
/// sampling record) rows in a FigureReport.
pub fn streaming_compare(
    params: &ExperimentParams,
    ns: &[usize],
    backend: &dyn ComputeBackend,
) -> Result<FigureReport> {
    let mut report = FigureReport::default();
    for &n in ns {
        for algo in [
            Algorithm::ParallelLloyd,
            Algorithm::SamplingLloyd,
            Algorithm::StreamingGuha,
        ] {
            report.add(run_cell(params, algo, n, backend)?);
        }
    }
    Ok(report)
}

/// One row of the E11 fault-tolerance sweep.
pub struct FaultSweepRow {
    /// Algorithm display name.
    pub algo: String,
    /// Injected per-attempt failure probability of this row.
    pub fail_prob: f64,
    /// Injected straggler probability of this row.
    pub straggler_prob: f64,
    /// Centers and cost exactly equal the fault-free run's (the recovery
    /// layer's determinism contract).
    pub bit_identical: bool,
    /// Lineage replays the run performed.
    pub replays: usize,
    /// Bytes re-materialized by those replays.
    pub recomputed_bytes: usize,
    /// Speculative backups that beat their straggling original.
    pub speculative_wins: usize,
    /// k-median objective of the recovered run.
    pub cost_median: f64,
    /// Simulated time including the fault model's charges.
    pub sim_time: std::time::Duration,
}

/// E11 — fault tolerance: run the paper's pipelines under fault/straggler
/// regimes (`(fail_prob, straggler_prob)` pairs, straggler factor 4x,
/// speculation on) and report the recovery accounting, verifying that
/// lineage replay keeps every output bit-identical to the fault-free run.
pub fn fault_sweep(
    params: &ExperimentParams,
    n: usize,
    regimes: &[(f64, f64)],
    backend: &dyn ComputeBackend,
) -> Result<Vec<FaultSweepRow>> {
    let algos = [
        Algorithm::ParallelLloyd,
        Algorithm::DivideLloyd,
        Algorithm::SamplingLloyd,
        Algorithm::MrKCenter,
        Algorithm::StreamingGuha,
        Algorithm::RobustKCenter,
        Algorithm::CoresetKMedian,
    ];
    let data = params.data_config(n, 0).generate();
    let mut rows = Vec::new();
    for algo in algos {
        let clean_cfg = ClusterConfig {
            fail_prob: 0.0,
            straggler_prob: 0.0,
            ..params.cluster_config(0)
        };
        let clean = run_algorithm_with(algo, &data.points, &clean_cfg, backend)?;
        for &(fail_prob, straggler_prob) in regimes {
            let cfg = ClusterConfig {
                fail_prob,
                straggler_prob,
                straggler_factor: 4.0,
                speculative: true,
                ..clean_cfg.clone()
            };
            let out = run_algorithm_with(algo, &data.points, &cfg, backend)?;
            let rec = out.stats.recovery_totals();
            rows.push(FaultSweepRow {
                algo: algo.name().to_string(),
                fail_prob,
                straggler_prob,
                bit_identical: out.centers == clean.centers
                    && out.cost.median == clean.cost.median,
                replays: rec.replayed_tasks,
                recomputed_bytes: rec.recomputed_bytes,
                speculative_wins: rec.speculative_wins,
                cost_median: out.cost_median,
                sim_time: out.sim_time,
            });
        }
    }
    Ok(rows)
}

/// One row of the E12 outlier-robustness comparison.
pub struct OutlierCompareRow {
    /// Algorithm display name.
    pub algo: String,
    /// Plain k-center objective (max distance, outliers included).
    pub cost_center: f64,
    /// k-center objective after the `z` farthest points are dropped — the
    /// fair yardstick on contaminated data.
    pub cost_center_z: f64,
    /// Centers under the lossy fault regime (fail_prob 0.05) are
    /// bit-identical to the clean run's.
    pub lossy_identical: bool,
    /// Lineage replays the lossy run performed.
    pub lossy_replays: usize,
}

/// E12 — outlier robustness: on a contaminated dataset, compare plain
/// MapReduce-kCenter against the summary-based Robust-kCenter, evaluating
/// both by the cost-with-`z`-outliers metric, and re-run each pipeline
/// under the scenario harness's lossy fault regime to verify recovery
/// stays bit-identical. Returns `(z, rows)` where `z` is the number of
/// outliers the generator actually planted (also used as the budget).
pub fn outlier_compare(
    params: &ExperimentParams,
    n: usize,
    backend: &dyn ComputeBackend,
) -> Result<(usize, Vec<OutlierCompareRow>)> {
    let data = params.data_config(n, 0).generate();
    let z = data.n_outliers();
    let clean_cfg = ClusterConfig {
        z,
        fail_prob: 0.0,
        straggler_prob: 0.0,
        ..params.cluster_config(0)
    };
    let lossy_cfg = ClusterConfig {
        fail_prob: 0.05,
        ..clean_cfg.clone()
    };
    let mut rows = Vec::new();
    for algo in [Algorithm::MrKCenter, Algorithm::RobustKCenter] {
        let clean = run_algorithm_with(algo, &data.points, &clean_cfg, backend)?;
        let lossy = run_algorithm_with(algo, &data.points, &lossy_cfg, backend)?;
        rows.push(OutlierCompareRow {
            algo: algo.name().to_string(),
            cost_center: clean.cost.center,
            // Same metric as the runs, or the z-dropped yardstick would be
            // evaluated in a different geometry than the centers.
            cost_center_z: crate::metrics::kcenter_cost_with_outliers_metric(
                &data.points,
                &clean.centers,
                z,
                clean_cfg.metric,
            ),
            lossy_identical: lossy.centers == clean.centers,
            lossy_replays: lossy.stats.total_retries(),
        });
    }
    Ok((z, rows))
}

/// One row of the E13 general-metrics comparison.
pub struct MetricCompareRow {
    /// Metric name (`l2sq`, `l2`, `l1`, `cosine`, `chebyshev`).
    pub metric: &'static str,
    /// Algorithm display name.
    pub algo: String,
    /// k-median objective under that metric (Σ d).
    pub cost_median: f64,
    /// k-center objective under that metric (max d).
    pub cost_center: f64,
    /// MapReduce rounds the run took (the medoid snap adds one per Lloyd
    /// iteration under non-Euclidean metrics — visible here).
    pub rounds: usize,
    /// Reduced instance size (sample / summary), when the pipeline has one.
    pub reduced: Option<usize>,
    /// A second run with the identical config reproduced centers and cost
    /// bit-for-bit (the determinism contract, per metric).
    pub deterministic: bool,
}

/// E13 — general metric spaces: run the registered pipelines under every
/// requested metric on the same dataset, reporting each run's objectives
/// *under its own metric* (cross-metric cost columns are not comparable —
/// the interesting columns are the rounds/size structure and the
/// within-metric cost vs. the metric's own oracle, which the scenario
/// tests check). Every cell is run twice and verified to replay
/// bit-identically, extending the determinism contract to the whole
/// metric matrix.
pub fn metric_compare(
    params: &ExperimentParams,
    n: usize,
    metrics: &[crate::geometry::MetricKind],
    backend: &dyn ComputeBackend,
) -> Result<Vec<MetricCompareRow>> {
    let algos = [
        Algorithm::SamplingLloyd,
        Algorithm::MrKCenter,
        Algorithm::CoresetKMedian,
    ];
    let data = params.data_config(n, 0).generate();
    let mut rows = Vec::new();
    for &metric in metrics {
        for algo in algos {
            let cfg = ClusterConfig {
                metric,
                ..params.cluster_config(0)
            };
            let out = run_algorithm_with(algo, &data.points, &cfg, backend)?;
            let replay = run_algorithm_with(algo, &data.points, &cfg, backend)?;
            rows.push(MetricCompareRow {
                metric: metric.name(),
                algo: algo.name().to_string(),
                cost_median: out.cost.median,
                cost_center: out.cost.center,
                rounds: out.rounds,
                reduced: out.reduced_size,
                deterministic: out.centers == replay.centers
                    && out.cost.median.to_bits() == replay.cost.median.to_bits(),
            });
        }
    }
    Ok(rows)
}

/// One row of the E14 out-of-core sweep.
#[derive(Clone, Debug)]
pub struct OocSweepRow {
    /// Algorithm display name.
    pub algo: String,
    /// Input size of this row.
    pub n: usize,
    /// k-median objective of the file-backed run.
    pub cost_median: f64,
    /// MapReduce rounds executed.
    pub rounds: usize,
    /// Peak host-resident streamed-coordinate bytes during the run.
    pub peak_resident_bytes: usize,
    /// Coordinate bytes of the whole dataset (what `mem` backing holds).
    pub total_bytes: usize,
    /// End-to-end throughput of the file-backed run (clustering plus the
    /// streamed cost sweep; dataset generation excluded): n per wall second.
    pub points_per_sec: f64,
    /// `Some(true)` when the small-scale oracle ran and the file-backed
    /// run matched the resident run bit-for-bit; `None` when `n` was above
    /// `oracle_cap` and the resident reference was skipped.
    pub matches_resident: Option<bool>,
}

/// E14 — out-of-core data plane: stream-generate an n-point dataset into
/// the v2 store format (O(1) generator memory), run the streaming
/// coordinators file-backed, and report cost / rounds /
/// peak-resident-bytes / end-to-end throughput per cell. Rows at or under
/// `oracle_cap` also run the resident pipeline and record bit-identity.
/// Dataset files are written under `dir` and removed after each n.
pub fn ooc_sweep(
    params: &ExperimentParams,
    ns: &[usize],
    chunk_points: usize,
    oracle_cap: usize,
    dir: &std::path::Path,
    backend: &dyn ComputeBackend,
) -> Result<Vec<OocSweepRow>> {
    std::fs::create_dir_all(dir)?;
    let algos = [Algorithm::MrKCenter, Algorithm::CoresetKMedian, Algorithm::DivideLloyd];
    let mut rows = Vec::new();
    for &n in ns {
        let gen = params.data_config(n, 0);
        let path = dir.join(format!("ooc_{n}.mrc"));
        let store = PointStore::from(gen.generate_stream(&path)?);
        let cfg = params.cluster_config(0);
        let resident = if n <= oracle_cap {
            Some(gen.generate().points)
        } else {
            None
        };
        for algo in algos {
            let meter = store.meter().expect("file store is metered").clone();
            meter.reset_peak();
            let t0 = std::time::Instant::now();
            let out = run_algorithm_store_with(algo, &store, &cfg, chunk_points, backend)?;
            let wall = t0.elapsed().as_secs_f64();
            let matches_resident = match &resident {
                Some(points) => {
                    let mem = run_algorithm_with(algo, points, &cfg, backend)?;
                    Some(
                        mem.centers == out.centers
                            && mem.cost.median.to_bits() == out.cost.median.to_bits(),
                    )
                }
                None => None,
            };
            rows.push(OocSweepRow {
                algo: algo.name().to_string(),
                n,
                cost_median: out.cost.median,
                rounds: out.rounds,
                peak_resident_bytes: meter.peak(),
                total_bytes: store.total_bytes(),
                points_per_sec: n as f64 / wall.max(1e-9),
                matches_resident,
            });
        }
        std::fs::remove_file(&path).ok();
    }
    Ok(rows)
}

/// Report of the E14 CI smoke check ([`ooc_check`]).
#[derive(Clone, Debug)]
pub struct OocCheckReport {
    /// Points in the smoke dataset.
    pub n: usize,
    /// Streaming window (in points) the check forced.
    pub chunk_points: usize,
    /// Peak host-resident streamed bytes across all checked pipelines.
    pub peak_resident_bytes: usize,
    /// The O(chunk) ceiling the peak was asserted against: the largest
    /// single window any pipeline legitimately loads (one machine-round
    /// partition or one cost-sweep window).
    pub resident_bound_bytes: usize,
    /// Coordinate bytes of the whole dataset.
    pub total_bytes: usize,
    /// Per-algorithm bit-identity verdicts (the check fails unless all
    /// are true; kept for display).
    pub verdicts: Vec<(String, bool)>,
}

/// E14 smoke check (CI): stream-generate a small dataset, force a tiny
/// streaming window, run every streaming coordinator both file-backed and
/// resident, and hard-assert that (a) centers, costs, and round counts
/// are bit-identical across backings and (b) the peak resident streamed
/// bytes stay within the O(chunk) ceiling while that ceiling is strictly
/// below the dataset size — i.e. the out-of-core path demonstrably
/// spilled instead of quietly loading everything.
pub fn ooc_check(
    params: &ExperimentParams,
    n: usize,
    chunk_points: usize,
    dir: &std::path::Path,
    backend: &dyn ComputeBackend,
) -> Result<OocCheckReport> {
    std::fs::create_dir_all(dir)?;
    let gen = params.data_config(n, 0);
    let path = dir.join(format!("ooc_check_{n}.mrc"));
    let store = PointStore::from(gen.generate_stream(&path)?);
    let points = gen.generate().points;
    // Serial machines and a serial cost sweep: the peak then equals the
    // single largest streamed window, which is what the ceiling bounds.
    let cfg = ClusterConfig {
        parallel: false,
        threads: 1,
        ..params.cluster_config(0)
    };
    let dim = store.dim();
    // The largest single load any checked pipeline performs: a sampling /
    // summarize partition (n over the round's machine count), a divide
    // block (n over ℓ = √(n/k)), or one cost-sweep window (chunk_points
    // rounded up to the fixed reduction block).
    let ell = ((n as f64 / cfg.k as f64).sqrt().ceil() as usize).clamp(1, n.max(1));
    let reps_cap = crate::coordinator::robust::MAX_SUMMARY_REPS;
    let robust_parts = cfg.machines.min(n).min((reps_cap / cfg.k.max(1)).max(1)).max(1);
    let block = 16 * 1024;
    let window = chunk_points.max(block).div_ceil(block) * block;
    let largest_load = [
        n.div_ceil(cfg.machines.min(n).max(1)),
        n.div_ceil(robust_parts),
        n.div_ceil(ell),
        window.min(n.max(1)),
    ]
    .into_iter()
    .max()
    .unwrap();
    let resident_bound_bytes = largest_load * dim * 4;
    anyhow::ensure!(
        resident_bound_bytes < store.total_bytes(),
        "smoke config cannot spill: ceiling {resident_bound_bytes} >= dataset {} — \
         raise n or shrink machines/chunk_points",
        store.total_bytes()
    );

    let meter = store.meter().expect("file store is metered").clone();
    let mut verdicts = Vec::new();
    let mut peak = 0usize;
    for algo in [Algorithm::MrKCenter, Algorithm::CoresetKMedian, Algorithm::DivideLloyd] {
        meter.reset_peak();
        let ooc = run_algorithm_store_with(algo, &store, &cfg, chunk_points, backend)?;
        let mem = run_algorithm_with(algo, &points, &cfg, backend)?;
        let ok = mem.centers == ooc.centers
            && mem.cost.median.to_bits() == ooc.cost.median.to_bits()
            && mem.cost.center.to_bits() == ooc.cost.center.to_bits()
            && mem.rounds == ooc.rounds;
        anyhow::ensure!(ok, "{}: file-backed run diverged from the resident run", algo.name());
        anyhow::ensure!(
            meter.peak() <= resident_bound_bytes,
            "{}: peak resident {} bytes exceeds the O(chunk) ceiling {resident_bound_bytes}",
            algo.name(),
            meter.peak()
        );
        anyhow::ensure!(meter.current() == 0, "{}: leaked a resident window", algo.name());
        peak = peak.max(meter.peak());
        verdicts.push((algo.name().to_string(), ok));
    }
    std::fs::remove_file(&path).ok();
    Ok(OocCheckReport {
        n,
        chunk_points,
        peak_resident_bytes: peak,
        resident_bound_bytes,
        total_bytes: store.total_bytes(),
        verdicts,
    })
}

/// One row of the E15 topology sweep.
#[derive(Clone, Debug)]
pub struct TopologySweepRow {
    /// Algorithm display name.
    pub algo: String,
    /// Simulated machine count of this row.
    pub machines: usize,
    /// Network scenario name (`flat` | `racked` | `oversubscribed`).
    pub scenario: &'static str,
    /// MapReduce rounds executed (identical across scenarios — the sim
    /// never steers the algorithm).
    pub rounds: usize,
    /// Total shuffled bytes (identical across scenarios, same reason).
    pub shuffle_bytes: usize,
    /// Discrete-event simulated wall-clock of the whole run — the only
    /// column the scenario is allowed to change.
    pub sim_wallclock: std::time::Duration,
    /// Centers, costs, rounds, and shuffle bytes are bit-identical to the
    /// sim-off baseline run (the observation-purity contract).
    pub matches_baseline: bool,
}

/// The E15 network scenarios for a given machine count: a flat
/// uncontended-fabric cluster, a racked cluster with log-normal host
/// speeds, and an 8x-oversubscribed racked cluster with a bimodal
/// (slow-population) fleet. Racks hold 16 hosts.
pub fn e15_scenarios(machines: usize) -> [(&'static str, SimConfig); 3] {
    let racks = machines.div_ceil(16).max(1);
    let base = SimConfig { enabled: true, ..SimConfig::default() };
    [
        ("flat", SimConfig { network: NetworkKind::Shared, ..base.clone() }),
        (
            "racked",
            SimConfig {
                network: NetworkKind::Topology,
                racks,
                hetero: Heterogeneity::LogNormal(0.5),
                placement: Placement::RackAware,
                ..base.clone()
            },
        ),
        (
            "oversubscribed",
            SimConfig {
                network: NetworkKind::Topology,
                racks,
                oversub: 8.0,
                hetero: Heterogeneity::Bimodal { slow_frac: 0.1, slow_factor: 4.0 },
                placement: Placement::RackAware,
                ..base
            },
        ),
    ]
}

/// E15 — topology sweep: run the scalable pipelines across machine counts
/// and the [`e15_scenarios`] network models, reporting rounds / shuffle
/// bytes / simulated wall-clock per cell. Every sim-on run is checked
/// bit-identical (centers, cost, rounds, shuffle bytes) to its sim-off
/// baseline — the simulation only ever adds the wall-clock column. As
/// machine counts grow, per-round network overhead (leader incast,
/// contended uplinks, flow latency) grows with them, which is where the
/// paper's constant-round pipelines pull ahead of round-heavy ones.
pub fn topology_sweep(
    params: &ExperimentParams,
    n: usize,
    machine_counts: &[usize],
    backend: &dyn ComputeBackend,
) -> Result<Vec<TopologySweepRow>> {
    let data = params.data_config(n, 0).generate();
    let mut rows = Vec::new();
    for &m in machine_counts {
        let base_cfg = ClusterConfig {
            machines: m,
            sim: SimConfig::default(),
            ..params.cluster_config(0)
        };
        for algo in Algorithm::figure2() {
            let base = run_algorithm_with(algo, &data.points, &base_cfg, backend)?;
            for (scenario, sim) in e15_scenarios(m) {
                let cfg = ClusterConfig { sim, ..base_cfg.clone() };
                let out = run_algorithm_with(algo, &data.points, &cfg, backend)?;
                let matches_baseline = out.centers == base.centers
                    && out.cost.median.to_bits() == base.cost.median.to_bits()
                    && out.rounds == base.rounds
                    && out.stats.shuffle_bytes() == base.stats.shuffle_bytes();
                log::info!(
                    "{} m={} {}: rounds {}, wallclock {:.3}s, identical {}",
                    algo.name(),
                    m,
                    scenario,
                    out.rounds,
                    out.sim_wallclock.as_secs_f64(),
                    matches_baseline
                );
                rows.push(TopologySweepRow {
                    algo: algo.name().to_string(),
                    machines: m,
                    scenario,
                    rounds: out.rounds,
                    shuffle_bytes: out.stats.shuffle_bytes(),
                    sim_wallclock: out.sim_wallclock,
                    matches_baseline,
                });
            }
        }
    }
    Ok(rows)
}

/// One row of the E17 arena: one `(dataset, contamination, metric,
/// algorithm)` cell, run five times — a sim-off baseline, a replay, and
/// the three [`e15_scenarios`] network models.
#[derive(Clone, Debug)]
pub struct ArenaRow {
    /// Dataset regime (`clustered` | `skewed` | `adversarial`).
    pub dataset: &'static str,
    /// Contamination fraction the dataset was generated with (the
    /// adversarial regime reports its built-in outlier share).
    pub contamination: f64,
    /// Metric name (`l2sq`, `l1`, …).
    pub metric: &'static str,
    /// Algorithm display name.
    pub algo: String,
    /// k-median objective (Σ true distance) under the cell's metric.
    pub cost_median: f64,
    /// k-center objective (max true distance) under the cell's metric.
    pub cost_center: f64,
    /// MapReduce rounds executed.
    pub rounds: usize,
    /// Total shuffled bytes.
    pub shuffle_bytes: usize,
    /// Reduced instance size (sample / summary / coreset), when the
    /// pipeline has one.
    pub reduced: Option<usize>,
    /// A second identical run reproduced centers and cost bit-for-bit.
    pub deterministic: bool,
    /// All three sim-on runs matched the sim-off baseline bit-for-bit
    /// (centers, cost, rounds, shuffle bytes — the observation-purity
    /// contract, per cell).
    pub matches_baseline: bool,
    /// Simulated wall-clock under the flat uncontended fabric.
    pub wallclock_flat: std::time::Duration,
    /// Simulated wall-clock under the racked heterogeneous cluster.
    pub wallclock_racked: std::time::Duration,
    /// Simulated wall-clock under the 8x-oversubscribed cluster.
    pub wallclock_oversub: std::time::Duration,
}

/// One row of the E17 oracle leg: one algorithm's cost ratio against the
/// brute-force optimum on the small companion instance.
#[derive(Clone, Debug)]
pub struct ArenaOracleRow {
    /// Algorithm display name.
    pub algo: String,
    /// Metric name the ratio was computed under.
    pub metric: &'static str,
    /// Which objective the algorithm is held to (`kmedian` | `kcenter`).
    pub objective: &'static str,
    /// The algorithm's cost on the companion instance.
    pub cost: f64,
    /// The exact brute-force optimum of that objective.
    pub opt: f64,
    /// `cost / opt`.
    pub ratio: f64,
    /// The documented approximation envelope the ratio is gated against.
    pub bound: f64,
    /// `ratio <= bound`.
    pub ok: bool,
}

/// Report of one E17 arena run ([`arena`]): the shootout rows, the oracle
/// leg, and the three gate verdicts the CI job fails on.
#[derive(Clone, Debug)]
pub struct ArenaReport {
    /// Points per arena dataset.
    pub n: usize,
    /// The shootout cells.
    pub rows: Vec<ArenaRow>,
    /// The oracle-companion ratios.
    pub oracle: Vec<ArenaOracleRow>,
    /// Every cell replayed bit-identically.
    pub all_deterministic: bool,
    /// Every sim-on run matched its sim-off baseline bit-for-bit.
    pub all_match_baseline: bool,
    /// Every oracle ratio stayed under its documented envelope.
    pub oracle_ok: bool,
}

/// One arena dataset: a named point set plus the outlier budget `z` the
/// contaminated regimes thread into the robust/rival pipelines.
struct ArenaDataset {
    name: &'static str,
    contamination: f64,
    points: crate::geometry::PointSet,
    z: usize,
}

/// The adversarial arena regime (mirrors the scenario harness): 70% of
/// points packed within 1e-4 of one location, 20% a collinear filament,
/// and the remainder extreme outliers marching away from everything.
fn arena_adversarial(n: usize, seed: u64) -> crate::geometry::PointSet {
    let mut rng = crate::util::rng::Rng::new(seed ^ 0xAD5A);
    let mut flat = Vec::with_capacity(n * 3);
    let heavy = n * 7 / 10;
    let line = n * 2 / 10;
    for _ in 0..heavy {
        for _ in 0..3 {
            flat.push(0.5 + (rng.f32() - 0.5) * 1e-4);
        }
    }
    for i in 0..line {
        let t = i as f32 / line.max(1) as f32;
        let c = t * 2.0 - 1.0;
        flat.extend_from_slice(&[c, c, c]);
    }
    let rest = n - heavy - line;
    for i in 0..rest {
        let s = (i + 1) as f32;
        flat.extend_from_slice(&[50.0 * s, -30.0 * s, 80.0]);
    }
    crate::geometry::PointSet::from_flat(3, flat)
}

/// The arena dataset matrix: clustered and Zipf-skewed blobs at every
/// requested contamination, plus the adversarial regime once (its outlier
/// share is structural, not a knob).
fn arena_datasets(params: &ExperimentParams, n: usize, contaminations: &[f64]) -> Vec<ArenaDataset> {
    let mut out = Vec::new();
    for &c in contaminations {
        let clustered = DataGenConfig {
            contamination: c,
            ..params.data_config(n, 0)
        }
        .generate();
        out.push(ArenaDataset {
            name: "clustered",
            contamination: c,
            z: clustered.n_outliers(),
            points: clustered.points,
        });
        let skewed = DataGenConfig {
            alpha: 1.2,
            contamination: c,
            seed: params.seed ^ 1,
            ..params.data_config(n, 0)
        }
        .generate();
        out.push(ArenaDataset {
            name: "skewed",
            contamination: c,
            z: skewed.n_outliers(),
            points: skewed.points,
        });
    }
    let adv = arena_adversarial(n, params.seed ^ 2);
    out.push(ArenaDataset {
        name: "adversarial",
        contamination: 0.1,
        z: n / 10,
        points: adv,
    });
    out
}

/// Visit every k-combination of `[0, n)` in lexicographic order (the
/// companion oracle's enumeration; n = 48, k = 3 is ~17k subsets).
fn arena_combinations(n: usize, k: usize, mut f: impl FnMut(&[usize])) {
    assert!((1..=n).contains(&k));
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        f(&idx);
        let mut i = k;
        while i > 0 && idx[i - 1] == n - k + (i - 1) {
            i -= 1;
        }
        if i == 0 {
            return;
        }
        idx[i - 1] += 1;
        for j in i..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// The oracle companion: three tight 2-D blobs, 16 points each — small
/// enough for exact combination enumeration, separated widely enough that
/// the documented envelopes hold by margin rather than seed luck (the
/// `tests/prop_metrics.rs` tri-blob construction, one blob larger).
fn arena_oracle_points() -> crate::geometry::PointSet {
    let centers = [[1.0f32, 0.2], [0.2, 1.0], [1.5, 1.5]];
    let mut rng = crate::util::rng::Rng::new(0xB10B ^ 0xE17);
    let mut p = crate::geometry::PointSet::with_capacity(2, 48);
    for c in &centers {
        for _ in 0..16 {
            p.push(&[
                c[0] + (rng.f32() - 0.5) * 0.2,
                c[1] + (rng.f32() - 0.5) * 0.2,
            ]);
        }
    }
    p
}

/// E17 oracle leg: on the 48-point companion, run every registered
/// pipeline under every requested metric and gate its cost ratio against
/// the documented approximation envelope — 12x the exact k-center optimum
/// for the k-center pipelines (MapReduce-kCenter's Theorem-3.7 factor
/// plus summary slack; Ceccarello et al.'s skeleton greedy sits under the
/// same envelope), 15x the exact k-median optimum for everything else
/// (the weakest registered pipeline's constant with slack; Mazzetto et
/// al.'s accuracy-oriented coreset sits far under it). Ratios compare
/// true-distance objectives, so the envelopes are metric-uniform.
fn arena_oracle(
    params: &ExperimentParams,
    metrics: &[crate::geometry::MetricKind],
    backend: &dyn ComputeBackend,
) -> Result<Vec<ArenaOracleRow>> {
    use crate::metrics::{kcenter_cost_metric, kmedian_cost_metric};
    let points = arena_oracle_points();
    let k = 3;
    let mut rows = Vec::new();
    for &metric in metrics {
        let mut opt_median = f64::INFINITY;
        let mut opt_center = f64::INFINITY;
        arena_combinations(points.len(), k, |idx| {
            let centers = points.gather(idx);
            opt_median = opt_median.min(kmedian_cost_metric(&points, &centers, metric));
            opt_center = opt_center.min(kcenter_cost_metric(&points, &centers, metric));
        });
        anyhow::ensure!(
            opt_median.is_finite() && opt_median > 0.0 && opt_center > 0.0,
            "degenerate oracle companion under {metric}"
        );
        for algo in Algorithm::all() {
            let cfg = ClusterConfig {
                k,
                machines: 3,
                epsilon: 0.2,
                ls_max_swaps: 40,
                metric,
                z: 0,
                seed: params.seed,
                ..ClusterConfig::default()
            };
            let out = run_algorithm_with(algo, &points, &cfg, backend)?;
            let kcenter_objective = matches!(
                algo,
                Algorithm::MrKCenter | Algorithm::RobustKCenter | Algorithm::CeccarelloKCenter
            );
            let (objective, cost, opt, bound) = if kcenter_objective {
                let c = kcenter_cost_metric(&points, &out.centers, metric);
                ("kcenter", c, opt_center, 12.0)
            } else {
                let c = kmedian_cost_metric(&points, &out.centers, metric);
                ("kmedian", c, opt_median, 15.0)
            };
            let ratio = cost / opt;
            rows.push(ArenaOracleRow {
                algo: algo.name().to_string(),
                metric: metric.name(),
                objective,
                cost,
                opt,
                ratio,
                bound,
                ok: ratio <= bound + 1e-9,
            });
        }
    }
    Ok(rows)
}

/// E17 — competitor arena: every registered pipeline (the paper's, the
/// repo's robust ones, and the rival-paper coordinators) × datasets
/// (clustered / skewed / adversarial, with and without contamination) ×
/// metrics. Each cell runs five times — sim-off baseline, replay, and the
/// three [`e15_scenarios`] network models — reporting objectives, rounds,
/// shuffle bytes, and simulated wall-clock per topology, with per-cell
/// replay bit-identity and sim observation-purity verdicts. A separate
/// oracle leg ([`arena_oracle`]) gates every pipeline's cost ratio on the
/// small companion against its documented approximation envelope.
/// LocalSearch (the sequential full-data baseline) only enters while
/// `n <= ls_cap`, mirroring the paper's N/A cells.
pub fn arena(
    params: &ExperimentParams,
    n: usize,
    contaminations: &[f64],
    metrics: &[crate::geometry::MetricKind],
    ls_cap: usize,
    backend: &dyn ComputeBackend,
) -> Result<ArenaReport> {
    anyhow::ensure!(!metrics.is_empty(), "need at least one metric");
    anyhow::ensure!(!contaminations.is_empty(), "need at least one contamination level");
    let datasets = arena_datasets(params, n, contaminations);
    let mut rows = Vec::new();
    for ds in &datasets {
        for &metric in metrics {
            for algo in Algorithm::all() {
                if algo == Algorithm::LocalSearch && n > ls_cap {
                    continue;
                }
                let base_cfg = ClusterConfig {
                    metric,
                    z: ds.z,
                    sim: SimConfig::default(),
                    ..params.cluster_config(0)
                };
                let base = run_algorithm_with(algo, &ds.points, &base_cfg, backend)?;
                let replay = run_algorithm_with(algo, &ds.points, &base_cfg, backend)?;
                let deterministic = base.centers == replay.centers
                    && base.cost.median.to_bits() == replay.cost.median.to_bits();
                let mut matches_baseline = true;
                let mut wallclocks = [std::time::Duration::ZERO; 3];
                for (i, (scenario, sim)) in
                    e15_scenarios(base_cfg.machines).into_iter().enumerate()
                {
                    let cfg = ClusterConfig { sim, ..base_cfg.clone() };
                    let out = run_algorithm_with(algo, &ds.points, &cfg, backend)?;
                    matches_baseline &= out.centers == base.centers
                        && out.cost.median.to_bits() == base.cost.median.to_bits()
                        && out.rounds == base.rounds
                        && out.stats.shuffle_bytes() == base.stats.shuffle_bytes();
                    wallclocks[i] = out.sim_wallclock;
                    log::info!(
                        "arena {} {} {} {}: wallclock {:.3}s, identical {}",
                        ds.name,
                        metric.name(),
                        algo.name(),
                        scenario,
                        out.sim_wallclock.as_secs_f64(),
                        matches_baseline
                    );
                }
                rows.push(ArenaRow {
                    dataset: ds.name,
                    contamination: ds.contamination,
                    metric: metric.name(),
                    algo: algo.name().to_string(),
                    cost_median: base.cost.median,
                    cost_center: base.cost.center,
                    rounds: base.rounds,
                    shuffle_bytes: base.stats.shuffle_bytes(),
                    reduced: base.reduced_size,
                    deterministic,
                    matches_baseline,
                    wallclock_flat: wallclocks[0],
                    wallclock_racked: wallclocks[1],
                    wallclock_oversub: wallclocks[2],
                });
            }
        }
    }
    let oracle = arena_oracle(params, metrics, backend)?;
    Ok(ArenaReport {
        n,
        all_deterministic: rows.iter().all(|r| r.deterministic),
        all_match_baseline: rows.iter().all(|r| r.matches_baseline),
        oracle_ok: oracle.iter().all(|r| r.ok),
        rows,
        oracle,
    })
}

/// One row of the E16 serving bench: one `(variant, threads, batch)` cell
/// with its latency distribution and throughput.
#[derive(Clone, Debug)]
pub struct ServeBenchRow {
    /// What was measured: `ingest` | `epoch_close` | `query`.
    pub variant: &'static str,
    /// Concurrent client threads (1 for `ingest`/`epoch_close`).
    pub threads: usize,
    /// Points per batch (`ingest`/`query`; the epoch-close row reports the
    /// ingest batch size its epochs were fed with).
    pub batch: usize,
    /// Operations measured — a deterministic counter (batches ingested,
    /// epochs closed, query batches answered), identical across repeat
    /// runs with the same arguments.
    pub count: u64,
    /// Median per-operation latency in microseconds.
    pub p50_us: f64,
    /// 99th-percentile per-operation latency in microseconds.
    pub p99_us: f64,
    /// Throughput: points/s for `ingest`, epochs/s for `epoch_close`,
    /// queries/s (batched queries, all threads combined) for `query`.
    pub per_sec: f64,
}

/// Report of one E16 run ([`serve_bench`]): deterministic counters plus
/// the measured rows. The counters (`epochs`, `batches`, `queries`) are
/// pure functions of the arguments — repeat runs must reproduce them
/// exactly, which `rust/tests/integration_cli.rs` checks.
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    /// Points in the ingest stream.
    pub n: usize,
    /// Point dimensionality.
    pub dim: usize,
    /// Centers per model.
    pub k: usize,
    /// `serve.tau` the engines ran with (0 = lossless).
    pub tau: usize,
    /// Total epochs closed across the whole run (oracle gate included).
    pub epochs: u64,
    /// Total batches ingested across the whole run.
    pub batches: u64,
    /// Total query batches answered across the whole run.
    pub queries: u64,
    /// The pre-timing bit-identity oracle gate ran and passed (the bench
    /// errors out before timing anything if it fails).
    pub oracle_checked: bool,
    /// The measured cells.
    pub rows: Vec<ServeBenchRow>,
}

fn percentile_us(sorted: &[std::time::Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx].as_secs_f64() * 1e6
}

/// E16 — serving bench: ingest throughput, epoch-close latency, and query
/// p50/p99 latency + queries/s across thread counts and batch sizes.
///
/// Before timing anything, a **bit-identity oracle gate** runs (the same
/// pattern as `benches/e2e.rs`): the stream is ingested under two
/// different batch partitions (lossless mode) or two arrival orders of
/// the same partition (compressed mode), both epochs close, and the
/// published centers must match bitwise — lossless mode additionally
/// matches the one-shot batch pipeline on the epoch's canonical point
/// arrangement. Any divergence errors out, so a reported row implies the
/// oracle passed.
pub fn serve_bench(
    params: &ExperimentParams,
    serve: &crate::config::ServeConfig,
    n: usize,
    batch_sizes: &[usize],
    thread_counts: &[usize],
    queries_per_thread: usize,
    backend: std::sync::Arc<dyn ComputeBackend>,
) -> Result<ServeBenchReport> {
    use crate::serve::ServeEngine;
    use std::time::Instant;
    anyhow::ensure!(!batch_sizes.is_empty(), "need at least one batch size");
    anyhow::ensure!(
        batch_sizes.iter().all(|&b| b >= 1),
        "batch sizes must be positive"
    );
    anyhow::ensure!(
        !thread_counts.is_empty() && thread_counts.iter().all(|&t| t >= 1),
        "need at least one (positive) thread count"
    );
    anyhow::ensure!(queries_per_thread >= 1, "need at least one query per thread");
    anyhow::ensure!(n >= 1, "need a non-empty stream");
    let data = params.data_config(n, 0).generate().points;
    let dim = data.dim();
    let cfg = params.cluster_config(0);
    let mut epochs = 0u64;
    let mut batches = 0u64;
    let mut queries = 0u64;

    let ingest_all = |engine: &ServeEngine, batch: usize| -> Result<u64> {
        let mut fed = 0u64;
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + batch).min(n);
            engine.ingest(&data.view(lo, hi))?;
            fed += 1;
            lo = hi;
        }
        Ok(fed)
    };

    // ---- Pre-timing bit-identity oracle gate ----
    let b0 = batch_sizes[0];
    let b1 = *batch_sizes.last().expect("non-empty");
    let engine_a = ServeEngine::with_backend(dim, &cfg, serve, std::sync::Arc::clone(&backend));
    batches += ingest_all(&engine_a, b0)?;
    let close_a = engine_a.close_epoch()?;
    epochs += 1;
    let engine_b = ServeEngine::with_backend(dim, &cfg, serve, std::sync::Arc::clone(&backend));
    if serve.tau == 0 {
        // Lossless: a *different* batch split, fed in reverse order, must
        // publish bit-identical centers.
        let mut spans = Vec::new();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + b1.max(1)).min(n);
            spans.push((lo, hi));
            lo = hi;
        }
        for &(lo, hi) in spans.iter().rev() {
            engine_b.ingest(&data.view(lo, hi))?;
            batches += 1;
        }
    } else {
        // Compressed: the same split, fed in reverse order.
        let mut spans = Vec::new();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + b0).min(n);
            spans.push((lo, hi));
            lo = hi;
        }
        for &(lo, hi) in spans.iter().rev() {
            engine_b.ingest(&data.view(lo, hi))?;
            batches += 1;
        }
    }
    let close_b = engine_b.close_epoch()?;
    epochs += 1;
    anyhow::ensure!(
        close_a.model.centers == close_b.model.centers,
        "oracle gate: re-partitioned/re-ordered ingest published different centers"
    );
    if serve.tau == 0 {
        // ...and the one-shot batch pipeline on the canonical arrangement.
        let canonical = crate::summaries::WeightedSet::unit(data.clone()).canonicalize();
        let mut cluster =
            crate::mapreduce::MrCluster::new(crate::coordinator::driver::mr_config(&cfg));
        let oneshot = crate::coordinator::robust::mr_coreset_kmedian(
            &mut cluster,
            canonical.points(),
            &cfg,
            backend.as_ref(),
        )?;
        anyhow::ensure!(
            close_a.model.centers == oneshot.centers,
            "oracle gate: serve epoch diverged from the one-shot batch pipeline"
        );
    }

    let mut rows = Vec::new();

    // ---- Ingest throughput per batch size ----
    for &b in batch_sizes {
        let engine = ServeEngine::with_backend(dim, &cfg, serve, std::sync::Arc::clone(&backend));
        let mut lat = Vec::new();
        let t0 = Instant::now();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + b).min(n);
            let t = Instant::now();
            engine.ingest(&data.view(lo, hi))?;
            lat.push(t.elapsed());
            lo = hi;
        }
        let wall = t0.elapsed().as_secs_f64();
        batches += lat.len() as u64;
        lat.sort_unstable();
        rows.push(ServeBenchRow {
            variant: "ingest",
            threads: 1,
            batch: b,
            count: lat.len() as u64,
            p50_us: percentile_us(&lat, 0.50),
            p99_us: percentile_us(&lat, 0.99),
            per_sec: n as f64 / wall.max(1e-9),
        });
    }

    // ---- Epoch-close latency (epochs fed at the first batch size) ----
    const CLOSE_REPS: usize = 3;
    let engine = ServeEngine::with_backend(dim, &cfg, serve, std::sync::Arc::clone(&backend));
    let mut lat = Vec::new();
    let mut close_wall = 0.0f64;
    for _ in 0..CLOSE_REPS {
        batches += ingest_all(&engine, b0)?;
        let t = Instant::now();
        engine.close_epoch()?;
        let d = t.elapsed();
        close_wall += d.as_secs_f64();
        lat.push(d);
        epochs += 1;
    }
    lat.sort_unstable();
    rows.push(ServeBenchRow {
        variant: "epoch_close",
        threads: 1,
        batch: b0,
        count: CLOSE_REPS as u64,
        p50_us: percentile_us(&lat, 0.50),
        p99_us: percentile_us(&lat, 0.99),
        per_sec: CLOSE_REPS as f64 / close_wall.max(1e-9),
    });

    // ---- Query latency/throughput across thread counts x batch sizes ----
    // The engine above has a published model; every cell queries it.
    anyhow::ensure!(engine.snapshot().is_some(), "no model published for the query phase");
    for &t in thread_counts {
        for &b in batch_sizes {
            let b = b.min(n);
            let q = engine.query_engine();
            let t0 = Instant::now();
            let mut lat: Vec<std::time::Duration> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..t)
                    .map(|ti| {
                        let q = q.clone();
                        let data = &data;
                        s.spawn(move || {
                            let mut lat = Vec::with_capacity(queries_per_thread);
                            for j in 0..queries_per_thread {
                                // Deterministic per-(thread, iteration) view.
                                let lo = ((ti * queries_per_thread + j) * b) % (n - b + 1);
                                let view = data.view(lo, lo + b);
                                let t = Instant::now();
                                let r = q.query(&view).expect("model is published");
                                lat.push(t.elapsed());
                                assert_eq!(r.assign.len(), b);
                            }
                            lat
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("query thread panicked"))
                    .collect()
            });
            let wall = t0.elapsed().as_secs_f64();
            let count = (t * queries_per_thread) as u64;
            queries += count;
            lat.sort_unstable();
            rows.push(ServeBenchRow {
                variant: "query",
                threads: t,
                batch: b,
                count,
                p50_us: percentile_us(&lat, 0.50),
                p99_us: percentile_us(&lat, 0.99),
                per_sec: count as f64 / wall.max(1e-9),
            });
        }
    }

    Ok(ServeBenchReport {
        n,
        dim,
        k: cfg.k,
        tau: serve.tau,
        epochs,
        batches,
        queries,
        oracle_checked: true,
        rows,
    })
}

/// E7 — Zipf-skew robustness sweep (the "similar results, omitted" claim).
pub fn skew_sweep(
    params: &ExperimentParams,
    n: usize,
    alphas: &[f64],
    backend: &dyn ComputeBackend,
) -> Result<FigureReport> {
    let mut report = FigureReport::default();
    for &alpha in alphas {
        let p = ExperimentParams {
            alpha,
            ..params.clone()
        };
        for algo in [
            Algorithm::ParallelLloyd,
            Algorithm::SamplingLloyd,
            Algorithm::SamplingLocalSearch,
        ] {
            let mut rec = run_cell(&p, algo, n, backend)?;
            // Encode alpha in the n column (the report is keyed by n).
            rec.n = (alpha * 1000.0) as usize;
            report.add(rec);
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn tiny() -> ExperimentParams {
        ExperimentParams {
            k: 5,
            repeats: 1,
            cluster: ClusterConfig {
                k: 5,
                epsilon: 0.2,
                machines: 8,
                ls_max_swaps: 20,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn figure1_produces_all_rows() {
        let rep = figure1(&tiny(), &[2000], 40_000, &NativeBackend).unwrap();
        assert_eq!(rep.records.len(), 6);
        let table = rep.cost_table("Parallel-Lloyd");
        assert_eq!(table.n_rows(), 6);
    }

    #[test]
    fn figure1_skips_localsearch_beyond_cap() {
        let rep = figure1(&tiny(), &[2000], 1000, &NativeBackend).unwrap();
        assert_eq!(rep.records.len(), 5);
    }

    #[test]
    fn fault_sweep_is_bit_identical_and_counts_replays() {
        let rows = fault_sweep(&tiny(), 1500, &[(0.3, 0.2)], &NativeBackend).unwrap();
        assert_eq!(rows.len(), 7);
        let mut total_replays = 0usize;
        for r in &rows {
            assert!(r.bit_identical, "{} diverged under faults", r.algo);
            total_replays += r.replays;
            // Single-leader-round pipelines draw one fate per run, so only
            // pipelines with many rounds are guaranteed injected failures
            // (the three-round robust pipelines draw few fates too).
            if !matches!(
                r.algo.as_str(),
                "Streaming-Guha" | "Robust-kCenter" | "Coreset-kMedian"
            ) {
                assert!(r.replays > 0, "{} saw no injected failures", r.algo);
                assert!(r.recomputed_bytes > 0, "{}", r.algo);
            }
        }
        assert!(total_replays > 0);
    }

    #[test]
    fn outlier_compare_robust_wins_and_recovers() {
        let params = ExperimentParams {
            sigma: 0.05,
            contamination: 0.02,
            ..tiny()
        };
        let (z, rows) = outlier_compare(&params, 1200, &NativeBackend).unwrap();
        assert!(z > 0, "contamination must plant outliers");
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.lossy_identical, "{} diverged under lossy faults", r.algo);
        }
        let (plain, robust) = (&rows[0], &rows[1]);
        assert_eq!(robust.algo, "Robust-kCenter");
        assert!(
            robust.cost_center_z <= plain.cost_center_z + 1e-9,
            "robust {} vs plain {}",
            robust.cost_center_z,
            plain.cost_center_z
        );
    }

    #[test]
    fn sample_stats_rows() {
        let rows = sample_stats(&tiny(), &[5000], &[0.1, 0.3]).unwrap();
        assert_eq!(rows.len(), 2);
        for r in rows {
            assert!(r.sample_size > 0);
        }
    }

    #[test]
    fn ooc_check_passes_on_a_spilling_config() {
        let dir = std::env::temp_dir().join("mrcluster_e14_tests");
        let rep = ooc_check(&tiny(), 40_000, 1024, &dir, &NativeBackend).unwrap();
        assert!(rep.peak_resident_bytes > 0, "nothing streamed");
        assert!(
            rep.peak_resident_bytes <= rep.resident_bound_bytes,
            "peak {} vs bound {}",
            rep.peak_resident_bytes,
            rep.resident_bound_bytes
        );
        assert!(rep.resident_bound_bytes < rep.total_bytes, "config did not spill");
        assert_eq!(rep.verdicts.len(), 3);
        assert!(rep.verdicts.iter().all(|(_, ok)| *ok));
    }

    #[test]
    fn ooc_sweep_reports_oracle_rows() {
        let dir = std::env::temp_dir().join("mrcluster_e14_tests");
        let rows = ooc_sweep(&tiny(), &[3000], 64 * 1024, 10_000, &dir, &NativeBackend).unwrap();
        assert_eq!(rows.len(), 3, "three streaming algorithms");
        for r in &rows {
            assert_eq!(r.matches_resident, Some(true), "{} diverged", r.algo);
            assert!(r.points_per_sec > 0.0);
            assert!(r.peak_resident_bytes > 0 && r.peak_resident_bytes <= r.total_bytes);
            assert!(r.rounds >= 1);
        }
    }

    #[test]
    fn topology_sweep_is_pure_observation() {
        let rows = topology_sweep(&tiny(), 1500, &[8, 16], &NativeBackend).unwrap();
        // 2 machine counts x 4 algorithms x 3 scenarios.
        assert_eq!(rows.len(), 24);
        let mut flat = std::time::Duration::ZERO;
        let mut oversub = std::time::Duration::ZERO;
        for r in &rows {
            let tag = format!("{} m={} {}", r.algo, r.machines, r.scenario);
            assert!(r.matches_baseline, "{tag}: outputs drifted");
            assert!(r.sim_wallclock > std::time::Duration::ZERO, "{} {}", r.algo, r.scenario);
            assert!(r.rounds >= 1 && r.shuffle_bytes > 0, "{}", r.algo);
            match r.scenario {
                "flat" => flat += r.sim_wallclock,
                "oversubscribed" => oversub += r.sim_wallclock,
                _ => {}
            }
        }
        // Slower links + a slow host population can only stretch the
        // aggregate simulated makespan.
        assert!(oversub >= flat, "oversubscribed {oversub:?} < flat {flat:?}");
    }

    #[test]
    fn serve_bench_rows_and_counters_are_deterministic() {
        let serve = crate::config::ServeConfig::default();
        let run = || {
            serve_bench(
                &tiny(),
                &serve,
                600,
                &[128, 256],
                &[1, 2],
                4,
                std::sync::Arc::new(NativeBackend),
            )
            .unwrap()
        };
        let a = run();
        // 2 ingest rows + 1 epoch-close row + (2 threads x 2 batches) query rows.
        assert_eq!(a.rows.len(), 7);
        assert!(a.oracle_checked);
        assert_eq!(a.epochs, 2 + 3, "oracle pair + CLOSE_REPS");
        assert!(a.batches > 0 && a.queries == (1 + 2) * 2 * 4);
        for r in &a.rows {
            assert!(r.count > 0, "{} cell measured nothing", r.variant);
            assert!(r.per_sec > 0.0 && r.p50_us >= 0.0 && r.p99_us >= r.p50_us);
        }
        // Counters are pure functions of the arguments.
        let b = run();
        assert_eq!((a.epochs, a.batches, a.queries), (b.epochs, b.batches, b.queries));
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(
                (x.variant, x.threads, x.batch, x.count),
                (y.variant, y.threads, y.batch, y.count)
            );
        }
    }

    #[test]
    fn serve_bench_compressed_mode_gate_passes() {
        let serve = crate::config::ServeConfig { tau: 16, epoch_batches: 0 };
        let rep = serve_bench(
            &tiny(),
            &serve,
            500,
            &[100],
            &[1],
            2,
            std::sync::Arc::new(NativeBackend),
        )
        .unwrap();
        assert_eq!(rep.tau, 16);
        assert!(rep.oracle_checked);
        assert_eq!(rep.rows.len(), 1 + 1 + 1);
    }

    #[test]
    fn arena_tiny_gate_passes() {
        use crate::geometry::MetricKind;
        let rep = arena(&tiny(), 400, &[0.0], &[MetricKind::L2Sq], 1000, &NativeBackend).unwrap();
        // 3 datasets (clustered, skewed, adversarial) x 1 metric x 12
        // algorithms (LocalSearch runs: 400 <= ls_cap).
        assert_eq!(rep.rows.len(), 36);
        assert!(rep.all_deterministic, "a cell diverged on replay");
        assert!(rep.all_match_baseline, "the sim steered an output");
        for r in &rep.rows {
            assert!(r.rounds >= 1 && r.cost_median.is_finite(), "{}", r.algo);
            assert!(
                r.wallclock_flat > std::time::Duration::ZERO,
                "{} {}: sim-on run reported no wall-clock",
                r.dataset,
                r.algo
            );
        }
        // Oracle leg: every registered pipeline under every metric, all
        // within their documented envelopes.
        assert_eq!(rep.oracle.len(), 12);
        assert!(rep.oracle_ok, "an oracle ratio blew its envelope");
        for r in &rep.oracle {
            assert!(r.opt > 0.0 && r.ratio.is_finite(), "{}", r.algo);
        }
        let kcenter_rows = rep.oracle.iter().filter(|r| r.objective == "kcenter").count();
        assert_eq!(kcenter_rows, 3, "MrKCenter, RobustKCenter, CeccarelloKCenter");
    }

    #[test]
    fn arena_ls_cap_drops_the_sequential_baseline() {
        use crate::geometry::MetricKind;
        let rep = arena(&tiny(), 400, &[0.0], &[MetricKind::L2Sq], 100, &NativeBackend).unwrap();
        assert_eq!(rep.rows.len(), 33, "3 datasets x 11 algorithms");
        assert!(rep.rows.iter().all(|r| r.algo != "LocalSearch"));
        // The oracle leg always runs the full registry (its companion is
        // tiny by construction).
        assert_eq!(rep.oracle.len(), 12);
    }

    #[test]
    fn metric_compare_rows_are_deterministic_per_metric() {
        use crate::geometry::MetricKind;
        let rows = metric_compare(
            &tiny(),
            1200,
            &[MetricKind::L2Sq, MetricKind::L1],
            &NativeBackend,
        )
        .unwrap();
        assert_eq!(rows.len(), 6, "2 metrics x 3 algorithms");
        for r in &rows {
            assert!(r.deterministic, "{} under {} diverged on replay", r.algo, r.metric);
            assert!(r.cost_median.is_finite() && r.cost_median > 0.0, "{}", r.algo);
            assert!(r.rounds >= 1);
        }
    }
}
