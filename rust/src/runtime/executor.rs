//! PJRT-backed compute backend: loads the AOT HLO-text artifacts and runs
//! them on the CPU PJRT client (the `xla` crate).
//!
//! Pipeline per bucket (lazy, cached):
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `PjRtClient::compile` → `PjRtLoadedExecutable`.
//!
//! HLO *text* is the interchange format (see python/compile/aot.py and
//! /opt/xla-example/README.md): jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! Concurrency: the PJRT wrapper types are raw-pointer handles without
//! `Send`/`Sync`, so the whole backend is wrapped in a `Mutex` and executes
//! one call at a time — the CPU client is internally multi-threaded, and the
//! MapReduce engine is configured sequentially when this backend is chosen
//! (the paper's timing methodology measures per-machine compute either way).

use super::bucket::{mask, pad_rows, select};
use super::manifest::{Entry, Manifest};
use super::{AssignOut, ComputeBackend, LloydStepOut};
use crate::geometry::PointSet;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    entry: Entry,
}

struct Inner {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, usize, usize, usize), Compiled>, // (func,b,k,d)
}

/// XLA/PJRT compute backend (see module docs).
pub struct XlaBackend {
    inner: Mutex<Inner>,
}

// SAFETY: all raw PJRT handles live behind the Mutex; every use of the
// client/executables goes through `lock()`, so only one thread touches them
// at a time. The PJRT CPU client itself is thread-safe for compilation and
// execution; the wrapper types merely lack the marker traits.
unsafe impl Send for XlaBackend {}
unsafe impl Sync for XlaBackend {}

impl XlaBackend {
    /// Load the manifest in `artifact_dir` and connect the PJRT CPU client.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        anyhow::ensure!(
            !manifest.entries.is_empty(),
            "artifact manifest is empty — run `make artifacts`"
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "XlaBackend: platform={} artifacts={} dir={}",
            client.platform_name(),
            manifest.entries.len(),
            artifact_dir.display()
        );
        Ok(XlaBackend {
            inner: Mutex::new(Inner {
                client,
                manifest,
                cache: HashMap::new(),
            }),
        })
    }

    /// True if an artifact exists for `func` at (k, d).
    pub fn supports(&self, func: &str, k: usize, d: usize) -> bool {
        let inner = self.inner.lock().expect("xla backend poisoned");
        select(&inner.manifest.entries_for(func), k, d).is_some()
    }

    /// Run `func` over `points`/`centers`, padding to the chosen bucket and
    /// executing once per point-block. Returns per-output flat f32/i32 data
    /// merged across blocks, plus the bucket's k (outputs per center are
    /// truncated by the caller).
    fn run(
        &self,
        func: &str,
        points: &PointSet,
        centers: &PointSet,
    ) -> Result<RunOut> {
        let n = points.len();
        let k = centers.len();
        let d = points.dim();
        anyhow::ensure!(d == centers.dim(), "dim mismatch");

        let mut inner = self.inner.lock().expect("xla backend poisoned");
        let inner = &mut *inner;

        // Resolve + compile the bucket (cached).
        let entry = {
            let entries = inner.manifest.entries_for(func);
            let e = select(&entries, k, d).with_context(|| {
                format!("no artifact for func={func} k={k} d={d}")
            })?;
            e.clone()
        };
        let key = (func.to_string(), entry.b, entry.k, entry.d);
        if !inner.cache.contains_key(&key) {
            let path = inner.manifest.path_of(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            log::debug!("compiled artifact {}", entry.file);
            inner.cache.insert(
                key.clone(),
                Compiled {
                    exe,
                    entry: entry.clone(),
                },
            );
        }
        let compiled = &inner.cache[&key];
        let (bb, bk) = (compiled.entry.b, compiled.entry.k);

        // Centers padded once per call.
        let cpad = pad_rows(centers.flat(), k, d, bk, 0.0);
        let cmask = mask(k, bk);
        let c_lit = xla::Literal::vec1(&cpad).reshape(&[bk as i64, d as i64])?;
        let cm_lit = xla::Literal::vec1(&cmask);

        let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); compiled.entry.n_outputs];
        let mut out_idx: Vec<Vec<u32>> = vec![Vec::new(); compiled.entry.n_outputs];

        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + bb).min(n);
            let rows = hi - lo;
            let ppad = pad_rows(&points.flat()[lo * d..hi * d], rows, d, bb, 0.0);
            let pmask = mask(rows, bb);
            let p_lit = xla::Literal::vec1(&ppad).reshape(&[bb as i64, d as i64])?;
            let pm_lit = xla::Literal::vec1(&pmask);

            let result = compiled
                .exe
                .execute::<&xla::Literal>(&[&p_lit, &c_lit, &pm_lit, &cm_lit])?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            anyhow::ensure!(
                tuple.len() == compiled.entry.n_outputs,
                "artifact {} returned {} outputs, manifest says {}",
                compiled.entry.file,
                tuple.len(),
                compiled.entry.n_outputs
            );
            for (slot, lit) in tuple.into_iter().enumerate() {
                match lit.ty()? {
                    xla::ElementType::S32 => {
                        let v = lit.to_vec::<i32>()?;
                        out_idx[slot].extend(v.into_iter().map(|x| x as u32));
                    }
                    _ => {
                        let v = lit.to_vec::<f32>()?;
                        outputs[slot].extend(v);
                    }
                }
            }
            lo = hi;
        }

        Ok(RunOut {
            f32s: outputs,
            u32s: out_idx,
            bucket_b: bb,
            bucket_k: bk,
            n,
            k,
            d,
        })
    }
}

struct RunOut {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
    bucket_b: usize,
    bucket_k: usize,
    n: usize,
    k: usize,
    d: usize,
}

impl ComputeBackend for XlaBackend {
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut {
        let out = self
            .run("assign", points, centers)
            .expect("xla assign failed");
        // Outputs per block: (min_sqdist f32[B], argmin s32[B]); blocks are
        // concatenated, so truncate to n (padding rows land past n only in
        // the final block and were already included — drop them).
        let mut sqdist = out.f32s[0].clone();
        let mut idx = out.u32s[1].clone();
        sqdist.truncate(out.n);
        idx.truncate(out.n);
        // Padded blocks can emit trailing rows only at the very end; the
        // per-block layout is contiguous because bucket_b divides each
        // block's output length.
        debug_assert!(out.f32s[0].len() % out.bucket_b == 0);
        AssignOut { sqdist, idx }
    }

    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut {
        let out = self
            .run("lloyd_step", points, centers)
            .expect("xla lloyd_step failed");
        // Outputs per block: sums f32[K,D], counts f32[K], cost_median f32[],
        // cost_means f32[] — sum across blocks, truncate K to k.
        let (bk, k, d) = (out.bucket_k, out.k, out.d);
        let blocks = out.f32s[0].len() / (bk * d);
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0.0f64; k];
        let mut cost_median = 0.0f64;
        let mut cost_means = 0.0f64;
        for blk in 0..blocks {
            let s = &out.f32s[0][blk * bk * d..(blk + 1) * bk * d];
            for c in 0..k {
                for j in 0..d {
                    sums[c * d + j] += s[c * d + j] as f64;
                }
            }
            let cn = &out.f32s[1][blk * bk..(blk + 1) * bk];
            for c in 0..k {
                counts[c] += cn[c] as f64;
            }
            cost_median += out.f32s[2][blk] as f64;
            cost_means += out.f32s[3][blk] as f64;
        }
        LloydStepOut {
            sums,
            counts,
            cost_median,
            cost_means,
        }
    }

    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64) {
        let out = self
            .run("weight_histogram", points, centers)
            .expect("xla weight_histogram failed");
        let (bk, k) = (out.bucket_k, out.k);
        let blocks = out.f32s[0].len() / bk;
        let mut w = vec![0.0f64; k];
        let mut cost = 0.0f64;
        for blk in 0..blocks {
            let cn = &out.f32s[0][blk * bk..(blk + 1) * bk];
            for c in 0..k {
                w[c] += cn[c] as f64;
            }
            cost += out.f32s[1][blk] as f64;
        }
        (w, cost)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
