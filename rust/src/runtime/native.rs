//! Pure-rust reference backend.
//!
//! Shares exact semantics with the L2 JAX model (`python/compile/model.py`):
//! nearest-center assignment by squared Euclidean distance, first index wins
//! ties, per-center sums/counts of assigned points, and both objective
//! shares. Works for any (n, k, d); this is also what the XLA path is
//! cross-checked against in tests.
//!
//! The assign inner loop is the library's single hottest piece of code (it
//! is what the paper's cluster spent its time on too), so it gets a blocked,
//! plane-major (SoA-transposed) implementation and, for large inputs, runs
//! its blocks on the shared worker pool; see EXPERIMENTS.md §Perf.
//!
//! ## General metrics
//!
//! The squared-Euclidean kernel ([`NativeBackend::assign`]) is the
//! specialized fast path; [`assign_metric_generic`] /
//! [`lloyd_step_metric_generic`] serve every registered [`MetricKind`]
//! through the same tile/block/pool structure with metric-dispatched inner
//! loops (dispatch happens once per tile batch, outside the hot loops).
//! The generic path's `L2Sq` arm replicates the fast path's op sequence
//! exactly, so the two are bit-identical — property-tested in
//! `rust/tests/prop_metrics.rs`, which is what licenses the
//! `ComputeBackend::assign_metric` dispatch to route `l2sq` to the fast
//! path.
//!
//! ## Determinism contract
//!
//! Results never depend on the worker count or schedule: work is cut into
//! fixed [`PAR_BLOCK`]-point blocks regardless of how many threads execute
//! them, each block writes either a disjoint output range (`assign`) or a
//! private partial (`lloyd_step`), and partials are merged in block-index
//! order on the calling thread. This is what makes `parallel = true` and
//! `parallel = false` cluster runs bit-identical (rust/tests/prop_data_plane.rs).

use super::{weights_from_assign_metric, AssignOut, ComputeBackend, LloydStepOut};
use crate::geometry::{MetricKind, PointSet};
use crate::util::pool;
use std::sync::Mutex;

/// Pure-rust compute backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

/// Tile height for the blocked assign loop: big enough to amortize the
/// center-loop setup, small enough that a (tile × k) walk stays in L1/L2.
const TILE: usize = 256;

/// Points per parallel work item: a multiple of [`TILE`] so tiles never
/// straddle block boundaries. Fixed (not derived from the thread count) so
/// the f64 merge order — and therefore the result — is schedule-independent.
pub const PAR_BLOCK: usize = 64 * TILE;

/// Inputs below this size stay on the calling thread: one block of work
/// cannot amortize a pool handoff. Public so the kernel bench can tell
/// whether a workload actually exercises the pooled path.
pub const PAR_MIN: usize = 2 * PAR_BLOCK;

/// Plane-major (SoA) assignment of rows `[lo, lo + out_len)` of `points`,
/// writing into `sqdist`/`idx` local slices indexed from 0.
///
/// Generalizes the old d=3 fast path to arbitrary `d`: the row-major
/// interleave defeats auto-vectorization of the center loop, so each TILE
/// of points is transposed once into coordinate planes; the inner loops
/// then walk *points* for a fixed center coordinate — branch-free selects
/// over contiguous lanes that LLVM vectorizes to masked min/blend (with
/// `-C target-cpu=native`). At d=3, k=25 this measured 1943 Mdist/s vs 326
/// for the scalar point-major loop (EXPERIMENTS.md §Perf).
fn assign_block(
    points: &PointSet,
    centers: &PointSet,
    lo: usize,
    sqdist: &mut [f32],
    idx: &mut [u32],
) {
    let d = points.dim();
    let k = centers.len();
    let pflat = points.flat();
    let cflat = centers.flat();
    let n = sqdist.len();
    debug_assert_eq!(idx.len(), n);
    // Scratch for one tile's coordinate planes (plane j at j*TILE..).
    let mut planes = vec![0.0f32; TILE * d];
    let mut t0 = 0usize;
    while t0 < n {
        let t1 = (t0 + TILE).min(n);
        let tn = t1 - t0;
        for i in 0..tn {
            let base = (lo + t0 + i) * d;
            for j in 0..d {
                planes[j * TILE + i] = pflat[base + j];
            }
        }
        let mut best = [f32::INFINITY; TILE];
        let mut bidx = [0u32; TILE];
        let mut acc = [0.0f32; TILE];
        for c in 0..k {
            let crow = &cflat[c * d..(c + 1) * d];
            // First coordinate initializes the accumulator, the rest add:
            // the same j-order as a scalar row walk, so results are
            // bit-identical to the point-major loop.
            let p0 = &planes[0..TILE];
            let c0 = crow[0];
            for i in 0..tn {
                let t = p0[i] - c0;
                acc[i] = t * t;
            }
            for (j, &cj) in crow.iter().enumerate().skip(1) {
                let pj = &planes[j * TILE..(j + 1) * TILE];
                for i in 0..tn {
                    let t = pj[i] - cj;
                    acc[i] += t * t;
                }
            }
            let cid = c as u32;
            for i in 0..tn {
                let better = acc[i] < best[i];
                best[i] = if better { acc[i] } else { best[i] };
                bidx[i] = if better { cid } else { bidx[i] };
            }
        }
        for i in 0..tn {
            sqdist[t0 + i] = best[i].max(0.0);
            idx[t0 + i] = bidx[i];
        }
        t0 = t1;
    }
}

/// Plane-major assignment of rows `[lo, lo + out_len)` under any
/// registered metric — the generic counterpart of [`assign_block`], same
/// tile transpose, metric-dispatched inner loops. The `L2Sq`/`L2` arm is
/// the fast path's accumulation verbatim (same j-order), so its surrogates
/// are bit-identical to [`assign_block`]'s; the L1/Chebyshev/Cosine arms
/// replay the scalar op sequences of [`MetricKind::surrogate`] plane-major,
/// so kernel and scalar (`assign_full_metric`) surrogates agree exactly.
fn assign_block_metric(
    points: &PointSet,
    centers: &PointSet,
    lo: usize,
    surr: &mut [f32],
    idx: &mut [u32],
    metric: MetricKind,
) {
    let d = points.dim();
    let k = centers.len();
    let pflat = points.flat();
    let cflat = centers.flat();
    let n = surr.len();
    debug_assert_eq!(idx.len(), n);
    let mut planes = vec![0.0f32; TILE * d];
    // Cosine-only precomputation: squared center norms, accumulated in
    // coordinate order (the scalar surrogate's op sequence).
    let mut cnorm2 = Vec::new();
    if metric == MetricKind::Cosine {
        cnorm2 = vec![0.0f32; k];
        for c in 0..k {
            let crow = &cflat[c * d..(c + 1) * d];
            let mut acc = 0.0f32;
            for &cj in crow {
                acc += cj * cj;
            }
            cnorm2[c] = acc;
        }
    }
    let mut pnorm2 = [0.0f32; TILE];
    let mut t0 = 0usize;
    while t0 < n {
        let t1 = (t0 + TILE).min(n);
        let tn = t1 - t0;
        for i in 0..tn {
            let base = (lo + t0 + i) * d;
            for j in 0..d {
                planes[j * TILE + i] = pflat[base + j];
            }
        }
        if metric == MetricKind::Cosine {
            // Squared point norms, plane by plane (coordinate order).
            for x in pnorm2.iter_mut().take(tn) {
                *x = 0.0;
            }
            for j in 0..d {
                let pj = &planes[j * TILE..(j + 1) * TILE];
                for i in 0..tn {
                    pnorm2[i] += pj[i] * pj[i];
                }
            }
        }
        let mut best = [f32::INFINITY; TILE];
        let mut bidx = [0u32; TILE];
        let mut acc = [0.0f32; TILE];
        for c in 0..k {
            let crow = &cflat[c * d..(c + 1) * d];
            let p0 = &planes[0..TILE];
            let c0 = crow[0];
            match metric {
                MetricKind::L2Sq | MetricKind::L2 => {
                    for i in 0..tn {
                        let t = p0[i] - c0;
                        acc[i] = t * t;
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            let t = pj[i] - cj;
                            acc[i] += t * t;
                        }
                    }
                    if metric == MetricKind::L2 {
                        // Convert BEFORE the compare so ties resolve on the
                        // same values (and with the same op order) as the
                        // scalar surrogate, `sq.max(0).sqrt()`.
                        for a in acc.iter_mut().take(tn) {
                            *a = a.max(0.0).sqrt();
                        }
                    }
                }
                MetricKind::L1 => {
                    for i in 0..tn {
                        acc[i] = (p0[i] - c0).abs();
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            acc[i] += (pj[i] - cj).abs();
                        }
                    }
                }
                MetricKind::Chebyshev => {
                    for i in 0..tn {
                        acc[i] = (p0[i] - c0).abs();
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            acc[i] = acc[i].max((pj[i] - cj).abs());
                        }
                    }
                }
                MetricKind::Cosine => {
                    // Dot product plane by plane, then the scalar
                    // surrogate's exact finish: 1 - dot / sqrt(|p|²|c|²)
                    // with the zero-norm convention.
                    for i in 0..tn {
                        acc[i] = p0[i] * c0;
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            acc[i] += pj[i] * cj;
                        }
                    }
                    let nc2 = cnorm2[c];
                    for i in 0..tn {
                        let denom = (pnorm2[i] * nc2).sqrt();
                        acc[i] = if denom > 0.0 {
                            1.0 - acc[i] / denom
                        } else if pnorm2[i] == 0.0 && nc2 == 0.0 {
                            0.0
                        } else {
                            1.0
                        };
                    }
                }
            }
            let cid = c as u32;
            for i in 0..tn {
                let better = acc[i] < best[i];
                best[i] = if better { acc[i] } else { best[i] };
                bidx[i] = if better { cid } else { bidx[i] };
            }
        }
        for i in 0..tn {
            surr[t0 + i] = best[i].max(0.0);
            idx[t0 + i] = bidx[i];
        }
        t0 = t1;
    }
}

/// Generic-metric nearest-center assignment: the same fixed-block pooled
/// driver as [`NativeBackend::assign`], with [`assign_block_metric`] doing
/// the work. `AssignOut::sqdist` holds the metric's *surrogate* (the
/// squared distance under `L2Sq`). Public so the property tests can force
/// the generic path and compare it bit-for-bit against the fast path.
pub fn assign_metric_generic(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
) -> AssignOut {
    assert_eq!(points.dim(), centers.dim(), "dim mismatch");
    assert!(!centers.is_empty(), "no centers");
    let n = points.len();
    let mut out = AssignOut {
        sqdist: vec![0.0; n],
        idx: vec![0; n],
    };
    if n < PAR_MIN {
        assign_block_metric(points, centers, 0, &mut out.sqdist, &mut out.idx, metric);
        return out;
    }
    let slots: Vec<Mutex<(&mut [f32], &mut [u32])>> = out
        .sqdist
        .chunks_mut(PAR_BLOCK)
        .zip(out.idx.chunks_mut(PAR_BLOCK))
        .map(Mutex::new)
        .collect();
    pool::global().run(slots.len(), &|b| {
        let mut guard = slots[b].lock().expect("assign slot poisoned");
        let (sq, ix) = &mut *guard;
        assign_block_metric(points, centers, b * PAR_BLOCK, sq, ix, metric);
    });
    drop(slots);
    out
}

/// Generic-metric Lloyd accumulation: one [`assign_metric_generic`] pass
/// plus the blocked scatter-add, with objective shares mapped through the
/// metric (`cost_median` = Σ d, `cost_means` = Σ d²). Public for the same
/// force-the-generic-path reason as [`assign_metric_generic`].
pub fn lloyd_step_metric_generic(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
) -> LloydStepOut {
    let a = assign_metric_generic(points, centers, metric);
    lloyd_accumulate(points, centers, &a, metric)
}

/// The shared post-assignment half of a Lloyd step (blocked scatter-add of
/// sums/counts + objective shares), used by both the fast path and the
/// generic path so the merge structure stays identical.
fn lloyd_accumulate(
    points: &PointSet,
    centers: &PointSet,
    a: &AssignOut,
    metric: MetricKind,
) -> LloydStepOut {
    let k = centers.len();
    let n = points.len();
    let ranges = block_ranges(n);
    if n < PAR_MIN || ranges.len() <= 1 {
        // Same block structure, executed inline.
        let mut agg = LloydStepOut::default();
        for &(lo, hi) in &ranges {
            agg.merge(&lloyd_block(points, k, lo, hi, a, metric));
        }
        if agg.sums.is_empty() {
            // n == 0: still shape the output for k centers.
            agg.sums = vec![0.0; k * points.dim()];
            agg.counts = vec![0.0; k];
        }
        return agg;
    }
    let partials: Vec<Mutex<Option<LloydStepOut>>> =
        ranges.iter().map(|_| Mutex::new(None)).collect();
    let rref = &ranges;
    pool::global().run(ranges.len(), &|b| {
        let (lo, hi) = rref[b];
        *partials[b].lock().expect("lloyd slot poisoned") =
            Some(lloyd_block(points, k, lo, hi, a, metric));
    });
    // Merge in block-index order: schedule-independent f64 sums.
    let mut agg = LloydStepOut::default();
    for slot in partials {
        let part = slot
            .into_inner()
            .expect("lloyd slot poisoned")
            .expect("block not run");
        agg.merge(&part);
    }
    agg
}

/// Costs + scatter-add of one block's assignment into a private partial.
fn lloyd_block(
    points: &PointSet,
    k: usize,
    lo: usize,
    hi: usize,
    a: &AssignOut,
    metric: MetricKind,
) -> LloydStepOut {
    let d = points.dim();
    let pflat = points.flat();
    let mut out = LloydStepOut {
        sums: vec![0.0; k * d],
        counts: vec![0.0; k],
        cost_median: 0.0,
        cost_means: 0.0,
    };
    // Costs first: a straight-line pass LLVM can pipeline (f32 surrogate →
    // distance per point, f64 accumulators — per-point conversion error is
    // << the f32 distance error itself). Under `L2Sq` this is exactly the
    // historical `d2 as f64` / `d2.sqrt() as f64` pair (surrogates are
    // pre-clamped ≥ 0 by the assign kernels).
    for i in lo..hi {
        let s = a.sqdist[i];
        out.cost_means += metric.means_share_f64(s);
        out.cost_median += metric.to_dist_f32(s) as f64;
    }
    // Scatter-add of coordinate sums over the flat buffer (no row() slice
    // construction in the hot loop).
    for i in lo..hi {
        let c = a.idx[i] as usize;
        let base = i * d;
        let cb = c * d;
        for j in 0..d {
            out.sums[cb + j] += pflat[base + j] as f64;
        }
        out.counts[c] += 1.0;
    }
    out
}

/// Fixed block decomposition of `n` items (see [`PAR_BLOCK`]).
fn block_ranges(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(n / PAR_BLOCK + 1);
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + PAR_BLOCK).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

impl ComputeBackend for NativeBackend {
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut {
        assert_eq!(points.dim(), centers.dim(), "dim mismatch");
        assert!(!centers.is_empty(), "no centers");
        let n = points.len();
        let mut out = AssignOut {
            sqdist: vec![0.0; n],
            idx: vec![0; n],
        };
        if n < PAR_MIN {
            assign_block(points, centers, 0, &mut out.sqdist, &mut out.idx);
            return out;
        }
        // Blocks write disjoint output ranges; hand each to the pool. The
        // result is identical to the serial path because the block cuts
        // are fixed and every write is index-addressed.
        let slots: Vec<Mutex<(&mut [f32], &mut [u32])>> = out
            .sqdist
            .chunks_mut(PAR_BLOCK)
            .zip(out.idx.chunks_mut(PAR_BLOCK))
            .map(Mutex::new)
            .collect();
        pool::global().run(slots.len(), &|b| {
            let mut guard = slots[b].lock().expect("assign slot poisoned");
            let (sq, ix) = &mut *guard;
            assign_block(points, centers, b * PAR_BLOCK, sq, ix);
        });
        drop(slots);
        out
    }

    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut {
        let a = self.assign(points, centers);
        lloyd_accumulate(points, centers, &a, MetricKind::L2Sq)
    }

    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64) {
        // One assign pass; the histogram + cost reduction is shared with
        // every other caller that already holds an AssignOut.
        let a = self.assign(points, centers);
        weights_from_assign_metric(&a, centers.len(), MetricKind::L2Sq)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
        let mut rng = Rng::new(seed);
        PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
    }

    #[test]
    fn assign_matches_bruteforce_all_dims() {
        for d in [1usize, 2, 3, 5, 8] {
            let p = random_ps(500, d, 1);
            let c = random_ps(17, d, 2);
            let got = NativeBackend.assign(&p, &c);
            let (want_d, want_i) = crate::metrics::cost::assign_full(&p, &c);
            assert_eq!(got.idx, want_i, "dim {d}");
            for (a, b) in got.sqdist.iter().zip(&want_d) {
                assert!((a - b).abs() < 1e-5, "dim {d}");
            }
        }
    }

    #[test]
    fn assign_parallel_path_matches_serial() {
        // Cross the PAR_MIN threshold so the pool path runs, and compare
        // bit-for-bit against a forced-serial execution.
        let n = PAR_MIN + 3 * TILE + 7;
        let p = random_ps(n, 3, 9);
        let c = random_ps(25, 3, 10);
        let par = NativeBackend.assign(&p, &c);
        let ser = pool::with_serial(|| NativeBackend.assign(&p, &c));
        assert_eq!(par.idx, ser.idx);
        assert_eq!(par.sqdist, ser.sqdist);
        let pstep = NativeBackend.lloyd_step(&p, &c);
        let sstep = pool::with_serial(|| NativeBackend.lloyd_step(&p, &c));
        assert_eq!(pstep.sums, sstep.sums);
        assert_eq!(pstep.counts, sstep.counts);
        assert_eq!(pstep.cost_median.to_bits(), sstep.cost_median.to_bits());
        assert_eq!(pstep.cost_means.to_bits(), sstep.cost_means.to_bits());
    }

    #[test]
    fn assign_first_index_wins_ties() {
        let p = PointSet::from_flat(3, vec![0.0, 0.0, 0.0]);
        // Two identical centers: index 0 must win.
        let c = PointSet::from_flat(3, vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let out = NativeBackend.assign(&p, &c);
        assert_eq!(out.idx, vec![0]);
    }

    #[test]
    fn lloyd_step_counts_and_sums() {
        // 4 points, 2 centers on a line; split 2/2.
        let p = PointSet::from_flat(1, vec![0.0, 0.2, 1.0, 1.2]);
        let c = PointSet::from_flat(1, vec![0.0, 1.0]);
        let out = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(out.counts, vec![2.0, 2.0]);
        assert!((out.sums[0] - 0.2).abs() < 1e-6);
        assert!((out.sums[1] - 2.2).abs() < 1e-6);
        assert!((out.cost_median - 0.4).abs() < 1e-5);
        assert!((out.cost_means - (0.04 + 0.04)).abs() < 1e-5);
    }

    #[test]
    fn lloyd_step_merge() {
        let p = random_ps(400, 3, 3);
        let c = random_ps(8, 3, 4);
        let whole = NativeBackend.lloyd_step(&p, &c);
        let parts = p.chunks(3);
        let mut merged = LloydStepOut::default();
        for part in &parts {
            merged.merge(&NativeBackend.lloyd_step(part, &c));
        }
        assert!((whole.cost_median - merged.cost_median).abs() < 1e-6);
        for (a, b) in whole.sums.iter().zip(&merged.sums) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(whole.counts, merged.counts);
    }

    #[test]
    fn lloyd_step_on_views_matches_owned_blocks() {
        // Zero-copy chunks must produce the same kernel results as owned
        // copies of the same rows.
        let p = random_ps(999, 3, 11);
        let c = random_ps(7, 3, 12);
        for chunk in p.chunks(4) {
            let owned = PointSet::from_flat(3, chunk.flat().to_vec());
            let a = NativeBackend.lloyd_step(&chunk, &c);
            let b = NativeBackend.lloyd_step(&owned, &c);
            assert_eq!(a.sums, b.sums);
            assert_eq!(a.counts, b.counts);
        }
    }

    #[test]
    fn weight_histogram_matches_lloyd_counts() {
        let p = random_ps(1000, 3, 5);
        let c = random_ps(16, 3, 6);
        let (w, cost) = NativeBackend.weight_histogram(&p, &c);
        let step = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(w, step.counts);
        assert!((cost - step.cost_median).abs() < 1e-6);
        assert!((w.iter().sum::<f64>() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn min_dist_is_sqrt_of_assign() {
        let p = random_ps(100, 3, 7);
        let c = random_ps(5, 3, 8);
        let md = NativeBackend.min_dist(&p, &c);
        let a = NativeBackend.assign(&p, &c);
        for (m, d2) in md.iter().zip(&a.sqdist) {
            assert!((m * m - d2).abs() < 1e-5);
        }
    }

    #[test]
    fn generic_l2sq_bit_identical_to_fast_path() {
        for d in [1usize, 3, 7] {
            let p = random_ps(900, d, 21);
            let c = random_ps(13, d, 22);
            let fast = NativeBackend.assign(&p, &c);
            let gen = assign_metric_generic(&p, &c, MetricKind::L2Sq);
            assert_eq!(fast.idx, gen.idx, "dim {d}");
            for (a, b) in fast.sqdist.iter().zip(&gen.sqdist) {
                assert_eq!(a.to_bits(), b.to_bits(), "dim {d}");
            }
            let fs = NativeBackend.lloyd_step(&p, &c);
            let gs = lloyd_step_metric_generic(&p, &c, MetricKind::L2Sq);
            assert_eq!(fs.sums, gs.sums, "dim {d}");
            assert_eq!(fs.counts, gs.counts, "dim {d}");
            assert_eq!(fs.cost_median.to_bits(), gs.cost_median.to_bits(), "dim {d}");
            assert_eq!(fs.cost_means.to_bits(), gs.cost_means.to_bits(), "dim {d}");
        }
    }

    #[test]
    fn generic_matches_scalar_oracle_per_metric() {
        for metric in MetricKind::ALL {
            for d in [1usize, 2, 3, 5] {
                let p = random_ps(400, d, 31);
                let c = random_ps(9, d, 32);
                let got = assign_metric_generic(&p, &c, metric);
                let (want_s, want_i) =
                    crate::metrics::cost::assign_full_metric(&p, &c, metric);
                assert_eq!(got.idx, want_i, "{metric} dim {d}");
                for (a, b) in got.sqdist.iter().zip(&want_s) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{metric} dim {d}");
                }
            }
        }
    }

    #[test]
    fn metric_dispatch_routes_l2sq_to_fast_path_semantics() {
        let p = random_ps(300, 3, 41);
        let c = random_ps(7, 3, 42);
        let via_dispatch = NativeBackend.assign_metric(&p, &c, MetricKind::L2Sq);
        let direct = NativeBackend.assign(&p, &c);
        assert_eq!(via_dispatch.idx, direct.idx);
        assert_eq!(via_dispatch.sqdist, direct.sqdist);
        // And non-L2Sq dispatch returns surrogates in the metric's scale.
        let l1 = NativeBackend.assign_metric(&p, &c, MetricKind::L1);
        let md = NativeBackend.min_dist_metric(&p, &c, MetricKind::L1);
        for (s, m) in l1.sqdist.iter().zip(&md) {
            assert_eq!(s.to_bits(), m.to_bits(), "L1 surrogate is the distance");
        }
    }

    #[test]
    fn generic_parallel_path_matches_serial_per_metric() {
        // Cross PAR_MIN so the pooled generic path runs; compare against a
        // forced-serial execution bit-for-bit (the determinism contract
        // extends to every metric).
        let n = PAR_MIN + 2 * TILE + 5;
        let p = random_ps(n, 3, 51);
        let c = random_ps(11, 3, 52);
        for metric in [MetricKind::L1, MetricKind::Cosine] {
            let par = assign_metric_generic(&p, &c, metric);
            let ser = pool::with_serial(|| assign_metric_generic(&p, &c, metric));
            assert_eq!(par.idx, ser.idx, "{metric}");
            assert_eq!(par.sqdist, ser.sqdist, "{metric}");
        }
    }
}
