//! Pure-rust reference backend.
//!
//! Shares exact semantics with the L2 JAX model (`python/compile/model.py`):
//! nearest-center assignment by squared Euclidean distance, first index wins
//! ties, per-center sums/counts of assigned points, and both objective
//! shares. Works for any (n, k, d); this is also what the XLA path is
//! cross-checked against in tests.
//!
//! The assign inner loop is the library's single hottest piece of code (it
//! is what the paper's cluster spent its time on too), so it gets a blocked,
//! plane-major (SoA-transposed) implementation and, for large inputs, runs
//! its blocks on the shared worker pool; see EXPERIMENTS.md §Perf.
//!
//! ## General metrics
//!
//! The squared-Euclidean kernel ([`NativeBackend::assign`]) is the
//! specialized fast path; [`assign_metric_generic`] /
//! [`lloyd_step_metric_generic`] serve every registered [`MetricKind`]
//! through the same tile/block/pool structure with metric-dispatched inner
//! loops (dispatch happens once per tile batch, outside the hot loops).
//! The generic path's `L2Sq` arm replicates the fast path's op sequence
//! exactly, so the two are bit-identical — property-tested in
//! `rust/tests/prop_metrics.rs`, which is what licenses the
//! `ComputeBackend::assign_metric` dispatch to route `l2sq` to the fast
//! path.
//!
//! ## Determinism contract
//!
//! Results never depend on the worker count or schedule: work is cut into
//! fixed [`PAR_BLOCK`]-point blocks regardless of how many threads execute
//! them, each block writes either a disjoint output range (`assign`) or a
//! private partial (`lloyd_step`), and partials are merged in block-index
//! order on the calling thread. This is what makes `parallel = true` and
//! `parallel = false` cluster runs bit-identical (rust/tests/prop_data_plane.rs).

use super::{
    weights_from_assign_metric, AssignOut, AssignPath, ComputeBackend, LloydStepOut, Precision,
};
use crate::geometry::{MetricKind, PointSet};
use crate::util::pool;
use std::sync::Mutex;

/// Pure-rust compute backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

/// Tile height for the blocked assign loop: big enough to amortize the
/// center-loop setup, small enough that a (tile × k) walk stays in L1/L2.
const TILE: usize = 256;

/// Points per parallel work item: a multiple of [`TILE`] so tiles never
/// straddle block boundaries. Fixed (not derived from the thread count) so
/// the f64 merge order — and therefore the result — is schedule-independent.
pub const PAR_BLOCK: usize = 64 * TILE;

/// Inputs below this size stay on the calling thread: one block of work
/// cannot amortize a pool handoff. Public so the kernel bench can tell
/// whether a workload actually exercises the pooled path.
pub const PAR_MIN: usize = 2 * PAR_BLOCK;

/// Plane-major (SoA) assignment of rows `[lo, lo + out_len)` of `points`,
/// writing into `sqdist`/`idx` local slices indexed from 0.
///
/// Generalizes the old d=3 fast path to arbitrary `d`: the row-major
/// interleave defeats auto-vectorization of the center loop, so each TILE
/// of points is transposed once into coordinate planes; the inner loops
/// then walk *points* for a fixed center coordinate — branch-free selects
/// over contiguous lanes that LLVM vectorizes to masked min/blend (with
/// `-C target-cpu=native`). At d=3, k=25 this measured 1943 Mdist/s vs 326
/// for the scalar point-major loop (EXPERIMENTS.md §Perf).
fn assign_block(
    points: &PointSet,
    centers: &PointSet,
    lo: usize,
    sqdist: &mut [f32],
    idx: &mut [u32],
) {
    let d = points.dim();
    let k = centers.len();
    let pflat = points.flat();
    let cflat = centers.flat();
    let n = sqdist.len();
    debug_assert_eq!(idx.len(), n);
    // Scratch for one tile's coordinate planes (plane j at j*TILE..).
    let mut planes = vec![0.0f32; TILE * d];
    let mut t0 = 0usize;
    while t0 < n {
        let t1 = (t0 + TILE).min(n);
        let tn = t1 - t0;
        for i in 0..tn {
            let base = (lo + t0 + i) * d;
            for j in 0..d {
                planes[j * TILE + i] = pflat[base + j];
            }
        }
        let mut best = [f32::INFINITY; TILE];
        let mut bidx = [0u32; TILE];
        let mut acc = [0.0f32; TILE];
        for c in 0..k {
            let crow = &cflat[c * d..(c + 1) * d];
            // First coordinate initializes the accumulator, the rest add:
            // the same j-order as a scalar row walk, so results are
            // bit-identical to the point-major loop.
            let p0 = &planes[0..TILE];
            let c0 = crow[0];
            for i in 0..tn {
                let t = p0[i] - c0;
                acc[i] = t * t;
            }
            for (j, &cj) in crow.iter().enumerate().skip(1) {
                let pj = &planes[j * TILE..(j + 1) * TILE];
                for i in 0..tn {
                    let t = pj[i] - cj;
                    acc[i] += t * t;
                }
            }
            let cid = c as u32;
            for i in 0..tn {
                let better = acc[i] < best[i];
                best[i] = if better { acc[i] } else { best[i] };
                bidx[i] = if better { cid } else { bidx[i] };
            }
        }
        for i in 0..tn {
            sqdist[t0 + i] = best[i].max(0.0);
            idx[t0 + i] = bidx[i];
        }
        t0 = t1;
    }
}

/// Plane-major assignment of rows `[lo, lo + out_len)` under any
/// registered metric — the generic counterpart of [`assign_block`], same
/// tile transpose, metric-dispatched inner loops. The `L2Sq`/`L2` arm is
/// the fast path's accumulation verbatim (same j-order), so its surrogates
/// are bit-identical to [`assign_block`]'s; the L1/Chebyshev/Cosine arms
/// replay the scalar op sequences of [`MetricKind::surrogate`] plane-major,
/// so kernel and scalar (`assign_full_metric`) surrogates agree exactly.
fn assign_block_metric(
    points: &PointSet,
    centers: &PointSet,
    lo: usize,
    surr: &mut [f32],
    idx: &mut [u32],
    metric: MetricKind,
) {
    let d = points.dim();
    let k = centers.len();
    let pflat = points.flat();
    let cflat = centers.flat();
    let n = surr.len();
    debug_assert_eq!(idx.len(), n);
    let mut planes = vec![0.0f32; TILE * d];
    // Cosine-only precomputation: squared center norms, accumulated in
    // coordinate order (the scalar surrogate's op sequence).
    let mut cnorm2 = Vec::new();
    if metric == MetricKind::Cosine {
        cnorm2 = vec![0.0f32; k];
        for c in 0..k {
            let crow = &cflat[c * d..(c + 1) * d];
            let mut acc = 0.0f32;
            for &cj in crow {
                acc += cj * cj;
            }
            cnorm2[c] = acc;
        }
    }
    let mut pnorm2 = [0.0f32; TILE];
    let mut t0 = 0usize;
    while t0 < n {
        let t1 = (t0 + TILE).min(n);
        let tn = t1 - t0;
        for i in 0..tn {
            let base = (lo + t0 + i) * d;
            for j in 0..d {
                planes[j * TILE + i] = pflat[base + j];
            }
        }
        if metric == MetricKind::Cosine {
            // Squared point norms, plane by plane (coordinate order).
            for x in pnorm2.iter_mut().take(tn) {
                *x = 0.0;
            }
            for j in 0..d {
                let pj = &planes[j * TILE..(j + 1) * TILE];
                for i in 0..tn {
                    pnorm2[i] += pj[i] * pj[i];
                }
            }
        }
        let mut best = [f32::INFINITY; TILE];
        let mut bidx = [0u32; TILE];
        let mut acc = [0.0f32; TILE];
        for c in 0..k {
            let crow = &cflat[c * d..(c + 1) * d];
            let p0 = &planes[0..TILE];
            let c0 = crow[0];
            match metric {
                MetricKind::L2Sq | MetricKind::L2 => {
                    for i in 0..tn {
                        let t = p0[i] - c0;
                        acc[i] = t * t;
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            let t = pj[i] - cj;
                            acc[i] += t * t;
                        }
                    }
                    if metric == MetricKind::L2 {
                        // Convert BEFORE the compare so ties resolve on the
                        // same values (and with the same op order) as the
                        // scalar surrogate, `sq.max(0).sqrt()`.
                        for a in acc.iter_mut().take(tn) {
                            *a = a.max(0.0).sqrt();
                        }
                    }
                }
                MetricKind::L1 => {
                    for i in 0..tn {
                        acc[i] = (p0[i] - c0).abs();
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            acc[i] += (pj[i] - cj).abs();
                        }
                    }
                }
                MetricKind::Chebyshev => {
                    for i in 0..tn {
                        acc[i] = (p0[i] - c0).abs();
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            acc[i] = acc[i].max((pj[i] - cj).abs());
                        }
                    }
                }
                MetricKind::Cosine => {
                    // Dot product plane by plane, then the scalar
                    // surrogate's exact finish: 1 - dot / sqrt(|p|²|c|²)
                    // with the zero-norm convention.
                    for i in 0..tn {
                        acc[i] = p0[i] * c0;
                    }
                    for (j, &cj) in crow.iter().enumerate().skip(1) {
                        let pj = &planes[j * TILE..(j + 1) * TILE];
                        for i in 0..tn {
                            acc[i] += pj[i] * cj;
                        }
                    }
                    let nc2 = cnorm2[c];
                    for i in 0..tn {
                        let denom = (pnorm2[i] * nc2).sqrt();
                        acc[i] = if denom > 0.0 {
                            1.0 - acc[i] / denom
                        } else if pnorm2[i] == 0.0 && nc2 == 0.0 {
                            0.0
                        } else {
                            1.0
                        };
                    }
                }
            }
            let cid = c as u32;
            for i in 0..tn {
                let better = acc[i] < best[i];
                best[i] = if better { acc[i] } else { best[i] };
                bidx[i] = if better { cid } else { bidx[i] };
            }
        }
        for i in 0..tn {
            surr[t0 + i] = best[i].max(0.0);
            idx[t0 + i] = bidx[i];
        }
        t0 = t1;
    }
}

/// Norm-expanded (GEMM-form) assignment of rows `[lo, lo + out_len)`:
/// d² = ‖x‖² + ‖c‖² − 2·x·c with squared center norms precomputed once per
/// call (`cnorm2`) and squared point norms once per tile, so the inner
/// tile loop is a *pure dot product* — a mul-add chain with no subtract,
/// which LLVM turns into straight FMA lanes. Same TILE transpose and
/// first-index-wins select as [`assign_block`].
///
/// Argmin comparisons run on the partial score s = ‖c‖² − 2·x·c (the
/// point norm is constant per point, so the ordering is unchanged); the
/// written surrogate is `(‖x‖² + s).max(0)` — the clamp matters because
/// cancellation can push the expansion slightly negative. This is the
/// ε-equivalent rung of the kernel ladder (ARCHITECTURE.md §Kernel
/// ladder): identical argmins away from exact ties, surrogates within
/// cancellation error of [`assign_block`]'s, but *not* bit-identical.
/// With `sqrt_out` the written surrogate is the `l2` distance instead.
fn assign_block_gemm(
    points: &PointSet,
    centers: &PointSet,
    lo: usize,
    cnorm2: &[f32],
    sqdist: &mut [f32],
    idx: &mut [u32],
    sqrt_out: bool,
) {
    let d = points.dim();
    let k = centers.len();
    let pflat = points.flat();
    let cflat = centers.flat();
    let n = sqdist.len();
    debug_assert_eq!(idx.len(), n);
    let mut planes = vec![0.0f32; TILE * d];
    let mut pnorm2 = [0.0f32; TILE];
    let mut t0 = 0usize;
    while t0 < n {
        let t1 = (t0 + TILE).min(n);
        let tn = t1 - t0;
        for i in 0..tn {
            let base = (lo + t0 + i) * d;
            for j in 0..d {
                planes[j * TILE + i] = pflat[base + j];
            }
        }
        // Squared point norms, plane by plane.
        for x in pnorm2.iter_mut().take(tn) {
            *x = 0.0;
        }
        for j in 0..d {
            let pj = &planes[j * TILE..(j + 1) * TILE];
            for i in 0..tn {
                pnorm2[i] += pj[i] * pj[i];
            }
        }
        let mut best = [f32::INFINITY; TILE];
        let mut bidx = [0u32; TILE];
        let mut acc = [0.0f32; TILE];
        for c in 0..k {
            let crow = &cflat[c * d..(c + 1) * d];
            let p0 = &planes[0..TILE];
            let c0 = crow[0];
            for i in 0..tn {
                acc[i] = p0[i] * c0;
            }
            for (j, &cj) in crow.iter().enumerate().skip(1) {
                let pj = &planes[j * TILE..(j + 1) * TILE];
                for i in 0..tn {
                    acc[i] += pj[i] * cj;
                }
            }
            let nc2 = cnorm2[c];
            let cid = c as u32;
            for i in 0..tn {
                let score = nc2 - 2.0 * acc[i];
                let better = score < best[i];
                best[i] = if better { score } else { best[i] };
                bidx[i] = if better { cid } else { bidx[i] };
            }
        }
        for i in 0..tn {
            let s = (pnorm2[i] + best[i]).max(0.0);
            sqdist[t0 + i] = if sqrt_out { s.sqrt() } else { s };
            idx[t0 + i] = bidx[i];
        }
        t0 = t1;
    }
}

/// Squared center norms in coordinate order (the GEMM form's per-call
/// precomputation).
fn center_sq_norms(centers: &PointSet) -> Vec<f32> {
    let d = centers.dim();
    let cflat = centers.flat();
    (0..centers.len())
        .map(|c| {
            let mut acc = 0.0f32;
            for &cj in &cflat[c * d..(c + 1) * d] {
                acc += cj * cj;
            }
            acc
        })
        .collect()
}

/// The shared pooled driver for the GEMM-form kernel (`sqrt_out` selects
/// `l2` surrogates over `l2sq`).
fn assign_gemm_family(points: &PointSet, centers: &PointSet, sqrt_out: bool) -> AssignOut {
    assert_eq!(points.dim(), centers.dim(), "dim mismatch");
    assert!(!centers.is_empty(), "no centers");
    let n = points.len();
    let cnorm2 = center_sq_norms(centers);
    let mut out = AssignOut {
        sqdist: vec![0.0; n],
        idx: vec![0; n],
    };
    if n < PAR_MIN {
        assign_block_gemm(points, centers, 0, &cnorm2, &mut out.sqdist, &mut out.idx, sqrt_out);
        return out;
    }
    let slots: Vec<Mutex<(&mut [f32], &mut [u32])>> = out
        .sqdist
        .chunks_mut(PAR_BLOCK)
        .zip(out.idx.chunks_mut(PAR_BLOCK))
        .map(Mutex::new)
        .collect();
    let cn = &cnorm2;
    pool::global().run(slots.len(), &|b| {
        let mut guard = slots[b].lock().expect("assign slot poisoned");
        let (sq, ix) = &mut *guard;
        assign_block_gemm(points, centers, b * PAR_BLOCK, cn, sq, ix, sqrt_out);
    });
    drop(slots);
    out
}

/// GEMM-form squared-Euclidean assignment — the norm-expanded rung of the
/// kernel ladder ([`AssignPath::Gemm`]). Same fixed-block pooled driver
/// (and therefore the same determinism contract) as
/// [`NativeBackend::assign`]; see [`FastNativeBackend`] for the config
/// surface and ARCHITECTURE.md §Kernel ladder for the ε-equivalence
/// contract. Public so the bench and the property tests can pin the
/// contract directly against the exact path and the scalar oracle.
pub fn assign_gemm(points: &PointSet, centers: &PointSet) -> AssignOut {
    assign_gemm_family(points, centers, false)
}

/// [`assign_gemm`] under an explicit metric: the GEMM form covers the
/// Euclidean family (`l2sq` surrogates, or `l2` distances via a final
/// sqrt); every other metric falls through to the exact
/// [`assign_metric_generic`] kernels — the ladder never changes
/// non-Euclidean semantics.
pub fn assign_gemm_metric(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
) -> AssignOut {
    match metric {
        MetricKind::L2Sq => assign_gemm_family(points, centers, false),
        MetricKind::L2 => assign_gemm_family(points, centers, true),
        _ => assign_metric_generic(points, centers, metric),
    }
}

/// Generic-metric nearest-center assignment: the same fixed-block pooled
/// driver as [`NativeBackend::assign`], with [`assign_block_metric`] doing
/// the work. `AssignOut::sqdist` holds the metric's *surrogate* (the
/// squared distance under `L2Sq`). Public so the property tests can force
/// the generic path and compare it bit-for-bit against the fast path.
pub fn assign_metric_generic(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
) -> AssignOut {
    assert_eq!(points.dim(), centers.dim(), "dim mismatch");
    assert!(!centers.is_empty(), "no centers");
    let n = points.len();
    let mut out = AssignOut {
        sqdist: vec![0.0; n],
        idx: vec![0; n],
    };
    if n < PAR_MIN {
        assign_block_metric(points, centers, 0, &mut out.sqdist, &mut out.idx, metric);
        return out;
    }
    let slots: Vec<Mutex<(&mut [f32], &mut [u32])>> = out
        .sqdist
        .chunks_mut(PAR_BLOCK)
        .zip(out.idx.chunks_mut(PAR_BLOCK))
        .map(Mutex::new)
        .collect();
    pool::global().run(slots.len(), &|b| {
        let mut guard = slots[b].lock().expect("assign slot poisoned");
        let (sq, ix) = &mut *guard;
        assign_block_metric(points, centers, b * PAR_BLOCK, sq, ix, metric);
    });
    drop(slots);
    out
}

/// Generic-metric Lloyd accumulation: one [`assign_metric_generic`] pass
/// plus the blocked scatter-add, with objective shares mapped through the
/// metric (`cost_median` = Σ d, `cost_means` = Σ d²). Public for the same
/// force-the-generic-path reason as [`assign_metric_generic`].
pub fn lloyd_step_metric_generic(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
) -> LloydStepOut {
    let a = assign_metric_generic(points, centers, metric);
    lloyd_accumulate(points, centers, &a, metric)
}

/// The shared post-assignment half of a Lloyd step (blocked scatter-add of
/// sums/counts + objective shares), used by both the fast path and the
/// generic path so the merge structure stays identical. `pub(crate)` so the
/// Hamerly-pruned Lloyd path (`algorithms/lloyd.rs`) can feed its pruned
/// assignment through the *same* accumulation and stay bit-identical to
/// the unpruned kernels.
pub(crate) fn lloyd_accumulate(
    points: &PointSet,
    centers: &PointSet,
    a: &AssignOut,
    metric: MetricKind,
) -> LloydStepOut {
    let k = centers.len();
    let n = points.len();
    let ranges = block_ranges(n);
    if n < PAR_MIN || ranges.len() <= 1 {
        // Same block structure, executed inline.
        let mut agg = LloydStepOut::default();
        for &(lo, hi) in &ranges {
            agg.merge(&lloyd_block(points, k, lo, hi, a, metric));
        }
        if agg.sums.is_empty() {
            // n == 0: still shape the output for k centers.
            agg.sums = vec![0.0; k * points.dim()];
            agg.counts = vec![0.0; k];
        }
        return agg;
    }
    let partials: Vec<Mutex<Option<LloydStepOut>>> =
        ranges.iter().map(|_| Mutex::new(None)).collect();
    let rref = &ranges;
    pool::global().run(ranges.len(), &|b| {
        let (lo, hi) = rref[b];
        *partials[b].lock().expect("lloyd slot poisoned") =
            Some(lloyd_block(points, k, lo, hi, a, metric));
    });
    // Merge in block-index order: schedule-independent f64 sums.
    let mut agg = LloydStepOut::default();
    for slot in partials {
        let part = slot
            .into_inner()
            .expect("lloyd slot poisoned")
            .expect("block not run");
        agg.merge(&part);
    }
    agg
}

/// Costs + scatter-add of one block's assignment into a private partial.
fn lloyd_block(
    points: &PointSet,
    k: usize,
    lo: usize,
    hi: usize,
    a: &AssignOut,
    metric: MetricKind,
) -> LloydStepOut {
    let d = points.dim();
    let pflat = points.flat();
    let mut out = LloydStepOut {
        sums: vec![0.0; k * d],
        counts: vec![0.0; k],
        cost_median: 0.0,
        cost_means: 0.0,
    };
    // Costs first: a straight-line pass LLVM can pipeline (f32 surrogate →
    // distance per point, f64 accumulators — per-point conversion error is
    // << the f32 distance error itself). Under `L2Sq` this is exactly the
    // historical `d2 as f64` / `d2.sqrt() as f64` pair (surrogates are
    // pre-clamped ≥ 0 by the assign kernels).
    for i in lo..hi {
        let s = a.sqdist[i];
        out.cost_means += metric.means_share_f64(s);
        out.cost_median += metric.to_dist_f32(s) as f64;
    }
    // Scatter-add of coordinate sums over the flat buffer (no row() slice
    // construction in the hot loop).
    for i in lo..hi {
        let c = a.idx[i] as usize;
        let base = i * d;
        let cb = c * d;
        for j in 0..d {
            out.sums[cb + j] += pflat[base + j] as f64;
        }
        out.counts[c] += 1.0;
    }
    out
}

/// The f32-precision counterpart of [`lloyd_block`]: single-precision
/// accumulators within the fixed block, widened to `f64` only at the
/// block boundary. Per-accumulator op order is still fixed (point-index
/// ascending), so the result is deterministic at any thread count — just
/// not bit-identical to the f64 path (ε contract: ARCHITECTURE.md
/// §Kernel ladder).
fn lloyd_block_f32(
    points: &PointSet,
    k: usize,
    lo: usize,
    hi: usize,
    a: &AssignOut,
    metric: MetricKind,
) -> LloydStepOut {
    let d = points.dim();
    let pflat = points.flat();
    let mut sums = vec![0.0f32; k * d];
    let mut counts = vec![0.0f32; k];
    let mut cost_median = 0.0f32;
    let mut cost_means = 0.0f32;
    for i in lo..hi {
        let s = a.sqdist[i];
        let dist = metric.to_dist_f32(s);
        cost_median += dist;
        cost_means += match metric {
            MetricKind::L2Sq => s.max(0.0),
            _ => dist * dist,
        };
    }
    for i in lo..hi {
        let c = a.idx[i] as usize;
        let base = i * d;
        let cb = c * d;
        for j in 0..d {
            sums[cb + j] += pflat[base + j];
        }
        counts[c] += 1.0;
    }
    LloydStepOut {
        sums: sums.into_iter().map(f64::from).collect(),
        counts: counts.into_iter().map(f64::from).collect(),
        cost_median: cost_median as f64,
        cost_means: cost_means as f64,
    }
}

/// [`lloyd_accumulate`] with f32 per-block accumulators
/// ([`Precision::F32`]) — same fixed-block decomposition and in-order f64
/// merge, so the determinism contract is untouched.
fn lloyd_accumulate_f32(
    points: &PointSet,
    centers: &PointSet,
    a: &AssignOut,
    metric: MetricKind,
) -> LloydStepOut {
    let k = centers.len();
    let n = points.len();
    let ranges = block_ranges(n);
    if n < PAR_MIN || ranges.len() <= 1 {
        let mut agg = LloydStepOut::default();
        for &(lo, hi) in &ranges {
            agg.merge(&lloyd_block_f32(points, k, lo, hi, a, metric));
        }
        if agg.sums.is_empty() {
            agg.sums = vec![0.0; k * points.dim()];
            agg.counts = vec![0.0; k];
        }
        return agg;
    }
    let partials: Vec<Mutex<Option<LloydStepOut>>> =
        ranges.iter().map(|_| Mutex::new(None)).collect();
    let rref = &ranges;
    pool::global().run(ranges.len(), &|b| {
        let (lo, hi) = rref[b];
        *partials[b].lock().expect("lloyd slot poisoned") =
            Some(lloyd_block_f32(points, k, lo, hi, a, metric));
    });
    let mut agg = LloydStepOut::default();
    for slot in partials {
        let part = slot
            .into_inner()
            .expect("lloyd slot poisoned")
            .expect("block not run");
        agg.merge(&part);
    }
    agg
}

/// Fixed block decomposition of `n` items (see [`PAR_BLOCK`]).
fn block_ranges(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(n / PAR_BLOCK + 1);
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + PAR_BLOCK).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

impl ComputeBackend for NativeBackend {
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut {
        assert_eq!(points.dim(), centers.dim(), "dim mismatch");
        assert!(!centers.is_empty(), "no centers");
        let n = points.len();
        let mut out = AssignOut {
            sqdist: vec![0.0; n],
            idx: vec![0; n],
        };
        if n < PAR_MIN {
            assign_block(points, centers, 0, &mut out.sqdist, &mut out.idx);
            return out;
        }
        // Blocks write disjoint output ranges; hand each to the pool. The
        // result is identical to the serial path because the block cuts
        // are fixed and every write is index-addressed.
        let slots: Vec<Mutex<(&mut [f32], &mut [u32])>> = out
            .sqdist
            .chunks_mut(PAR_BLOCK)
            .zip(out.idx.chunks_mut(PAR_BLOCK))
            .map(Mutex::new)
            .collect();
        pool::global().run(slots.len(), &|b| {
            let mut guard = slots[b].lock().expect("assign slot poisoned");
            let (sq, ix) = &mut *guard;
            assign_block(points, centers, b * PAR_BLOCK, sq, ix);
        });
        drop(slots);
        out
    }

    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut {
        let a = self.assign(points, centers);
        lloyd_accumulate(points, centers, &a, MetricKind::L2Sq)
    }

    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64) {
        // One assign pass; the histogram + cost reduction is shared with
        // every other caller that already holds an AssignOut.
        let a = self.assign(points, centers);
        weights_from_assign_metric(&a, centers.len(), MetricKind::L2Sq)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The opt-in fast-path backend — the configurable rungs of the kernel
/// speed ladder (`cluster.kernel` / `cluster.precision`; see
/// ARCHITECTURE.md §Kernel ladder for the full contract).
///
/// * [`AssignPath::Gemm`] serves the Euclidean family (`l2sq`/`l2`)
///   through the norm-expanded [`assign_gemm`] kernel — ε-equivalent to
///   the exact path (identical argmins away from exact ties).
/// * [`Precision::F32`] accumulates the Lloyd reduction in single
///   precision per fixed block — ε-equivalent objective shares and sums;
///   counts stay exact (they are whole numbers well inside f32 range).
///
/// Non-Euclidean metrics always route to the exact generic kernels, and
/// `FastNativeBackend { assign_path: Exact, precision: F64 }` reproduces
/// [`NativeBackend`] bit-for-bit. Both knobs keep the determinism
/// contract: fixed blocks, in-order merges, schedule-independent results.
///
/// The exact path and the GEMM path agree on assignments:
///
/// ```
/// use mrcluster::geometry::PointSet;
/// use mrcluster::runtime::{
///     AssignPath, ComputeBackend, FastNativeBackend, NativeBackend, Precision,
/// };
///
/// // Two well-separated clusters, two centers.
/// let points = PointSet::from_flat(2, vec![0.1, 0.0, 0.2, 0.1, 9.0, 9.1, 9.2, 9.0]);
/// let centers = PointSet::from_flat(2, vec![0.0, 0.0, 9.0, 9.0]);
/// let fast = FastNativeBackend {
///     assign_path: AssignPath::Gemm,
///     precision: Precision::F32,
/// };
/// assert_eq!(fast.assign(&points, &centers).idx, NativeBackend.assign(&points, &centers).idx);
/// ```
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FastNativeBackend {
    /// Which assign kernel serves the Euclidean family.
    pub assign_path: AssignPath,
    /// Accumulator precision for the Lloyd reduction.
    pub precision: Precision,
}

impl ComputeBackend for FastNativeBackend {
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut {
        match self.assign_path {
            AssignPath::Exact => NativeBackend.assign(points, centers),
            AssignPath::Gemm => assign_gemm(points, centers),
        }
    }

    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut {
        let a = self.assign(points, centers);
        match self.precision {
            Precision::F64 => lloyd_accumulate(points, centers, &a, MetricKind::L2Sq),
            Precision::F32 => lloyd_accumulate_f32(points, centers, &a, MetricKind::L2Sq),
        }
    }

    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64) {
        // Histogram counts are integral and the cost share stays f64: the
        // precision knob only governs the Lloyd scatter-add accumulators.
        let a = self.assign(points, centers);
        weights_from_assign_metric(&a, centers.len(), MetricKind::L2Sq)
    }

    fn assign_metric(
        &self,
        points: &PointSet,
        centers: &PointSet,
        metric: MetricKind,
    ) -> AssignOut {
        match metric {
            MetricKind::L2Sq => self.assign(points, centers),
            MetricKind::L2 if self.assign_path == AssignPath::Gemm => {
                assign_gemm_metric(points, centers, metric)
            }
            _ => assign_metric_generic(points, centers, metric),
        }
    }

    fn lloyd_step_metric(
        &self,
        points: &PointSet,
        centers: &PointSet,
        metric: MetricKind,
    ) -> LloydStepOut {
        match metric {
            MetricKind::L2Sq => self.lloyd_step(points, centers),
            MetricKind::L2 => {
                let a = self.assign_metric(points, centers, metric);
                match self.precision {
                    Precision::F64 => lloyd_accumulate(points, centers, &a, metric),
                    Precision::F32 => lloyd_accumulate_f32(points, centers, &a, metric),
                }
            }
            // The ladder never changes non-Euclidean semantics.
            _ => lloyd_step_metric_generic(points, centers, metric),
        }
    }

    fn name(&self) -> &'static str {
        match (self.assign_path, self.precision) {
            (AssignPath::Exact, Precision::F64) => "native",
            (AssignPath::Gemm, Precision::F64) => "native+gemm",
            (AssignPath::Exact, Precision::F32) => "native+f32",
            (AssignPath::Gemm, Precision::F32) => "native+gemm+f32",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
        let mut rng = Rng::new(seed);
        PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
    }

    #[test]
    fn assign_matches_bruteforce_all_dims() {
        for d in [1usize, 2, 3, 5, 8] {
            let p = random_ps(500, d, 1);
            let c = random_ps(17, d, 2);
            let got = NativeBackend.assign(&p, &c);
            let (want_d, want_i) = crate::metrics::cost::assign_full(&p, &c);
            assert_eq!(got.idx, want_i, "dim {d}");
            for (a, b) in got.sqdist.iter().zip(&want_d) {
                assert!((a - b).abs() < 1e-5, "dim {d}");
            }
        }
    }

    #[test]
    fn assign_parallel_path_matches_serial() {
        // Cross the PAR_MIN threshold so the pool path runs, and compare
        // bit-for-bit against a forced-serial execution.
        let n = PAR_MIN + 3 * TILE + 7;
        let p = random_ps(n, 3, 9);
        let c = random_ps(25, 3, 10);
        let par = NativeBackend.assign(&p, &c);
        let ser = pool::with_serial(|| NativeBackend.assign(&p, &c));
        assert_eq!(par.idx, ser.idx);
        assert_eq!(par.sqdist, ser.sqdist);
        let pstep = NativeBackend.lloyd_step(&p, &c);
        let sstep = pool::with_serial(|| NativeBackend.lloyd_step(&p, &c));
        assert_eq!(pstep.sums, sstep.sums);
        assert_eq!(pstep.counts, sstep.counts);
        assert_eq!(pstep.cost_median.to_bits(), sstep.cost_median.to_bits());
        assert_eq!(pstep.cost_means.to_bits(), sstep.cost_means.to_bits());
    }

    #[test]
    fn assign_first_index_wins_ties() {
        let p = PointSet::from_flat(3, vec![0.0, 0.0, 0.0]);
        // Two identical centers: index 0 must win.
        let c = PointSet::from_flat(3, vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let out = NativeBackend.assign(&p, &c);
        assert_eq!(out.idx, vec![0]);
    }

    #[test]
    fn lloyd_step_counts_and_sums() {
        // 4 points, 2 centers on a line; split 2/2.
        let p = PointSet::from_flat(1, vec![0.0, 0.2, 1.0, 1.2]);
        let c = PointSet::from_flat(1, vec![0.0, 1.0]);
        let out = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(out.counts, vec![2.0, 2.0]);
        assert!((out.sums[0] - 0.2).abs() < 1e-6);
        assert!((out.sums[1] - 2.2).abs() < 1e-6);
        assert!((out.cost_median - 0.4).abs() < 1e-5);
        assert!((out.cost_means - (0.04 + 0.04)).abs() < 1e-5);
    }

    #[test]
    fn lloyd_step_merge() {
        let p = random_ps(400, 3, 3);
        let c = random_ps(8, 3, 4);
        let whole = NativeBackend.lloyd_step(&p, &c);
        let parts = p.chunks(3);
        let mut merged = LloydStepOut::default();
        for part in &parts {
            merged.merge(&NativeBackend.lloyd_step(part, &c));
        }
        assert!((whole.cost_median - merged.cost_median).abs() < 1e-6);
        for (a, b) in whole.sums.iter().zip(&merged.sums) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(whole.counts, merged.counts);
    }

    #[test]
    fn lloyd_step_on_views_matches_owned_blocks() {
        // Zero-copy chunks must produce the same kernel results as owned
        // copies of the same rows.
        let p = random_ps(999, 3, 11);
        let c = random_ps(7, 3, 12);
        for chunk in p.chunks(4) {
            let owned = PointSet::from_flat(3, chunk.flat().to_vec());
            let a = NativeBackend.lloyd_step(&chunk, &c);
            let b = NativeBackend.lloyd_step(&owned, &c);
            assert_eq!(a.sums, b.sums);
            assert_eq!(a.counts, b.counts);
        }
    }

    #[test]
    fn weight_histogram_matches_lloyd_counts() {
        let p = random_ps(1000, 3, 5);
        let c = random_ps(16, 3, 6);
        let (w, cost) = NativeBackend.weight_histogram(&p, &c);
        let step = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(w, step.counts);
        assert!((cost - step.cost_median).abs() < 1e-6);
        assert!((w.iter().sum::<f64>() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn min_dist_is_sqrt_of_assign() {
        let p = random_ps(100, 3, 7);
        let c = random_ps(5, 3, 8);
        let md = NativeBackend.min_dist(&p, &c);
        let a = NativeBackend.assign(&p, &c);
        for (m, d2) in md.iter().zip(&a.sqdist) {
            assert!((m * m - d2).abs() < 1e-5);
        }
    }

    #[test]
    fn generic_l2sq_bit_identical_to_fast_path() {
        for d in [1usize, 3, 7] {
            let p = random_ps(900, d, 21);
            let c = random_ps(13, d, 22);
            let fast = NativeBackend.assign(&p, &c);
            let gen = assign_metric_generic(&p, &c, MetricKind::L2Sq);
            assert_eq!(fast.idx, gen.idx, "dim {d}");
            for (a, b) in fast.sqdist.iter().zip(&gen.sqdist) {
                assert_eq!(a.to_bits(), b.to_bits(), "dim {d}");
            }
            let fs = NativeBackend.lloyd_step(&p, &c);
            let gs = lloyd_step_metric_generic(&p, &c, MetricKind::L2Sq);
            assert_eq!(fs.sums, gs.sums, "dim {d}");
            assert_eq!(fs.counts, gs.counts, "dim {d}");
            assert_eq!(fs.cost_median.to_bits(), gs.cost_median.to_bits(), "dim {d}");
            assert_eq!(fs.cost_means.to_bits(), gs.cost_means.to_bits(), "dim {d}");
        }
    }

    #[test]
    fn generic_matches_scalar_oracle_per_metric() {
        for metric in MetricKind::ALL {
            for d in [1usize, 2, 3, 5] {
                let p = random_ps(400, d, 31);
                let c = random_ps(9, d, 32);
                let got = assign_metric_generic(&p, &c, metric);
                let (want_s, want_i) =
                    crate::metrics::cost::assign_full_metric(&p, &c, metric);
                assert_eq!(got.idx, want_i, "{metric} dim {d}");
                for (a, b) in got.sqdist.iter().zip(&want_s) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{metric} dim {d}");
                }
            }
        }
    }

    #[test]
    fn metric_dispatch_routes_l2sq_to_fast_path_semantics() {
        let p = random_ps(300, 3, 41);
        let c = random_ps(7, 3, 42);
        let via_dispatch = NativeBackend.assign_metric(&p, &c, MetricKind::L2Sq);
        let direct = NativeBackend.assign(&p, &c);
        assert_eq!(via_dispatch.idx, direct.idx);
        assert_eq!(via_dispatch.sqdist, direct.sqdist);
        // And non-L2Sq dispatch returns surrogates in the metric's scale.
        let l1 = NativeBackend.assign_metric(&p, &c, MetricKind::L1);
        let md = NativeBackend.min_dist_metric(&p, &c, MetricKind::L1);
        for (s, m) in l1.sqdist.iter().zip(&md) {
            assert_eq!(s.to_bits(), m.to_bits(), "L1 surrogate is the distance");
        }
    }

    #[test]
    fn generic_parallel_path_matches_serial_per_metric() {
        // Cross PAR_MIN so the pooled generic path runs; compare against a
        // forced-serial execution bit-for-bit (the determinism contract
        // extends to every metric).
        let n = PAR_MIN + 2 * TILE + 5;
        let p = random_ps(n, 3, 51);
        let c = random_ps(11, 3, 52);
        for metric in [MetricKind::L1, MetricKind::Cosine] {
            let par = assign_metric_generic(&p, &c, metric);
            let ser = pool::with_serial(|| assign_metric_generic(&p, &c, metric));
            assert_eq!(par.idx, ser.idx, "{metric}");
            assert_eq!(par.sqdist, ser.sqdist, "{metric}");
        }
    }

    #[test]
    fn gemm_surrogates_close_to_exact_all_dims() {
        for d in [1usize, 2, 3, 5, 8] {
            let p = random_ps(700, d, 61);
            let c = random_ps(19, d, 62);
            let exact = NativeBackend.assign(&p, &c);
            let gemm = assign_gemm(&p, &c);
            for i in 0..p.len() {
                // The ε contract: the GEMM surrogate of whatever center it
                // picked is within cancellation error of the exact squared
                // distance to that center.
                let want = crate::geometry::metric::sq_dist(p.row(i), c.row(gemm.idx[i] as usize));
                assert!(
                    (gemm.sqdist[i] - want).abs() <= 1e-4 * (1.0 + want),
                    "dim {d} i {i}: gemm {} vs exact {want}",
                    gemm.sqdist[i]
                );
                // And its pick is never meaningfully worse than the exact one.
                assert!(
                    want <= exact.sqdist[i] + 1e-4 * (1.0 + exact.sqdist[i]),
                    "dim {d} i {i}: gemm picked a worse center"
                );
            }
        }
    }

    #[test]
    fn gemm_parallel_path_matches_serial() {
        let n = PAR_MIN + 2 * TILE + 11;
        let p = random_ps(n, 3, 71);
        let c = random_ps(25, 3, 72);
        let par = assign_gemm(&p, &c);
        let ser = pool::with_serial(|| assign_gemm(&p, &c));
        assert_eq!(par.idx, ser.idx);
        assert_eq!(par.sqdist, ser.sqdist);
    }

    #[test]
    fn gemm_surrogates_clamped_and_ties_deterministic() {
        // A point exactly on a duplicated center: cancellation would go
        // negative without the clamp, and the duplicate tie must keep a
        // deterministic winner.
        let p = PointSet::from_flat(3, vec![2.0, 3.0, 4.0]);
        let c = PointSet::from_flat(3, vec![2.0, 3.0, 4.0, 2.0, 3.0, 4.0]);
        let out = assign_gemm(&p, &c);
        assert!(out.sqdist[0] >= 0.0);
        assert!(out.sqdist[0] < 1e-4);
        let rerun = assign_gemm(&p, &c);
        assert_eq!(out.idx, rerun.idx);
    }

    #[test]
    fn gemm_l2_surrogate_is_distance() {
        let p = random_ps(300, 3, 81);
        let c = random_ps(9, 3, 82);
        let sq = assign_gemm_metric(&p, &c, MetricKind::L2Sq);
        let l2 = assign_gemm_metric(&p, &c, MetricKind::L2);
        assert_eq!(sq.idx, l2.idx);
        for (s, d) in sq.sqdist.iter().zip(&l2.sqdist) {
            assert!((d * d - s).abs() <= 1e-4 * (1.0 + s), "{d} vs sqrt({s})");
        }
        // Non-Euclidean metrics fall through to the exact generic kernel.
        let via_gemm = assign_gemm_metric(&p, &c, MetricKind::L1);
        let exact = assign_metric_generic(&p, &c, MetricKind::L1);
        assert_eq!(via_gemm.idx, exact.idx);
        assert_eq!(via_gemm.sqdist, exact.sqdist);
    }

    #[test]
    fn fast_backend_default_knobs_reproduce_native() {
        let p = random_ps(800, 3, 91);
        let c = random_ps(13, 3, 92);
        let fast = FastNativeBackend::default();
        assert_eq!(fast.name(), "native");
        let a = fast.assign(&p, &c);
        let b = NativeBackend.assign(&p, &c);
        assert_eq!(a.idx, b.idx);
        assert_eq!(a.sqdist, b.sqdist);
        let fs = fast.lloyd_step(&p, &c);
        let ns = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(fs.sums, ns.sums);
        assert_eq!(fs.counts, ns.counts);
        assert_eq!(fs.cost_median.to_bits(), ns.cost_median.to_bits());
    }

    #[test]
    fn f32_precision_counts_exact_sums_close() {
        let p = random_ps(5000, 3, 101);
        let c = random_ps(25, 3, 102);
        let f32b = FastNativeBackend {
            assign_path: AssignPath::Exact,
            precision: Precision::F32,
        };
        let lo = f32b.lloyd_step(&p, &c);
        let hi = NativeBackend.lloyd_step(&p, &c);
        // Exact assign path => identical assignment => identical counts.
        assert_eq!(lo.counts, hi.counts);
        for (a, b) in lo.sums.iter().zip(&hi.sums) {
            assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
        let rel = (lo.cost_median - hi.cost_median).abs() / hi.cost_median.max(1e-9);
        assert!(rel < 1e-3, "f32 cost {} vs f64 {}", lo.cost_median, hi.cost_median);
    }

    #[test]
    fn f32_precision_parallel_matches_serial() {
        // The determinism contract extends to the f32 accumulators: fixed
        // blocks + in-order merge => thread-count independent.
        let n = PAR_MIN + TILE + 3;
        let p = random_ps(n, 3, 111);
        let c = random_ps(11, 3, 112);
        let b = FastNativeBackend {
            assign_path: AssignPath::Gemm,
            precision: Precision::F32,
        };
        let par = b.lloyd_step(&p, &c);
        let ser = pool::with_serial(|| b.lloyd_step(&p, &c));
        assert_eq!(par.sums, ser.sums);
        assert_eq!(par.counts, ser.counts);
        assert_eq!(par.cost_median.to_bits(), ser.cost_median.to_bits());
        assert_eq!(par.cost_means.to_bits(), ser.cost_means.to_bits());
    }

    #[test]
    fn fast_backend_names_reflect_knobs() {
        let mk = |ap, pr| FastNativeBackend {
            assign_path: ap,
            precision: pr,
        };
        assert_eq!(mk(AssignPath::Gemm, Precision::F64).name(), "native+gemm");
        assert_eq!(mk(AssignPath::Exact, Precision::F32).name(), "native+f32");
        assert_eq!(mk(AssignPath::Gemm, Precision::F32).name(), "native+gemm+f32");
    }
}
