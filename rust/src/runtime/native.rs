//! Pure-rust reference backend.
//!
//! Shares exact semantics with the L2 JAX model (`python/compile/model.py`):
//! nearest-center assignment by squared Euclidean distance, first index wins
//! ties, per-center sums/counts of assigned points, and both objective
//! shares. Works for any (n, k, d); this is also what the XLA path is
//! cross-checked against in tests.
//!
//! The assign inner loop is the library's single hottest piece of code (it
//! is what the paper's cluster spent its time on too), so it gets a blocked,
//! d=3-specialized implementation; see EXPERIMENTS.md §Perf.

use super::{AssignOut, ComputeBackend, LloydStepOut};
use crate::geometry::PointSet;

/// Pure-rust compute backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

/// Tile height for the blocked assign loop: big enough to amortize the
/// center-loop setup, small enough that a (tile × k) walk stays in L1/L2.
const TILE: usize = 256;

#[inline(always)]
fn assign_rows_generic(
    points: &PointSet,
    centers: &PointSet,
    lo: usize,
    hi: usize,
    sqdist: &mut [f32],
    idx: &mut [u32],
) {
    let d = points.dim();
    let k = centers.len();
    for i in lo..hi {
        let row = points.row(i);
        let mut best = f32::INFINITY;
        let mut bj = 0u32;
        for c in 0..k {
            let crow = centers.row(c);
            let mut acc = 0.0f32;
            for j in 0..d {
                let t = row[j] - crow[j];
                acc += t * t;
            }
            if acc < best {
                best = acc;
                bj = c as u32;
            }
        }
        sqdist[i] = best.max(0.0);
        idx[i] = bj;
    }
}

/// d = 3 fast path, SoA-tiled for SIMD.
///
/// The row-major (x,y,z) interleave defeats auto-vectorization of the
/// center loop, so each tile is transposed once into coordinate planes
/// (xs/ys/zs); the inner loop then walks *points* for a fixed center —
/// a branch-free select over contiguous lanes that LLVM vectorizes to
/// AVX-512 masked min/blend (with `-C target-cpu=native`). Measured
/// 1943 Mdist/s at k=25 vs 326 for the scalar point-major loop — ~6x
/// (EXPERIMENTS.md §Perf has the full iteration log).
#[inline(always)]
fn assign_rows_d3(
    points: &[f32],
    centers: &[f32],
    k: usize,
    lo: usize,
    hi: usize,
    sqdist: &mut [f32],
    idx: &mut [u32],
) {
    let n = hi - lo;
    let mut xs = [0.0f32; TILE];
    let mut ys = [0.0f32; TILE];
    let mut zs = [0.0f32; TILE];
    debug_assert!(n <= TILE);
    for i in 0..n {
        let base = (lo + i) * 3;
        xs[i] = points[base];
        ys[i] = points[base + 1];
        zs[i] = points[base + 2];
    }
    let mut best = [f32::INFINITY; TILE];
    let mut bidx = [0u32; TILE];
    for c in 0..k {
        let cx = centers[c * 3];
        let cy = centers[c * 3 + 1];
        let cz = centers[c * 3 + 2];
        let cid = c as u32;
        // Branch-free select over contiguous lanes: vectorizes cleanly.
        for i in 0..n {
            let dx = xs[i] - cx;
            let dy = ys[i] - cy;
            let dz = zs[i] - cz;
            let d = dx * dx + dy * dy + dz * dz;
            let better = d < best[i];
            best[i] = if better { d } else { best[i] };
            bidx[i] = if better { cid } else { bidx[i] };
        }
    }
    for i in 0..n {
        sqdist[lo + i] = best[i].max(0.0);
        idx[lo + i] = bidx[i];
    }
}

impl ComputeBackend for NativeBackend {
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut {
        assert_eq!(points.dim(), centers.dim(), "dim mismatch");
        assert!(!centers.is_empty(), "no centers");
        let n = points.len();
        let mut out = AssignOut {
            sqdist: vec![0.0; n],
            idx: vec![0; n],
        };
        let mut lo = 0;
        while lo < n {
            let hi = (lo + TILE).min(n);
            if points.dim() == 3 {
                assign_rows_d3(
                    points.flat(),
                    centers.flat(),
                    centers.len(),
                    lo,
                    hi,
                    &mut out.sqdist,
                    &mut out.idx,
                );
            } else {
                assign_rows_generic(points, centers, lo, hi, &mut out.sqdist, &mut out.idx);
            }
            lo = hi;
        }
        out
    }

    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut {
        let a = self.assign(points, centers);
        let k = centers.len();
        let d = points.dim();
        let mut out = LloydStepOut {
            sums: vec![0.0; k * d],
            counts: vec![0.0; k],
            cost_median: 0.0,
            cost_means: 0.0,
        };
        // Costs first: a straight-line pass LLVM can pipeline (f32 sqrt per
        // point, f64 accumulators — per-point sqrt error is << the f32
        // distance error itself).
        let n = points.len();
        for i in 0..n {
            let d2 = a.sqdist[i];
            out.cost_means += d2 as f64;
            out.cost_median += d2.sqrt() as f64;
        }
        // Scatter-add of coordinate sums; flat d=3 path avoids the row()
        // slice construction in the hot loop.
        if d == 3 {
            let flat = points.flat();
            for i in 0..n {
                let c = a.idx[i] as usize * 3;
                let b = i * 3;
                out.sums[c] += flat[b] as f64;
                out.sums[c + 1] += flat[b + 1] as f64;
                out.sums[c + 2] += flat[b + 2] as f64;
                out.counts[a.idx[i] as usize] += 1.0;
            }
        } else {
            for i in 0..n {
                let c = a.idx[i] as usize;
                let row = points.row(i);
                for j in 0..d {
                    out.sums[c * d + j] += row[j] as f64;
                }
                out.counts[c] += 1.0;
            }
        }
        out
    }

    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64) {
        let a = self.assign(points, centers);
        let mut w = vec![0.0f64; centers.len()];
        let mut cost = 0.0f64;
        for i in 0..points.len() {
            w[a.idx[i] as usize] += 1.0;
            cost += (a.sqdist[i] as f64).sqrt();
        }
        (w, cost)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
        let mut rng = Rng::new(seed);
        PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
    }

    #[test]
    fn assign_matches_bruteforce_d3_and_generic() {
        for d in [1usize, 2, 3, 5, 8] {
            let p = random_ps(500, d, 1);
            let c = random_ps(17, d, 2);
            let got = NativeBackend.assign(&p, &c);
            let (want_d, want_i) = crate::metrics::cost::assign_full(&p, &c);
            assert_eq!(got.idx, want_i, "dim {d}");
            for (a, b) in got.sqdist.iter().zip(&want_d) {
                assert!((a - b).abs() < 1e-5, "dim {d}");
            }
        }
    }

    #[test]
    fn assign_first_index_wins_ties() {
        let p = PointSet::from_flat(3, vec![0.0, 0.0, 0.0]);
        // Two identical centers: index 0 must win.
        let c = PointSet::from_flat(3, vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let out = NativeBackend.assign(&p, &c);
        assert_eq!(out.idx, vec![0]);
    }

    #[test]
    fn lloyd_step_counts_and_sums() {
        // 4 points, 2 centers on a line; split 2/2.
        let p = PointSet::from_flat(1, vec![0.0, 0.2, 1.0, 1.2]);
        let c = PointSet::from_flat(1, vec![0.0, 1.0]);
        let out = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(out.counts, vec![2.0, 2.0]);
        assert!((out.sums[0] - 0.2).abs() < 1e-6);
        assert!((out.sums[1] - 2.2).abs() < 1e-6);
        assert!((out.cost_median - 0.4).abs() < 1e-5);
        assert!((out.cost_means - (0.04 + 0.04)).abs() < 1e-5);
    }

    #[test]
    fn lloyd_step_merge() {
        let p = random_ps(400, 3, 3);
        let c = random_ps(8, 3, 4);
        let whole = NativeBackend.lloyd_step(&p, &c);
        let parts = p.chunks(3);
        let mut merged = LloydStepOut::default();
        for part in &parts {
            merged.merge(&NativeBackend.lloyd_step(part, &c));
        }
        assert!((whole.cost_median - merged.cost_median).abs() < 1e-6);
        for (a, b) in whole.sums.iter().zip(&merged.sums) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(whole.counts, merged.counts);
    }

    #[test]
    fn weight_histogram_matches_lloyd_counts() {
        let p = random_ps(1000, 3, 5);
        let c = random_ps(16, 3, 6);
        let (w, cost) = NativeBackend.weight_histogram(&p, &c);
        let step = NativeBackend.lloyd_step(&p, &c);
        assert_eq!(w, step.counts);
        assert!((cost - step.cost_median).abs() < 1e-6);
        assert!((w.iter().sum::<f64>() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn min_dist_is_sqrt_of_assign() {
        let p = random_ps(100, 3, 7);
        let c = random_ps(5, 3, 8);
        let md = NativeBackend.min_dist(&p, &c);
        let a = NativeBackend.assign(&p, &c);
        for (m, d2) in md.iter().zip(&a.sqdist) {
            assert!((m * m - d2).abs() < 1e-5);
        }
    }
}
