//! Reader for `artifacts/manifest.json` (written by `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One exported HLO artifact, specialized to a shape bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    /// Exported function: "assign" | "lloyd_step" | "weight_histogram".
    pub func: String,
    /// Point-block rows.
    pub b: usize,
    /// Center rows.
    pub k: usize,
    /// Coordinate dimension.
    pub d: usize,
    /// HLO text file (relative to the manifest's directory).
    pub file: String,
    /// Number of tuple outputs.
    pub n_outputs: usize,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest (and its HLO files) live in.
    pub dir: PathBuf,
    /// Every artifact the manifest lists.
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated from I/O for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .context("manifest missing 'format'")?;
        anyhow::ensure!(
            format == "hlo-text",
            "unsupported artifact format {format:?} (want hlo-text)"
        );
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing 'entries'")?;
        let mut out = Vec::new();
        for (i, e) in entries.iter().enumerate() {
            let get_usize = |key: &str| {
                e.get(key)
                    .and_then(Json::as_usize)
                    .with_context(|| format!("entry {i} missing '{key}'"))
            };
            out.push(Entry {
                func: e
                    .get("func")
                    .and_then(Json::as_str)
                    .with_context(|| format!("entry {i} missing 'func'"))?
                    .to_string(),
                b: get_usize("b")?,
                k: get_usize("k")?,
                d: get_usize("d")?,
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .with_context(|| format!("entry {i} missing 'file'"))?
                    .to_string(),
                n_outputs: get_usize("n_outputs")?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries: out,
        })
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, e: &Entry) -> PathBuf {
        self.dir.join(&e.file)
    }

    /// All entries for a function, sorted by (d, k, b) so bucket selection
    /// can take the first fit.
    pub fn entries_for(&self, func: &str) -> Vec<&Entry> {
        let mut v: Vec<&Entry> = self.entries.iter().filter(|e| e.func == func).collect();
        v.sort_by_key(|e| (e.d, e.k, e.b));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "format": "hlo-text",
      "jax_version": "0.8.2",
      "entries": [
        {"func": "assign", "b": 2048, "k": 128, "d": 3,
         "file": "assign_b2048_k128_d3.hlo.txt", "sha256": "x", "bytes": 1,
         "n_outputs": 2},
        {"func": "assign", "b": 2048, "k": 32, "d": 3,
         "file": "assign_b2048_k32_d3.hlo.txt", "sha256": "x", "bytes": 1,
         "n_outputs": 2},
        {"func": "lloyd_step", "b": 2048, "k": 32, "d": 3,
         "file": "lloyd_step_b2048_k32_d3.hlo.txt", "sha256": "x", "bytes": 1,
         "n_outputs": 4}
      ]
    }"#;

    #[test]
    fn parses_and_sorts() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        let assigns = m.entries_for("assign");
        assert_eq!(assigns.len(), 2);
        assert_eq!(assigns[0].k, 32, "sorted by k");
        assert_eq!(assigns[1].k, 128);
        assert!(m
            .path_of(assigns[0])
            .to_string_lossy()
            .ends_with("assign_b2048_k32_d3.hlo.txt"));
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"format": "hlo-text", "entries": [{"func": "assign"}]}"#;
        assert!(Manifest::parse(Path::new("/tmp"), bad).is_err());
    }

    #[test]
    fn empty_entries_ok() {
        let m =
            Manifest::parse(Path::new("/tmp"), r#"{"format": "hlo-text", "entries": []}"#)
                .unwrap();
        assert!(m.entries_for("assign").is_empty());
    }
}
