//! Shape-bucket selection and padding.
//!
//! XLA executables are shape-monomorphic: one artifact per (B, K, D). A real
//! workload `(n points, k centers, d dims)` is served by the smallest bucket
//! with `d_bucket == d`, `k_bucket >= k`, padding points up to a multiple of
//! the bucket's B (multiple executions of the same executable cover n > B)
//! and masking padded rows/centers with the validity masks the L2 model
//! takes as inputs.

use super::manifest::Entry;

/// A chosen artifact bucket for a workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// Point-block capacity B of the artifact.
    pub b: usize,
    /// Center capacity K of the artifact.
    pub k: usize,
    /// Dimensionality D of the artifact (must match exactly).
    pub d: usize,
}

impl Bucket {
    /// The bucket a manifest entry describes.
    pub fn of_entry(e: &Entry) -> Bucket {
        Bucket {
            b: e.b,
            k: e.k,
            d: e.d,
        }
    }
}

/// Pick the cheapest entry that can serve `(k, d)`: exact `d`, smallest
/// `k_bucket >= k`. Returns `None` if no artifact fits (the caller then
/// falls back to the native backend).
pub fn select<'a>(entries: &[&'a Entry], k: usize, d: usize) -> Option<&'a Entry> {
    entries
        .iter()
        .copied()
        .filter(|e| e.d == d && e.k >= k)
        .min_by_key(|e| (e.k, e.b))
}

/// Pad a flat row-major `(rows, d)` buffer up to `rows_padded` rows with a
/// constant fill value.
pub fn pad_rows(flat: &[f32], rows: usize, d: usize, rows_padded: usize, fill: f32) -> Vec<f32> {
    debug_assert_eq!(flat.len(), rows * d);
    debug_assert!(rows_padded >= rows);
    let mut out = Vec::with_capacity(rows_padded * d);
    out.extend_from_slice(flat);
    out.resize(rows_padded * d, fill);
    out
}

/// A 0/1 validity mask with `valid` ones followed by padding zeros.
pub fn mask(valid: usize, total: usize) -> Vec<f32> {
    debug_assert!(valid <= total);
    let mut m = vec![1.0f32; valid];
    m.resize(total, 0.0);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(b: usize, k: usize, d: usize) -> Entry {
        Entry {
            func: "assign".into(),
            b,
            k,
            d,
            file: format!("assign_b{b}_k{k}_d{d}.hlo.txt"),
            n_outputs: 2,
        }
    }

    #[test]
    fn selects_smallest_fitting_k() {
        let e32 = entry(2048, 32, 3);
        let e128 = entry(2048, 128, 3);
        let e512 = entry(2048, 512, 3);
        let entries = vec![&e32, &e128, &e512];
        assert_eq!(select(&entries, 25, 3).unwrap().k, 32);
        assert_eq!(select(&entries, 32, 3).unwrap().k, 32);
        assert_eq!(select(&entries, 33, 3).unwrap().k, 128);
        assert_eq!(select(&entries, 513, 3), None);
    }

    #[test]
    fn requires_exact_dim() {
        let e = entry(2048, 64, 8);
        let entries = vec![&e];
        assert!(select(&entries, 10, 3).is_none());
        assert!(select(&entries, 10, 8).is_some());
    }

    #[test]
    fn pad_rows_fills() {
        let flat = vec![1.0, 2.0, 3.0, 4.0];
        let out = pad_rows(&flat, 2, 2, 4, 9.0);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 9.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    fn mask_shape() {
        assert_eq!(mask(2, 4), vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(mask(0, 2), vec![0.0, 0.0]);
        assert_eq!(mask(3, 3), vec![1.0, 1.0, 1.0]);
    }
}
