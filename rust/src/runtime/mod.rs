//! Compute backends for the numeric hot loop.
//!
//! Every per-machine computation in the system (Lloyd accumulation steps,
//! Iterative-Sample distance updates, MapReduce-kMedian weight histograms)
//! funnels through the [`ComputeBackend`] trait:
//!
//! * [`NativeBackend`] — pure rust, works for any shape, no setup. Also the
//!   semantic reference the AOT path is cross-checked against.
//! * `XlaBackend` (behind the `xla` cargo feature) — loads the HLO-text
//!   artifacts produced by `python/compile/aot.py` (L2 JAX functions
//!   wrapping the L1 Pallas kernel), compiles them once per shape bucket on
//!   the PJRT CPU client (`PjRtClient::cpu() ->
//!   HloModuleProto::from_text_file -> compile -> execute`), and pads
//!   workloads up to bucket shapes with validity masks.
//!
//! The two backends agree to float tolerance (rust/tests/integration_runtime.rs).
//!
//! ## Backend selection and fallback
//!
//! `coordinator::driver::make_backend` resolves `cluster.backend` from the
//! config. Requesting the `xla` backend **never** aborts a run; it degrades
//! to [`NativeBackend`] with a `log::warn!` in every failure mode:
//!
//! * built without the `xla` feature — the executor module is not compiled
//!   at all, so the request falls straight through to native;
//! * built with the feature but without a linked PJRT runtime (the default
//!   `vendor/xla` stub) — `XlaBackend::new` reports the runtime as
//!   unavailable;
//! * runtime present but `artifacts/manifest.json` missing or empty (the
//!   AOT pipeline has not been run) — `XlaBackend::new` fails cleanly.
//!
//! Per-call, a compiled `XlaBackend` additionally falls back shape-by-shape
//! when no artifact bucket fits (see [`bucket::select`]).

pub mod bucket;
#[cfg(feature = "xla")]
pub mod executor;
pub mod manifest;
pub mod native;

pub use bucket::Bucket;
#[cfg(feature = "xla")]
pub use executor::XlaBackend;
pub use manifest::Manifest;
pub use native::NativeBackend;

use crate::geometry::PointSet;

/// Nearest-center assignment of a point block.
#[derive(Clone, Debug, Default)]
pub struct AssignOut {
    /// Squared Euclidean distance to the nearest center, per point.
    pub sqdist: Vec<f32>,
    /// Index of the nearest center, per point.
    pub idx: Vec<u32>,
}

/// One Lloyd accumulation step over a point block.
#[derive(Clone, Debug, Default)]
pub struct LloydStepOut {
    /// Per-center coordinate sums of assigned points (k x dim, row-major).
    pub sums: Vec<f64>,
    /// Per-center assigned point counts.
    pub counts: Vec<f64>,
    /// Σ d(x, C) over the block (k-median objective share).
    pub cost_median: f64,
    /// Σ d(x, C)² over the block (k-means objective share).
    pub cost_means: f64,
}

impl LloydStepOut {
    /// Element-wise accumulate another block's contribution.
    pub fn merge(&mut self, other: &LloydStepOut) {
        if self.sums.is_empty() {
            *self = other.clone();
            return;
        }
        assert_eq!(self.sums.len(), other.sums.len());
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.cost_median += other.cost_median;
        self.cost_means += other.cost_means;
    }
}

/// Derive the MapReduce-kMedian weight histogram (per-center assigned
/// counts) plus the k-median cost share from an existing assignment.
/// Shared by [`ComputeBackend::weight_histogram`] and by coordinators that
/// already hold an [`AssignOut`] (or a [`LloydStepOut`], whose `counts`
/// field is the same histogram) so the n×k distance pass runs only once
/// per (points, centers) pair.
pub fn weights_from_assign(a: &AssignOut, k: usize) -> (Vec<f64>, f64) {
    let mut w = vec![0.0f64; k];
    let mut cost = 0.0f64;
    for (d2, &c) in a.sqdist.iter().zip(&a.idx) {
        w[c as usize] += 1.0;
        cost += (*d2 as f64).sqrt();
    }
    (w, cost)
}

/// The numeric kernel surface shared by the native and XLA paths.
pub trait ComputeBackend: Send + Sync {
    /// Nearest-center assignment (squared distances).
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut;

    /// Assignment + per-center sums/counts + objective shares.
    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut;

    /// MapReduce-kMedian step 4: per-center weights `w^i(y)` over this
    /// block, plus the block's k-median cost share.
    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64);

    /// Minimum distance (true metric, not squared) from each point to the
    /// center set — Iterative-Sample's `d(x, S)`.
    fn min_dist(&self, points: &PointSet, centers: &PointSet) -> Vec<f32> {
        self.assign(points, centers)
            .sqdist
            .into_iter()
            .map(|d| d.max(0.0).sqrt())
            .collect()
    }

    /// Backend display name ("native", "xla") for logs and reports.
    fn name(&self) -> &'static str;
}
