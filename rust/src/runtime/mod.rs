//! Compute backends for the numeric hot loop.
//!
//! Every per-machine computation in the system (Lloyd accumulation steps,
//! Iterative-Sample distance updates, MapReduce-kMedian weight histograms)
//! funnels through the [`ComputeBackend`] trait:
//!
//! * [`NativeBackend`] — pure rust, works for any shape, no setup. Also the
//!   semantic reference the AOT path is cross-checked against.
//! * `XlaBackend` (behind the `xla` cargo feature) — loads the HLO-text
//!   artifacts produced by `python/compile/aot.py` (L2 JAX functions
//!   wrapping the L1 Pallas kernel), compiles them once per shape bucket on
//!   the PJRT CPU client (`PjRtClient::cpu() ->
//!   HloModuleProto::from_text_file -> compile -> execute`), and pads
//!   workloads up to bucket shapes with validity masks.
//!
//! The two backends agree to float tolerance (rust/tests/integration_runtime.rs).
//!
//! ## Backend selection and fallback
//!
//! `coordinator::driver::make_backend` resolves `cluster.backend` from the
//! config. Requesting the `xla` backend **never** aborts a run; it degrades
//! to [`NativeBackend`] with a `log::warn!` in every failure mode:
//!
//! * built without the `xla` feature — the executor module is not compiled
//!   at all, so the request falls straight through to native;
//! * built with the feature but without a linked PJRT runtime (the default
//!   `vendor/xla` stub) — `XlaBackend::new` reports the runtime as
//!   unavailable;
//! * runtime present but `artifacts/manifest.json` missing or empty (the
//!   AOT pipeline has not been run) — `XlaBackend::new` fails cleanly.
//!
//! Per-call, a compiled `XlaBackend` additionally falls back shape-by-shape
//! when no artifact bucket fits (see [`bucket::select`]).

pub mod bucket;
#[cfg(feature = "xla")]
pub mod executor;
pub mod manifest;
pub mod native;

pub use bucket::Bucket;
#[cfg(feature = "xla")]
pub use executor::XlaBackend;
pub use manifest::Manifest;
pub use native::{FastNativeBackend, NativeBackend};

use crate::geometry::{MetricKind, PointSet};

/// Which assign kernel serves the Euclidean family (`cluster.kernel`).
///
/// Rung (a) of the kernel speed ladder (ARCHITECTURE.md §Kernel ladder):
/// the GEMM form trades bit-identity for a pure-dot-product inner loop.
/// Non-Euclidean metrics always run the exact generic kernels regardless
/// of this knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AssignPath {
    /// The exact plane-major kernel — bit-identical to the scalar
    /// surrogate op order (the default, and the semantic reference).
    #[default]
    Exact,
    /// Norm-expanded form: d² = ‖x‖² + ‖c‖² − 2·x·c with precomputed
    /// point/center norms, so the inner tile loop is a pure dot product.
    /// ε-equivalent: identical argmins away from exact ties, surrogate
    /// values within float-cancellation error of the exact path.
    Gemm,
}

impl AssignPath {
    /// Config-file / CLI name.
    pub fn name(self) -> &'static str {
        match self {
            AssignPath::Exact => "exact",
            AssignPath::Gemm => "gemm",
        }
    }

    /// Parse a config-file / CLI name.
    pub fn parse(s: &str) -> Option<AssignPath> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Some(AssignPath::Exact),
            "gemm" | "norm" | "norm-expanded" => Some(AssignPath::Gemm),
            _ => None,
        }
    }
}

impl std::fmt::Display for AssignPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Accumulator precision for the fast-path Lloyd scatter-add and
/// objective shares (`cluster.precision`).
///
/// Rung (b) of the kernel speed ladder. Point storage is `f32` either way
/// ([`PointSet`] is single-precision); this knob governs the *accumulator*
/// width of the Lloyd reduction. `f64` (the default) is the bit-exact
/// historical path; `f32` accumulates sums/counts/costs in single
/// precision per fixed block before widening at the block boundary —
/// ε-equivalent, still deterministic at any thread count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Double-precision accumulators (bit-exact default).
    #[default]
    F64,
    /// Single-precision per-block accumulators (opt-in, serving-style
    /// workloads; see README "when to use f32").
    F32,
}

impl Precision {
    /// Config-file / CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    /// Parse a config-file / CLI name.
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "double" => Some(Precision::F64),
            "f32" | "single" => Some(Precision::F32),
            _ => None,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Nearest-center assignment of a point block.
#[derive(Clone, Debug, Default)]
pub struct AssignOut {
    /// Surrogate distance to the nearest center, per point, in the metric
    /// that produced the assignment: the squared Euclidean distance under
    /// the default `l2sq` metric (hence the field name), the true distance
    /// under `l2`/`l1`/`chebyshev`, `1 − cos θ` under `cosine`. Convert
    /// with [`MetricKind::to_dist_f32`] / [`MetricKind::to_dist_f64`].
    pub sqdist: Vec<f32>,
    /// Index of the nearest center, per point.
    pub idx: Vec<u32>,
}

/// One Lloyd accumulation step over a point block.
#[derive(Clone, Debug, Default)]
pub struct LloydStepOut {
    /// Per-center coordinate sums of assigned points (k x dim, row-major).
    pub sums: Vec<f64>,
    /// Per-center assigned point counts.
    pub counts: Vec<f64>,
    /// Σ d(x, C) over the block (k-median objective share).
    pub cost_median: f64,
    /// Σ d(x, C)² over the block (k-means objective share).
    pub cost_means: f64,
}

impl LloydStepOut {
    /// Element-wise accumulate another block's contribution.
    pub fn merge(&mut self, other: &LloydStepOut) {
        if self.sums.is_empty() {
            *self = other.clone();
            return;
        }
        assert_eq!(self.sums.len(), other.sums.len());
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.cost_median += other.cost_median;
        self.cost_means += other.cost_means;
    }
}

/// Derive the MapReduce-kMedian weight histogram (per-center assigned
/// counts) plus the k-median cost share from an existing assignment.
/// Shared by [`ComputeBackend::weight_histogram`] and by coordinators that
/// already hold an [`AssignOut`] (or a [`LloydStepOut`], whose `counts`
/// field is the same histogram) so the n×k distance pass runs only once
/// per (points, centers) pair.
pub fn weights_from_assign(a: &AssignOut, k: usize) -> (Vec<f64>, f64) {
    weights_from_assign_metric(a, k, MetricKind::L2Sq)
}

/// [`weights_from_assign`] under an explicit metric: the assignment's
/// surrogates are mapped through [`MetricKind::to_dist_f64`] so the cost
/// share is the true metric distance sum. Under `l2sq` this is the
/// historical `sqrt(d²)` accumulation bit-for-bit.
pub fn weights_from_assign_metric(a: &AssignOut, k: usize, metric: MetricKind) -> (Vec<f64>, f64) {
    let mut w = vec![0.0f64; k];
    let mut cost = 0.0f64;
    for (s, &c) in a.sqdist.iter().zip(&a.idx) {
        w[c as usize] += 1.0;
        cost += metric.to_dist_f64(*s);
    }
    (w, cost)
}

/// The numeric kernel surface shared by the native and XLA paths.
///
/// The plain methods (`assign`, `lloyd_step`, `weight_histogram`,
/// `min_dist`) are the squared-Euclidean (`l2sq`) fast path every paper
/// experiment runs under. The `*_metric` counterparts accept a
/// [`MetricKind`] and, by default, dispatch: `l2sq` routes to the
/// backend's own fast path (so the default metric is bit-identical to the
/// pre-metric pipeline — including through the XLA backend's AOT kernels),
/// every other metric routes to the generic tiled native kernels
/// ([`native::assign_metric_generic`]). Backends with native support for
/// more metrics can override.
pub trait ComputeBackend: Send + Sync {
    /// Nearest-center assignment (squared Euclidean surrogates).
    fn assign(&self, points: &PointSet, centers: &PointSet) -> AssignOut;

    /// Assignment + per-center sums/counts + objective shares.
    fn lloyd_step(&self, points: &PointSet, centers: &PointSet) -> LloydStepOut;

    /// MapReduce-kMedian step 4: per-center weights `w^i(y)` over this
    /// block, plus the block's k-median cost share.
    fn weight_histogram(&self, points: &PointSet, centers: &PointSet) -> (Vec<f64>, f64);

    /// Minimum distance (true metric, not squared) from each point to the
    /// center set — Iterative-Sample's `d(x, S)`.
    fn min_dist(&self, points: &PointSet, centers: &PointSet) -> Vec<f32> {
        self.assign(points, centers)
            .sqdist
            .into_iter()
            .map(|d| d.max(0.0).sqrt())
            .collect()
    }

    /// [`ComputeBackend::assign`] under an explicit metric (surrogates in
    /// `AssignOut::sqdist`; see the dispatch contract in the trait docs).
    fn assign_metric(
        &self,
        points: &PointSet,
        centers: &PointSet,
        metric: MetricKind,
    ) -> AssignOut {
        if metric == MetricKind::L2Sq {
            self.assign(points, centers)
        } else {
            native::assign_metric_generic(points, centers, metric)
        }
    }

    /// [`ComputeBackend::lloyd_step`] under an explicit metric: objective
    /// shares are true metric distances (`cost_median` = Σ d, `cost_means`
    /// = Σ d²); `sums`/`counts` are the plain per-center scatter-add either
    /// way (the *update* rule for non-Euclidean metrics is the caller's
    /// concern — see `algorithms/lloyd.rs`).
    fn lloyd_step_metric(
        &self,
        points: &PointSet,
        centers: &PointSet,
        metric: MetricKind,
    ) -> LloydStepOut {
        if metric == MetricKind::L2Sq {
            self.lloyd_step(points, centers)
        } else {
            native::lloyd_step_metric_generic(points, centers, metric)
        }
    }

    /// [`ComputeBackend::weight_histogram`] under an explicit metric.
    fn weight_histogram_metric(
        &self,
        points: &PointSet,
        centers: &PointSet,
        metric: MetricKind,
    ) -> (Vec<f64>, f64) {
        if metric == MetricKind::L2Sq {
            self.weight_histogram(points, centers)
        } else {
            let a = self.assign_metric(points, centers, metric);
            weights_from_assign_metric(&a, centers.len(), metric)
        }
    }

    /// [`ComputeBackend::min_dist`] under an explicit metric.
    fn min_dist_metric(
        &self,
        points: &PointSet,
        centers: &PointSet,
        metric: MetricKind,
    ) -> Vec<f32> {
        if metric == MetricKind::L2Sq {
            self.min_dist(points, centers)
        } else {
            self.assign_metric(points, centers, metric)
                .sqdist
                .into_iter()
                .map(|s| metric.to_dist_f32(s))
                .collect()
        }
    }

    /// Backend display name ("native", "xla") for logs and reports.
    fn name(&self) -> &'static str;
}
