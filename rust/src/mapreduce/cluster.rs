//! The simulated cluster: machines, rounds, shuffle, timing, memory,
//! failure injection with real recovery (see [`super::recovery`]).

use super::kv::MemSize;
use super::recovery::{self, FaultModel, RecoveryLog, TaskFate};
use super::stats::{RoundStats, RunStats};
use super::MrError;
use crate::sim::{ClusterSim, SimConfig, TaskSpec};
use crate::util::pool::ThreadPool;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct MrConfig {
    /// Number of simulated machines (paper: 100).
    pub n_machines: usize,
    /// Per-machine memory budget in bytes; `None` disables enforcement.
    /// The `MRC^0` model requires this to be sub-linear in the input.
    pub mem_limit: Option<usize>,
    /// Execute machines on worker threads (true) or sequentially (false).
    /// Simulated time is measured per machine either way.
    pub parallel: bool,
    /// Worker threads used when `parallel` (0 = available cores).
    pub threads: usize,
    /// Fault injection: probability any single task *attempt* fails. A
    /// failing attempt runs to completion and then **loses its machine's
    /// output partition**; the round recovers by lineage replay — the task
    /// is actually re-executed from its retained inputs (mutable resident
    /// blocks are restored from a pre-round checkpoint first) and the
    /// replay's output is the one the round uses. Each replay is charged
    /// one full task duration and counted in
    /// [`super::RecoveryLog::replayed_tasks`]; a task that fails more than
    /// [`MrConfig::max_task_retries`] attempts aborts the job with
    /// [`MrError::TaskFailed`].
    pub fail_prob: f64,
    /// Straggler injection: probability a machine-task runs slow.
    pub straggler_prob: f64,
    /// Simulated-time multiplier for straggling tasks (>= 1.0).
    pub straggler_factor: f64,
    /// Failed attempts tolerated per task before the job aborts
    /// (Hadoop's `mapred.max.attempts`; the default comfortably survives
    /// `fail_prob = 0.3`: the abort probability per task is `0.3^17`).
    pub max_task_retries: usize,
    /// Launch speculative backup copies for straggling tasks: the task then
    /// completes at `min(straggler_factor, 2) x` its clean duration, and
    /// the duplicate work is accounted (see `recovery::fate_duration`).
    pub speculative: bool,
    /// Round-granularity checkpointing: charge a durable write of every
    /// round's output partitions to [`super::RecoveryLog::checkpoint_bytes`]
    /// (leader rounds are exempt — their outputs carry no `MemSize`). The
    /// engine always materializes round boundaries in host memory, so this
    /// knob models the I/O cost a real cluster pays for the same
    /// round-level recovery the replay path assumes.
    pub checkpoint: bool,
    /// Seed of the deterministic fault/straggler stream.
    pub fault_seed: u64,
    /// Discrete-event simulation of the cluster's timing (`sim.*` keys):
    /// when `sim.enabled`, every round also records a deterministic
    /// [`RoundStats::sim_wallclock`] replayed over a modeled network and
    /// heterogeneous hosts. Pure observation — outputs, round counts,
    /// shuffle bytes, and fates are bit-identical with it on or off.
    pub sim: SimConfig,
}

impl Default for MrConfig {
    fn default() -> Self {
        MrConfig {
            n_machines: 100,
            mem_limit: None,
            parallel: true,
            threads: 0,
            fail_prob: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            max_task_retries: 16,
            speculative: false,
            checkpoint: false,
            fault_seed: 0xFA17,
            sim: SimConfig::default(),
        }
    }
}

impl MrConfig {
    fn effective_threads(&self) -> usize {
        if !self.parallel {
            return 1;
        }
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        }
    }

    fn fault_model(&self) -> FaultModel {
        FaultModel {
            fail_prob: self.fail_prob,
            straggler_prob: self.straggler_prob,
            straggler_factor: self.straggler_factor,
            max_task_retries: self.max_task_retries,
            speculative: self.speculative,
        }
    }
}

/// A simulated MapReduce cluster accumulating [`RunStats`].
#[derive(Debug)]
pub struct MrCluster {
    /// The engine configuration this cluster was built with.
    pub config: MrConfig,
    /// Accumulated per-round accounting of every job run on this cluster.
    pub stats: RunStats,
    /// Deterministic stream driving fault/straggler injection.
    fault_rng: crate::util::rng::Rng,
    /// Persistent worker pool shared by every round of every job on this
    /// cluster: workers are spawned once in [`MrCluster::new`] and reused,
    /// instead of the previous scoped-thread spawn per round.
    pool: ThreadPool,
    /// The discrete-event timing observer (`Some` iff `config.sim.enabled`):
    /// replays each round's deterministic facts over the modeled cluster.
    sim: Option<ClusterSim>,
}

impl Default for MrCluster {
    fn default() -> Self {
        MrCluster::new(MrConfig::default())
    }
}

/// The FxHash multiply-xor word hash (rustc's hasher): much cheaper than
/// SipHash for the short keys that cross the shuffle, and deterministic
/// across runs and platforms.
struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut words = bytes.chunks_exact(8);
        for w in &mut words {
            self.add(u64::from_le_bytes(w.try_into().expect("8-byte chunk")));
        }
        let rem = words.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }
}

fn key_machine<K: Hash>(key: &K, n_machines: usize) -> usize {
    let mut h = FxHasher { hash: 0 };
    key.hash(&mut h);
    (h.finish() % n_machines as u64) as usize
}

/// Pool output slot (claimed exactly once per task index).
type TaskSlot<U> = Mutex<Option<(Duration, U)>>;

/// Run per-machine tasks (index, payload) -> (duration, output) on the
/// cluster's persistent pool (or inline when it has no workers),
/// preserving input order.
fn run_tasks<T, U, F>(pool: &ThreadPool, tasks: Vec<T>, f: F) -> Vec<(Duration, U)>
where
    T: Send,
    U: Send,
    F: Fn(usize, T) -> U + Send + Sync,
{
    let n = tasks.len();
    if pool.worker_count() == 0 || n <= 1 {
        // Inline execution models one machine at a time, so the numeric
        // kernels must not fan out on the global pool here — pool workers
        // are implicitly serial, and this keeps the measured per-machine
        // durations comparable between parallel and sequential runs.
        return tasks
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let t0 = Instant::now();
                let out = crate::util::pool::with_serial(|| f(i, t));
                (t0.elapsed(), out)
            })
            .collect();
    }
    let inputs: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<TaskSlot<U>> = (0..n).map(|_| Mutex::new(None)).collect();
    pool.run(n, &|i| {
        let task = inputs[i]
            .lock()
            .expect("input slot poisoned")
            .take()
            .expect("task claimed twice");
        let t0 = Instant::now();
        let out = f(i, task);
        *outputs[i].lock().expect("output slot poisoned") = Some((t0.elapsed(), out));
    });
    outputs
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("output slot poisoned")
                .expect("task not run")
        })
        .collect()
}

/// Recover one task's lost output: when `fate` carries failures, drop the
/// lost output, actually re-execute the task via `replay` (serially — the
/// recovering machine is one simulated machine), and account the replays:
/// `held_mem` is the machine-side memory held while recovering under the
/// engine's standing charge model, `bytes_of` sizes the regenerated
/// output. All five round surfaces funnel through here so the recovery
/// semantics cannot drift between them.
fn replay_lost<O>(
    fate: TaskFate,
    out: O,
    held_mem: usize,
    log: &mut RecoveryLog,
    bytes_of: impl Fn(&O) -> usize,
    replay: impl FnOnce() -> O,
) -> O {
    if fate.failures == 0 {
        return out;
    }
    drop(out);
    let replayed = crate::util::pool::with_serial(replay);
    log.record_replay(fate.failures, bytes_of(&replayed), held_mem);
    replayed
}

impl MrCluster {
    /// Build a cluster: spawns the persistent worker pool and seeds the
    /// deterministic fault stream from `config.fault_seed`.
    pub fn new(config: MrConfig) -> Self {
        let fault_rng = crate::util::rng::Rng::new(config.fault_seed);
        // Spawn the workers once; every round of every job reuses them.
        let pool = ThreadPool::new(config.effective_threads());
        let sim = config
            .sim
            .enabled
            .then(|| ClusterSim::new(&config.sim, config.n_machines));
        MrCluster {
            config,
            stats: RunStats::default(),
            fault_rng,
            pool,
            sim,
        }
    }

    /// The discrete-event simulator attached to this cluster (`Some` iff
    /// `config.sim.enabled`) — tests use it to replay rounds and inspect
    /// event traces and host speeds.
    pub fn sim(&self) -> Option<&ClusterSim> {
        self.sim.as_ref()
    }

    /// Simulated wall-clock of a machine round, or zero with sim off.
    fn sim_machine(&self, specs: &[TaskSpec], broadcast_bytes: usize) -> Duration {
        match &self.sim {
            Some(s) => s.machine_round(specs, broadcast_bytes).wallclock,
            None => Duration::ZERO,
        }
    }

    /// Simulated wall-clock of a shuffle round, or zero with sim off.
    fn sim_shuffle(&self, map: &[TaskSpec], reduce: &[TaskSpec]) -> Duration {
        match &self.sim {
            Some(s) => s.shuffle_round(map, reduce).wallclock,
            None => Duration::ZERO,
        }
    }

    /// Simulated wall-clock of a leader round, or zero with sim off.
    fn sim_leader(&self, work_bytes: usize, attempts: usize) -> Duration {
        match &self.sim {
            Some(s) => s.leader_round(work_bytes, attempts).wallclock,
            None => Duration::ZERO,
        }
    }

    /// Pre-draw the fates of one phase's `n_tasks` tasks from the seeded
    /// fault stream (before anything executes, in task-index order — the
    /// determinism anchor), and abort the job if any task's failure chain
    /// exhausts its retry budget.
    fn plan_phase(&mut self, label: &str, n_tasks: usize) -> Result<Vec<TaskFate>, MrError> {
        let model = self.config.fault_model();
        let fates = recovery::plan_fates(&mut self.fault_rng, n_tasks, &model);
        for (task, fate) in fates.iter().enumerate() {
            if fate.failures > self.config.max_task_retries {
                return Err(MrError::TaskFailed {
                    round: label.to_string(),
                    task,
                    attempts: fate.failures,
                });
            }
        }
        Ok(fates)
    }

    /// Check a per-machine memory charge against the budget.
    fn charge(&self, round: &str, machine: usize, used: usize) -> Result<(), MrError> {
        if let Some(limit) = self.config.mem_limit {
            if used > limit {
                return Err(MrError::MemoryExceeded {
                    round: round.to_string(),
                    machine,
                    used,
                    limit,
                });
            }
        }
        Ok(())
    }

    /// A faithful generic MapReduce round.
    ///
    /// * `input` — key/value pairs; the pair's *input* machine is
    ///   `hash(key) % n_machines` (inputs are wherever the previous round
    ///   left them; hashing models that placement).
    /// * `map` — reads each resident pair and emits intermediate pairs via
    ///   the `emit` closure. Inputs are borrowed, not consumed: they stay
    ///   resident on their machine so a failed map task can be replayed
    ///   from them.
    /// * `reduce` — receives one key plus all its values (on the machine
    ///   `hash(key) % n_machines`), emits output pairs. The grouped values
    ///   likewise stay materialized until the round commits, so failed
    ///   reduce tasks replay from the shuffle output.
    ///
    /// Returns all reducer outputs. Map/reduce compute is timed per machine;
    /// the round is charged `max(map) + max(reduce)` of simulated time, with
    /// lost attempts, replays, and stragglers charged by the fault model.
    ///
    /// The *order* of the returned pairs follows the reducers' machine
    /// placement and is not specified across runs — treat the result as a
    /// multiset (sort it, or make the reduction order-insensitive like
    /// [`crate::summaries::Coreset::compose`]).
    ///
    /// # Examples
    ///
    /// The classic word-count, on four simulated machines:
    ///
    /// ```
    /// use mrcluster::mapreduce::{MrCluster, MrConfig};
    ///
    /// let mut cluster = MrCluster::new(MrConfig {
    ///     n_machines: 4,
    ///     ..Default::default()
    /// });
    /// let docs: Vec<(usize, String)> =
    ///     vec![(0, "a b a".into()), (1, "b c".into())];
    /// let mut counts = cluster
    ///     .run_round(
    ///         "word-count",
    ///         docs,
    ///         |_id, doc: &String, emit| {
    ///             for word in doc.split_whitespace() {
    ///                 emit(word.to_string(), 1usize);
    ///             }
    ///         },
    ///         |word: &String, ones: &[usize], emit| {
    ///             emit(word.clone(), ones.iter().sum::<usize>());
    ///         },
    ///     )
    ///     .unwrap();
    /// counts.sort();
    /// assert_eq!(
    ///     counts,
    ///     vec![("a".into(), 2), ("b".into(), 2), ("c".into(), 1)]
    /// );
    /// assert_eq!(cluster.stats.n_rounds(), 1);
    /// ```
    pub fn run_round<K1, V1, K2, V2, K3, V3, M, R>(
        &mut self,
        label: &str,
        input: Vec<(K1, V1)>,
        map: M,
        reduce: R,
    ) -> Result<Vec<(K3, V3)>, MrError>
    where
        K1: Hash + Send + Sync,
        V1: Send + Sync,
        K2: Hash + Eq + Send + Sync + MemSize,
        V2: Send + Sync + MemSize,
        K3: Send + MemSize,
        V3: Send + MemSize,
        M: Fn(&K1, &V1, &mut dyn FnMut(K2, V2)) + Send + Sync,
        R: Fn(&K2, &[V2], &mut dyn FnMut(K3, V3)) + Send + Sync,
    {
        let nm = self.config.n_machines;
        let model = self.config.fault_model();
        let mut recovery_log = RecoveryLog::default();

        // ---- distribute input pairs to their resident machines ----
        let mut per_machine: Vec<Vec<(K1, V1)>> = (0..nm).map(|_| Vec::new()).collect();
        for (k, v) in input {
            let m = key_machine(&k, nm);
            per_machine[m].push((k, v));
        }

        // ---- map phase (timed per machine) ----
        let map_fates = self.plan_phase(label, nm)?;
        let map_ref = &map;
        let exec_map = |pairs: &Vec<(K1, V1)>| -> Vec<(K2, V2)> {
            let mut out: Vec<(K2, V2)> = Vec::new();
            for (k, v) in pairs.iter() {
                map_ref(k, v, &mut |k2, v2| out.push((k2, v2)));
            }
            out
        };
        let exec_ref = &exec_map;
        let results = run_tasks(
            &self.pool,
            per_machine.iter().collect::<Vec<&Vec<(K1, V1)>>>(),
            move |_m, pairs| exec_ref(pairs),
        );
        let mut map_max = Duration::ZERO;
        let mut shuffle_bytes = 0usize;
        let mut machines_used = 0usize;
        let mut intermediate: Vec<(K2, V2)> = Vec::new();
        // Per-machine task specs for the timing simulation. Inputs carry
        // no `MemSize` bound, so map work is modeled by the bytes the
        // task emits — deterministic, and proportional to what crosses
        // the machine's uplink.
        let mut map_specs: Vec<TaskSpec> = Vec::with_capacity(nm);
        for (m, (d, out)) in results.into_iter().enumerate() {
            if !out.is_empty() || d > Duration::ZERO {
                machines_used += 1;
            }
            let fate = map_fates[m];
            // Lost map outputs replay over the inputs still resident on
            // machine m. Map-side memory is never charged by this engine
            // (for original attempts either), so held_mem is 0 here.
            let out = replay_lost(
                fate,
                out,
                0,
                &mut recovery_log,
                |o| o.iter().map(|(k, v)| k.mem_bytes() + v.mem_bytes()).sum(),
                || exec_map(&per_machine[m]),
            );
            map_max = map_max.max(recovery::fate_duration(d, &fate, &model, &mut recovery_log));
            let before = shuffle_bytes;
            for (k, v) in out {
                shuffle_bytes += k.mem_bytes() + v.mem_bytes();
                intermediate.push((k, v));
            }
            let emitted = shuffle_bytes - before;
            map_specs.push(TaskSpec::new(emitted, emitted, fate.attempts()));
        }

        // ---- shuffle: group by key, key -> machine by hash ----
        let mut groups: HashMap<K2, Vec<V2>> = HashMap::new();
        for (k, v) in intermediate {
            groups.entry(k).or_default().push(v);
        }
        let mut machine_load: Vec<Vec<(K2, Vec<V2>)>> = (0..nm).map(|_| Vec::new()).collect();
        let mut machine_mem: Vec<usize> = vec![0; nm];
        for (k, vs) in groups {
            let m = key_machine(&k, nm);
            machine_mem[m] +=
                k.mem_bytes() + vs.iter().map(MemSize::mem_bytes).sum::<usize>();
            machine_load[m].push((k, vs));
        }
        let max_machine_mem = machine_mem.iter().copied().max().unwrap_or(0);
        for (m, &used) in machine_mem.iter().enumerate() {
            self.charge(label, m, used)?;
        }

        // ---- reduce phase (timed per machine) ----
        let reduce_fates = self.plan_phase(label, nm)?;
        // Reduce task r both receives and processes machine_mem[r] bytes.
        let reduce_specs: Vec<TaskSpec> = machine_mem
            .iter()
            .zip(reduce_fates.iter())
            .map(|(&b, fate)| TaskSpec::new(b, 0, fate.attempts()))
            .collect();
        let reduce_ref = &reduce;
        let exec_reduce = |pairs: &Vec<(K2, Vec<V2>)>| -> Vec<(K3, V3)> {
            let mut out: Vec<(K3, V3)> = Vec::new();
            for (k, vs) in pairs.iter() {
                reduce_ref(k, vs.as_slice(), &mut |k3, v3| out.push((k3, v3)));
            }
            out
        };
        let exec_ref = &exec_reduce;
        let results = run_tasks(
            &self.pool,
            machine_load.iter().collect::<Vec<&Vec<(K2, Vec<V2>)>>>(),
            move |_m, pairs| exec_ref(pairs),
        );
        let mut reduce_max = Duration::ZERO;
        let mut output = Vec::new();
        for (m, (d, out)) in results.into_iter().enumerate() {
            let fate = reduce_fates[m];
            // Lost reduce outputs replay from the materialized shuffle
            // groups still held by machine m (its standing charge).
            let out = replay_lost(
                fate,
                out,
                machine_mem[m],
                &mut recovery_log,
                |o| o.iter().map(|(k, v)| k.mem_bytes() + v.mem_bytes()).sum(),
                || exec_reduce(&machine_load[m]),
            );
            reduce_max =
                reduce_max.max(recovery::fate_duration(d, &fate, &model, &mut recovery_log));
            if self.config.checkpoint {
                recovery_log.checkpoint_bytes +=
                    out.iter().map(|(k, v)| k.mem_bytes() + v.mem_bytes()).sum::<usize>();
            }
            output.extend(out);
        }

        self.stats.push(RoundStats {
            map_max,
            reduce_max,
            shuffle_bytes,
            max_machine_mem,
            machines_used: machines_used.max(1),
            recovery: recovery_log,
            sim_wallclock: self.sim_shuffle(&map_specs, &reduce_specs),
            ..RoundStats::new(label)
        });
        Ok(output)
    }

    /// The "resident data" round every algorithm in the paper uses: machine
    /// `i mod n_machines` computes `f(i, &parts[i])` on the block it already
    /// holds; the leader gathers the outputs. Broadcast payloads (e.g. the
    /// current centers) should be included in the caller's `extra_mem`
    /// charge, and gathered outputs are charged to the leader.
    ///
    /// When there are more blocks than machines (Divide's ℓ = √(n/k)
    /// partitions on 100 machines), a machine processes its blocks
    /// sequentially: its round time is the *sum* of its block times, and its
    /// memory charge is the largest single block (Hadoop task slots).
    ///
    /// A task fated to fail loses its output and is replayed from its
    /// resident block (which an immutable round retains by construction).
    ///
    /// Timed as one round: `max_machine Σ_its-blocks time` simulated.
    pub fn run_machine_round<T, U, F>(
        &mut self,
        label: &str,
        parts: &[T],
        extra_mem: usize,
        f: F,
    ) -> Result<Vec<U>, MrError>
    where
        T: MemSize + Sync,
        U: MemSize + Send,
        F: Fn(usize, &T) -> U + Send + Sync,
    {
        let nm = self.config.n_machines;
        let model = self.config.fault_model();
        let fates = self.plan_phase(label, parts.len())?;
        let mut recovery_log = RecoveryLog::default();

        // Memory: each machine holds one block at a time + broadcast extra.
        // Blocks are typically zero-copy views over one shared allocation;
        // the charge is still the *logical* block size, because a real
        // machine would hold its own copy of the partition.
        let mut max_machine_mem = 0usize;
        for (m, part) in parts.iter().enumerate() {
            let used = part.mem_bytes() + extra_mem;
            max_machine_mem = max_machine_mem.max(used);
            self.charge(label, m % nm, used)?;
        }

        let fref = &f;
        let results = run_tasks(
            &self.pool,
            parts.iter().collect::<Vec<&T>>(),
            move |i, part| fref(i, part),
        );

        // Per-machine time = sum over the blocks it owns (i mod nm).
        let mut machine_time = vec![Duration::ZERO; nm.min(parts.len()).max(1)];
        let mut outputs = Vec::with_capacity(parts.len());
        let mut gathered_bytes = 0usize;
        let mut specs: Vec<TaskSpec> = Vec::with_capacity(parts.len());
        for (i, (d, out)) in results.into_iter().enumerate() {
            let fate = fates[i];
            // Lost output partition: replay from the resident block. The
            // replaying machine holds exactly what the original attempt
            // held, so recovery stays inside the same budget.
            let out = replay_lost(
                fate,
                out,
                parts[i].mem_bytes() + extra_mem,
                &mut recovery_log,
                U::mem_bytes,
                || f(i, &parts[i]),
            );
            let mt_len = machine_time.len();
            machine_time[i % mt_len] +=
                recovery::fate_duration(d, &fate, &model, &mut recovery_log);
            specs.push(TaskSpec::new(parts[i].mem_bytes(), out.mem_bytes(), fate.attempts()));
            gathered_bytes += out.mem_bytes();
            outputs.push(out);
        }
        let map_max = machine_time.iter().copied().max().unwrap_or(Duration::ZERO);
        // The leader receives every machine's output.
        let leader_mem = gathered_bytes + extra_mem;
        max_machine_mem = max_machine_mem.max(leader_mem);
        self.charge(label, usize::MAX, leader_mem)?;
        if self.config.checkpoint {
            recovery_log.checkpoint_bytes += gathered_bytes;
        }

        self.stats.push(RoundStats {
            map_max,
            shuffle_bytes: gathered_bytes,
            max_machine_mem,
            machines_used: parts.len().min(nm),
            recovery: recovery_log,
            sim_wallclock: self.sim_machine(&specs, extra_mem),
            ..RoundStats::new(label)
        });
        Ok(outputs)
    }

    /// Like [`MrCluster::run_machine_round`] but each machine may *mutate*
    /// its resident block (Iterative-Sample's distance updates and pruning
    /// keep per-machine state across rounds this way).
    ///
    /// A mutable task's lineage is its *pre-round block state*, so blocks
    /// whose task is fated to fail are checkpointed (cloned) before the
    /// round runs — hence the `T: Clone` bound — and restored before the
    /// replay. While the checkpoint exists the machine holds two copies of
    /// its block; that doubled residency is charged against the memory
    /// budget and audited by `Mrc0Report::recovery_ok`.
    pub fn run_machine_round_mut<T, U, F>(
        &mut self,
        label: &str,
        parts: &mut [T],
        extra_mem: usize,
        f: F,
    ) -> Result<Vec<U>, MrError>
    where
        T: MemSize + Send + Clone,
        U: MemSize + Send,
        F: Fn(usize, &mut T) -> U + Send + Sync,
    {
        let nm = self.config.n_machines;
        let model = self.config.fault_model();
        let fates = self.plan_phase(label, parts.len())?;
        let mut recovery_log = RecoveryLog::default();

        let mut max_machine_mem = 0usize;
        for (m, part) in parts.iter().enumerate() {
            let block = part.mem_bytes();
            let used = if fates[m].failures > 0 {
                // Pre-round checkpoint coexists with the live block for the
                // whole attempt chain.
                let held = 2 * block + extra_mem;
                recovery_log.replay_peak_mem = recovery_log.replay_peak_mem.max(held);
                held
            } else {
                block + extra_mem
            };
            max_machine_mem = max_machine_mem.max(used);
            self.charge(label, m % nm, used)?;
        }

        // Checkpoint exactly the blocks that will need restoring.
        let mut snapshots: Vec<Option<T>> = parts
            .iter()
            .zip(fates.iter())
            .map(|(part, fate)| if fate.failures > 0 { Some(part.clone()) } else { None })
            .collect();

        let n_parts = parts.len();
        let fref = &f;
        let results = run_tasks(
            &self.pool,
            parts.iter_mut().collect::<Vec<&mut T>>(),
            move |i, part: &mut T| fref(i, part),
        );

        let mut machine_time = vec![Duration::ZERO; nm.min(n_parts).max(1)];
        let mut outputs = Vec::with_capacity(n_parts);
        let mut gathered_bytes = 0usize;
        let mut specs: Vec<TaskSpec> = Vec::with_capacity(n_parts);
        for (i, (d, out)) in results.into_iter().enumerate() {
            let fate = fates[i];
            let out = if fate.failures > 0 {
                // Lost output *and* unusable post-attempt block state:
                // restore the checkpoint, then replay. The machine held
                // both copies of its block for the whole attempt chain.
                parts[i] = snapshots[i].take().expect("checkpoint for fated task");
                let held = 2 * parts[i].mem_bytes() + extra_mem;
                replay_lost(fate, out, held, &mut recovery_log, U::mem_bytes, || {
                    f(i, &mut parts[i])
                })
            } else {
                out
            };
            let mt_len = machine_time.len();
            machine_time[i % mt_len] +=
                recovery::fate_duration(d, &fate, &model, &mut recovery_log);
            // Post-round block size: deterministic (the mutation is), and
            // it is what the machine actually held while computing.
            specs.push(TaskSpec::new(parts[i].mem_bytes(), out.mem_bytes(), fate.attempts()));
            gathered_bytes += out.mem_bytes();
            outputs.push(out);
        }
        let map_max = machine_time.iter().copied().max().unwrap_or(Duration::ZERO);
        let leader_mem = gathered_bytes + extra_mem;
        max_machine_mem = max_machine_mem.max(leader_mem);
        self.charge(label, usize::MAX, leader_mem)?;
        if self.config.checkpoint {
            recovery_log.checkpoint_bytes += gathered_bytes;
        }

        self.stats.push(RoundStats {
            map_max,
            shuffle_bytes: gathered_bytes,
            max_machine_mem,
            machines_used: n_parts.min(nm),
            recovery: recovery_log,
            sim_wallclock: self.sim_machine(&specs, extra_mem),
            ..RoundStats::new(label)
        });
        Ok(outputs)
    }

    /// A leader-only round: one machine runs `f` (e.g. the final clustering
    /// of the gathered sample). Timed as one round with one machine. `f`
    /// must be re-runnable (`Fn`, not `FnOnce`) so a fated failure can
    /// replay it from the leader's retained input.
    pub fn run_leader_round<U, F>(
        &mut self,
        label: &str,
        input_mem: usize,
        f: F,
    ) -> Result<U, MrError>
    where
        F: Fn() -> U,
    {
        self.charge(label, 0, input_mem)?;
        let model = self.config.fault_model();
        let fate = self.plan_phase(label, 1)?[0];
        let mut recovery_log = RecoveryLog::default();
        let t0 = Instant::now();
        // The leader is one simulated machine: its compute is timed
        // single-threaded (no global-pool fan-out), like any machine task.
        let out = crate::util::pool::with_serial(&f);
        let measured = t0.elapsed();
        // A lost leader output is re-run from the retained input; leader
        // outputs carry no `MemSize`, so the re-read input stands in for
        // both the recompute bytes and the held memory.
        let out = replay_lost(fate, out, input_mem, &mut recovery_log, |_| input_mem, &f);
        let d = recovery::fate_duration(measured, &fate, &model, &mut recovery_log);
        self.stats.push(RoundStats {
            map_max: d,
            max_machine_mem: input_mem,
            machines_used: 1,
            recovery: recovery_log,
            sim_wallclock: self.sim_leader(input_mem, fate.attempts()),
            ..RoundStats::new(label)
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(nm: usize, parallel: bool) -> MrCluster {
        MrCluster::new(MrConfig {
            n_machines: nm,
            mem_limit: None,
            parallel,
            threads: 4,
            ..Default::default()
        })
    }

    fn faulty_cluster(nm: usize, fail_prob: f64, seed: u64) -> MrCluster {
        MrCluster::new(MrConfig {
            n_machines: nm,
            parallel: false,
            threads: 1,
            fail_prob,
            fault_seed: seed,
            ..Default::default()
        })
    }

    /// Classic word-count exercises the full map/shuffle/reduce path.
    fn word_count_on(mut c: MrCluster) -> Vec<(String, usize)> {
        let docs: Vec<(usize, String)> = vec![
            (0, "a b a".into()),
            (1, "b c".into()),
            (2, "a".into()),
        ];
        let mut out = c
            .run_round(
                "word-count",
                docs,
                |_k, doc: &String, emit| {
                    for w in doc.split_whitespace() {
                        emit(w.to_string(), 1usize);
                    }
                },
                |k: &String, vs: &[usize], emit| {
                    emit(k.clone(), vs.iter().sum::<usize>());
                },
            )
            .unwrap();
        out.sort();
        assert_eq!(c.stats.n_rounds(), 1);
        assert!(c.stats.shuffle_bytes() > 0);
        out
    }

    fn word_count(parallel: bool) -> Vec<(String, usize)> {
        word_count_on(cluster(8, parallel))
    }

    #[test]
    fn word_count_sequential() {
        assert_eq!(
            word_count(false),
            vec![("a".into(), 3), ("b".into(), 2), ("c".into(), 1)]
        );
    }

    #[test]
    fn word_count_parallel_matches() {
        assert_eq!(word_count(true), word_count(false));
    }

    #[test]
    fn word_count_survives_heavy_faults_bit_identically() {
        // Real failure semantics: map and reduce outputs are lost and
        // replayed, and the result must still be bit-identical.
        let out = word_count_on(faulty_cluster(8, 0.5, 0xDEAD));
        assert_eq!(out, word_count(false));
    }

    #[test]
    fn shuffle_groups_all_values_of_a_key() {
        let mut c = cluster(4, true);
        let input: Vec<(usize, usize)> = (0..100).map(|i| (i, i)).collect();
        let out = c
            .run_round(
                "group",
                input,
                |_k, v: &usize, emit| emit(v % 7, *v),
                |k: &usize, vs: &[usize], emit| emit(*k, vs.len()),
            )
            .unwrap();
        let total: usize = out.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 100);
        assert_eq!(out.len(), 7);
    }

    #[test]
    fn memory_limit_enforced() {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 1, // everything lands on one machine
            mem_limit: Some(64),
            parallel: false,
            threads: 1,
            ..Default::default()
        });
        let input: Vec<(usize, u64)> = (0..100).map(|i| (i, i as u64)).collect();
        let err = c
            .run_round(
                "overflow",
                input,
                |_k, v: &u64, emit| emit(0usize, *v),
                |_k: &usize, _vs: &[u64], _emit: &mut dyn FnMut(usize, u64)| {},
            )
            .unwrap_err();
        match err {
            MrError::MemoryExceeded { used, limit, .. } => {
                assert!(used > limit);
            }
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn machine_round_outputs_in_order() {
        let mut c = cluster(8, true);
        let parts: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32; 10]).collect();
        let out = c
            .run_machine_round("sum", &parts, 0, |i, part: &Vec<u32>| {
                assert!(part.iter().all(|&x| x == i as u32));
                part.iter().sum::<u32>()
            })
            .unwrap();
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
        assert_eq!(c.stats.rounds[0].machines_used, 8);
    }

    #[test]
    fn machine_round_memory_includes_broadcast() {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 2,
            mem_limit: Some(100),
            parallel: false,
            threads: 1,
            ..Default::default()
        });
        let parts: Vec<Vec<u8>> = vec![vec![0u8; 50], vec![0u8; 50]];
        // 50 (block) + 60 (broadcast) > 100 -> must fail.
        let res = c.run_machine_round("bc", &parts, 60, |_i, _p: &Vec<u8>| 0u8);
        assert!(res.is_err());
    }

    #[test]
    fn leader_round_counts_one_round_one_machine() {
        let mut c = cluster(8, true);
        let out = c.run_leader_round("final", 128, || 7u32).unwrap();
        assert_eq!(out, 7);
        assert_eq!(c.stats.n_rounds(), 1);
        assert_eq!(c.stats.rounds[0].machines_used, 1);
        assert_eq!(c.stats.peak_machine_mem(), 128);
    }

    #[test]
    fn key_machine_spreads_keys() {
        // The FxHash placement must spread keys roughly evenly: over random
        // u64 keys and several machine counts, every machine gets work and
        // no machine exceeds 2x its fair share. String keys (word-count
        // style) go through the byte path and must behave the same way.
        let mut rng = crate::util::rng::Rng::new(0xFA);
        for &nm in &[4usize, 16, 100] {
            let mut counts = vec![0usize; nm];
            let n_keys = 10_000;
            for _ in 0..n_keys {
                counts[key_machine(&rng.next_u64(), nm)] += 1;
            }
            let mean = n_keys / nm;
            assert!(counts.iter().all(|&c| c > 0), "empty machine at nm={nm}");
            assert!(
                counts.iter().all(|&c| c < mean * 2),
                "skewed placement at nm={nm}: {counts:?}"
            );
        }
        let mut scounts = vec![0usize; 10];
        for i in 0..5_000 {
            scounts[key_machine(&format!("key-{i}"), 10)] += 1;
        }
        assert!(scounts.iter().all(|&c| c > 250 && c < 1000), "{scounts:?}");
    }

    #[test]
    fn key_machine_is_deterministic() {
        assert_eq!(key_machine(&42u64, 7), key_machine(&42u64, 7));
        assert_eq!(
            key_machine(&"abc".to_string(), 13),
            key_machine(&"abc".to_string(), 13)
        );
    }

    #[test]
    fn sim_time_is_sum_of_max_machine() {
        let mut c = cluster(4, false);
        let parts: Vec<Vec<u64>> = (0..4).map(|i| vec![i as u64; 1000 * (i + 1)]).collect();
        c.run_machine_round("spin", &parts, 0, |_i, p: &Vec<u64>| {
            // Unequal work so max > mean.
            p.iter().map(|&x| x.wrapping_mul(2654435761)).sum::<u64>()
        })
        .unwrap();
        assert!(c.stats.sim_time() >= c.stats.rounds[0].map_max);
    }

    #[test]
    fn machine_round_replays_lost_outputs() {
        let parts: Vec<Vec<u64>> = (0..32).map(|i| vec![i as u64; 50]).collect();
        let run = |fail: f64| {
            let mut c = faulty_cluster(8, fail, 0xFEED);
            let out = c
                .run_machine_round("sums", &parts, 16, |_i, p: &Vec<u64>| p.iter().sum::<u64>())
                .unwrap();
            (out, c.stats)
        };
        let (clean, clean_stats) = run(0.0);
        let (faulty, faulty_stats) = run(0.4);
        assert_eq!(clean, faulty, "replays must reconstruct lost outputs");
        assert_eq!(clean_stats.total_retries(), 0);
        let rec = faulty_stats.recovery_totals();
        assert!(rec.replayed_tasks > 0, "p=0.4 over 32 tasks must fail some");
        assert!(rec.recomputed_bytes > 0);
        // An immutable replay holds what the original attempt held. (No
        // cross-run sim_time comparison here: two separately measured runs
        // of nanosecond tasks are noise-dominated; the attempt-chain timing
        // model is unit-tested deterministically in recovery.rs.)
        assert_eq!(rec.replay_peak_mem, parts[0].mem_bytes() + 16);
    }

    #[test]
    fn mut_round_restores_checkpoint_before_replay() {
        // The task mutates its block; without checkpoint/restore a replay
        // would double-apply the mutation and both state and outputs would
        // drift from the clean run.
        let run = |fail: f64| {
            let mut c = faulty_cluster(4, fail, 0xC0FFEE);
            let mut parts: Vec<Vec<u64>> =
                (0..16).map(|i| vec![i as u64; 20]).collect();
            let out = c
                .run_machine_round_mut("grow", &mut parts, 0, |i, p: &mut Vec<u64>| {
                    p.push(i as u64 * 1000);
                    p.iter().sum::<u64>()
                })
                .unwrap();
            (out, parts, c.stats.total_retries())
        };
        let (clean_out, clean_parts, r0) = run(0.0);
        let (faulty_out, faulty_parts, r1) = run(0.5);
        assert_eq!(r0, 0);
        assert!(r1 > 0);
        assert_eq!(clean_out, faulty_out);
        assert_eq!(clean_parts, faulty_parts, "blocks mutated exactly once");
    }

    #[test]
    fn mut_round_checkpoint_charges_double_residency() {
        let mut c = faulty_cluster(4, 0.5, 0xC0FFEE);
        let mut parts: Vec<Vec<u64>> = (0..16).map(|i| vec![i as u64; 20]).collect();
        let block = parts[0].mem_bytes();
        c.run_machine_round_mut("grow", &mut parts, 0, |_i, p: &mut Vec<u64>| p.len())
            .unwrap();
        let rec = c.stats.recovery_totals();
        assert!(rec.replayed_tasks > 0);
        assert!(
            rec.replay_peak_mem >= 2 * block,
            "checkpointed machine holds two copies: {} < {}",
            rec.replay_peak_mem,
            2 * block
        );
        assert!(c.stats.peak_machine_mem() >= rec.replay_peak_mem);
    }

    #[test]
    fn leader_round_replay_is_transparent() {
        let mut c = faulty_cluster(4, 0.5, 0x1EAD);
        for i in 0..50u32 {
            let out = c.run_leader_round("final", 64, || i * 3).unwrap();
            assert_eq!(out, i * 3);
        }
        assert!(c.stats.total_retries() > 0, "p=0.5 over 50 rounds");
        assert!(c.stats.peak_replay_mem() <= 64);
    }

    #[test]
    fn retry_exhaustion_aborts_the_job() {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 4,
            parallel: false,
            threads: 1,
            fail_prob: 1.0,
            max_task_retries: 2,
            ..Default::default()
        });
        let parts: Vec<Vec<u64>> = (0..4).map(|i| vec![i as u64; 4]).collect();
        let err = c
            .run_machine_round("doomed", &parts, 0, |_i, p: &Vec<u64>| p.len())
            .unwrap_err();
        match err {
            MrError::TaskFailed { attempts, .. } => assert_eq!(attempts, 3),
            other => panic!("wrong error {other:?}"),
        }
        // The failed round must not be recorded.
        assert_eq!(c.stats.n_rounds(), 0);
    }

    #[test]
    fn speculation_is_accounted_per_straggling_task() {
        // The min(factor, 2) timing math itself is unit-tested
        // deterministically in recovery.rs (fate_duration); comparing two
        // separately *measured* runs here would be wall-clock noise.
        let parts: Vec<Vec<u64>> = (0..8).map(|i| vec![i as u64; 64]).collect();
        let run = |speculative: bool| {
            let mut c = MrCluster::new(MrConfig {
                n_machines: 8,
                parallel: false,
                threads: 1,
                straggler_prob: 1.0,
                straggler_factor: 8.0,
                speculative,
                fault_seed: 3,
                ..Default::default()
            });
            c.run_machine_round("straggle", &parts, 0, |_i, p: &Vec<u64>| {
                p.iter().map(|&x| x.wrapping_mul(0x9E3779B9)).sum::<u64>()
            })
            .unwrap();
            c.stats.recovery_totals()
        };
        let rec_off = run(false);
        let rec_on = run(true);
        assert_eq!(rec_off.speculative_launched, 0);
        assert_eq!(rec_on.speculative_launched, 8, "every task straggled");
        assert_eq!(rec_on.speculative_wins, 8, "factor 8 > 2 => backup wins");
    }

    /// `sim.*` is pure timing observation: with the simulation on, every
    /// output, round count, and shuffle byte stays bit-identical to the
    /// sim-off run — only `sim_wallclock` appears. And because the
    /// simulated clock is a function of byte counts and fates (never of
    /// measured thread durations), it is identical across the pooled and
    /// sequential executors and across repeats.
    #[test]
    fn sim_is_pure_observation_and_deterministic() {
        let run = |enabled: bool, parallel: bool| {
            let mut c = MrCluster::new(MrConfig {
                n_machines: 8,
                parallel,
                threads: 4,
                fail_prob: 0.3,
                fault_seed: 0xB0B,
                sim: SimConfig {
                    enabled,
                    network: crate::sim::NetworkKind::Topology,
                    racks: 2,
                    oversub: 4.0,
                    hetero: crate::sim::Heterogeneity::LogNormal(0.5),
                    ..SimConfig::default()
                },
                ..Default::default()
            });
            let docs: Vec<(usize, String)> =
                (0..12).map(|i| (i, format!("w{} w{} x", i % 3, i % 5))).collect();
            let mut words = c
                .run_round(
                    "wc",
                    docs,
                    |_k, d: &String, emit| {
                        for w in d.split_whitespace() {
                            emit(w.to_string(), 1usize);
                        }
                    },
                    |k: &String, vs: &[usize], emit| emit(k.clone(), vs.iter().sum::<usize>()),
                )
                .unwrap();
            words.sort();
            let parts: Vec<Vec<u64>> = (0..16).map(|i| vec![i as u64; 32]).collect();
            let sums = c
                .run_machine_round("sums", &parts, 64, |_i, p: &Vec<u64>| p.iter().sum::<u64>())
                .unwrap();
            let fin = c.run_leader_round("final", 256, || 9u8).unwrap();
            (
                words,
                sums,
                fin,
                c.stats.n_rounds(),
                c.stats.shuffle_bytes(),
                c.stats.sim_wallclock(),
            )
        };
        let off = run(false, false);
        let on = run(true, false);
        assert_eq!(off.0, on.0, "outputs must not depend on the sim");
        assert_eq!(off.1, on.1);
        assert_eq!(off.2, on.2);
        assert_eq!(off.3, on.3, "round count must not depend on the sim");
        assert_eq!(off.4, on.4, "shuffle bytes must not depend on the sim");
        assert_eq!(off.5, Duration::ZERO, "sim off records no wallclock");
        assert!(on.5 > Duration::ZERO, "sim on records a wallclock");
        // Bit-identical across repeats and executors.
        assert_eq!(on.5, run(true, false).5);
        assert_eq!(on.5, run(true, true).5);
    }

    #[test]
    fn checkpoint_accounts_round_outputs() {
        let parts: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32; 10]).collect();
        let mut c = MrCluster::new(MrConfig {
            n_machines: 8,
            parallel: false,
            threads: 1,
            checkpoint: true,
            ..Default::default()
        });
        c.run_machine_round("ck", &parts, 0, |_i, p: &Vec<u32>| p.iter().sum::<u32>())
            .unwrap();
        let round = &c.stats.rounds[0];
        assert_eq!(round.recovery.checkpoint_bytes, round.shuffle_bytes);
        assert!(round.recovery.checkpoint_bytes > 0);
    }
}
