//! The simulated cluster: machines, rounds, shuffle, timing, memory.

use super::kv::MemSize;
use super::stats::{RoundStats, RunStats};
use super::MrError;
use crate::util::pool::ThreadPool;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct MrConfig {
    /// Number of simulated machines (paper: 100).
    pub n_machines: usize,
    /// Per-machine memory budget in bytes; `None` disables enforcement.
    /// The `MRC^0` model requires this to be sub-linear in the input.
    pub mem_limit: Option<usize>,
    /// Execute machines on worker threads (true) or sequentially (false).
    /// Simulated time is measured per machine either way.
    pub parallel: bool,
    /// Worker threads used when `parallel` (0 = available cores).
    pub threads: usize,
    /// Fault injection: probability a machine-task fails transiently and
    /// is re-executed (Hadoop-style task retry). The retry is charged as
    /// doubled task time and counted in [`super::RoundStats::retries`].
    pub fail_prob: f64,
    /// Straggler injection: probability a machine-task runs slow.
    pub straggler_prob: f64,
    /// Simulated-time multiplier for straggling tasks (>= 1.0).
    pub straggler_factor: f64,
    /// Seed of the deterministic fault/straggler stream.
    pub fault_seed: u64,
}

impl Default for MrConfig {
    fn default() -> Self {
        MrConfig {
            n_machines: 100,
            mem_limit: None,
            parallel: true,
            threads: 0,
            fail_prob: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            fault_seed: 0xFA17,
        }
    }
}

impl MrConfig {
    fn effective_threads(&self) -> usize {
        if !self.parallel {
            return 1;
        }
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        }
    }
}

/// A simulated MapReduce cluster accumulating [`RunStats`].
#[derive(Debug)]
pub struct MrCluster {
    pub config: MrConfig,
    pub stats: RunStats,
    /// Deterministic stream driving fault/straggler injection.
    fault_rng: crate::util::rng::Rng,
    /// Persistent worker pool shared by every round of every job on this
    /// cluster: workers are spawned once in [`MrCluster::new`] and reused,
    /// instead of the previous scoped-thread spawn per round.
    pool: ThreadPool,
}

impl Default for MrCluster {
    fn default() -> Self {
        MrCluster::new(MrConfig::default())
    }
}

/// The FxHash multiply-xor word hash (rustc's hasher): much cheaper than
/// SipHash for the short keys that cross the shuffle, and deterministic
/// across runs and platforms.
struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut words = bytes.chunks_exact(8);
        for w in &mut words {
            self.add(u64::from_le_bytes(w.try_into().expect("8-byte chunk")));
        }
        let rem = words.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }
}

fn key_machine<K: Hash>(key: &K, n_machines: usize) -> usize {
    let mut h = FxHasher { hash: 0 };
    key.hash(&mut h);
    (h.finish() % n_machines as u64) as usize
}

/// Pool output slot (claimed exactly once per task index).
type TaskSlot<U> = Mutex<Option<(Duration, U)>>;

/// Run per-machine tasks (index, payload) -> (duration, output) on the
/// cluster's persistent pool (or inline when it has no workers),
/// preserving input order.
fn run_tasks<T, U, F>(pool: &ThreadPool, tasks: Vec<T>, f: F) -> Vec<(Duration, U)>
where
    T: Send,
    U: Send,
    F: Fn(usize, T) -> U + Send + Sync,
{
    let n = tasks.len();
    if pool.worker_count() == 0 || n <= 1 {
        // Inline execution models one machine at a time, so the numeric
        // kernels must not fan out on the global pool here — pool workers
        // are implicitly serial, and this keeps the measured per-machine
        // durations comparable between parallel and sequential runs.
        return tasks
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let t0 = Instant::now();
                let out = crate::util::pool::with_serial(|| f(i, t));
                (t0.elapsed(), out)
            })
            .collect();
    }
    let inputs: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<TaskSlot<U>> = (0..n).map(|_| Mutex::new(None)).collect();
    pool.run(n, &|i| {
        let task = inputs[i]
            .lock()
            .expect("input slot poisoned")
            .take()
            .expect("task claimed twice");
        let t0 = Instant::now();
        let out = f(i, task);
        *outputs[i].lock().expect("output slot poisoned") = Some((t0.elapsed(), out));
    });
    outputs
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("output slot poisoned")
                .expect("task not run")
        })
        .collect()
}

impl MrCluster {
    pub fn new(config: MrConfig) -> Self {
        let fault_rng = crate::util::rng::Rng::new(config.fault_seed);
        // Spawn the workers once; every round of every job reuses them.
        let pool = ThreadPool::new(config.effective_threads());
        MrCluster {
            config,
            stats: RunStats::default(),
            fault_rng,
            pool,
        }
    }

    /// Apply the configured fault/straggler model to one task's measured
    /// duration. Returns (adjusted duration, retries incurred).
    fn inject_faults(&mut self, d: Duration) -> (Duration, usize) {
        let mut out = d;
        let mut retries = 0;
        if self.config.fail_prob > 0.0 && self.fault_rng.bernoulli(self.config.fail_prob) {
            out += d; // the task is re-executed from scratch
            retries = 1;
        }
        if self.config.straggler_prob > 0.0
            && self.config.straggler_factor > 1.0
            && self.fault_rng.bernoulli(self.config.straggler_prob)
        {
            out = Duration::from_secs_f64(out.as_secs_f64() * self.config.straggler_factor);
        }
        (out, retries)
    }

    /// Check a per-machine memory charge against the budget.
    fn charge(&self, round: &str, machine: usize, used: usize) -> Result<(), MrError> {
        if let Some(limit) = self.config.mem_limit {
            if used > limit {
                return Err(MrError::MemoryExceeded {
                    round: round.to_string(),
                    machine,
                    used,
                    limit,
                });
            }
        }
        Ok(())
    }

    /// A faithful generic MapReduce round.
    ///
    /// * `input` — key/value pairs; the pair's *input* machine is
    ///   `hash(key) % n_machines` (inputs are wherever the previous round
    ///   left them; hashing models that placement).
    /// * `map` — emits intermediate pairs via the `emit` closure.
    /// * `reduce` — receives one key plus all its values (on the machine
    ///   `hash(key) % n_machines`), emits output pairs.
    ///
    /// Returns all reducer outputs. Map/reduce compute is timed per machine;
    /// the round is charged `max(map) + max(reduce)` of simulated time.
    pub fn run_round<K1, V1, K2, V2, K3, V3, M, R>(
        &mut self,
        label: &str,
        input: Vec<(K1, V1)>,
        map: M,
        reduce: R,
    ) -> Result<Vec<(K3, V3)>, MrError>
    where
        K1: Hash + Send,
        V1: Send,
        K2: Hash + Eq + Send + MemSize,
        V2: Send + MemSize,
        K3: Send,
        V3: Send,
        M: Fn(K1, V1, &mut dyn FnMut(K2, V2)) + Send + Sync,
        R: Fn(&K2, Vec<V2>, &mut dyn FnMut(K3, V3)) + Send + Sync,
    {
        let nm = self.config.n_machines;

        // ---- distribute input pairs to their resident machines ----
        let mut per_machine: Vec<Vec<(K1, V1)>> = (0..nm).map(|_| Vec::new()).collect();
        for (k, v) in input {
            let m = key_machine(&k, nm);
            per_machine[m].push((k, v));
        }

        // ---- map phase (timed per machine) ----
        let map_ref = &map;
        let results = run_tasks(&self.pool, per_machine, move |_m, pairs| {
            let mut out: Vec<(K2, V2)> = Vec::new();
            for (k, v) in pairs {
                map_ref(k, v, &mut |k2, v2| out.push((k2, v2)));
            }
            out
        });
        let mut map_max = Duration::ZERO;
        let mut shuffle_bytes = 0usize;
        let mut machines_used = 0usize;
        let mut retries = 0usize;
        let mut intermediate: Vec<(K2, V2)> = Vec::new();
        for (d, out) in results {
            if !out.is_empty() || d > Duration::ZERO {
                machines_used += 1;
            }
            let (d, r) = self.inject_faults(d);
            retries += r;
            map_max = map_max.max(d);
            for (k, v) in out {
                shuffle_bytes += k.mem_bytes() + v.mem_bytes();
                intermediate.push((k, v));
            }
        }

        // ---- shuffle: group by key, key -> machine by hash ----
        let mut groups: HashMap<K2, Vec<V2>> = HashMap::new();
        for (k, v) in intermediate {
            groups.entry(k).or_default().push(v);
        }
        let mut machine_load: Vec<Vec<(K2, Vec<V2>)>> = (0..nm).map(|_| Vec::new()).collect();
        let mut machine_mem: Vec<usize> = vec![0; nm];
        for (k, vs) in groups {
            let m = key_machine(&k, nm);
            machine_mem[m] +=
                k.mem_bytes() + vs.iter().map(MemSize::mem_bytes).sum::<usize>();
            machine_load[m].push((k, vs));
        }
        let max_machine_mem = machine_mem.iter().copied().max().unwrap_or(0);
        for (m, &used) in machine_mem.iter().enumerate() {
            self.charge(label, m, used)?;
        }

        // ---- reduce phase (timed per machine) ----
        let reduce_ref = &reduce;
        let results = run_tasks(&self.pool, machine_load, move |_m, pairs| {
            let mut out: Vec<(K3, V3)> = Vec::new();
            for (k, vs) in pairs {
                reduce_ref(&k, vs, &mut |k3, v3| out.push((k3, v3)));
            }
            out
        });
        let mut reduce_max = Duration::ZERO;
        let mut output = Vec::new();
        for (d, out) in results {
            let (d, r) = self.inject_faults(d);
            retries += r;
            reduce_max = reduce_max.max(d);
            output.extend(out);
        }

        self.stats.push(RoundStats {
            label: label.to_string(),
            map_max,
            reduce_max,
            shuffle_bytes,
            max_machine_mem,
            machines_used: machines_used.max(1),
            retries,
        });
        Ok(output)
    }

    /// The "resident data" round every algorithm in the paper uses: machine
    /// `i mod n_machines` computes `f(i, &parts[i])` on the block it already
    /// holds; the leader gathers the outputs. Broadcast payloads (e.g. the
    /// current centers) should be included in the caller's `extra_mem`
    /// charge, and gathered outputs are charged to the leader.
    ///
    /// When there are more blocks than machines (Divide's ℓ = √(n/k)
    /// partitions on 100 machines), a machine processes its blocks
    /// sequentially: its round time is the *sum* of its block times, and its
    /// memory charge is the largest single block (Hadoop task slots).
    ///
    /// Timed as one round: `max_machine Σ_its-blocks time` simulated.
    pub fn run_machine_round<T, U, F>(
        &mut self,
        label: &str,
        parts: &[T],
        extra_mem: usize,
        f: F,
    ) -> Result<Vec<U>, MrError>
    where
        T: MemSize + Sync,
        U: MemSize + Send,
        F: Fn(usize, &T) -> U + Send + Sync,
    {
        let nm = self.config.n_machines;

        // Memory: each machine holds one block at a time + broadcast extra.
        // Blocks are typically zero-copy views over one shared allocation;
        // the charge is still the *logical* block size, because a real
        // machine would hold its own copy of the partition.
        let mut max_machine_mem = 0usize;
        for (m, part) in parts.iter().enumerate() {
            let used = part.mem_bytes() + extra_mem;
            max_machine_mem = max_machine_mem.max(used);
            self.charge(label, m % nm, used)?;
        }

        let fref = &f;
        let results = run_tasks(
            &self.pool,
            parts.iter().collect::<Vec<&T>>(),
            move |i, part| fref(i, part),
        );

        // Per-machine time = sum over the blocks it owns (i mod nm).
        let mut machine_time = vec![Duration::ZERO; nm.min(parts.len()).max(1)];
        let mut outputs = Vec::with_capacity(parts.len());
        let mut gathered_bytes = 0usize;
        let mut retries = 0usize;
        for (i, (d, out)) in results.into_iter().enumerate() {
            let (d, r) = self.inject_faults(d);
            retries += r;
            let mt_len = machine_time.len();
            machine_time[i % mt_len] += d;
            gathered_bytes += out.mem_bytes();
            outputs.push(out);
        }
        let map_max = machine_time.iter().copied().max().unwrap_or(Duration::ZERO);
        // The leader receives every machine's output.
        let leader_mem = gathered_bytes + extra_mem;
        max_machine_mem = max_machine_mem.max(leader_mem);
        self.charge(label, usize::MAX, leader_mem)?;

        self.stats.push(RoundStats {
            label: label.to_string(),
            map_max,
            reduce_max: Duration::ZERO,
            shuffle_bytes: gathered_bytes,
            max_machine_mem,
            machines_used: parts.len().min(nm),
            retries,
        });
        Ok(outputs)
    }

    /// Like [`MrCluster::run_machine_round`] but each machine may *mutate*
    /// its resident block (Iterative-Sample's distance updates and pruning
    /// keep per-machine state across rounds this way).
    pub fn run_machine_round_mut<T, U, F>(
        &mut self,
        label: &str,
        parts: &mut [T],
        extra_mem: usize,
        f: F,
    ) -> Result<Vec<U>, MrError>
    where
        T: MemSize + Send,
        U: MemSize + Send,
        F: Fn(usize, &mut T) -> U + Send + Sync,
    {
        let nm = self.config.n_machines;

        let mut max_machine_mem = 0usize;
        for (m, part) in parts.iter().enumerate() {
            let used = part.mem_bytes() + extra_mem;
            max_machine_mem = max_machine_mem.max(used);
            self.charge(label, m % nm, used)?;
        }

        let n_parts = parts.len();
        let fref = &f;
        let results = run_tasks(
            &self.pool,
            parts.iter_mut().collect::<Vec<&mut T>>(),
            move |i, part: &mut T| fref(i, part),
        );

        let mut machine_time = vec![Duration::ZERO; nm.min(n_parts).max(1)];
        let mut outputs = Vec::with_capacity(n_parts);
        let mut gathered_bytes = 0usize;
        let mut retries = 0usize;
        for (i, (d, out)) in results.into_iter().enumerate() {
            let (d, r) = self.inject_faults(d);
            retries += r;
            let mt_len = machine_time.len();
            machine_time[i % mt_len] += d;
            gathered_bytes += out.mem_bytes();
            outputs.push(out);
        }
        let map_max = machine_time.iter().copied().max().unwrap_or(Duration::ZERO);
        let leader_mem = gathered_bytes + extra_mem;
        max_machine_mem = max_machine_mem.max(leader_mem);
        self.charge(label, usize::MAX, leader_mem)?;

        self.stats.push(RoundStats {
            label: label.to_string(),
            map_max,
            reduce_max: Duration::ZERO,
            shuffle_bytes: gathered_bytes,
            max_machine_mem,
            machines_used: n_parts.min(nm),
            retries,
        });
        Ok(outputs)
    }

    /// A leader-only round: one machine runs `f` (e.g. the final clustering
    /// of the gathered sample). Timed as one round with one machine.
    pub fn run_leader_round<U, F>(
        &mut self,
        label: &str,
        input_mem: usize,
        f: F,
    ) -> Result<U, MrError>
    where
        F: FnOnce() -> U,
    {
        self.charge(label, 0, input_mem)?;
        let t0 = Instant::now();
        // The leader is one simulated machine: its compute is timed
        // single-threaded (no global-pool fan-out), like any machine task.
        let out = crate::util::pool::with_serial(f);
        let (d, retries) = self.inject_faults(t0.elapsed());
        self.stats.push(RoundStats {
            label: label.to_string(),
            map_max: d,
            reduce_max: Duration::ZERO,
            shuffle_bytes: 0,
            max_machine_mem: input_mem,
            machines_used: 1,
            retries,
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(nm: usize, parallel: bool) -> MrCluster {
        MrCluster::new(MrConfig {
            n_machines: nm,
            mem_limit: None,
            parallel,
            threads: 4,
            ..Default::default()
        })
    }

    /// Classic word-count exercises the full map/shuffle/reduce path.
    fn word_count(parallel: bool) -> Vec<(String, usize)> {
        let mut c = cluster(8, parallel);
        let docs: Vec<(usize, String)> = vec![
            (0, "a b a".into()),
            (1, "b c".into()),
            (2, "a".into()),
        ];
        let mut out = c
            .run_round(
                "word-count",
                docs,
                |_k, doc: String, emit| {
                    for w in doc.split_whitespace() {
                        emit(w.to_string(), 1usize);
                    }
                },
                |k: &String, vs: Vec<usize>, emit| {
                    emit(k.clone(), vs.into_iter().sum::<usize>());
                },
            )
            .unwrap();
        out.sort();
        assert_eq!(c.stats.n_rounds(), 1);
        assert!(c.stats.shuffle_bytes() > 0);
        out
    }

    #[test]
    fn word_count_sequential() {
        assert_eq!(
            word_count(false),
            vec![("a".into(), 3), ("b".into(), 2), ("c".into(), 1)]
        );
    }

    #[test]
    fn word_count_parallel_matches() {
        assert_eq!(word_count(true), word_count(false));
    }

    #[test]
    fn shuffle_groups_all_values_of_a_key() {
        let mut c = cluster(4, true);
        let input: Vec<(usize, usize)> = (0..100).map(|i| (i, i)).collect();
        let out = c
            .run_round(
                "group",
                input,
                |_k, v, emit| emit(v % 7, v),
                |k: &usize, vs: Vec<usize>, emit| emit(*k, vs.len()),
            )
            .unwrap();
        let total: usize = out.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 100);
        assert_eq!(out.len(), 7);
    }

    #[test]
    fn memory_limit_enforced() {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 1, // everything lands on one machine
            mem_limit: Some(64),
            parallel: false,
            threads: 1,
            ..Default::default()
        });
        let input: Vec<(usize, u64)> = (0..100).map(|i| (i, i as u64)).collect();
        let err = c
            .run_round(
                "overflow",
                input,
                |_k, v, emit| emit(0usize, v),
                |_k: &usize, _vs: Vec<u64>, _emit: &mut dyn FnMut(usize, u64)| {},
            )
            .unwrap_err();
        match err {
            MrError::MemoryExceeded { used, limit, .. } => {
                assert!(used > limit);
            }
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn machine_round_outputs_in_order() {
        let mut c = cluster(8, true);
        let parts: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32; 10]).collect();
        let out = c
            .run_machine_round("sum", &parts, 0, |i, part: &Vec<u32>| {
                assert!(part.iter().all(|&x| x == i as u32));
                part.iter().sum::<u32>()
            })
            .unwrap();
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
        assert_eq!(c.stats.rounds[0].machines_used, 8);
    }

    #[test]
    fn machine_round_memory_includes_broadcast() {
        let mut c = MrCluster::new(MrConfig {
            n_machines: 2,
            mem_limit: Some(100),
            parallel: false,
            threads: 1,
            ..Default::default()
        });
        let parts: Vec<Vec<u8>> = vec![vec![0u8; 50], vec![0u8; 50]];
        // 50 (block) + 60 (broadcast) > 100 -> must fail.
        let res = c.run_machine_round("bc", &parts, 60, |_i, _p: &Vec<u8>| 0u8);
        assert!(res.is_err());
    }

    #[test]
    fn leader_round_counts_one_round_one_machine() {
        let mut c = cluster(8, true);
        let out = c.run_leader_round("final", 128, || 7u32).unwrap();
        assert_eq!(out, 7);
        assert_eq!(c.stats.n_rounds(), 1);
        assert_eq!(c.stats.rounds[0].machines_used, 1);
        assert_eq!(c.stats.peak_machine_mem(), 128);
    }

    #[test]
    fn key_machine_spreads_keys() {
        // The FxHash placement must spread keys roughly evenly: over random
        // u64 keys and several machine counts, every machine gets work and
        // no machine exceeds 2x its fair share. String keys (word-count
        // style) go through the byte path and must behave the same way.
        let mut rng = crate::util::rng::Rng::new(0xFA);
        for &nm in &[4usize, 16, 100] {
            let mut counts = vec![0usize; nm];
            let n_keys = 10_000;
            for _ in 0..n_keys {
                counts[key_machine(&rng.next_u64(), nm)] += 1;
            }
            let mean = n_keys / nm;
            assert!(counts.iter().all(|&c| c > 0), "empty machine at nm={nm}");
            assert!(
                counts.iter().all(|&c| c < mean * 2),
                "skewed placement at nm={nm}: {counts:?}"
            );
        }
        let mut scounts = vec![0usize; 10];
        for i in 0..5_000 {
            scounts[key_machine(&format!("key-{i}"), 10)] += 1;
        }
        assert!(scounts.iter().all(|&c| c > 250 && c < 1000), "{scounts:?}");
    }

    #[test]
    fn key_machine_is_deterministic() {
        assert_eq!(key_machine(&42u64, 7), key_machine(&42u64, 7));
        assert_eq!(
            key_machine(&"abc".to_string(), 13),
            key_machine(&"abc".to_string(), 13)
        );
    }

    #[test]
    fn sim_time_is_sum_of_max_machine() {
        let mut c = cluster(4, false);
        let parts: Vec<Vec<u64>> = (0..4).map(|i| vec![i as u64; 1000 * (i + 1)]).collect();
        c.run_machine_round("spin", &parts, 0, |_i, p: &Vec<u64>| {
            // Unequal work so max > mean.
            p.iter().map(|&x| x.wrapping_mul(2654435761)).sum::<u64>()
        })
        .unwrap();
        assert!(c.stats.sim_time() >= c.stats.rounds[0].map_max);
    }
}
