//! `MRC^0` compliance checking (Karloff, Suri, Vassilvitskii — SODA'10).
//!
//! A problem is in `MRC^0` if it can be solved with `O(N^{1-ε})` machines,
//! `O(N^{1-ε})` memory per machine, and a *constant* number of rounds,
//! where `N` is the input size in bytes. The paper's Theorems 1.1/1.2 claim
//! membership for k-center/k-median under `memory = O(k² n^δ)`; this module
//! turns a finished [`RunStats`] into a pass/fail report against those
//! bounds so experiments and tests can assert the claim empirically.

use super::stats::RunStats;

/// Result of checking one run against the `MRC^0` resource bounds.
#[derive(Clone, Debug)]
pub struct Mrc0Report {
    /// Input size N in bytes used for the bounds.
    pub input_bytes: usize,
    /// The ε used: bounds are `c * N^{1-ε}`.
    pub epsilon: f64,
    /// Constant factor allowed on both bounds.
    pub slack: f64,
    /// The machine-count bound `slack * N^(1-eps)`.
    pub machine_bound: f64,
    /// The per-machine memory bound `slack * N^(1-eps)` bytes.
    pub memory_bound: f64,
    /// Rounds the run executed.
    pub rounds: usize,
    /// The constant round bound the caller's configuration implies.
    pub round_bound: usize,
    /// Most machines any round used.
    pub peak_machines: usize,
    /// Highest per-machine memory charge of any round.
    pub peak_machine_mem: usize,
    /// Highest per-machine memory held *for recovery* (lineage replays,
    /// mutable-block checkpoints). Fault tolerance must not be a loophole
    /// in the per-machine budget, so it is audited against the same bound.
    pub peak_replay_mem: usize,
    /// peak_machines within machine_bound.
    pub machines_ok: bool,
    /// peak_machine_mem within memory_bound.
    pub memory_ok: bool,
    /// rounds within round_bound.
    pub rounds_ok: bool,
    /// peak_replay_mem within memory_bound.
    pub recovery_ok: bool,
}

impl Mrc0Report {
    /// True when every bound holds.
    pub fn ok(&self) -> bool {
        self.machines_ok && self.memory_ok && self.rounds_ok && self.recovery_ok
    }
}

impl std::fmt::Display for Mrc0Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "MRC^0 check (N = {} bytes, eps = {}, slack = {}):",
            self.input_bytes, self.epsilon, self.slack
        )?;
        writeln!(
            f,
            "  machines : {} <= {:.0} : {}",
            self.peak_machines,
            self.machine_bound,
            if self.machines_ok { "OK" } else { "VIOLATED" }
        )?;
        writeln!(
            f,
            "  memory   : {} <= {:.0} bytes : {}",
            self.peak_machine_mem,
            self.memory_bound,
            if self.memory_ok { "OK" } else { "VIOLATED" }
        )?;
        writeln!(
            f,
            "  rounds   : {} <= {} : {}",
            self.rounds,
            self.round_bound,
            if self.rounds_ok { "OK" } else { "VIOLATED" }
        )?;
        write!(
            f,
            "  recovery : {} <= {:.0} bytes : {}",
            self.peak_replay_mem,
            self.memory_bound,
            if self.recovery_ok { "OK" } else { "VIOLATED" }
        )
    }
}

/// Check `stats` against the `MRC^0` bounds for input size `input_bytes`.
///
/// `round_bound` is the constant the algorithm is supposed to respect — for
/// the paper's algorithms that is `O(1/ε_sample)` rounds plus the constant
/// overhead of the weight/cluster phases; callers pass the concrete number
/// their configuration implies.
pub fn check_mrc0(
    stats: &RunStats,
    input_bytes: usize,
    epsilon: f64,
    slack: f64,
    round_bound: usize,
) -> Mrc0Report {
    let nf = input_bytes.max(1) as f64;
    let bound = slack * nf.powf(1.0 - epsilon);
    let peak_machines = stats.peak_machines();
    let peak_mem = stats.peak_machine_mem();
    let peak_replay = stats.peak_replay_mem();
    let rounds = stats.n_rounds();
    Mrc0Report {
        input_bytes,
        epsilon,
        slack,
        machine_bound: bound,
        memory_bound: bound,
        rounds,
        round_bound,
        peak_machines,
        peak_machine_mem: peak_mem,
        peak_replay_mem: peak_replay,
        machines_ok: (peak_machines as f64) <= bound,
        memory_ok: (peak_mem as f64) <= bound,
        rounds_ok: rounds <= round_bound,
        recovery_ok: (peak_replay as f64) <= bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::stats::RoundStats;
    use std::time::Duration;

    fn stats(rounds: usize, mem: usize, machines: usize) -> RunStats {
        let mut s = RunStats::default();
        for i in 0..rounds {
            s.push(RoundStats {
                map_max: Duration::from_millis(1),
                max_machine_mem: mem,
                machines_used: machines,
                ..RoundStats::new(format!("r{i}"))
            });
        }
        s
    }

    #[test]
    fn passes_sublinear_run() {
        // N = 1e9 bytes, eps = 0.3: bound ~ 1e9^0.7 ~ 4e6.
        let s = stats(5, 1_000_000, 100);
        let r = check_mrc0(&s, 1_000_000_000, 0.3, 1.0, 10);
        assert!(r.ok(), "{r}");
    }

    #[test]
    fn fails_memory_hog() {
        // A machine holding the whole input is never MRC.
        let n = 1_000_000_000;
        let s = stats(3, n, 10);
        let r = check_mrc0(&s, n, 0.1, 1.0, 10);
        assert!(!r.memory_ok, "{r}");
        assert!(!r.ok());
    }

    #[test]
    fn fails_round_blowup() {
        let s = stats(50, 10, 10);
        let r = check_mrc0(&s, 1_000_000, 0.3, 1.0, 10);
        assert!(!r.rounds_ok);
    }

    #[test]
    fn display_renders() {
        let s = stats(2, 10, 10);
        let r = check_mrc0(&s, 1_000_000, 0.3, 1.0, 10);
        let text = format!("{r}");
        assert!(text.contains("machines"));
        assert!(text.contains("recovery"));
        assert!(text.contains("OK"));
    }

    #[test]
    fn fails_replay_memory_hog() {
        // Ordinary memory within bounds, but recovery held a near-full copy
        // of the input on one machine: the report must flag it.
        let n = 1_000_000_000usize;
        let mut s = stats(3, 1_000_000, 10);
        s.rounds[1].recovery.record_replay(1, 1000, n / 2);
        let r = check_mrc0(&s, n, 0.3, 1.0, 10);
        assert!(r.memory_ok, "{r}");
        assert!(!r.recovery_ok, "{r}");
        assert!(!r.ok());
        assert!(format!("{r}").contains("VIOLATED"));
    }

    #[test]
    fn bounded_replay_memory_passes() {
        let mut s = stats(3, 1_000_000, 10);
        s.rounds[0].recovery.record_replay(2, 500, 1_500_000);
        let r = check_mrc0(&s, 1_000_000_000, 0.3, 1.0, 10);
        assert!(r.recovery_ok, "{r}");
        assert!(r.ok(), "{r}");
    }
}
