//! Per-round and per-run accounting — the numbers every experiment reports.

use super::recovery::RecoveryLog;
use std::time::Duration;

/// Measurements of one MapReduce round.
///
/// Construct with [`RoundStats::new`] (or `Default`) and fill in the
/// fields that apply — exhaustive struct literals would break every
/// call site each time a field lands (and several have: `recovery`,
/// then `sim_wallclock`).
#[derive(Clone, Debug, Default)]
pub struct RoundStats {
    /// Human label ("iterative-sample iter 2: prune", ...).
    pub label: String,
    /// Max over machines of the map-side compute time (includes lost
    /// attempts, replays, and the straggler/speculation model).
    pub map_max: Duration,
    /// Max over machines of the reduce-side compute time.
    pub reduce_max: Duration,
    /// Total bytes crossing the shuffle (map outputs).
    pub shuffle_bytes: usize,
    /// Highest per-machine memory charge this round (including recovery
    /// state: a replayed task's inputs, a mutable block's checkpoint).
    pub max_machine_mem: usize,
    /// Machines that actually received work.
    pub machines_used: usize,
    /// Recovery accounting: lineage replays, recomputed bytes, speculative
    /// backups, checkpoint writes (see `recovery::RecoveryLog`).
    pub recovery: RecoveryLog,
    /// Discrete-event simulated wall-clock of the round (`sim/`): a
    /// deterministic function of byte counts, fates, and the `sim.*`
    /// config — unlike [`RoundStats::sim_time`], which sums *measured*
    /// thread durations. Zero when the simulation is disabled.
    pub sim_wallclock: Duration,
}

impl RoundStats {
    /// A zeroed round with the given label.
    pub fn new(label: impl Into<String>) -> RoundStats {
        RoundStats { label: label.into(), ..RoundStats::default() }
    }

    /// The paper's per-round cost: the slowest machine's compute.
    pub fn sim_time(&self) -> Duration {
        self.map_max + self.reduce_max
    }
}

/// Accumulated measurements of a whole MapReduce run.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    /// Every recorded round, in execution order.
    pub rounds: Vec<RoundStats>,
}

impl RunStats {
    /// Record one finished round.
    pub fn push(&mut self, r: RoundStats) {
        self.rounds.push(r);
    }

    /// Number of rounds executed (the `MRC^0` round count).
    pub fn n_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// The paper's headline timing: Σ over rounds of max-machine time.
    pub fn sim_time(&self) -> Duration {
        self.rounds.iter().map(RoundStats::sim_time).sum()
    }

    /// Total discrete-event simulated wall-clock across the run: Σ over
    /// rounds of `sim_wallclock` (rounds are barrier-synchronized, so
    /// the run's simulated makespan is the sum). Zero when `sim.*` is
    /// disabled.
    pub fn sim_wallclock(&self) -> Duration {
        self.rounds.iter().map(|r| r.sim_wallclock).sum()
    }

    /// Total shuffled bytes across the run.
    pub fn shuffle_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.shuffle_bytes).sum()
    }

    /// High-water per-machine memory across all rounds.
    pub fn peak_machine_mem(&self) -> usize {
        self.rounds.iter().map(|r| r.max_machine_mem).max().unwrap_or(0)
    }

    /// Most machines used in any round.
    pub fn peak_machines(&self) -> usize {
        self.rounds.iter().map(|r| r.machines_used).max().unwrap_or(0)
    }

    /// Total injected-failure re-executions (lineage replays) across the
    /// run. The name predates real recovery; it is kept because every
    /// replay corresponds to exactly one failed attempt being retried.
    pub fn total_retries(&self) -> usize {
        self.rounds.iter().map(|r| r.recovery.replayed_tasks).sum()
    }

    /// Run-level roll-up of every round's recovery accounting.
    pub fn recovery_totals(&self) -> RecoveryLog {
        let mut total = RecoveryLog::default();
        for r in &self.rounds {
            total.absorb(&r.recovery);
        }
        total
    }

    /// Bytes re-materialized by lineage replays across the run.
    pub fn total_recomputed_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.recovery.recomputed_bytes).sum()
    }

    /// High-water per-machine memory held for recovery across all rounds.
    /// `check_mrc0` audits this against the same bound as ordinary memory.
    pub fn peak_replay_mem(&self) -> usize {
        self.rounds
            .iter()
            .map(|r| r.recovery.replay_peak_mem)
            .max()
            .unwrap_or(0)
    }

    /// Merge another run's rounds into this one (sub-procedures).
    pub fn absorb(&mut self, other: RunStats) {
        self.rounds.extend(other.rounds);
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} rounds, sim {:.3}s, shuffle {:.1} MiB, peak mem {:.1} MiB, peak machines {}",
            self.n_rounds(),
            self.sim_time().as_secs_f64(),
            self.shuffle_bytes() as f64 / (1 << 20) as f64,
            self.peak_machine_mem() as f64 / (1 << 20) as f64,
            self.peak_machines()
        );
        let wallclock = self.sim_wallclock();
        if wallclock > Duration::ZERO {
            s.push_str(&format!(", wallclock {:.3}s", wallclock.as_secs_f64()));
        }
        let rec = self.recovery_totals();
        if rec.replayed_tasks > 0 || rec.speculative_launched > 0 {
            s.push_str(&format!(
                ", {} replays ({:.1} KiB recomputed), {} speculative ({} wins)",
                rec.replayed_tasks,
                rec.recomputed_bytes as f64 / 1024.0,
                rec.speculative_launched,
                rec.speculative_wins
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(label: &str, map_ms: u64, red_ms: u64, bytes: usize, mem: usize) -> RoundStats {
        RoundStats {
            map_max: Duration::from_millis(map_ms),
            reduce_max: Duration::from_millis(red_ms),
            shuffle_bytes: bytes,
            max_machine_mem: mem,
            machines_used: 4,
            ..RoundStats::new(label)
        }
    }

    #[test]
    fn sim_time_sums_round_maxima() {
        let mut s = RunStats::default();
        s.push(round("a", 10, 5, 100, 50));
        s.push(round("b", 20, 0, 200, 80));
        assert_eq!(s.sim_time(), Duration::from_millis(35));
        assert_eq!(s.n_rounds(), 2);
        assert_eq!(s.shuffle_bytes(), 300);
        assert_eq!(s.peak_machine_mem(), 80);
    }

    #[test]
    fn absorb_concatenates() {
        let mut a = RunStats::default();
        a.push(round("a", 1, 1, 1, 1));
        let mut b = RunStats::default();
        b.push(round("b", 2, 2, 2, 2));
        a.absorb(b);
        assert_eq!(a.n_rounds(), 2);
    }

    #[test]
    fn empty_run() {
        let s = RunStats::default();
        assert_eq!(s.sim_time(), Duration::ZERO);
        assert_eq!(s.peak_machine_mem(), 0);
        assert_eq!(s.peak_machines(), 0);
        assert_eq!(s.total_retries(), 0);
        assert_eq!(s.peak_replay_mem(), 0);
    }

    #[test]
    fn recovery_totals_roll_up() {
        let mut s = RunStats::default();
        let mut a = round("a", 1, 0, 10, 100);
        a.recovery.record_replay(2, 64, 400);
        a.recovery.speculative_launched = 1;
        let mut b = round("b", 1, 0, 10, 100);
        b.recovery.record_replay(1, 16, 900);
        b.recovery.checkpoint_bytes = 128;
        s.push(a);
        s.push(b);
        assert_eq!(s.total_retries(), 3);
        assert_eq!(s.total_recomputed_bytes(), 2 * 64 + 16);
        assert_eq!(s.peak_replay_mem(), 900);
        let t = s.recovery_totals();
        assert_eq!(t.speculative_launched, 1);
        assert_eq!(t.checkpoint_bytes, 128);
        assert!(s.summary().contains("3 replays"));
    }

    #[test]
    fn clean_summary_omits_recovery() {
        let mut s = RunStats::default();
        s.push(round("a", 1, 1, 1, 1));
        assert!(!s.summary().contains("replays"));
    }

    #[test]
    fn sim_wallclock_diverges_from_sim_time() {
        // sim_time sums *measured* per-machine maxima; sim_wallclock is
        // the discrete-event verdict and includes network transfer the
        // measured clock never sees. The two are independent columns.
        let mut s = RunStats::default();
        let mut a = round("a", 10, 5, 100, 50); // sim_time 15ms
        a.sim_wallclock = Duration::from_millis(40);
        let mut b = round("b", 20, 0, 200, 80); // sim_time 20ms
        b.sim_wallclock = Duration::from_millis(70);
        s.push(a);
        s.push(b);
        assert_eq!(s.sim_time(), Duration::from_millis(35));
        assert_eq!(s.sim_wallclock(), Duration::from_millis(110));
        assert_ne!(s.sim_time(), s.sim_wallclock());
        assert!(s.summary().contains("wallclock 0.110s"));
    }

    #[test]
    fn disabled_sim_reports_zero_wallclock_and_hides_column() {
        let mut s = RunStats::default();
        s.push(round("a", 1, 1, 1, 1));
        assert_eq!(s.sim_wallclock(), Duration::ZERO);
        assert!(!s.summary().contains("wallclock"));
        // The builder seam: new() + Default keep struct-literal sites
        // compiling as fields land.
        let r = RoundStats::new("x");
        assert_eq!(r.label, "x");
        assert_eq!(r.sim_wallclock, Duration::ZERO);
        assert_eq!(RoundStats::default().machines_used, 0);
    }
}
