//! Failure semantics and recovery for the simulated cluster.
//!
//! Earlier versions of the engine modeled a task failure as a *timing tax*
//! (the task's simulated duration was doubled) — nothing was ever actually
//! lost or re-executed. This module upgrades fault injection to the real
//! Hadoop/Spark semantics the `MRC` literature assumes:
//!
//! * **output loss** — a failing attempt runs to completion and then its
//!   machine dies before the output partition is consumed. The partition is
//!   gone; the engine drops it for real.
//! * **lineage replay** — the round recovers by re-running the lost task
//!   from its retained inputs (map inputs stay on their resident machines,
//!   reduce inputs are the materialized shuffle groups, a mutable resident
//!   block is restored from the pre-round checkpoint). The replay actually
//!   executes the task closure again, and the round uses the *replayed*
//!   output — so a nondeterministic task function would be caught by the
//!   bit-identical-under-faults property tests.
//! * **bounded retries** — each attempt fails independently with
//!   `fail_prob`; a task that exhausts [`FaultModel::max_task_retries`]
//!   attempts aborts the job with [`super::MrError::TaskFailed`] (Hadoop's
//!   `mapred.max.attempts`).
//! * **speculative re-execution** — when enabled, a straggling task gets a
//!   backup copy launched once it overruns its expected clean duration. The
//!   backup runs at clean speed, so the task completes at
//!   `min(straggler_factor, 2) ×` its clean time; the backup "wins"
//!   whenever `straggler_factor > 2`. Both copies compute the same output
//!   (determinism is the engine's contract), so speculation is modeled in
//!   the simulated-time domain — exactly the domain where the paper's
//!   methodology measures everything — and accounted as duplicate work.
//!
//! **Determinism contract.** Every fate is drawn from the cluster's seeded
//! `fault_rng` *before* the round's tasks execute, in task-index order
//! ([`plan_fates`]), so the fault stream never depends on measured
//! durations, the worker schedule, or the thread count. Runs with the same
//! `fault_seed` replay bit-identically, and because replays re-execute
//! deterministic tasks, a faulty run's *outputs* are bit-identical to the
//! fault-free run's.

use crate::util::rng::Rng;
use std::time::Duration;

/// The fault-injection knobs of one cluster, in the form the planner and
/// the timing model consume (mirrors the fields of `MrConfig`).
#[derive(Clone, Debug)]
pub struct FaultModel {
    /// Probability any single task attempt fails (loses its output).
    pub fail_prob: f64,
    /// Probability the surviving attempt straggles.
    pub straggler_prob: f64,
    /// Simulated-time multiplier of a straggling attempt (>= 1.0).
    pub straggler_factor: f64,
    /// Failed attempts allowed per task before the job aborts.
    pub max_task_retries: usize,
    /// Launch a backup copy for straggling tasks.
    pub speculative: bool,
}

impl FaultModel {
    /// Whether the failure branch of the planner draws at all.
    pub fn injects_failures(&self) -> bool {
        self.fail_prob > 0.0
    }

    /// Whether the straggler branch of the planner draws at all.
    pub fn injects_stragglers(&self) -> bool {
        self.straggler_prob > 0.0 && self.straggler_factor > 1.0
    }
}

/// The pre-drawn fate of one task: how many attempts lose their output
/// before one succeeds, and whether the surviving attempt straggles.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TaskFate {
    /// Attempts that run to completion and then lose their output.
    /// `failures > max_task_retries` marks a task that never succeeds.
    pub failures: usize,
    /// The surviving attempt runs `straggler_factor` slow.
    pub straggles: bool,
}

impl TaskFate {
    /// No failures, no straggling: the round's fast path.
    pub fn is_clean(&self) -> bool {
        self.failures == 0 && !self.straggles
    }

    /// Total attempts the task executes: every lost attempt plus the
    /// surviving one. This is what the discrete-event simulation charges
    /// as serial rework on the task's host (`sim::TaskSpec::attempts`).
    pub fn attempts(&self) -> usize {
        self.failures + 1
    }
}

/// Draw the fates of one round's `n_tasks` tasks, in task-index order.
///
/// This is a pure function of the rng state and the model, independent of
/// task durations and scheduling — the determinism anchor of the whole
/// recovery layer. Tests replay it against a fresh `Rng` with the cluster's
/// `fault_seed` to cross-check the engine's accounting.
///
/// Failure chains are geometric (each attempt fails independently with
/// `fail_prob`) and capped at `max_task_retries + 1`: a fate with
/// `failures > max_task_retries` means the task exhausted its budget and
/// the round must abort.
pub fn plan_fates(rng: &mut Rng, n_tasks: usize, model: &FaultModel) -> Vec<TaskFate> {
    let mut fates = Vec::with_capacity(n_tasks);
    for _ in 0..n_tasks {
        let mut failures = 0usize;
        if model.injects_failures() {
            while failures <= model.max_task_retries && rng.bernoulli(model.fail_prob) {
                failures += 1;
            }
        }
        let straggles = model.injects_stragglers() && rng.bernoulli(model.straggler_prob);
        fates.push(TaskFate { failures, straggles });
    }
    fates
}

/// Per-round recovery accounting, carried inside `RoundStats`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryLog {
    /// Failed attempts replayed via lineage (the run's "retries").
    pub replayed_tasks: usize,
    /// Bytes re-materialized by replays: the lost output partitions
    /// (leader rounds, whose outputs are unsized, charge the re-read input
    /// instead; map-side inputs are never charged, matching the engine's
    /// memory model).
    pub recomputed_bytes: usize,
    /// Backup copies launched for straggling tasks.
    pub speculative_launched: usize,
    /// Backups that finished before the straggling original
    /// (`straggler_factor > 2`).
    pub speculative_wins: usize,
    /// Durable bytes written by round-granularity checkpointing
    /// (`MrConfig::checkpoint`).
    pub checkpoint_bytes: usize,
    /// Highest per-machine memory held *for recovery* this round, under
    /// the engine's standing charge model (task outputs are charged to the
    /// leader, map-side inputs are never charged): a replayed task's
    /// resident inputs, or 2x a mutable block while its pre-round
    /// checkpoint exists. `Mrc0Report` audits this against the same
    /// `N^{1-eps}` bound as ordinary memory — recovery must not be a
    /// loophole in the per-machine budget.
    pub replay_peak_mem: usize,
}

impl RecoveryLog {
    /// True when the round needed no recovery and wrote no checkpoint.
    pub fn is_empty(&self) -> bool {
        *self == RecoveryLog::default()
    }

    /// Account one task's replays: `attempts` failed attempts, each
    /// re-materializing `bytes`, on a machine holding `mem` while
    /// recovering.
    pub fn record_replay(&mut self, attempts: usize, bytes: usize, mem: usize) {
        self.replayed_tasks += attempts;
        self.recomputed_bytes += bytes.saturating_mul(attempts);
        self.replay_peak_mem = self.replay_peak_mem.max(mem);
    }

    /// Merge another round's log into this one (used by run-level totals).
    pub fn absorb(&mut self, other: &RecoveryLog) {
        self.replayed_tasks += other.replayed_tasks;
        self.recomputed_bytes += other.recomputed_bytes;
        self.speculative_launched += other.speculative_launched;
        self.speculative_wins += other.speculative_wins;
        self.checkpoint_bytes += other.checkpoint_bytes;
        self.replay_peak_mem = self.replay_peak_mem.max(other.replay_peak_mem);
    }
}

/// Simulated duration of one task's whole attempt chain, given the clean
/// (measured) duration of a single attempt.
///
/// * Each failed attempt runs to completion before its output is lost, so
///   it costs one full clean duration.
/// * A straggling survivor costs `straggler_factor x` clean — unless
///   speculation is on, in which case a backup launched at `1x` (the
///   scheduler notices the overrun) finishes at `2x`, capping the factor
///   at `min(straggler_factor, 2)`; the backup's duplicate pass is counted
///   in the log.
pub fn fate_duration(
    clean: Duration,
    fate: &TaskFate,
    model: &FaultModel,
    log: &mut RecoveryLog,
) -> Duration {
    let lost = clean * fate.failures as u32;
    let survivor = if fate.straggles {
        let factor = if model.speculative {
            log.speculative_launched += 1;
            if model.straggler_factor > 2.0 {
                log.speculative_wins += 1;
            }
            model.straggler_factor.min(2.0)
        } else {
            model.straggler_factor
        };
        Duration::from_secs_f64(clean.as_secs_f64() * factor)
    } else {
        clean
    };
    lost + survivor
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(fail: f64, straggle: f64, factor: f64) -> FaultModel {
        FaultModel {
            fail_prob: fail,
            straggler_prob: straggle,
            straggler_factor: factor,
            max_task_retries: 16,
            speculative: false,
        }
    }

    #[test]
    fn quiet_model_draws_nothing() {
        // With both branches disabled the rng is never touched, so the
        // stream stays aligned with a run that planned no rounds at all.
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let fates = plan_fates(&mut a, 100, &model(0.0, 0.0, 1.0));
        assert!(fates.iter().all(TaskFate::is_clean));
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn plan_is_deterministic_and_order_stable() {
        let m = model(0.3, 0.2, 4.0);
        let a = plan_fates(&mut Rng::new(42), 500, &m);
        let b = plan_fates(&mut Rng::new(42), 500, &m);
        assert_eq!(a, b);
        assert!(a.iter().any(|f| f.failures > 0));
        assert!(a.iter().any(|f| f.straggles));
    }

    #[test]
    fn failure_rate_tracks_probability() {
        let m = model(0.3, 0.0, 1.0);
        let fates = plan_fates(&mut Rng::new(7), 20_000, &m);
        let failures: usize = fates.iter().map(|f| f.failures).sum();
        // Geometric chains: E[failures] = p / (1 - p) ~ 0.4286.
        let rate = failures as f64 / 20_000.0;
        assert!((rate - 0.4286).abs() < 0.03, "rate {rate}");
    }

    #[test]
    fn retry_budget_caps_the_chain() {
        let m = FaultModel {
            max_task_retries: 3,
            ..model(1.0, 0.0, 1.0)
        };
        let fates = plan_fates(&mut Rng::new(1), 10, &m);
        // fail_prob = 1 always exhausts the budget: failures = max + 1.
        assert!(fates.iter().all(|f| f.failures == 4));
    }

    #[test]
    fn fate_duration_charges_every_lost_attempt() {
        let m = model(0.5, 0.0, 1.0);
        let mut log = RecoveryLog::default();
        let d = fate_duration(
            Duration::from_millis(10),
            &TaskFate { failures: 3, straggles: false },
            &m,
            &mut log,
        );
        assert_eq!(d, Duration::from_millis(40));
    }

    #[test]
    fn speculation_caps_straggler_factor_at_two() {
        let slow = model(0.0, 1.0, 10.0);
        let fast = FaultModel { speculative: true, ..slow.clone() };
        let fate = TaskFate { failures: 0, straggles: true };
        let clean = Duration::from_millis(100);
        let mut log = RecoveryLog::default();
        let unspec = fate_duration(clean, &fate, &slow, &mut log);
        assert_eq!(unspec, Duration::from_millis(1000));
        assert_eq!(log.speculative_launched, 0);
        let spec = fate_duration(clean, &fate, &fast, &mut log);
        assert_eq!(spec, Duration::from_millis(200));
        assert_eq!(log.speculative_launched, 1);
        assert_eq!(log.speculative_wins, 1);
    }

    #[test]
    fn mild_straggler_needs_no_backup_win() {
        let m = FaultModel { speculative: true, ..model(0.0, 1.0, 1.5) };
        let fate = TaskFate { failures: 0, straggles: true };
        let mut log = RecoveryLog::default();
        let d = fate_duration(Duration::from_millis(100), &fate, &m, &mut log);
        // The original finishes at 1.5x before the backup would at 2x.
        assert_eq!(d, Duration::from_millis(150));
        assert_eq!(log.speculative_launched, 1);
        assert_eq!(log.speculative_wins, 0);
    }

    #[test]
    fn record_replay_accumulates_and_peaks() {
        let mut log = RecoveryLog::default();
        log.record_replay(2, 100, 5000);
        log.record_replay(1, 30, 2000);
        assert_eq!(log.replayed_tasks, 3);
        assert_eq!(log.recomputed_bytes, 230);
        assert_eq!(log.replay_peak_mem, 5000);
        assert!(!log.is_empty());
        let mut total = RecoveryLog::default();
        total.absorb(&log);
        total.absorb(&log);
        assert_eq!(total.replayed_tasks, 6);
        assert_eq!(total.replay_peak_mem, 5000);
    }
}
