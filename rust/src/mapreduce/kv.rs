//! Memory sizing of the data that flows through the engine.
//!
//! The `MRC^0` model restricts the *bytes held per machine*; to enforce that
//! we need a size for every key and value type that crosses the shuffle.
//! [`MemSize`] is a deliberately simple "payload bytes" measure — heap
//! payload plus inline size — not a precise allocator model; it is the same
//! convention the paper uses when it counts "the distances from each point
//! in H to each point in S" as `|H||S| log n` bits.

use crate::geometry::{PointSet, StoreBlock};

/// Approximate in-memory footprint in bytes.
pub trait MemSize {
    /// Payload bytes plus inline size of `self`.
    fn mem_bytes(&self) -> usize;
}

macro_rules! memsize_fixed {
    ($($t:ty),*) => {
        $(impl MemSize for $t {
            #[inline]
            fn mem_bytes(&self) -> usize { std::mem::size_of::<$t>() }
        })*
    };
}

memsize_fixed!(u8, u16, u32, u64, usize, i8, i16, i32, i64, isize, f32, f64, bool, char, ());

impl MemSize for String {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<String>() + self.len()
    }
}

impl<T: MemSize> MemSize for Vec<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Vec<T>>() + self.iter().map(MemSize::mem_bytes).sum::<usize>()
    }
}

impl<T: MemSize> MemSize for Option<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Option<T>>()
            + self.as_ref().map(MemSize::mem_bytes).unwrap_or(0)
    }
}

impl<A: MemSize, B: MemSize> MemSize for (A, B) {
    fn mem_bytes(&self) -> usize {
        self.0.mem_bytes() + self.1.mem_bytes()
    }
}

impl<A: MemSize, B: MemSize, C: MemSize> MemSize for (A, B, C) {
    fn mem_bytes(&self) -> usize {
        self.0.mem_bytes() + self.1.mem_bytes() + self.2.mem_bytes()
    }
}

impl MemSize for PointSet {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<PointSet>() + PointSet::mem_bytes(self)
    }
}

/// A [`StoreBlock`] partition charges exactly what the equivalent resident
/// [`PointSet`] partition would: a simulated machine holds every byte of
/// its block whether the host streamed it from disk or not. Keeping the
/// two charges byte-identical is what makes the engine ledger (round
/// stats, `MRC^0` audits) of a file-backed run bit-identical to the
/// in-memory run's.
impl MemSize for StoreBlock {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<PointSet>() + StoreBlock::mem_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives() {
        assert_eq!(1u32.mem_bytes(), 4);
        assert_eq!(1u64.mem_bytes(), 8);
        assert_eq!(1.0f32.mem_bytes(), 4);
    }

    #[test]
    fn vec_counts_payload() {
        let v: Vec<f32> = vec![0.0; 100];
        assert!(v.mem_bytes() >= 400);
    }

    #[test]
    fn string_counts_bytes() {
        let s = "hello".to_string();
        assert!(s.mem_bytes() >= 5);
    }

    #[test]
    fn pointset_counts_coords() {
        let p = PointSet::from_flat(3, vec![0.0; 300]);
        assert!(p.mem_bytes() >= 1200);
    }

    #[test]
    fn store_block_charges_like_resident_partition() {
        use crate::geometry::PointStore;
        let p = PointSet::from_flat(3, vec![0.0; 300]);
        let blocks = PointStore::from(p.clone()).blocks(4);
        for (c, b) in p.chunks(4).iter().zip(&blocks) {
            assert_eq!(MemSize::mem_bytes(c), MemSize::mem_bytes(b));
        }
    }

    #[test]
    fn tuples_sum() {
        assert_eq!((1u32, 2u32).mem_bytes(), 8);
        assert_eq!((1u32, 2u64, 3u32).mem_bytes(), 16);
    }
}
