//! A simulated-cluster MapReduce engine — the substrate the paper assumes.
//!
//! The paper evaluates its algorithms by *simulating* a 100-machine
//! MapReduce cluster on a single host (§4.2): per round, each simulated
//! machine's compute time is measured, the round costs the *maximum* over
//! machines, and the run costs the sum over rounds (communication ignored).
//! This engine reproduces that methodology exactly and adds what the
//! `MRC^0` model (Karloff–Suri–Vassilvitskii) actually constrains:
//!
//! * **memory accounting** — every machine's received bytes are charged
//!   against a configurable per-machine budget; exceeding it is a hard
//!   error (the `O(N^{1-ε})` restriction);
//! * **machine accounting** — how many machines a round actually touched;
//! * **round counting** — the quantity all of the paper's theorems bound;
//! * **shuffle accounting** — bytes moved between map and reduce, reported
//!   even though (like the paper) simulated time excludes communication.
//!
//! Two execution surfaces:
//!
//! * [`MrCluster::run_round`] — a faithful generic key/value round
//!   (map → shuffle-by-key-hash → reduce);
//! * [`MrCluster::run_machine_round`] — the "resident data" round shape
//!   every algorithm in the paper uses (each machine computes on the block
//!   it already holds, the leader gathers the per-machine outputs). This is
//!   Hadoop's map-only job + single reducer, and it is how the paper's
//!   Parallel-Lloyd keeps points on machines across iterations.
//!
//! Machines can execute truly in parallel (worker threads) or sequentially;
//! simulated time is identical either way because it is derived from
//! per-machine measurements, not the host wall-clock.
//!
//! **Failure semantics** (see [`recovery`]): injected task failures *lose
//! the machine's output partition* for real, and the round recovers by
//! lineage replay — the lost task is re-executed from its retained inputs
//! (mutable resident blocks are restored from a pre-round checkpoint
//! first). Stragglers can be mitigated by speculative backups. All fates
//! are pre-drawn from the seeded fault stream, so faulty runs complete
//! with outputs bit-identical to the fault-free run, at any thread count.
//!
//! **Timing simulation** (see [`crate::sim`]): with `sim.enabled`, every
//! round additionally records [`RoundStats::sim_wallclock`] — a
//! discrete-event replay of the round over a modeled cluster (contended
//! network links, seeded heterogeneous host speeds, rack topology). The
//! simulation is a pure observer fed by deterministic facts (byte counts,
//! pre-drawn fates), so enabling it never changes outputs, round counts,
//! shuffle bytes, or MRC⁰ verdicts — only the extra timing column.

pub mod cluster;
pub mod constraints;
pub mod kv;
pub mod recovery;
pub mod stats;

pub use cluster::{MrCluster, MrConfig};
pub use constraints::{check_mrc0, Mrc0Report};
pub use kv::MemSize;
pub use recovery::{plan_fates, FaultModel, RecoveryLog, TaskFate};
pub use stats::{RoundStats, RunStats};

/// Errors surfaced by the engine.
#[derive(Debug)]
pub enum MrError {
    /// A machine's memory charge exceeded `MrConfig::mem_limit` (the
    /// `MRC^0` per-machine budget).
    MemoryExceeded {
        /// Label of the offending round.
        round: String,
        /// Machine index that blew the budget (`usize::MAX` = the leader).
        machine: usize,
        /// Bytes the machine was charged.
        used: usize,
        /// The configured budget in bytes.
        limit: usize,
    },
    /// A task failed more than `MrConfig::max_task_retries` consecutive
    /// attempts; the job aborts (Hadoop's `mapred.max.attempts`).
    TaskFailed {
        /// Label of the offending round.
        round: String,
        /// Task index whose retry budget ran out.
        task: usize,
        /// Attempts the task consumed before the abort.
        attempts: usize,
    },
    /// A worker thread panicked while executing machine tasks.
    WorkerPanic {
        /// Label of the offending round.
        round: String,
    },
}

impl std::fmt::Display for MrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MrError::MemoryExceeded {
                round,
                machine,
                used,
                limit,
            } => write!(
                f,
                "machine {machine} exceeded its memory budget in round '{round}': \
                 {used} bytes used > {limit} bytes allowed"
            ),
            MrError::TaskFailed {
                round,
                task,
                attempts,
            } => write!(
                f,
                "task {task} in round '{round}' lost its output {attempts} times \
                 and exhausted its retry budget"
            ),
            MrError::WorkerPanic { round } => {
                write!(f, "worker thread panicked in round '{round}'")
            }
        }
    }
}

impl std::error::Error for MrError {}
