//! The paper's L3 contribution: its clustering algorithms as MapReduce jobs
//! on the simulated cluster.
//!
//! | Paper | Module |
//! |---|---|
//! | Algorithm 3, `MapReduce-Iterative-Sample` | [`mr_iterative_sample`] |
//! | Algorithm 4, `MapReduce-kCenter`          | [`kcenter`] |
//! | Algorithm 5, `MapReduce-kMedian`          | [`kmedian`] |
//! | Algorithm 6, `MapReduce-Divide-kMedian`   | [`divide`] |
//! | §4.1 `Parallel-Lloyd`                     | [`parallel_lloyd`] |
//! | §4.1 sequential `LocalSearch` baseline    | [`driver`] (direct call) |
//!
//! Beyond the paper, [`robust`] adds the outlier-robust pipelines built on
//! the composable summary layer ([`crate::summaries`]): k-center with
//! outliers (Ceccarello et al.) and composable-coreset k-median (Mazzetto
//! et al.). The E17 arena adds the rival papers' own 2-round pipelines as
//! first-class competitors behind the same registry: [`mazzetto`]
//! (coreset k-median, accuracy-oriented sizing, arXiv:1904.12728) and
//! [`ceccarello`] (Gonzalez-skeleton k-center with outliers,
//! arXiv:1802.09205).
//!
//! [`driver::run_algorithm`] is the single entry point used by the CLI,
//! examples, and benches.

pub mod ceccarello;
pub mod divide;
pub mod driver;
pub mod kcenter;
pub mod kmedian;
pub mod mazzetto;
pub mod mr_iterative_sample;
pub mod parallel_lloyd;
pub mod robust;

pub use driver::{
    run_algorithm, run_algorithm_store, run_algorithm_store_with, run_algorithm_with, Algorithm,
    Outcome,
};

use crate::mapreduce::MemSize;
use crate::runtime::LloydStepOut;

impl MemSize for LloydStepOut {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<LloydStepOut>()
            + (self.sums.len() + self.counts.len()) * std::mem::size_of::<f64>()
    }
}

/// Which sequential algorithm `A` runs on the collapsed data (the sample or
/// the union of per-partition centers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InnerAlgo {
    /// Lloyd's algorithm (the fast heuristic the experiments favor).
    Lloyd,
    /// Arya et al. single-swap local search (the constant-factor `A`).
    LocalSearch,
}
