//! `Parallel-Lloyd` (§4.1) — the paper's main baseline.
//!
//! Points are partitioned across the machines once and stay resident. Each
//! iteration is one MapReduce round: the current k centers are broadcast;
//! every machine assigns its resident points and emits per-center
//! (sum, count) plus its share of the objective; the leader aggregates and
//! recomputes the means. By construction this computes *exactly* the
//! sequential Lloyd iterate (the paper makes the same point).
//!
//! ## Non-Euclidean metrics
//!
//! Under a metric where the mean is not the minimizer
//! ([`crate::geometry::MetricKind::mean_is_minimizer`] false), each
//! iteration adds a second machine round — the *medoid snap*: the leader
//! broadcasts the aggregated mean targets, every machine proposes its
//! resident point nearest to each target (under the active metric, with
//! its global index for tie-breaking), and the leader promotes the global
//! winners. This mirrors the sequential [`crate::algorithms::lloyd`]
//! medoid rule exactly, keeping the "same iterate as sequential Lloyd"
//! contract across metrics; under the default `l2sq`/`l2` metrics the
//! round structure is unchanged (one round per iteration).

use crate::config::ClusterConfig;
use crate::geometry::PointSet;
use crate::mapreduce::{MemSize, MrCluster, MrError};
use crate::runtime::{ComputeBackend, LloydStepOut};
use crate::util::rng::Rng;

/// Result of a Parallel-Lloyd run.
#[derive(Clone, Debug)]
pub struct ParallelLloydResult {
    /// The k centers after the final iteration.
    pub centers: PointSet,
    /// Lloyd iterations (= MapReduce rounds) executed.
    pub iters: usize,
    /// k-median objective of the final centers.
    pub cost_median: f64,
    /// Objective value per iteration (for convergence plots).
    pub history: Vec<f64>,
}

/// One machine's medoid-snap proposal: per cluster, the surrogate distance
/// and global index of its best resident candidate (`u64::MAX` = none),
/// plus the candidate rows themselves.
struct MedoidMsg {
    best: Vec<(f32, u64)>,
    rows: PointSet,
}

impl MemSize for MedoidMsg {
    fn mem_bytes(&self) -> usize {
        self.best.len() * (4 + 8) + self.rows.mem_bytes()
    }
}

/// Run Parallel-Lloyd on `cluster` (adds its rounds to the cluster stats).
pub fn parallel_lloyd(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<ParallelLloydResult, MrError> {
    let d = points.dim();
    let metric = cfg.metric;
    let mut rng = Rng::new(cfg.seed);
    let mut centers = crate::algorithms::seeding::random_distinct(points, cfg.k, &mut rng);
    let k = centers.len();

    // Partition once; blocks stay resident across iterations. The chunks
    // are zero-copy views over the input storage, so this costs O(machines)
    // metadata, not an O(n·d) memcpy (each block's logical bytes are still
    // charged to its machine by the engine).
    let parts = points.chunks(cfg.machines.min(points.len()).max(1));
    // Global index of each part's first row (medoid tie-breaking).
    let offsets: Vec<usize> = parts
        .iter()
        .scan(0usize, |lo, part| {
            let here = *lo;
            *lo += part.len();
            Some(here)
        })
        .collect();
    let bcast_bytes = k * d * 4;

    let mut history = Vec::new();
    let mut last_cost = f64::INFINITY;
    let mut iters = 0usize;

    for it in 0..cfg.lloyd_max_iters {
        iters += 1;
        let c_ref = &centers;
        let steps: Vec<LloydStepOut> = cluster.run_machine_round(
            &format!("parallel-lloyd iter {it}"),
            &parts,
            bcast_bytes,
            move |_m, part: &PointSet| backend.lloyd_step_metric(part, c_ref, metric),
        )?;

        // Leader: aggregate and recompute the mean targets.
        let mut agg = LloydStepOut::default();
        for s in &steps {
            agg.merge(s);
        }
        let cost = agg.cost_median;
        history.push(cost);

        let mut targets = PointSet::with_capacity(d, k);
        let mut row = vec![0.0f32; d];
        for c in 0..k {
            if agg.counts[c] > 0.0 {
                for j in 0..d {
                    row[j] = (agg.sums[c * d + j] / agg.counts[c]) as f32;
                }
                targets.push(&row);
            } else {
                targets.push(centers.row(c));
            }
        }

        centers = if metric.mean_is_minimizer() {
            targets
        } else {
            // Medoid snap (second machine round): broadcast the targets;
            // every machine proposes its resident point nearest to each
            // target under the metric; the leader promotes the global
            // winner by (surrogate, global index) — deterministic at any
            // machine count. Mirrors the sequential medoid rule.
            let t_ref = &targets;
            let o_ref = &offsets;
            let msgs: Vec<MedoidMsg> = cluster.run_machine_round(
                &format!("parallel-lloyd iter {it}: medoid snap"),
                &parts,
                // Two broadcast point sets: the old centers (to recompute
                // the assignment) AND the mean targets.
                2 * bcast_bytes,
                move |m, part: &PointSet| {
                    let a = backend.assign_metric(part, c_ref, metric);
                    let mut best: Vec<(f32, u64)> = vec![(f32::INFINITY, u64::MAX); k];
                    for (pos, &c) in a.idx.iter().enumerate() {
                        let cu = c as usize;
                        let s = metric.surrogate(part.row(pos), t_ref.row(cu));
                        // Strict less keeps the lowest position on ties
                        // (positions ascend within a machine).
                        if s.total_cmp(&best[cu].0) == std::cmp::Ordering::Less {
                            best[cu] = (s, (o_ref[m] + pos) as u64);
                        }
                    }
                    let mut rows = PointSet::with_capacity(d, k);
                    let zero = vec![0.0f32; d];
                    for &(_, gi) in &best {
                        if gi == u64::MAX {
                            rows.push(&zero);
                        } else {
                            rows.push(part.row(gi as usize - o_ref[m]));
                        }
                    }
                    MedoidMsg { best, rows }
                },
            )?;
            let mut next = PointSet::with_capacity(d, k);
            for c in 0..k {
                let mut win: Option<(f32, u64, usize)> = None; // (s, gi, machine)
                for (m, msg) in msgs.iter().enumerate() {
                    let (s, gi) = msg.best[c];
                    if gi == u64::MAX {
                        continue;
                    }
                    let better = match win {
                        None => true,
                        Some((ws, wgi, _)) => match s.total_cmp(&ws) {
                            std::cmp::Ordering::Less => true,
                            std::cmp::Ordering::Equal => gi < wgi,
                            std::cmp::Ordering::Greater => false,
                        },
                    };
                    if better {
                        win = Some((s, gi, m));
                    }
                }
                match win {
                    Some((_, _, m)) => next.push(msgs[m].rows.row(c)),
                    None => next.push(targets.row(c)), // empty cluster
                }
            }
            next
        };

        if last_cost.is_finite() {
            let rel = (last_cost - cost) / last_cost.max(1e-12);
            if rel.abs() < cfg.lloyd_tol {
                break;
            }
        }
        last_cost = cost;
    }

    let cost_median = history.last().copied().unwrap_or(0.0);
    Ok(ParallelLloydResult {
        centers,
        iters,
        cost_median,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::lloyd::{lloyd, LloydConfig};
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::runtime::NativeBackend;

    fn cfg(k: usize, machines: usize) -> ClusterConfig {
        ClusterConfig {
            k,
            machines,
            ..Default::default()
        }
    }

    #[test]
    fn matches_sequential_lloyd_exactly() {
        // Same seed => same init; partitioned sums must reproduce the
        // sequential iterate bit-for-near-bit.
        let data = DataGenConfig {
            n: 4000,
            k: 8,
            seed: 5,
            ..Default::default()
        }
        .generate();
        let ccfg = cfg(8, 16);
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 16,
            ..Default::default()
        });
        let par = parallel_lloyd(&mut cluster, &data.points, &ccfg, &NativeBackend).unwrap();
        let seq = lloyd(
            &data.points,
            None,
            &LloydConfig {
                k: 8,
                max_iters: ccfg.lloyd_max_iters,
                tol: ccfg.lloyd_tol,
                seed: ccfg.seed,
                ..Default::default()
            },
            &NativeBackend,
        );
        // Partitioned accumulation reorders float sums, so trajectories can
        // drift by float noise; the clustering itself must agree closely.
        let rel = (par.cost_median - seq.cost_median).abs() / seq.cost_median.max(1e-9);
        assert!(
            rel < 1e-3,
            "parallel {} vs sequential {}",
            par.cost_median,
            seq.cost_median
        );
        assert!((par.iters as i64 - seq.iters as i64).abs() <= 1);
    }

    #[test]
    fn one_round_per_iteration() {
        let data = DataGenConfig {
            n: 1000,
            k: 4,
            seed: 6,
            ..Default::default()
        }
        .generate();
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 10,
            ..Default::default()
        });
        let res = parallel_lloyd(&mut cluster, &data.points, &cfg(4, 10), &NativeBackend).unwrap();
        assert_eq!(cluster.stats.n_rounds(), res.iters);
    }

    #[test]
    fn machine_count_does_not_change_result() {
        let data = DataGenConfig {
            n: 3000,
            k: 5,
            seed: 7,
            ..Default::default()
        }
        .generate();
        let mut costs = Vec::new();
        for m in [1usize, 7, 50] {
            let mut cluster = MrCluster::new(MrConfig {
                n_machines: m,
                ..Default::default()
            });
            let res =
                parallel_lloyd(&mut cluster, &data.points, &cfg(5, m), &NativeBackend).unwrap();
            costs.push(res.cost_median);
        }
        for w in costs.windows(2) {
            let rel = (w[0] - w[1]).abs() / w[0].max(1e-9);
            assert!(rel < 1e-6, "costs diverge across machine counts: {costs:?}");
        }
    }
}
