//! `Parallel-Lloyd` (§4.1) — the paper's main baseline.
//!
//! Points are partitioned across the machines once and stay resident. Each
//! iteration is one MapReduce round: the current k centers are broadcast;
//! every machine assigns its resident points and emits per-center
//! (sum, count) plus its share of the objective; the leader aggregates and
//! recomputes the means. By construction this computes *exactly* the
//! sequential Lloyd iterate (the paper makes the same point).
//!
//! ## Non-Euclidean metrics
//!
//! Under a metric where the mean is not the minimizer
//! ([`crate::geometry::MetricKind::mean_is_minimizer`] false), each
//! iteration adds a second machine round — the *medoid snap*: the leader
//! broadcasts the aggregated mean targets, every machine proposes its
//! resident point nearest to each target (under the active metric, with
//! its global index for tie-breaking), and the leader promotes the global
//! winners. This mirrors the sequential [`crate::algorithms::lloyd`]
//! medoid rule exactly, keeping the "same iterate as sequential Lloyd"
//! contract across metrics; under the default `l2sq`/`l2` metrics the
//! round structure is unchanged (one round per iteration).
//!
//! ## Hamerly-pruned rounds (`cluster.prune = hamerly`)
//!
//! With [`PruneKind::Hamerly`] (and a triangle-valid metric — see
//! `algorithms/lloyd.rs`), each machine keeps its Hamerly bound state
//! resident next to its points ([`run_machine_round_mut`] carries it
//! through fault injection: checkpointing a machine honestly re-clones its
//! bounds). The broadcast grows by the k half-separation radii plus the
//! scalar movement decay; the round *count* is unchanged, and the medoid
//! snap reuses the resident assignment instead of re-running a full
//! assign pass (so its broadcast shrinks to just the mean targets). The
//! iterates are bit-identical to the unpruned coordinator at any machine
//! count — same per-part accumulation, same part-order merge.
//!
//! [`run_machine_round_mut`]: crate::mapreduce::MrCluster::run_machine_round_mut

use crate::algorithms::lloyd::{
    half_separation, hamerly_pass, max_center_shift, PruneKind, PruneStats, BOUND_INFLATE,
};
use crate::config::ClusterConfig;
use crate::geometry::PointSet;
use crate::mapreduce::{MemSize, MrCluster, MrError};
use crate::runtime::{AssignOut, ComputeBackend, LloydStepOut};
use crate::util::rng::Rng;

/// Result of a Parallel-Lloyd run.
#[derive(Clone, Debug)]
pub struct ParallelLloydResult {
    /// The k centers after the final iteration.
    pub centers: PointSet,
    /// Lloyd iterations (= MapReduce rounds) executed.
    pub iters: usize,
    /// k-median objective of the final centers.
    pub cost_median: f64,
    /// Objective value per iteration (for convergence plots).
    pub history: Vec<f64>,
    /// Distance-evaluation counters when the run took the Hamerly-pruned
    /// path; `None` when it ran unpruned (including the cosine fallback).
    pub prune: Option<PruneStats>,
}

/// One machine's resident state on the Hamerly-pruned path: its point
/// block plus the per-point bound arrays (assigned center, second-closest
/// lower bound, surrogate to the assigned center). `Clone` is the honest
/// checkpoint cost under fault injection.
#[derive(Clone)]
struct BoundedPart {
    part: PointSet,
    idx: Vec<u32>,
    lb: Vec<f32>,
    surr: Vec<f32>,
}

impl MemSize for BoundedPart {
    fn mem_bytes(&self) -> usize {
        self.part.mem_bytes() + self.idx.len() * 4 + self.lb.len() * 4 + self.surr.len() * 4
    }
}

/// One machine's medoid-snap proposal: per cluster, the surrogate distance
/// and global index of its best resident candidate (`u64::MAX` = none),
/// plus the candidate rows themselves.
struct MedoidMsg {
    best: Vec<(f32, u64)>,
    rows: PointSet,
}

impl MemSize for MedoidMsg {
    fn mem_bytes(&self) -> usize {
        self.best.len() * (4 + 8) + self.rows.mem_bytes()
    }
}

/// Run Parallel-Lloyd on `cluster` (adds its rounds to the cluster stats).
pub fn parallel_lloyd(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<ParallelLloydResult, MrError> {
    let d = points.dim();
    let metric = cfg.metric;
    // The Hamerly-pruned coordinator (bit-identical iterates, fewer
    // distance evaluations; see module docs). Like the sequential path it
    // always runs the native kernels, so `backend` only serves the
    // unpruned rounds below.
    if cfg.prune == PruneKind::Hamerly && metric.supports_triangle_pruning() {
        return parallel_lloyd_hamerly(cluster, points, cfg);
    }
    let mut rng = Rng::new(cfg.seed);
    let mut centers = crate::algorithms::seeding::random_distinct(points, cfg.k, &mut rng);
    let k = centers.len();

    // Partition once; blocks stay resident across iterations. The chunks
    // are zero-copy views over the input storage, so this costs O(machines)
    // metadata, not an O(n·d) memcpy (each block's logical bytes are still
    // charged to its machine by the engine).
    let parts = points.chunks(cfg.machines.min(points.len()).max(1));
    // Global index of each part's first row (medoid tie-breaking).
    let offsets: Vec<usize> = parts
        .iter()
        .scan(0usize, |lo, part| {
            let here = *lo;
            *lo += part.len();
            Some(here)
        })
        .collect();
    let bcast_bytes = k * d * 4;

    let mut history = Vec::new();
    let mut last_cost = f64::INFINITY;
    let mut iters = 0usize;

    for it in 0..cfg.lloyd_max_iters {
        iters += 1;
        let c_ref = &centers;
        let steps: Vec<LloydStepOut> = cluster.run_machine_round(
            &format!("parallel-lloyd iter {it}"),
            &parts,
            bcast_bytes,
            move |_m, part: &PointSet| backend.lloyd_step_metric(part, c_ref, metric),
        )?;

        // Leader: aggregate and recompute the mean targets.
        let mut agg = LloydStepOut::default();
        for s in &steps {
            agg.merge(s);
        }
        let cost = agg.cost_median;
        history.push(cost);

        let mut targets = PointSet::with_capacity(d, k);
        let mut row = vec![0.0f32; d];
        for c in 0..k {
            if agg.counts[c] > 0.0 {
                for j in 0..d {
                    row[j] = (agg.sums[c * d + j] / agg.counts[c]) as f32;
                }
                targets.push(&row);
            } else {
                targets.push(centers.row(c));
            }
        }

        centers = if metric.mean_is_minimizer() {
            targets
        } else {
            // Medoid snap (second machine round): broadcast the targets;
            // every machine proposes its resident point nearest to each
            // target under the metric; the leader promotes the global
            // winner by (surrogate, global index) — deterministic at any
            // machine count. Mirrors the sequential medoid rule.
            let t_ref = &targets;
            let o_ref = &offsets;
            let msgs: Vec<MedoidMsg> = cluster.run_machine_round(
                &format!("parallel-lloyd iter {it}: medoid snap"),
                &parts,
                // Two broadcast point sets: the old centers (to recompute
                // the assignment) AND the mean targets.
                2 * bcast_bytes,
                move |m, part: &PointSet| {
                    let a = backend.assign_metric(part, c_ref, metric);
                    let mut best: Vec<(f32, u64)> = vec![(f32::INFINITY, u64::MAX); k];
                    for (pos, &c) in a.idx.iter().enumerate() {
                        let cu = c as usize;
                        let s = metric.surrogate(part.row(pos), t_ref.row(cu));
                        // Strict less keeps the lowest position on ties
                        // (positions ascend within a machine).
                        if s.total_cmp(&best[cu].0) == std::cmp::Ordering::Less {
                            best[cu] = (s, (o_ref[m] + pos) as u64);
                        }
                    }
                    let mut rows = PointSet::with_capacity(d, k);
                    let zero = vec![0.0f32; d];
                    for &(_, gi) in &best {
                        if gi == u64::MAX {
                            rows.push(&zero);
                        } else {
                            rows.push(part.row(gi as usize - o_ref[m]));
                        }
                    }
                    MedoidMsg { best, rows }
                },
            )?;
            let mut next = PointSet::with_capacity(d, k);
            for c in 0..k {
                let mut win: Option<(f32, u64, usize)> = None; // (s, gi, machine)
                for (m, msg) in msgs.iter().enumerate() {
                    let (s, gi) = msg.best[c];
                    if gi == u64::MAX {
                        continue;
                    }
                    let better = match win {
                        None => true,
                        Some((ws, wgi, _)) => match s.total_cmp(&ws) {
                            std::cmp::Ordering::Less => true,
                            std::cmp::Ordering::Equal => gi < wgi,
                            std::cmp::Ordering::Greater => false,
                        },
                    };
                    if better {
                        win = Some((s, gi, m));
                    }
                }
                match win {
                    Some((_, _, m)) => next.push(msgs[m].rows.row(c)),
                    None => next.push(targets.row(c)), // empty cluster
                }
            }
            next
        };

        if last_cost.is_finite() {
            let rel = (last_cost - cost) / last_cost.max(1e-12);
            if rel.abs() < cfg.lloyd_tol {
                break;
            }
        }
        last_cost = cost;
    }

    let cost_median = history.last().copied().unwrap_or(0.0);
    Ok(ParallelLloydResult {
        centers,
        iters,
        cost_median,
        history,
        prune: None,
    })
}

/// The Hamerly-pruned Parallel-Lloyd (see module docs): same seeding, same
/// partitioning, same leader aggregation and round count as the unpruned
/// [`parallel_lloyd`] — each machine just keeps bound state resident and
/// skips the distances its bounds prove redundant.
fn parallel_lloyd_hamerly(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
) -> Result<ParallelLloydResult, MrError> {
    let d = points.dim();
    let metric = cfg.metric;
    let mut rng = Rng::new(cfg.seed);
    let mut centers = crate::algorithms::seeding::random_distinct(points, cfg.k, &mut rng);
    let k = centers.len();

    let parts = points.chunks(cfg.machines.min(points.len()).max(1));
    let offsets: Vec<usize> = parts
        .iter()
        .scan(0usize, |lo, part| {
            let here = *lo;
            *lo += part.len();
            Some(here)
        })
        .collect();
    let mut states: Vec<BoundedPart> = parts
        .into_iter()
        .map(|part| BoundedPart {
            part,
            idx: Vec::new(),
            lb: Vec::new(),
            surr: Vec::new(),
        })
        .collect();
    // Broadcast per iteration: the k centers, the k half-separation radii,
    // and the scalar movement decay.
    let bcast_bytes = k * d * 4 + k * 4 + 4;

    let mut delta_max = 0.0f32;
    let mut half_sep = vec![0.0f32; k];
    let mut history = Vec::new();
    let mut last_cost = f64::INFINITY;
    let mut iters = 0usize;
    let mut stats = PruneStats::default();

    for it in 0..cfg.lloyd_max_iters {
        iters += 1;
        stats.possible += points.len() as u64 * k as u64;
        let c_ref = &centers;
        let hs_ref: &[f32] = &half_sep;
        let dm = delta_max;
        let steps: Vec<(LloydStepOut, u64)> = cluster.run_machine_round_mut(
            &format!("parallel-lloyd iter {it}"),
            &mut states,
            bcast_bytes,
            move |_m, st: &mut BoundedPart| {
                let evaluated = hamerly_pass(
                    &st.part, c_ref, metric, &mut st.idx, &mut st.lb, &mut st.surr, dm, hs_ref,
                );
                let a = AssignOut {
                    sqdist: st.surr.clone(),
                    idx: st.idx.clone(),
                };
                // The unpruned round's exact per-part accumulation, fed the
                // pruned (identical) assignment.
                let step = crate::runtime::native::lloyd_accumulate(&st.part, c_ref, &a, metric);
                (step, evaluated)
            },
        )?;

        // Leader: aggregate in part order (the unpruned merge order).
        let mut agg = LloydStepOut::default();
        for (s, ev) in &steps {
            agg.merge(s);
            stats.evaluated += ev;
        }
        let cost = agg.cost_median;
        history.push(cost);

        let mut targets = PointSet::with_capacity(d, k);
        let mut row = vec![0.0f32; d];
        for c in 0..k {
            if agg.counts[c] > 0.0 {
                for j in 0..d {
                    row[j] = (agg.sums[c * d + j] / agg.counts[c]) as f32;
                }
                targets.push(&row);
            } else {
                targets.push(centers.row(c));
            }
        }

        let next = if metric.mean_is_minimizer() {
            targets
        } else {
            // Medoid snap: same winner rule as the unpruned coordinator,
            // but the assignment is already resident in the bound state —
            // no second assign pass, and the broadcast is just the mean
            // targets.
            let t_ref = &targets;
            let o_ref = &offsets;
            let msgs: Vec<MedoidMsg> = cluster.run_machine_round(
                &format!("parallel-lloyd iter {it}: medoid snap"),
                &states,
                k * d * 4,
                move |m, st: &BoundedPart| {
                    let mut best: Vec<(f32, u64)> = vec![(f32::INFINITY, u64::MAX); k];
                    for (pos, &c) in st.idx.iter().enumerate() {
                        let cu = c as usize;
                        let s = metric.surrogate(st.part.row(pos), t_ref.row(cu));
                        if s.total_cmp(&best[cu].0) == std::cmp::Ordering::Less {
                            best[cu] = (s, (o_ref[m] + pos) as u64);
                        }
                    }
                    let mut rows = PointSet::with_capacity(d, k);
                    let zero = vec![0.0f32; d];
                    for &(_, gi) in &best {
                        if gi == u64::MAX {
                            rows.push(&zero);
                        } else {
                            rows.push(st.part.row(gi as usize - o_ref[m]));
                        }
                    }
                    MedoidMsg { best, rows }
                },
            )?;
            let mut next = PointSet::with_capacity(d, k);
            for c in 0..k {
                let mut win: Option<(f32, u64, usize)> = None; // (s, gi, machine)
                for (m, msg) in msgs.iter().enumerate() {
                    let (s, gi) = msg.best[c];
                    if gi == u64::MAX {
                        continue;
                    }
                    let better = match win {
                        None => true,
                        Some((ws, wgi, _)) => match s.total_cmp(&ws) {
                            std::cmp::Ordering::Less => true,
                            std::cmp::Ordering::Equal => gi < wgi,
                            std::cmp::Ordering::Greater => false,
                        },
                    };
                    if better {
                        win = Some((s, gi, m));
                    }
                }
                match win {
                    Some((_, _, m)) => next.push(msgs[m].rows.row(c)),
                    None => next.push(targets.row(c)), // empty cluster
                }
            }
            next
        };

        delta_max = max_center_shift(&centers, &next, metric) * BOUND_INFLATE;
        half_sep = half_separation(&next, metric);
        centers = next;

        if last_cost.is_finite() {
            let rel = (last_cost - cost) / last_cost.max(1e-12);
            if rel.abs() < cfg.lloyd_tol {
                break;
            }
        }
        last_cost = cost;
    }

    let cost_median = history.last().copied().unwrap_or(0.0);
    Ok(ParallelLloydResult {
        centers,
        iters,
        cost_median,
        history,
        prune: Some(stats),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::lloyd::{lloyd, LloydConfig};
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::runtime::NativeBackend;

    fn cfg(k: usize, machines: usize) -> ClusterConfig {
        ClusterConfig {
            k,
            machines,
            ..Default::default()
        }
    }

    #[test]
    fn matches_sequential_lloyd_exactly() {
        // Same seed => same init; partitioned sums must reproduce the
        // sequential iterate bit-for-near-bit.
        let data = DataGenConfig {
            n: 4000,
            k: 8,
            seed: 5,
            ..Default::default()
        }
        .generate();
        let ccfg = cfg(8, 16);
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 16,
            ..Default::default()
        });
        let par = parallel_lloyd(&mut cluster, &data.points, &ccfg, &NativeBackend).unwrap();
        let seq = lloyd(
            &data.points,
            None,
            &LloydConfig {
                k: 8,
                max_iters: ccfg.lloyd_max_iters,
                tol: ccfg.lloyd_tol,
                seed: ccfg.seed,
                ..Default::default()
            },
            &NativeBackend,
        );
        // Partitioned accumulation reorders float sums, so trajectories can
        // drift by float noise; the clustering itself must agree closely.
        let rel = (par.cost_median - seq.cost_median).abs() / seq.cost_median.max(1e-9);
        assert!(
            rel < 1e-3,
            "parallel {} vs sequential {}",
            par.cost_median,
            seq.cost_median
        );
        assert!((par.iters as i64 - seq.iters as i64).abs() <= 1);
    }

    #[test]
    fn one_round_per_iteration() {
        let data = DataGenConfig {
            n: 1000,
            k: 4,
            seed: 6,
            ..Default::default()
        }
        .generate();
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 10,
            ..Default::default()
        });
        let res = parallel_lloyd(&mut cluster, &data.points, &cfg(4, 10), &NativeBackend).unwrap();
        assert_eq!(cluster.stats.n_rounds(), res.iters);
    }

    #[test]
    fn hamerly_matches_unpruned_parallel_bitwise() {
        use crate::geometry::MetricKind;
        let data = DataGenConfig {
            n: 3000,
            k: 6,
            seed: 15,
            ..Default::default()
        }
        .generate();
        for metric in [MetricKind::L2Sq, MetricKind::L1] {
            let base = ClusterConfig {
                k: 6,
                machines: 12,
                metric,
                ..Default::default()
            };
            let pruned_cfg = ClusterConfig {
                prune: PruneKind::Hamerly,
                ..base.clone()
            };
            let mut c1 = MrCluster::new(MrConfig {
                n_machines: 12,
                ..Default::default()
            });
            let mut c2 = MrCluster::new(MrConfig {
                n_machines: 12,
                ..Default::default()
            });
            let a = parallel_lloyd(&mut c1, &data.points, &base, &NativeBackend).unwrap();
            let b = parallel_lloyd(&mut c2, &data.points, &pruned_cfg, &NativeBackend).unwrap();
            assert_eq!(a.iters, b.iters, "{metric}");
            assert_eq!(
                a.centers.flat(),
                b.centers.flat(),
                "{metric}: centers diverged"
            );
            assert_eq!(a.history, b.history, "{metric}: history diverged");
            assert_eq!(
                c1.stats.n_rounds(),
                c2.stats.n_rounds(),
                "{metric}: pruning must not change the round count"
            );
            let st = b.prune.expect("pruned run reports stats");
            assert!(st.evaluated < st.possible, "{metric}: no pruning: {st:?}");
            assert!(a.prune.is_none());
        }
    }

    #[test]
    fn hamerly_parallel_machine_count_invariant() {
        let data = DataGenConfig {
            n: 2500,
            k: 5,
            seed: 23,
            ..Default::default()
        }
        .generate();
        let mut costs = Vec::new();
        for m in [1usize, 9, 40] {
            let mut cluster = MrCluster::new(MrConfig {
                n_machines: m,
                ..Default::default()
            });
            let ccfg = ClusterConfig {
                k: 5,
                machines: m,
                prune: PruneKind::Hamerly,
                ..Default::default()
            };
            let res = parallel_lloyd(&mut cluster, &data.points, &ccfg, &NativeBackend).unwrap();
            costs.push(res.cost_median);
        }
        // Part boundaries reorder the f64 merges (same as unpruned), so
        // only float-noise drift is allowed across machine counts.
        for w in costs.windows(2) {
            let rel = (w[0] - w[1]).abs() / w[0].max(1e-9);
            assert!(rel < 1e-6, "pruned costs diverge: {costs:?}");
        }
    }

    #[test]
    fn machine_count_does_not_change_result() {
        let data = DataGenConfig {
            n: 3000,
            k: 5,
            seed: 7,
            ..Default::default()
        }
        .generate();
        let mut costs = Vec::new();
        for m in [1usize, 7, 50] {
            let mut cluster = MrCluster::new(MrConfig {
                n_machines: m,
                ..Default::default()
            });
            let res =
                parallel_lloyd(&mut cluster, &data.points, &cfg(5, m), &NativeBackend).unwrap();
            costs.push(res.cost_median);
        }
        for w in costs.windows(2) {
            let rel = (w[0] - w[1]).abs() / w[0].max(1e-9);
            assert!(rel < 1e-6, "costs diverge across machine counts: {costs:?}");
        }
    }
}
