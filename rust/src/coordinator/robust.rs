//! Outlier-robust coordinator pipelines over the composable summary layer
//! ([`crate::summaries`]).
//!
//! Both pipelines share the same three-round shape — the composable-coreset
//! structure of Ceccarello et al. (k-center with outliers) and Mazzetto et
//! al. (coreset k-median):
//!
//! 1. **summarize** (machine round, resident blocks): every machine
//!    compresses its block into a [`CoverageSummary`] — a weighted
//!    farthest-point skeleton sized so that far outliers survive as their
//!    own low-weight representatives;
//! 2. **compose** (a *generic key/value round*, [`MrCluster::run_round`]):
//!    summaries shuffle to `⌈√m⌉` reducers which merge them with
//!    [`Coreset::compose`]. Because composition is associative and
//!    commutative bit-for-bit, the unspecified shuffle order and lineage
//!    replay of lost reduce outputs cannot change a byte of the result;
//! 3. **final `A`** (leader round): the composed weighted summary is small
//!    enough for one machine, which runs the outlier-robust sequential
//!    algorithm ([`kcenter_with_outliers`]) or weighted local search
//!    ([`local_search_weighted`]).
//!
//! Rounds are O(1), each machine holds its block plus a summary, and the
//! leader holds only the composed summary plus the greedy's pairwise
//! distances. Per-machine summary sizes are clamped so the composed
//! summary never exceeds [`MAX_SUMMARY_REPS`] representatives — without
//! the clamp a large `z` (or machine count) would quietly degenerate the
//! summary back into the whole dataset and void both the leader's memory
//! envelope and the final step's feasibility.

use crate::algorithms::local_search::{local_search_weighted, LocalSearchConfig};
use crate::algorithms::outliers::kcenter_with_outliers_metric;
use crate::config::ClusterConfig;
use crate::geometry::{PointSet, PointStore, StoreBlock};
use crate::mapreduce::{MrCluster, MrError};
use crate::runtime::ComputeBackend;
use crate::summaries::{Coreset, CoverageSummary, WeightedSet};

/// Result of the k-center-with-outliers pipeline.
#[derive(Clone, Debug)]
pub struct RobustKCenterResult {
    /// The k centers.
    pub centers: PointSet,
    /// Representatives in the composed summary the final `A` ran on.
    pub summary_size: usize,
    /// Summary weight the final `A` left uncovered (≤ the `z` budget).
    pub dropped_weight: f64,
    /// Max coverage radius over all per-machine summaries (the summary
    /// layer's contribution to the approximation error).
    pub summary_radius: f64,
}

/// Result of the composable-coreset k-median pipeline.
#[derive(Clone, Debug)]
pub struct CoresetKMedianResult {
    /// The k centers.
    pub centers: PointSet,
    /// Representatives in the composed summary (before outlier trimming).
    pub summary_size: usize,
    /// Summary entries trimmed as suspected outliers before the final `A`.
    pub trimmed: usize,
}

/// Hard cap on the composed summary's representative count, enforced
/// unconditionally: both the per-machine size (the requested `k + z` /
/// `4k + z`) *and* the summarize round's partition count are clamped so
/// that `n_parts · tau ≤ MAX_SUMMARY_REPS`. The leader's final `A` is
/// `O(k · m² · log m)`, so an uncapped `z` or machine count must not be
/// able to degenerate the summary back into the whole dataset. When
/// `machines · k` exceeds the cap, *fewer, larger* blocks are summarized
/// (each still to ≥ `k` representatives); grouped outliers remain
/// droppable either way — the outlier *weight* is unchanged, only its
/// granularity coarsens. The cap is below
/// [`crate::algorithms::outliers::MAX_MATRIX`], so the final greedy
/// always runs against its cached distance matrix.
pub const MAX_SUMMARY_REPS: usize = 2048;

/// The summarize round's shape under the [`MAX_SUMMARY_REPS`] cap:
/// `(n_parts, tau)` with `n_parts · tau ≤ MAX_SUMMARY_REPS` always. First
/// the partition count is bounded so every machine can still afford ≥ `k`
/// representatives, then the per-machine size is bounded by the
/// remainder.
fn summary_shape(machines: usize, n: usize, k: usize, tau_request: usize) -> (usize, usize) {
    let max_parts = (MAX_SUMMARY_REPS / k.max(1)).max(1);
    let n_parts = machines.min(n).min(max_parts).max(1);
    let tau = tau_request.min(MAX_SUMMARY_REPS / n_parts).max(1);
    (n_parts, tau)
}

/// Rounds 1–2 shared by both pipelines: summarize every block to (up to)
/// `tau` weighted representatives, then merge the per-machine summaries
/// in a reduce step. Returns the fully composed summary.
///
/// The summarize round runs over [`StoreBlock`] descriptors: each machine
/// loads its block inside the map closure — an O(1) zero-copy view for a
/// resident store, a streamed window for a file-backed one — summarizes
/// it, and drops the coordinates. Block boundaries, memory charges, and
/// RNG seeds are identical for both backings, so the two runs are
/// bit-identical by construction.
fn summarize_and_compose(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
    label: &str,
    tau: usize,
) -> Result<CoverageSummary, MrError> {
    let (n_parts, tau) = summary_shape(cfg.machines, store.len(), cfg.k, tau);
    let blocks = store.blocks(n_parts);

    // ---- Round 1: per-machine coverage summaries over blocks ----
    let seed = cfg.seed;
    let metric = cfg.metric;
    let summaries: Vec<CoverageSummary> = cluster.run_machine_round(
        &format!("{label}: summarize blocks"),
        &blocks,
        0,
        move |m, block: &StoreBlock| {
            let part = block.load();
            CoverageSummary::build_metric(
                part.points(),
                tau.min(part.len()).max(1),
                seed ^ (m as u64),
                backend,
                metric,
            )
        },
    )?;

    // ---- Round 2: associative composition inside a reduce step ----
    // ⌈√m⌉ groups: each reducer folds ~√m summaries, the leader folds the
    // √m group results — a two-level compose tree. compose() is immune to
    // the shuffle's grouping and ordering, so this round is bit-exact under
    // any thread count and any injected-failure replay.
    let groups = (summaries.len() as f64).sqrt().ceil().max(1.0) as usize;
    let keyed: Vec<(usize, CoverageSummary)> = summaries.into_iter().enumerate().collect();
    let merged_groups: Vec<(usize, CoverageSummary)> = cluster.run_round(
        &format!("{label}: compose summaries"),
        keyed,
        move |i: &usize, s: &CoverageSummary, emit| emit(i % groups, s.clone()),
        |g: &usize, group: &[CoverageSummary], emit| {
            // compose_all: one canonicalization per reducer, byte-identical
            // to the pairwise fold (see the CoverageSummary docs).
            let folded = CoverageSummary::compose_all(group.iter().cloned())
                .expect("non-empty shuffle group");
            emit(*g, folded);
        },
    )?;

    Ok(
        CoverageSummary::compose_all(merged_groups.into_iter().map(|(_, s)| s))
            .unwrap_or_else(|| {
                CoverageSummary::from_weighted(WeightedSet::with_capacity(store.dim(), 0), 0.0)
            }),
    )
}

/// MapReduce k-center with outliers: per-machine coverage summaries of
/// size `k + z` (Ceccarello et al.'s sizing — enough representatives that
/// the `z` outliers cannot hide inside a cluster's summary; clamped to
/// keep the composed summary under [`MAX_SUMMARY_REPS`]), composed
/// associatively, with the `z` outliers dropped only at the final
/// sequential step.
pub fn mr_kcenter_outliers(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<RobustKCenterResult, MrError> {
    mr_kcenter_outliers_store(cluster, &PointStore::from(points.clone()), cfg, backend)
}

/// [`mr_kcenter_outliers`] over any [`PointStore`] backing. With a
/// file-backed store each summarize machine streams only its own block
/// into memory; the result is bit-identical to the resident run on the
/// same seed and config.
pub fn mr_kcenter_outliers_store(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<RobustKCenterResult, MrError> {
    let tau = (cfg.k + cfg.z).max(1);
    let merged = summarize_and_compose(cluster, store, cfg, backend, "robust-kcenter", tau)?;

    // ---- Round 3: weighted outlier-robust A on one machine. The leader
    // holds the summary plus the greedy's cached distance matrix (the
    // same |C|²-style charge MapReduce-kCenter pays for its sample);
    // above MAX_MATRIX the greedy recomputes on the fly and no matrix is
    // charged. The summary cap keeps m under MAX_MATRIX in this pipeline,
    // so the branch only matters for direct library callers.
    let m = merged.len();
    let matrix_bytes = if m <= crate::algorithms::outliers::MAX_MATRIX {
        m * m * 4
    } else {
        0
    };
    let leader_mem = crate::mapreduce::MemSize::mem_bytes(&merged) + matrix_bytes;
    let k = cfg.k;
    let z = cfg.z as f64;
    let metric = cfg.metric;
    let merged_ref = &merged;
    let result = cluster.run_leader_round("robust-kcenter: A on summary", leader_mem, || {
        kcenter_with_outliers_metric(merged_ref.reps(), k, z, metric)
    })?;

    Ok(RobustKCenterResult {
        centers: result.centers,
        summary_size: m,
        dropped_weight: result.dropped_weight,
        summary_radius: merged.radius(),
    })
}

/// Composable-coreset k-median: per-machine coverage summaries (sized
/// `4k + z` so cluster geometry survives the compression), composed
/// associatively, then weighted local search on the merged summary — with
/// the `z` lightest representatives trimmed first, since outliers surface
/// in a coverage summary as their own weight-≈1 entries.
pub fn mr_coreset_kmedian(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<CoresetKMedianResult, MrError> {
    mr_coreset_kmedian_store(cluster, &PointStore::from(points.clone()), cfg, backend)
}

/// [`mr_coreset_kmedian`] over any [`PointStore`] backing. With a
/// file-backed store each summarize machine streams only its own block
/// into memory; the result is bit-identical to the resident run on the
/// same seed and config.
pub fn mr_coreset_kmedian_store(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<CoresetKMedianResult, MrError> {
    let tau = (4 * cfg.k + cfg.z).max(1);
    let merged = summarize_and_compose(cluster, store, cfg, backend, "coreset-kmedian", tau)?;
    solve_summary_kmedian(cluster, &merged, cfg)
}

/// The coreset-k-median pipeline's final round on an already-composed
/// summary: trim up to `z` suspected outliers (lightest entries; ties
/// resolve by the canonical order, so the trim is deterministic), but never
/// below `k` survivors, then run weighted local search on the leader.
///
/// Exposed so the serving layer ([`crate::serve`]) can re-solve an epoch
/// sketch through the exact same leader step (same trim order, same
/// local-search seed derivation) that the one-shot pipeline uses.
pub fn solve_summary_kmedian(
    cluster: &mut MrCluster,
    merged: &CoverageSummary,
    cfg: &ClusterConfig,
) -> Result<CoresetKMedianResult, MrError> {
    let summary_size = merged.len();
    let reps = merged.reps();
    let trimmed = cfg.z.min(summary_size.saturating_sub(cfg.k));
    let mut order: Vec<usize> = (0..summary_size).collect();
    order.sort_by(|&a, &b| reps.weight(a).total_cmp(&reps.weight(b)).then(a.cmp(&b)));
    let mut keep: Vec<usize> = order[trimmed..].to_vec();
    keep.sort_unstable(); // back to canonical order for the final A
    let trimmed_set = reps.gather(&keep);

    let leader_mem = crate::mapreduce::MemSize::mem_bytes(&trimmed_set);
    let ls_cfg = LocalSearchConfig {
        k: cfg.k,
        min_rel_gain: cfg.ls_min_rel_gain,
        max_swaps: cfg.ls_max_swaps,
        candidate_fraction: cfg.ls_candidate_fraction,
        metric: cfg.metric,
        seed: cfg.seed ^ 0xC0_5E7,
    };
    let set_ref = &trimmed_set;
    let ls_ref = &ls_cfg;
    let centers = cluster.run_leader_round(
        "coreset-kmedian: weighted local search",
        leader_mem,
        || local_search_weighted(set_ref, ls_ref).centers,
    )?;

    Ok(CoresetKMedianResult {
        centers,
        summary_size,
        trimmed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::metrics::{kcenter_cost_with_outliers, kmedian_cost};
    use crate::runtime::NativeBackend;

    fn contaminated(n: usize, k: usize, contamination: f64, seed: u64) -> crate::data::Dataset {
        DataGenConfig {
            n,
            k,
            sigma: 0.05,
            contamination,
            seed,
            ..Default::default()
        }
        .generate()
    }

    fn cluster(machines: usize) -> MrCluster {
        MrCluster::new(MrConfig {
            n_machines: machines,
            ..Default::default()
        })
    }

    #[test]
    fn robust_kcenter_three_rounds_and_shapes() {
        let data = contaminated(2000, 5, 0.01, 51);
        let z = data.n_outliers();
        let cfg = ClusterConfig {
            k: 5,
            machines: 8,
            z,
            seed: 51,
            ..Default::default()
        };
        let mut c = cluster(8);
        let res = mr_kcenter_outliers(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(c.stats.n_rounds(), 3, "summarize + compose + A");
        assert_eq!(res.centers.len(), 5);
        assert!(res.summary_size <= 8 * (5 + z));
        assert!(res.dropped_weight <= z as f64 + 1e-9);
    }

    #[test]
    fn robust_kcenter_shrugs_off_contamination() {
        let data = contaminated(2000, 5, 0.01, 52);
        let z = data.n_outliers();
        assert!(z > 0, "contamination must have produced outliers");
        let cfg = ClusterConfig {
            k: 5,
            machines: 8,
            z,
            seed: 52,
            ..Default::default()
        };
        let mut c = cluster(8);
        let res = mr_kcenter_outliers(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        let robust_cost = kcenter_cost_with_outliers(&data.points, &res.centers, z);
        // Calibration: the planted centers with the same z dropped are the
        // harness's reference; the pipeline pays the summary radius plus
        // the greedy's 3x, so 4x the reference is a conservative envelope.
        let reference = kcenter_cost_with_outliers(&data.points, &data.planted_centers, z);
        assert!(
            robust_cost <= reference * 4.0 + 1e-6,
            "robust {robust_cost} vs reference {reference}"
        );
    }

    #[test]
    fn coreset_kmedian_quality_on_clean_data() {
        let data = contaminated(4000, 8, 0.0, 53);
        let cfg = ClusterConfig {
            k: 8,
            machines: 8,
            seed: 53,
            ls_max_swaps: 40,
            ..Default::default()
        };
        let mut c = cluster(8);
        let res = mr_coreset_kmedian(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(c.stats.n_rounds(), 3);
        assert_eq!(res.centers.len(), 8);
        assert_eq!(res.trimmed, 0, "z defaults to 0");
        let cost = kmedian_cost(&data.points, &res.centers);
        let planted = data.planted_cost_median();
        assert!(cost < planted * 2.0, "cost {cost} vs planted {planted}");
    }

    #[test]
    fn replays_identically_at_any_machine_count() {
        let data = contaminated(1000, 4, 0.02, 54);
        let z = data.n_outliers();
        for machines in [4usize, 9] {
            let cfg = ClusterConfig {
                k: 4,
                machines,
                z,
                seed: 54,
                ..Default::default()
            };
            let a = mr_kcenter_outliers(&mut cluster(machines), &data.points, &cfg, &NativeBackend)
                .unwrap();
            let b = mr_kcenter_outliers(&mut cluster(machines), &data.points, &cfg, &NativeBackend)
                .unwrap();
            assert_eq!(a.centers, b.centers, "same config must replay identically");
        }
    }

    #[test]
    fn summary_shape_invariants_hold_across_the_knob_space() {
        // The cap must hold for EVERY (machines, n, k, z) combination —
        // including machines * k far beyond the cap, where the partition
        // count itself must shrink.
        for machines in [1usize, 4, 100, 1000, 5000] {
            for n in [1usize, 100, 10_000, 1_000_000] {
                for k in [1usize, 5, 25, 400] {
                    for z in [0usize, 10, 1000, 100_000] {
                        let (n_parts, tau) = summary_shape(machines, n, k, k + z);
                        assert!(
                            n_parts * tau <= MAX_SUMMARY_REPS,
                            "cap violated: machines={machines} n={n} k={k} z={z} \
                             -> {n_parts} x {tau}"
                        );
                        assert!(n_parts >= 1 && tau >= 1);
                        assert!(n_parts <= machines.min(n.max(1)));
                        // Every machine can afford k reps while the
                        // request allows it and k itself fits the cap.
                        if k <= MAX_SUMMARY_REPS {
                            assert!(tau >= k.min(k + z), "tau {tau} < k {k}");
                        }
                    }
                }
            }
        }
        // The documented-default regime the review flagged: 100 machines,
        // k = 25 must stay under the cap (81 x 25 = 2025).
        let (n_parts, tau) = summary_shape(100, 50_000, 25, 25 + 500);
        assert!(n_parts * tau <= MAX_SUMMARY_REPS);
        assert_eq!(tau, 25);
        // And the summary always fits the greedy's distance-matrix cache.
        assert!(MAX_SUMMARY_REPS <= crate::algorithms::outliers::MAX_MATRIX);
    }

    #[test]
    fn huge_z_cannot_degenerate_the_summary_into_the_dataset() {
        // z is a user knob: an absurd budget must clamp the per-machine
        // summary size instead of shipping every point to the leader
        // (k = 1 keeps the final greedy cheap at the capped size).
        let data = contaminated(4096, 3, 0.0, 56);
        let cfg = ClusterConfig {
            k: 1,
            machines: 4,
            z: 1000,
            seed: 56,
            ..Default::default()
        };
        let mut c = cluster(4);
        let res = mr_kcenter_outliers(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        assert!(
            res.summary_size <= super::MAX_SUMMARY_REPS,
            "summary {} blew past the cap",
            res.summary_size
        );
        assert!(
            res.summary_size < data.points.len() / 2,
            "summary {} is not a summary",
            res.summary_size
        );
        assert_eq!(res.centers.len(), 1);
    }

    #[test]
    fn file_backed_run_is_bit_identical_to_resident() {
        let gen = DataGenConfig {
            n: 1500,
            k: 4,
            sigma: 0.05,
            contamination: 0.02,
            seed: 57,
            ..Default::default()
        };
        let data = gen.generate();
        let z = data.n_outliers();
        let dir = std::env::temp_dir().join("mrcluster_robust_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let store = PointStore::from(gen.generate_stream(&dir.join("robust_ooc.mrc")).unwrap());
        let cfg = ClusterConfig {
            k: 4,
            machines: 6,
            z,
            seed: 57,
            ..Default::default()
        };
        let mem = mr_kcenter_outliers(&mut cluster(6), &data.points, &cfg, &NativeBackend).unwrap();
        let ooc = mr_kcenter_outliers_store(&mut cluster(6), &store, &cfg, &NativeBackend).unwrap();
        assert_eq!(mem.centers, ooc.centers, "file-backed centers diverged");
        assert_eq!(mem.summary_size, ooc.summary_size);
        assert_eq!(mem.dropped_weight.to_bits(), ooc.dropped_weight.to_bits());
        let meter = store.meter().expect("file store is metered");
        assert_eq!(meter.current(), 0, "every resident window must be dropped");
        assert!(meter.peak() > 0, "the run must have streamed something");
    }

    #[test]
    fn single_machine_degenerate_case() {
        let data = contaminated(100, 3, 0.0, 55);
        let cfg = ClusterConfig {
            k: 3,
            machines: 1,
            seed: 55,
            ..Default::default()
        };
        let res =
            mr_kcenter_outliers(&mut cluster(1), &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(res.centers.len(), 3);
    }
}
