//! Algorithm registry and the single entry point the CLI / examples /
//! benches use.

use super::divide::{mr_divide_kmedian, mr_divide_kmedian_store};
use super::kcenter::{mr_kcenter, mr_kcenter_store};
use super::kmedian::mr_kmedian;
use super::parallel_lloyd::parallel_lloyd;
use super::InnerAlgo;
use crate::algorithms::local_search::{local_search, LocalSearchConfig};
use crate::config::{ClusterConfig, RuntimeBackendKind};
use crate::geometry::{PointSet, PointStore};
use crate::mapreduce::{MrCluster, MrConfig, RunStats};
use crate::metrics::cost::{eval_costs_metric, eval_costs_store, CostSummary};
use crate::runtime::{ComputeBackend, FastNativeBackend, NativeBackend};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Every algorithm the paper evaluates (§4.1), plus MapReduce-kCenter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// §4.1 Parallel-Lloyd (baseline all costs are normalized to).
    ParallelLloyd,
    /// Algorithm 6 with A = Lloyd.
    DivideLloyd,
    /// Algorithm 6 with A = local search.
    DivideLocalSearch,
    /// Algorithm 5 with A = Lloyd.
    SamplingLloyd,
    /// Algorithm 5 with A = local search.
    SamplingLocalSearch,
    /// Sequential Arya et al. local search on the full data.
    LocalSearch,
    /// Algorithm 4 (k-center objective).
    MrKCenter,
    /// Guha et al. hierarchical streaming k-median [20] — the streaming
    /// baseline the paper contrasts its constant-round guarantee with.
    StreamingGuha,
    /// k-center with `z` outliers over composable coverage summaries
    /// (Ceccarello et al.; see [`super::robust`]).
    RobustKCenter,
    /// Composable-coreset k-median: weighted local search on the merged
    /// per-machine summaries (Mazzetto et al.; see [`super::robust`]).
    CoresetKMedian,
    /// Rival 2-round coreset k-median with accuracy-oriented
    /// `(k/ε²)·polylog(n)` per-machine sizing (Mazzetto et al.,
    /// arXiv:1904.12728; see [`super::mazzetto`]).
    MazzettoKMedian,
    /// Rival 2-round k-center with outliers: per-machine Gonzalez
    /// skeletons of `k + z + √(n/m)` reps, outlier-aware greedy on the
    /// union (Ceccarello et al., arXiv:1802.09205; see
    /// [`super::ceccarello`]).
    CeccarelloKCenter,
}

impl Algorithm {
    /// The paper's display name (Figures 1–2 row labels).
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::ParallelLloyd => "Parallel-Lloyd",
            Algorithm::DivideLloyd => "Divide-Lloyd",
            Algorithm::DivideLocalSearch => "Divide-LocalSearch",
            Algorithm::SamplingLloyd => "Sampling-Lloyd",
            Algorithm::SamplingLocalSearch => "Sampling-LocalSearch",
            Algorithm::LocalSearch => "LocalSearch",
            Algorithm::MrKCenter => "MapReduce-kCenter",
            Algorithm::StreamingGuha => "Streaming-Guha",
            Algorithm::RobustKCenter => "Robust-kCenter",
            Algorithm::CoresetKMedian => "Coreset-kMedian",
            Algorithm::MazzettoKMedian => "Mazzetto-kMedian",
            Algorithm::CeccarelloKCenter => "Ceccarello-kCenter",
        }
    }

    /// Parse a CLI name (case/format tolerant).
    pub fn parse(s: &str) -> Option<Algorithm> {
        let norm: String = s
            .chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .collect::<String>()
            .to_ascii_lowercase();
        Some(match norm.as_str() {
            "parallellloyd" | "plloyd" => Algorithm::ParallelLloyd,
            "dividelloyd" => Algorithm::DivideLloyd,
            "dividelocalsearch" => Algorithm::DivideLocalSearch,
            "samplinglloyd" => Algorithm::SamplingLloyd,
            "samplinglocalsearch" => Algorithm::SamplingLocalSearch,
            "localsearch" => Algorithm::LocalSearch,
            "mrkcenter" | "kcenter" | "mapreducekcenter" => Algorithm::MrKCenter,
            "streamingguha" | "streaming" => Algorithm::StreamingGuha,
            "robustkcenter" | "kcenteroutliers" | "kcenterwithoutliers" => {
                Algorithm::RobustKCenter
            }
            "coresetkmedian" | "coreset" => Algorithm::CoresetKMedian,
            "mazzettokmedian" | "mazzetto" => Algorithm::MazzettoKMedian,
            "ceccarellokcenter" | "ceccarello" => Algorithm::CeccarelloKCenter,
            _ => return None,
        })
    }

    /// All Figure-1 algorithms in the paper's row order.
    pub fn figure1() -> [Algorithm; 6] {
        [
            Algorithm::ParallelLloyd,
            Algorithm::DivideLloyd,
            Algorithm::DivideLocalSearch,
            Algorithm::SamplingLloyd,
            Algorithm::SamplingLocalSearch,
            Algorithm::LocalSearch,
        ]
    }

    /// The scalable subset the paper runs at n ≥ 2M (Figure 2).
    pub fn figure2() -> [Algorithm; 4] {
        [
            Algorithm::ParallelLloyd,
            Algorithm::DivideLloyd,
            Algorithm::SamplingLloyd,
            Algorithm::SamplingLocalSearch,
        ]
    }

    /// Every registered pipeline, in registry order — the E17 arena's row
    /// set (paper algorithms, then the repo's robust pipelines, then the
    /// rival-paper coordinators).
    pub fn all() -> [Algorithm; 12] {
        [
            Algorithm::ParallelLloyd,
            Algorithm::DivideLloyd,
            Algorithm::DivideLocalSearch,
            Algorithm::SamplingLloyd,
            Algorithm::SamplingLocalSearch,
            Algorithm::LocalSearch,
            Algorithm::MrKCenter,
            Algorithm::StreamingGuha,
            Algorithm::RobustKCenter,
            Algorithm::CoresetKMedian,
            Algorithm::MazzettoKMedian,
            Algorithm::CeccarelloKCenter,
        ]
    }
}

/// The uniform result record all drivers produce.
#[derive(Clone, Debug)]
pub struct Outcome {
    /// Which algorithm produced this outcome.
    pub algorithm: Algorithm,
    /// The k centers the run selected.
    pub centers: PointSet,
    /// Exact objectives of `centers` over the full input, evaluated under
    /// the run's configured metric (`ClusterConfig::metric`).
    pub cost: CostSummary,
    /// k-median objective (= cost.median; kept for ergonomic access).
    pub cost_median: f64,
    /// Paper-methodology simulated time (Σ rounds max-machine compute).
    pub sim_time: std::time::Duration,
    /// Discrete-event simulated wall-clock (Σ rounds; see
    /// [`crate::sim`]). Zero unless `sim.enabled`.
    pub sim_wallclock: std::time::Duration,
    /// Host wall-clock for the whole run.
    pub wall_time: std::time::Duration,
    /// MapReduce rounds executed (the quantity the paper's theorems bound).
    pub rounds: usize,
    /// |C| for the sampling algorithms, ℓ·k for divide, the composed
    /// summary size for the robust pipelines, None otherwise.
    pub reduced_size: Option<usize>,
    /// Full per-round accounting (timing, shuffle, memory, recovery).
    pub stats: RunStats,
}

/// Instantiate the configured compute backend. Requesting XLA never fails
/// the run: without the `xla` cargo feature, or when the PJRT runtime /
/// AOT artifacts are missing, it falls back to [`NativeBackend`] with a
/// logged warning (see `runtime` module docs).
///
/// The kernel-ladder knobs (`cluster.kernel`, `cluster.precision`) route
/// to [`FastNativeBackend`] when either is set off its exact default; the
/// AOT path has no fast-path kernels, so combining them with
/// `cluster.backend = xla` falls back to the fast *native* backend with a
/// warning rather than silently dropping the request.
pub fn make_backend(cfg: &ClusterConfig) -> Arc<dyn ComputeBackend> {
    use crate::runtime::{AssignPath, Precision};
    let fast = cfg.kernel != AssignPath::Exact || cfg.precision != Precision::F64;
    if fast {
        if cfg.backend == RuntimeBackendKind::Xla {
            log::warn!(
                "cluster.kernel={} / cluster.precision={} have no XLA \
                 implementation; running the fast native backend instead.",
                cfg.kernel,
                cfg.precision
            );
        }
        return Arc::new(FastNativeBackend {
            assign_path: cfg.kernel,
            precision: cfg.precision,
        });
    }
    match cfg.backend {
        RuntimeBackendKind::Native => Arc::new(NativeBackend),
        #[cfg(feature = "xla")]
        RuntimeBackendKind::Xla => match crate::runtime::XlaBackend::new(&cfg.artifact_dir) {
            Ok(b) => Arc::new(b),
            Err(e) => {
                log::warn!(
                    "XLA backend unavailable ({e:#}); falling back to native. \
                     Run `make artifacts` to build the AOT kernels."
                );
                Arc::new(NativeBackend)
            }
        },
        #[cfg(not(feature = "xla"))]
        RuntimeBackendKind::Xla => {
            log::warn!(
                "XLA backend requested but this build has no `xla` feature; \
                 falling back to native. Rebuild with `--features xla`."
            );
            Arc::new(NativeBackend)
        }
    }
}

/// Engine config derived from the cluster config (shared with the serving
/// layer so epoch re-solves run under the identical fault/sim regime).
pub(crate) fn mr_config(cfg: &ClusterConfig) -> MrConfig {
    MrConfig {
        n_machines: cfg.machines,
        mem_limit: cfg.mem_limit,
        parallel: cfg.parallel,
        threads: cfg.threads,
        fail_prob: cfg.fail_prob,
        straggler_prob: cfg.straggler_prob,
        straggler_factor: cfg.straggler_factor,
        max_task_retries: cfg.max_task_retries,
        speculative: cfg.speculative,
        checkpoint: cfg.checkpoint,
        fault_seed: cfg.seed ^ 0xFA17,
        sim: cfg.sim.clone(),
    }
}

/// Run `algorithm` over `points` under `cfg`. This is the API entry point.
pub fn run_algorithm(
    algorithm: Algorithm,
    points: &PointSet,
    cfg: &ClusterConfig,
) -> Result<Outcome> {
    let backend = make_backend(cfg);
    run_algorithm_with(algorithm, points, cfg, backend.as_ref())
}

/// Like [`run_algorithm`] but with an explicit backend (used by benches to
/// share one PJRT client across runs).
pub fn run_algorithm_with(
    algorithm: Algorithm,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<Outcome> {
    let t0 = Instant::now();
    let mut cluster = MrCluster::new(mr_config(cfg));

    let (centers, reduced_size) = match algorithm {
        Algorithm::ParallelLloyd => {
            let r = parallel_lloyd(&mut cluster, points, cfg, backend)?;
            (r.centers, None)
        }
        Algorithm::DivideLloyd => {
            let r = mr_divide_kmedian(&mut cluster, points, cfg, InnerAlgo::Lloyd, backend)?;
            (r.centers, Some(r.collapsed_size))
        }
        Algorithm::DivideLocalSearch => {
            let r =
                mr_divide_kmedian(&mut cluster, points, cfg, InnerAlgo::LocalSearch, backend)?;
            (r.centers, Some(r.collapsed_size))
        }
        Algorithm::SamplingLloyd => {
            let r = mr_kmedian(&mut cluster, points, cfg, InnerAlgo::Lloyd, backend)?;
            (r.centers, Some(r.sample_size))
        }
        Algorithm::SamplingLocalSearch => {
            let r = mr_kmedian(&mut cluster, points, cfg, InnerAlgo::LocalSearch, backend)?;
            (r.centers, Some(r.sample_size))
        }
        Algorithm::LocalSearch => {
            // The sequential baseline: one machine, the whole input.
            let centers = cluster.run_leader_round(
                "local-search (sequential)",
                points.mem_bytes(),
                || {
                    local_search(
                        points,
                        None,
                        &LocalSearchConfig {
                            k: cfg.k,
                            min_rel_gain: cfg.ls_min_rel_gain,
                            max_swaps: cfg.ls_max_swaps,
                            candidate_fraction: cfg.ls_candidate_fraction,
                            metric: cfg.metric,
                            seed: cfg.seed,
                        },
                    )
                    .centers
                },
            )?;
            (centers, None)
        }
        Algorithm::MrKCenter => {
            let r = mr_kcenter(&mut cluster, points, cfg, backend)?;
            (r.centers, Some(r.sample_size))
        }
        Algorithm::RobustKCenter => {
            let r = super::robust::mr_kcenter_outliers(&mut cluster, points, cfg, backend)?;
            (r.centers, Some(r.summary_size))
        }
        Algorithm::CoresetKMedian => {
            let r = super::robust::mr_coreset_kmedian(&mut cluster, points, cfg, backend)?;
            (r.centers, Some(r.summary_size))
        }
        Algorithm::MazzettoKMedian => {
            let r = super::mazzetto::mr_mazzetto_kmedian(&mut cluster, points, cfg, backend)?;
            (r.centers, Some(r.coreset_size))
        }
        Algorithm::CeccarelloKCenter => {
            let r = super::ceccarello::mr_ceccarello_kcenter(&mut cluster, points, cfg, backend)?;
            (r.centers, Some(r.skeleton_size))
        }
        Algorithm::StreamingGuha => {
            // One-pass hierarchical streaming on a single machine; its
            // memory charge is one block per level (the streaming model's
            // whole point).
            use crate::algorithms::streaming::{streaming_kmedian, StreamingConfig};
            let block = (points.len() as f64).sqrt().ceil() as usize;
            let scfg = StreamingConfig {
                k: cfg.k,
                block_size: block.max(cfg.k * 4),
                lloyd_max_iters: cfg.lloyd_max_iters,
                lloyd_tol: cfg.lloyd_tol,
                metric: cfg.metric,
                seed: cfg.seed,
            };
            let mem = scfg.block_size * points.dim() * 4 * 4; // ~levels
            let r = cluster.run_leader_round("streaming-guha (one pass)", mem, || {
                streaming_kmedian(points, &scfg)
            })?;
            (r.centers, Some(r.block_clusterings))
        }
    };

    let wall_time = t0.elapsed();
    // Host-side exact evaluation (not simulated), under the configured
    // metric: threads = 1 forces a single pass; any other value uses the
    // shared worker pool, whose size is fixed per process (cores /
    // MRCLUSTER_POOL_THREADS) — the config value is a serial/parallel
    // switch here, not a worker count.
    let cost = eval_costs_metric(points, &centers, cfg.metric, cfg.threads);
    Ok(Outcome {
        algorithm,
        cost_median: cost.median,
        cost,
        centers,
        sim_time: cluster.stats.sim_time(),
        sim_wallclock: cluster.stats.sim_wallclock(),
        wall_time,
        rounds: cluster.stats.n_rounds(),
        reduced_size,
        stats: cluster.stats,
    })
}

/// Run `algorithm` over any [`PointStore`] backing.
///
/// For a resident store this is exactly [`run_algorithm`]. For a
/// file-backed store the streaming coordinators — MapReduce-kCenter,
/// Robust-kCenter, Coreset-kMedian, Mazzetto-kMedian, Ceccarello-kCenter,
/// Divide-Lloyd / Divide-LocalSearch —
/// make one sequential pass per round over the backing file, the final
/// cost sweep streams `chunk_points`-sized windows, and the result is
/// bit-identical to the resident run on the same seed and config.
/// Algorithms that fundamentally hold the whole input on one machine
/// (LocalSearch, Streaming-Guha) or rebroadcast the input every iteration
/// (Parallel-Lloyd, the Sampling k-median weight round) fail with a clear
/// error under file backing instead of silently loading everything.
pub fn run_algorithm_store(
    algorithm: Algorithm,
    store: &PointStore,
    cfg: &ClusterConfig,
    chunk_points: usize,
) -> Result<Outcome> {
    let backend = make_backend(cfg);
    run_algorithm_store_with(algorithm, store, cfg, chunk_points, backend.as_ref())
}

/// Like [`run_algorithm_store`] but with an explicit backend.
pub fn run_algorithm_store_with(
    algorithm: Algorithm,
    store: &PointStore,
    cfg: &ClusterConfig,
    chunk_points: usize,
    backend: &dyn ComputeBackend,
) -> Result<Outcome> {
    if let PointStore::Mem(points) = store {
        return run_algorithm_with(algorithm, points, cfg, backend);
    }
    let t0 = Instant::now();
    let mut cluster = MrCluster::new(mr_config(cfg));

    let (centers, reduced_size) = match algorithm {
        Algorithm::MrKCenter => {
            let r = mr_kcenter_store(&mut cluster, store, cfg, backend)?;
            (r.centers, Some(r.sample_size))
        }
        Algorithm::RobustKCenter => {
            let r = super::robust::mr_kcenter_outliers_store(&mut cluster, store, cfg, backend)?;
            (r.centers, Some(r.summary_size))
        }
        Algorithm::CoresetKMedian => {
            let r = super::robust::mr_coreset_kmedian_store(&mut cluster, store, cfg, backend)?;
            (r.centers, Some(r.summary_size))
        }
        Algorithm::MazzettoKMedian => {
            let r =
                super::mazzetto::mr_mazzetto_kmedian_store(&mut cluster, store, cfg, backend)?;
            (r.centers, Some(r.coreset_size))
        }
        Algorithm::CeccarelloKCenter => {
            let r = super::ceccarello::mr_ceccarello_kcenter_store(
                &mut cluster,
                store,
                cfg,
                backend,
            )?;
            (r.centers, Some(r.skeleton_size))
        }
        Algorithm::DivideLloyd => {
            let r =
                mr_divide_kmedian_store(&mut cluster, store, cfg, InnerAlgo::Lloyd, backend)?;
            (r.centers, Some(r.collapsed_size))
        }
        Algorithm::DivideLocalSearch => {
            let r = mr_divide_kmedian_store(
                &mut cluster,
                store,
                cfg,
                InnerAlgo::LocalSearch,
                backend,
            )?;
            (r.centers, Some(r.collapsed_size))
        }
        other => anyhow::bail!(
            "{} has no out-of-core path (it holds the full input on one machine or \
             rebroadcasts it every round); rerun with data.backing = mem",
            other.name()
        ),
    };

    let wall_time = t0.elapsed();
    // Host-side exact evaluation, streamed over the backing file in
    // windows of `chunk_points` (rounded to the fixed reduction block, so
    // the result is bit-identical to the resident evaluation).
    let cost = eval_costs_store(store, &centers, cfg.metric, cfg.threads, chunk_points);
    Ok(Outcome {
        algorithm,
        cost_median: cost.median,
        cost,
        centers,
        sim_time: cluster.stats.sim_time(),
        sim_wallclock: cluster.stats.sim_wallclock(),
        wall_time,
        rounds: cluster.stats.n_rounds(),
        reduced_size,
        stats: cluster.stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;

    fn small_cfg(seed: u64) -> (PointSet, ClusterConfig, f64) {
        let data = DataGenConfig {
            n: 8000,
            k: 8,
            sigma: 0.05,
            seed,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 8,
            epsilon: 0.2,
            machines: 8,
            seed,
            ls_max_swaps: 40,
            ..Default::default()
        };
        let planted = data.planted_cost_median();
        (data.points, cfg, planted)
    }

    #[test]
    fn every_algorithm_runs_and_is_sane() {
        let (points, cfg, planted) = small_cfg(41);
        for algo in Algorithm::figure1() {
            let out = run_algorithm(algo, &points, &cfg).unwrap();
            assert_eq!(out.centers.len(), 8, "{}", algo.name());
            assert!(out.rounds >= 1, "{}", algo.name());
            assert!(
                out.cost_median < planted * 3.0,
                "{}: cost {} vs planted {planted}",
                algo.name(),
                out.cost_median
            );
        }
    }

    #[test]
    fn kcenter_runs() {
        let (points, cfg, _) = small_cfg(42);
        let out = run_algorithm(Algorithm::MrKCenter, &points, &cfg).unwrap();
        assert_eq!(out.centers.len(), 8);
        assert!(out.cost.center > 0.0);
    }

    #[test]
    fn names_roundtrip_through_parse() {
        for algo in Algorithm::all() {
            assert_eq!(Algorithm::parse(algo.name()), Some(algo), "{}", algo.name());
        }
        assert_eq!(Algorithm::parse("sampling-lloyd"), Some(Algorithm::SamplingLloyd));
        assert_eq!(Algorithm::parse("mazzetto"), Some(Algorithm::MazzettoKMedian));
        assert_eq!(Algorithm::parse("ceccarello"), Some(Algorithm::CeccarelloKCenter));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn all_covers_every_variant_once() {
        let all = Algorithm::all();
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a, b, "duplicate registry entry {}", a.name());
            }
        }
        assert!(all.contains(&Algorithm::MazzettoKMedian));
        assert!(all.contains(&Algorithm::CeccarelloKCenter));
    }

    #[test]
    fn sampling_reduced_size_reported() {
        let (points, cfg, _) = small_cfg(43);
        let out = run_algorithm(Algorithm::SamplingLloyd, &points, &cfg).unwrap();
        let rs = out.reduced_size.unwrap();
        assert!(rs > 0 && rs < points.len());
    }

    #[test]
    fn file_backed_outcome_matches_resident() {
        let gen = DataGenConfig {
            n: 6000,
            k: 6,
            sigma: 0.05,
            seed: 44,
            ..Default::default()
        };
        let data = gen.generate();
        let dir = std::env::temp_dir().join("mrcluster_driver_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let store = PointStore::from(gen.generate_stream(&dir.join("drv.mrc")).unwrap());
        let cfg = ClusterConfig {
            k: 6,
            epsilon: 0.2,
            machines: 8,
            seed: 44,
            ..Default::default()
        };
        for algo in [Algorithm::MrKCenter, Algorithm::CoresetKMedian, Algorithm::DivideLloyd] {
            let mem = run_algorithm(algo, &data.points, &cfg).unwrap();
            let ooc = run_algorithm_store(algo, &store, &cfg, 64 * 1024).unwrap();
            assert_eq!(mem.centers, ooc.centers, "{}", algo.name());
            assert_eq!(mem.rounds, ooc.rounds, "{}", algo.name());
            assert_eq!(
                mem.cost.median.to_bits(),
                ooc.cost.median.to_bits(),
                "{}",
                algo.name()
            );
        }
    }

    #[test]
    fn non_streaming_algorithms_refuse_file_backing() {
        let gen = DataGenConfig {
            n: 500,
            k: 3,
            seed: 45,
            ..Default::default()
        };
        let dir = std::env::temp_dir().join("mrcluster_driver_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let store = PointStore::from(gen.generate_stream(&dir.join("refuse.mrc")).unwrap());
        let cfg = ClusterConfig {
            k: 3,
            machines: 4,
            seed: 45,
            ..Default::default()
        };
        let err = run_algorithm_store(Algorithm::ParallelLloyd, &store, &cfg, 4096).unwrap_err();
        assert!(
            format!("{err:#}").contains("no out-of-core path"),
            "{err:#}"
        );
        // A resident store runs everything, streaming or not.
        let mem_store = PointStore::from(gen.generate().points);
        assert!(run_algorithm_store(Algorithm::ParallelLloyd, &mem_store, &cfg, 4096).is_ok());
    }
}
