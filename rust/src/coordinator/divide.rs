//! `MapReduce-Divide-kMedian` (Algorithm 6) — the Guha et al. partition
//! scheme the paper compares against (Divide-Lloyd / Divide-LocalSearch).
//!
//! Partition V into ℓ = √(n/k) blocks; cluster each block with `A` to get k
//! centers + weights (points represented); ship the ℓ·k weighted centers to
//! one machine and cluster them with weighted `A`. Corollary 4.3: 3α-approx.
//!
//! Note the resource profile the paper criticizes: the final machine holds
//! Θ(k·√(n/k)) = Θ(√(nk)) centers — Ω(kn) memory once pairwise distances
//! are materialized — and `A` runs on Θ(√(nk)) points, which is what makes
//! Divide-LocalSearch slow at large n (Figure 1).

use super::kmedian::run_weighted_inner;
use super::InnerAlgo;
use crate::algorithms::lloyd::{lloyd, LloydConfig};
use crate::algorithms::local_search::{local_search, LocalSearchConfig};
use crate::config::ClusterConfig;
use crate::geometry::{PointSet, PointStore, StoreBlock};
use crate::mapreduce::{MemSize, MrCluster, MrError};
use crate::runtime::{ComputeBackend, NativeBackend};

/// Result of MapReduce-Divide-kMedian.
#[derive(Clone, Debug)]
pub struct DivideResult {
    /// The k centers.
    pub centers: PointSet,
    /// Number of partitions ℓ.
    pub partitions: usize,
    /// Size of the collapsed weighted instance (ℓ·k).
    pub collapsed_size: usize,
}

struct BlockMsg {
    centers: PointSet,
    weights: Vec<f32>,
}

impl MemSize for BlockMsg {
    fn mem_bytes(&self) -> usize {
        self.centers.mem_bytes() + self.weights.len() * 4
    }
}

/// Run Algorithm 6 on `cluster` with the given inner `A`.
pub fn mr_divide_kmedian(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    inner: InnerAlgo,
    backend: &dyn ComputeBackend,
) -> Result<DivideResult, MrError> {
    mr_divide_kmedian_store(cluster, &PointStore::from(points.clone()), cfg, inner, backend)
}

/// [`mr_divide_kmedian`] over any [`PointStore`] backing. Each block
/// machine loads its partition inside the map closure (a zero-copy view
/// for resident stores, a streamed window for file-backed ones), clusters
/// it, and drops the coordinates; only the ℓ·k weighted centers survive
/// to the leader. Bit-identical to the resident run on the same config.
pub fn mr_divide_kmedian_store(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    inner: InnerAlgo,
    backend: &dyn ComputeBackend,
) -> Result<DivideResult, MrError> {
    let n = store.len();
    // ℓ = sqrt(n/k) minimizes the max machine memory (§4.1).
    let ell = ((n as f64 / cfg.k as f64).sqrt().ceil() as usize).clamp(1, n.max(1));
    let blocks = store.blocks(ell);

    // ---- Steps 3–7: cluster every block independently ----
    let k = cfg.k;
    let metric = cfg.metric;
    let msgs: Vec<BlockMsg> = cluster.run_machine_round(
        "divide: cluster blocks",
        &blocks,
        0,
        move |m, block: &StoreBlock| {
            let loaded = block.load();
            let part = loaded.points();
            // Step 6: w(y) = |{x in S^i : x^{C_i} = y}| + 1. (Lloyd centers
            // are means, not input points; the weights are still the
            // represented-point counts.) Lloyd's final cost pass already
            // computes exactly this histogram, so the Lloyd arm reuses it
            // instead of re-running the full n×k assign sweep.
            let (centers, w) = match inner {
                InnerAlgo::Lloyd => {
                    let res = lloyd(
                        part,
                        None,
                        &LloydConfig {
                            k,
                            max_iters: cfg.lloyd_max_iters,
                            tol: cfg.lloyd_tol,
                            metric,
                            prune: cfg.prune,
                            seed: cfg.seed ^ (m as u64),
                            ..Default::default()
                        },
                        backend,
                    );
                    (res.centers, res.final_counts)
                }
                InnerAlgo::LocalSearch => {
                    let centers = local_search(
                        part,
                        None,
                        &LocalSearchConfig {
                            k,
                            min_rel_gain: cfg.ls_min_rel_gain,
                            max_swaps: cfg.ls_max_swaps,
                            candidate_fraction: cfg.ls_candidate_fraction,
                            metric,
                            seed: cfg.seed ^ (m as u64),
                        },
                    )
                    .centers;
                    // Local search tracks no assignment; one histogram pass
                    // with the same backend kernel as the kMedian phase.
                    let (w, _) = NativeBackend.weight_histogram_metric(part, &centers, metric);
                    (centers, w)
                }
            };
            BlockMsg {
                weights: w.iter().map(|&x| (x + 1.0) as f32).collect(),
                centers,
            }
        },
    )?;

    // ---- Steps 8–10: weighted A on the union of block centers ----
    let mut all = PointSet::with_capacity(store.dim(), msgs.len() * cfg.k);
    let mut weights = Vec::with_capacity(msgs.len() * cfg.k);
    let mut gathered = 0usize;
    for m in &msgs {
        gathered += m.mem_bytes();
        all.extend(&m.centers);
        weights.extend_from_slice(&m.weights);
    }
    // The paper notes this step needs the pairwise distances of C on one
    // machine — Ω((ℓk)²) bytes; charge it.
    let leader_mem = gathered + all.len() * all.len() * 4;
    let all_ref = &all;
    let w_ref = &weights;
    let centers = cluster.run_leader_round("divide: weighted A on centers", leader_mem, || {
        run_weighted_inner(all_ref, w_ref, cfg, inner)
    })?;

    Ok(DivideResult {
        centers,
        partitions: ell,
        collapsed_size: all.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::metrics::kmedian_cost;

    fn run(inner: InnerAlgo, n: usize, seed: u64) -> (f64, f64, DivideResult) {
        let data = DataGenConfig {
            n,
            k: 10,
            sigma: 0.05,
            seed,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 10,
            machines: 16,
            seed,
            ..Default::default()
        };
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 16,
            ..Default::default()
        });
        let res =
            mr_divide_kmedian(&mut cluster, &data.points, &cfg, inner, &NativeBackend).unwrap();
        (
            kmedian_cost(&data.points, &res.centers),
            data.planted_cost_median(),
            res,
        )
    }

    #[test]
    fn partitions_follow_sqrt_rule() {
        let (_, _, res) = run(InnerAlgo::Lloyd, 10_000, 31);
        // sqrt(10000/10) ~ 31.6 -> 32
        assert!(res.partitions >= 31 && res.partitions <= 33, "{}", res.partitions);
        assert!(res.collapsed_size <= res.partitions * 10);
    }

    #[test]
    fn divide_lloyd_quality() {
        let (cost, planted, _) = run(InnerAlgo::Lloyd, 10_000, 32);
        assert!(cost < planted * 2.0, "cost {cost} vs planted {planted}");
    }

    #[test]
    fn divide_local_search_quality() {
        let (cost, planted, _) = run(InnerAlgo::LocalSearch, 4_000, 33);
        assert!(cost < planted * 2.0, "cost {cost} vs planted {planted}");
    }

    #[test]
    fn two_rounds_total() {
        let data = DataGenConfig {
            n: 2000,
            k: 5,
            seed: 34,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 5,
            machines: 8,
            seed: 34,
            ..Default::default()
        };
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 8,
            ..Default::default()
        });
        mr_divide_kmedian(&mut cluster, &data.points, &cfg, InnerAlgo::Lloyd, &NativeBackend)
            .unwrap();
        assert_eq!(cluster.stats.n_rounds(), 2, "Proposition 4.1: O(1) rounds");
    }
}
