//! `MapReduce-Iterative-Sample` (Algorithm 3).
//!
//! The sequential Algorithm 1 with `R` partitioned across machines. One
//! iteration of the while-loop costs two engine rounds:
//!
//! 1. **sample round** (machines, resident `R^i`): Bernoulli-sample the
//!    local S-batch `S^i` and witness set `H^i`; ship both (points + the
//!    witnesses' current d(x, S)) to the leader.
//! 2. **select + prune**: the leader updates the witnesses' distances
//!    against the fresh batch and picks the pivot (Algorithm 2); the pivot
//!    and the batch are broadcast; every machine updates its residents'
//!    d(x, S) against the batch (the L1/L2 kernel via the backend) and
//!    drops points closer than the pivot, plus its own sampled points.
//!
//! Per-machine state (`MachinePart`) persists across iterations — indices,
//! coordinates, and the incrementally-maintained d(x, S) array — exactly
//! the "data stays on the machines" structure the paper assumes.

use crate::config::ClusterConfig;
use crate::geometry::{PointSet, PointStore};
use crate::mapreduce::{MemSize, MrCluster, MrError};
use crate::runtime::ComputeBackend;
use crate::sampling::select::select_pivot;
use crate::sampling::IterativeSampleConfig;
use crate::util::rng::Rng;

/// Resident per-machine state for the sampling loop.
///
/// `Clone` backs the engine's recovery checkpoint: a mutable round whose
/// task is fated to fail snapshots the pre-round block (including the
/// machine-local rng state, so a replayed task re-draws the same samples)
/// and restores it before the lineage replay.
#[derive(Clone)]
pub struct MachinePart {
    /// Global indices of the still-remaining points on this machine.
    pub idx: Vec<usize>,
    /// Their coordinates (same order as `idx`).
    pub pts: PointSet,
    /// Their current distance to the accumulated sample S.
    pub dist: Vec<f32>,
    /// Machine-local RNG (forked from the run seed).
    rng: Rng,
}

impl MemSize for MachinePart {
    fn mem_bytes(&self) -> usize {
        self.idx.len() * std::mem::size_of::<usize>()
            + self.pts.mem_bytes()
            + self.dist.len() * 4
    }
}

/// What one machine ships to the leader in the sample round.
struct SampleMsg {
    batch_idx: Vec<usize>,
    batch_pts: PointSet,
    witness_dist: Vec<f32>,
}

impl MemSize for SampleMsg {
    fn mem_bytes(&self) -> usize {
        self.batch_idx.len() * 8 + self.batch_pts.mem_bytes() + self.witness_dist.len() * 4
    }
}

/// Result of the distributed sampling loop.
pub struct MrSampleResult {
    /// The sample C = S ∪ R (points).
    pub sample: PointSet,
    /// Global indices of C into the input point set.
    pub indices: Vec<usize>,
    /// While-loop iterations the distributed sampler ran.
    pub iterations: usize,
}

/// Run Algorithm 3 on `cluster`. Rounds/memory/time are charged to
/// `cluster.stats`.
pub fn mr_iterative_sample(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<MrSampleResult, MrError> {
    let n = points.len();
    let dim = points.dim();
    let metric = cfg.metric;
    let scfg = IterativeSampleConfig {
        k: cfg.k,
        epsilon: cfg.epsilon,
        constants: cfg.profile.constants(),
        metric,
        seed: cfg.seed,
        max_iters: 200,
    };
    let threshold = scfg.constants.threshold(n, cfg.k, cfg.epsilon).max(1);
    let mut root_rng = Rng::new(cfg.seed ^ 0x5eed_5a11_3d5a_11ce);

    // Initial partition: contiguous blocks of V — zero-copy views into the
    // input until the first prune rewrites a machine's resident set (and a
    // prune that drops nothing stays a view, via the contiguous-gather
    // fast path).
    let n_parts = cfg.machines.min(n).max(1);
    let mut parts: Vec<MachinePart> = points
        .chunks(n_parts)
        .into_iter()
        .scan(0usize, |start, chunk| {
            let lo = *start;
            *start += chunk.len();
            Some((lo, chunk))
        })
        .enumerate()
        .map(|(m, (lo, chunk))| MachinePart {
            idx: (lo..lo + chunk.len()).collect(),
            dist: vec![f32::INFINITY; chunk.len()],
            pts: chunk,
            rng: root_rng.fork(m as u64),
        })
        .collect();

    let mut sample_indices: Vec<usize> = Vec::new();
    let mut sample_pts = PointSet::with_capacity(dim, 1024);
    let mut iterations = 0usize;

    loop {
        let remaining: usize = parts.iter().map(|p| p.idx.len()).sum();
        if remaining <= threshold || iterations >= scfg.max_iters {
            break;
        }
        iterations += 1;

        let ps = scfg.constants.p_sample(n, cfg.k, cfg.epsilon, remaining);
        let ph = scfg.constants.p_witness(n, cfg.epsilon, remaining);

        // ---- Round 1: local Bernoulli sampling on every machine ----
        let msgs: Vec<SampleMsg> = cluster.run_machine_round_mut(
            &format!("iterative-sample iter {iterations}: sample"),
            &mut parts,
            0,
            move |_m, part: &mut MachinePart| {
                let mut batch_idx = Vec::new();
                let mut batch_pts = PointSet::with_capacity(dim, 8);
                let mut witness_dist = Vec::new();
                for pos in 0..part.idx.len() {
                    if part.rng.bernoulli(ps) {
                        batch_idx.push(part.idx[pos]);
                        batch_pts.push(part.pts.row(pos));
                    }
                    if part.rng.bernoulli(ph) {
                        witness_dist.push(part.dist[pos]);
                    }
                }
                SampleMsg {
                    batch_idx,
                    batch_pts,
                    witness_dist,
                }
            },
        )?;

        // ---- Leader: assemble batch, update witness dists, pick pivot ----
        let mut batch_idx = Vec::new();
        let mut batch_pts = PointSet::with_capacity(dim, 64);
        let mut h_dists = Vec::new();
        let mut msg_bytes = 0usize;
        for m in &msgs {
            msg_bytes += m.mem_bytes();
            batch_idx.extend_from_slice(&m.batch_idx);
            batch_pts.extend(&m.batch_pts);
            h_dists.extend_from_slice(&m.witness_dist);
        }
        if batch_idx.is_empty() {
            // Probabilities underflowed (tiny R); promote one arbitrary
            // remaining point so the loop always progresses.
            if let Some(part) = parts.iter_mut().find(|p| !p.idx.is_empty()) {
                batch_idx.push(part.idx[0]);
                batch_pts.push(part.pts.row(0));
            } else {
                break;
            }
        }
        let rank = scfg.constants.pivot_rank(n);
        let batch_ref = &batch_pts;
        let pivot = cluster.run_leader_round(
            &format!("iterative-sample iter {iterations}: select"),
            msg_bytes,
            || {
                // Witness dists were sampled *before* the batch existed;
                // Algorithm 2 orders H by distance to S ∪ batch. The batch
                // contribution can only shrink distances; witnesses are a
                // small set so the leader recomputes against the batch...
                // except the leader only has distances, not the witness
                // coordinates — conservatively use the pre-batch distances,
                // which upper-bound the true ones. (The pivot is a noisy
                // threshold either way; Lemma 3.2's rank window tolerates
                // constant-factor slack, and the prune step below uses the
                // *true* post-batch distances.)
                let _ = batch_ref;
                select_pivot(&h_dists, rank)
            },
        )?;

        sample_indices.extend_from_slice(&batch_idx);
        sample_pts.extend(&batch_pts);

        // ---- Round 2: broadcast (batch, pivot); update + prune ----
        let bcast = batch_pts.mem_bytes() + 4;
        let batch_set: std::collections::HashSet<usize> =
            batch_idx.iter().copied().collect();
        let batch_ref = &batch_pts;
        let batch_set_ref = &batch_set;
        cluster.run_machine_round_mut(
            &format!("iterative-sample iter {iterations}: prune"),
            &mut parts,
            bcast,
            move |_m, part: &mut MachinePart| {
                if part.idx.is_empty() {
                    return 0usize;
                }
                // d(x, S) update against the fresh batch — the hot kernel,
                // in the configured metric.
                let nd = backend.min_dist_metric(&part.pts, batch_ref, metric);
                for (pos, v) in nd.iter().enumerate() {
                    if *v < part.dist[pos] {
                        part.dist[pos] = *v;
                    }
                }
                // Prune: drop sampled points and well-represented points.
                let keep: Vec<usize> = (0..part.idx.len())
                    .filter(|&pos| {
                        let gi = part.idx[pos];
                        !batch_set_ref.contains(&gi)
                            && match pivot {
                                Some(pv) => part.dist[pos] >= pv,
                                None => true,
                            }
                    })
                    .collect();
                let dropped = part.idx.len() - keep.len();
                part.pts = part.pts.gather(&keep);
                part.dist = keep.iter().map(|&pos| part.dist[pos]).collect();
                part.idx = keep.iter().map(|&pos| part.idx[pos]).collect();
                dropped
            },
        )?;
    }

    // ---- Final gather: C = S ∪ R ----
    let rem_msgs: Vec<SampleMsg> = cluster.run_machine_round(
        "iterative-sample: gather remainder",
        &parts,
        0,
        |_m, part: &MachinePart| SampleMsg {
            batch_idx: part.idx.clone(),
            batch_pts: part.pts.clone(),
            witness_dist: Vec::new(),
        },
    )?;
    let mut indices = sample_indices;
    let mut sample = sample_pts;
    for m in rem_msgs {
        indices.extend_from_slice(&m.batch_idx);
        sample.extend(&m.batch_pts);
    }
    // Defensive de-dup (keeps first occurrence, preserves order).
    let mut seen = std::collections::HashSet::new();
    let keep: Vec<usize> = (0..indices.len()).filter(|&i| seen.insert(indices[i])).collect();
    if keep.len() != indices.len() {
        sample = sample.gather(&keep);
        indices = keep.iter().map(|&i| indices[i]).collect();
    }

    Ok(MrSampleResult {
        sample,
        indices,
        iterations,
    })
}

/// Resident per-machine state for the out-of-core sampling loop.
///
/// Mirrors [`MachinePart`], but the block's coordinates stay in the
/// backing store until the first prune shrinks the block: `idx`, the
/// maintained `dist` array, and the machine RNG persist across
/// iterations, while each round streams the machine's window back in and
/// drops it on completion. After a prune the (much smaller) survivor set
/// is materialized resident, so later iterations touch the file no more.
/// The `MRC^0` charge is identical to [`MachinePart`]'s — the simulated
/// machine holds its block whether the host streamed it or not.
#[derive(Clone)]
struct StorePart {
    store: PointStore,
    /// First store row of this machine's block (valid while `pts` is
    /// `None`, i.e. before the first prune, when `idx` is contiguous).
    lo: usize,
    /// Global indices of the still-remaining points on this machine.
    idx: Vec<usize>,
    /// Resident survivor coordinates after the first prune; `None` while
    /// the block still lives only in the backing store.
    pts: Option<PointSet>,
    /// Current distance to the accumulated sample S (same order as `idx`).
    dist: Vec<f32>,
    rng: Rng,
}

impl MemSize for StorePart {
    fn mem_bytes(&self) -> usize {
        // Byte-identical to MachinePart: idx + coordinates + dist, with
        // the coordinate charge counted from the logical block length
        // even while the bytes live only in the backing file.
        self.idx.len() * std::mem::size_of::<usize>()
            + self.idx.len() * self.store.dim() * 4
            + self.dist.len() * 4
    }
}

/// [`mr_iterative_sample`] over any [`PointStore`] backing (Algorithm 3,
/// out-of-core).
///
/// Each while-loop round makes one sequential pass over the machine's
/// window of the backing file and drops it afterwards; only the global
/// indices, the d(x, S) array, the machine RNGs, and (after the first
/// prune) the shrunken survivor coordinates stay resident. Round labels,
/// memory charges, RNG forks, and every arithmetic operation mirror the
/// resident implementation, so the two runs are bit-identical on the same
/// seed and config — property-tested in `tests/prop_ooc.rs`.
pub fn mr_iterative_sample_store(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<MrSampleResult, MrError> {
    let n = store.len();
    let dim = store.dim();
    let metric = cfg.metric;
    let scfg = IterativeSampleConfig {
        k: cfg.k,
        epsilon: cfg.epsilon,
        constants: cfg.profile.constants(),
        metric,
        seed: cfg.seed,
        max_iters: 200,
    };
    let threshold = scfg.constants.threshold(n, cfg.k, cfg.epsilon).max(1);
    let mut root_rng = Rng::new(cfg.seed ^ 0x5eed_5a11_3d5a_11ce);

    // Initial partition: the same contiguous blocks as the resident
    // implementation (both sides derive them from `chunk_spans`), but
    // only descriptors — no coordinates are loaded yet.
    let n_parts = cfg.machines.min(n).max(1);
    let mut parts: Vec<StorePart> = store
        .blocks(n_parts)
        .into_iter()
        .enumerate()
        .map(|(m, b)| StorePart {
            idx: (b.lo..b.hi).collect(),
            dist: vec![f32::INFINITY; b.hi - b.lo],
            lo: b.lo,
            pts: None,
            rng: root_rng.fork(m as u64),
            store: store.clone(),
        })
        .collect();

    let mut sample_indices: Vec<usize> = Vec::new();
    let mut sample_pts = PointSet::with_capacity(dim, 1024);
    let mut iterations = 0usize;

    loop {
        let remaining: usize = parts.iter().map(|p| p.idx.len()).sum();
        if remaining <= threshold || iterations >= scfg.max_iters {
            break;
        }
        iterations += 1;

        let ps = scfg.constants.p_sample(n, cfg.k, cfg.epsilon, remaining);
        let ph = scfg.constants.p_witness(n, cfg.epsilon, remaining);

        // ---- Round 1: local Bernoulli sampling, one streamed pass ----
        let msgs: Vec<SampleMsg> = cluster.run_machine_round_mut(
            &format!("iterative-sample iter {iterations}: sample"),
            &mut parts,
            0,
            move |_m, part: &mut StorePart| {
                let resident;
                let view: &PointSet = match &part.pts {
                    Some(p) => p,
                    None => {
                        resident = part.store.load(part.lo, part.lo + part.idx.len());
                        resident.points()
                    }
                };
                let mut batch_idx = Vec::new();
                let mut batch_pts = PointSet::with_capacity(dim, 8);
                let mut witness_dist = Vec::new();
                for pos in 0..part.idx.len() {
                    if part.rng.bernoulli(ps) {
                        batch_idx.push(part.idx[pos]);
                        batch_pts.push(view.row(pos));
                    }
                    if part.rng.bernoulli(ph) {
                        witness_dist.push(part.dist[pos]);
                    }
                }
                SampleMsg {
                    batch_idx,
                    batch_pts,
                    witness_dist,
                }
            },
        )?;

        // ---- Leader: assemble batch, update witness dists, pick pivot ----
        let mut batch_idx = Vec::new();
        let mut batch_pts = PointSet::with_capacity(dim, 64);
        let mut h_dists = Vec::new();
        let mut msg_bytes = 0usize;
        for m in &msgs {
            msg_bytes += m.mem_bytes();
            batch_idx.extend_from_slice(&m.batch_idx);
            batch_pts.extend(&m.batch_pts);
            h_dists.extend_from_slice(&m.witness_dist);
        }
        if batch_idx.is_empty() {
            // Probabilities underflowed (tiny R); promote one arbitrary
            // remaining point so the loop always progresses.
            if let Some(part) = parts.iter_mut().find(|p| !p.idx.is_empty()) {
                batch_idx.push(part.idx[0]);
                match &part.pts {
                    Some(p) => batch_pts.push(p.row(0)),
                    None => {
                        let one = part.store.load(part.lo, part.lo + 1);
                        batch_pts.push(one.points().row(0));
                    }
                }
            } else {
                break;
            }
        }
        let rank = scfg.constants.pivot_rank(n);
        let pivot = cluster.run_leader_round(
            &format!("iterative-sample iter {iterations}: select"),
            msg_bytes,
            || select_pivot(&h_dists, rank),
        )?;

        sample_indices.extend_from_slice(&batch_idx);
        sample_pts.extend(&batch_pts);

        // ---- Round 2: broadcast (batch, pivot); update + prune ----
        let bcast = batch_pts.mem_bytes() + 4;
        let batch_set: std::collections::HashSet<usize> =
            batch_idx.iter().copied().collect();
        let batch_ref = &batch_pts;
        let batch_set_ref = &batch_set;
        cluster.run_machine_round_mut(
            &format!("iterative-sample iter {iterations}: prune"),
            &mut parts,
            bcast,
            move |_m, part: &mut StorePart| {
                if part.idx.is_empty() {
                    return 0usize;
                }
                let streamed = part.pts.is_none();
                let resident;
                let view: &PointSet = match &part.pts {
                    Some(p) => p,
                    None => {
                        resident = part.store.load(part.lo, part.lo + part.idx.len());
                        resident.points()
                    }
                };
                let nd = backend.min_dist_metric(view, batch_ref, metric);
                for (pos, v) in nd.iter().enumerate() {
                    if *v < part.dist[pos] {
                        part.dist[pos] = *v;
                    }
                }
                let keep: Vec<usize> = (0..part.idx.len())
                    .filter(|&pos| {
                        let gi = part.idx[pos];
                        !batch_set_ref.contains(&gi)
                            && match pivot {
                                Some(pv) => part.dist[pos] >= pv,
                                None => true,
                            }
                    })
                    .collect();
                let dropped = part.idx.len() - keep.len();
                let survivors = if streamed {
                    // Deep-copy the survivors so the streamed window's
                    // buffer really frees — a zero-copy gather view would
                    // pin the whole window behind the meter's back.
                    let mut owned = PointSet::with_capacity(dim, keep.len());
                    for &pos in &keep {
                        owned.push(view.row(pos));
                    }
                    owned
                } else {
                    view.gather(&keep)
                };
                part.pts = Some(survivors);
                part.dist = keep.iter().map(|&pos| part.dist[pos]).collect();
                part.idx = keep.iter().map(|&pos| part.idx[pos]).collect();
                dropped
            },
        )?;
    }

    // ---- Final gather: C = S ∪ R ----
    let rem_msgs: Vec<SampleMsg> = cluster.run_machine_round(
        "iterative-sample: gather remainder",
        &parts,
        0,
        |_m, part: &StorePart| {
            let batch_pts = match &part.pts {
                Some(p) => p.clone(),
                // Loop never ran (n at or under the threshold): the
                // remainder is the machine's whole untouched block.
                None => part.store.load(part.lo, part.lo + part.idx.len()).points().clone(),
            };
            SampleMsg {
                batch_idx: part.idx.clone(),
                batch_pts,
                witness_dist: Vec::new(),
            }
        },
    )?;
    let mut indices = sample_indices;
    let mut sample = sample_pts;
    for m in rem_msgs {
        indices.extend_from_slice(&m.batch_idx);
        sample.extend(&m.batch_pts);
    }
    // Defensive de-dup (keeps first occurrence, preserves order).
    let mut seen = std::collections::HashSet::new();
    let keep: Vec<usize> = (0..indices.len()).filter(|&i| seen.insert(indices[i])).collect();
    if keep.len() != indices.len() {
        sample = sample.gather(&keep);
        indices = keep.iter().map(|&i| indices[i]).collect();
    }

    Ok(MrSampleResult {
        sample,
        indices,
        iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::runtime::NativeBackend;

    fn run(n: usize, machines: usize, seed: u64) -> (MrSampleResult, MrCluster) {
        let data = DataGenConfig {
            n,
            k: 10,
            seed,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 10,
            epsilon: 0.2,
            machines,
            seed,
            ..Default::default()
        };
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: machines,
            ..Default::default()
        });
        let res = mr_iterative_sample(&mut cluster, &data.points, &cfg, &NativeBackend).unwrap();
        (res, cluster)
    }

    #[test]
    fn indices_valid_and_unique() {
        let (res, _) = run(20_000, 16, 1);
        let mut s = res.indices.clone();
        s.sort_unstable();
        let len = s.len();
        s.dedup();
        assert_eq!(s.len(), len);
        assert!(s.iter().all(|&i| i < 20_000));
        assert_eq!(res.sample.len(), res.indices.len());
    }

    #[test]
    fn sample_is_sublinear() {
        let (res, _) = run(20_000, 16, 2);
        assert!(
            res.sample.len() < 20_000 / 4,
            "sample size {}",
            res.sample.len()
        );
        assert!(res.sample.len() >= 10);
    }

    #[test]
    fn constant_rounds() {
        let (res, cluster) = run(50_000, 32, 3);
        // 2 rounds + 1 leader round per iteration + 1 final gather.
        assert!(res.iterations <= 12, "iterations {}", res.iterations);
        assert!(
            cluster.stats.n_rounds() <= 3 * res.iterations + 1,
            "{} rounds for {} iterations",
            cluster.stats.n_rounds(),
            res.iterations
        );
    }

    #[test]
    fn sample_points_match_indices() {
        let data = DataGenConfig {
            n: 5000,
            k: 5,
            seed: 4,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 5,
            epsilon: 0.2,
            machines: 8,
            seed: 4,
            ..Default::default()
        };
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 8,
            ..Default::default()
        });
        let res = mr_iterative_sample(&mut cluster, &data.points, &cfg, &NativeBackend).unwrap();
        for (pos, &gi) in res.indices.iter().enumerate() {
            assert_eq!(res.sample.row(pos), data.points.row(gi));
        }
    }

    #[test]
    fn single_machine_still_works() {
        let (res, _) = run(5000, 1, 5);
        assert!(res.sample.len() >= 10);
        assert!(res.sample.len() < 5000);
    }

    #[test]
    fn store_run_matches_resident_bit_for_bit() {
        let gen = DataGenConfig {
            n: 8000,
            k: 6,
            seed: 6,
            ..Default::default()
        };
        let data = gen.generate();
        let dir = std::env::temp_dir().join("mrcluster_itersample_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let store = PointStore::from(gen.generate_stream(&dir.join("iter.mrc")).unwrap());
        let cfg = ClusterConfig {
            k: 6,
            epsilon: 0.2,
            machines: 8,
            seed: 6,
            ..Default::default()
        };
        let mut c_mem = MrCluster::new(MrConfig {
            n_machines: 8,
            ..Default::default()
        });
        let mut c_ooc = MrCluster::new(MrConfig {
            n_machines: 8,
            ..Default::default()
        });
        let mem = mr_iterative_sample(&mut c_mem, &data.points, &cfg, &NativeBackend).unwrap();
        let ooc = mr_iterative_sample_store(&mut c_ooc, &store, &cfg, &NativeBackend).unwrap();
        assert_eq!(mem.indices, ooc.indices, "sampled indices diverged");
        assert_eq!(mem.sample, ooc.sample, "sampled coordinates diverged");
        assert_eq!(mem.iterations, ooc.iterations);
        assert_eq!(c_mem.stats.n_rounds(), c_ooc.stats.n_rounds(), "ledger diverged");
    }
}
