//! Rival coordinator: Mazzetto, Pietracaprina & Pucci's coreset-based
//! MapReduce k-median (arXiv:1904.12728), behind the same driver registry
//! as the paper's own pipelines (E17 arena).
//!
//! The accuracy-oriented rival: each machine builds a weighted coreset of
//! ~`(k/ε²) · polylog(n)` representatives — much larger than the robust
//! pipeline's `4k + z` summaries — so the composed coreset tracks the
//! k-median objective to within an ε-style factor and the final weighted
//! local search lands near the sequential solution. The trade is one
//! fewer round than [`super::robust`] but a bigger shuffle into the
//! leader:
//!
//! 1. **coreset** (machine round, [`StoreBlock`] descriptors): every
//!    machine compresses its block into a [`CoverageSummary`] of
//!    τ = min((k/ε²)·log₂ n, cap) weighted representatives via the
//!    farthest-point traversal (outliers survive as weight-≈1 entries);
//! 2. **compose + weighted local search** (leader round): the leader
//!    takes the canonical multiset union of the coresets
//!    ([`CoverageSummary::compose_all`] — bit-deterministic under any
//!    arrival order and lineage replay), trims up to `z` suspected
//!    outliers (lightest entries, canonical tie-break), and runs weighted
//!    local search ([`local_search_weighted`]) on the survivors.
//!
//! Per-machine sizes and the partition count are clamped so the composed
//! coreset never exceeds [`MAX_SUMMARY_REPS`] representatives — the
//! polylog sizing is a *request*, and the cap is the leader's memory
//! envelope. The coreset round streams [`StoreBlock`]s, so the pipeline
//! runs file-backed with bit-identical output.

use crate::algorithms::local_search::{local_search_weighted, LocalSearchConfig};
use crate::config::ClusterConfig;
use crate::geometry::{PointSet, PointStore, StoreBlock};
use crate::mapreduce::{MemSize, MrCluster, MrError};
use crate::runtime::ComputeBackend;
use crate::summaries::{CoverageSummary, WeightedSet};

use super::robust::MAX_SUMMARY_REPS;

/// Seed-stream separator for the coreset round (`cfg.seed ^ MAZZETTO_SEED
/// ^ machine`), keeping this pipeline's traversals disjoint from the
/// robust pipeline's on the same config.
const MAZZETTO_SEED: u64 = 0x3A22_2019;

/// Seed-stream separator for the leader's weighted local search (distinct
/// from the robust pipeline's `0xC0_5E7` local-search stream).
const MAZZETTO_LS_SEED: u64 = 0x3A22_E770;

/// Result of the Mazzetto-style coreset k-median pipeline.
#[derive(Clone, Debug)]
pub struct MazzettoResult {
    /// The k centers.
    pub centers: PointSet,
    /// Representatives in the composed coreset (before outlier trimming).
    pub coreset_size: usize,
    /// Coreset entries trimmed as suspected outliers before local search.
    pub trimmed: usize,
}

/// The coreset round's shape under the [`MAX_SUMMARY_REPS`] cap:
/// `(n_parts, tau)` with `n_parts · tau ≤ MAX_SUMMARY_REPS` always. The
/// requested per-machine size is the accuracy-oriented
/// `(k/ε²) · log₂ n`; the partition count is first bounded so every
/// machine affords ≥ k representatives, then τ is bounded by the
/// remainder.
fn coreset_shape(machines: usize, n: usize, k: usize, epsilon: f64) -> (usize, usize) {
    let max_parts = (MAX_SUMMARY_REPS / k.max(1)).max(1);
    let n_parts = machines.min(n).min(max_parts).max(1);
    let eps = if epsilon > 0.0 { epsilon.min(1.0) } else { 0.1 };
    let request = (k.max(1) as f64 / (eps * eps)) * (n.max(2) as f64).log2();
    let tau_request = request.min(MAX_SUMMARY_REPS as f64).ceil() as usize;
    let tau = tau_request.min(MAX_SUMMARY_REPS / n_parts).max(1);
    (n_parts, tau)
}

/// Mazzetto et al.'s 2-round coreset MapReduce k-median: per-machine
/// weighted coresets of ~`(k/ε²)·polylog(n)` representatives composed at
/// the leader, then weighted local search with up to `z` suspected
/// outliers trimmed first. Resident-input wrapper over
/// [`mr_mazzetto_kmedian_store`].
pub fn mr_mazzetto_kmedian(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<MazzettoResult, MrError> {
    mr_mazzetto_kmedian_store(cluster, &PointStore::from(points.clone()), cfg, backend)
}

/// [`mr_mazzetto_kmedian`] over any [`PointStore`] backing. With a
/// file-backed store each coreset machine streams only its own block into
/// memory; the result is bit-identical to the resident run on the same
/// seed and config.
pub fn mr_mazzetto_kmedian_store(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<MazzettoResult, MrError> {
    let (n_parts, tau) = coreset_shape(cfg.machines, store.len(), cfg.k, cfg.epsilon);
    let blocks = store.blocks(n_parts);

    // ---- Round 1: per-machine weighted coresets over blocks ----
    let seed = cfg.seed ^ MAZZETTO_SEED;
    let metric = cfg.metric;
    let coresets: Vec<CoverageSummary> = cluster.run_machine_round(
        "mazzetto: weighted coresets",
        &blocks,
        0,
        move |m, block: &StoreBlock| {
            let part = block.load();
            CoverageSummary::build_metric(
                part.points(),
                tau.min(part.len()).max(1),
                seed ^ (m as u64),
                backend,
                metric,
            )
        },
    )?;

    // ---- Round 2: compose + trim + weighted local search on the leader ----
    // Composition is a canonical multiset union, so the composed size is
    // the sum of the per-machine sizes — known up front for the memory
    // charge and the result record.
    let coreset_size: usize = coresets.iter().map(CoverageSummary::len).sum();
    let leader_mem = coresets.iter().map(MemSize::mem_bytes).sum::<usize>();
    let k = cfg.k;
    let z = cfg.z;
    let dim = store.dim();
    let ls_cfg = LocalSearchConfig {
        k: cfg.k,
        min_rel_gain: cfg.ls_min_rel_gain,
        max_swaps: cfg.ls_max_swaps,
        candidate_fraction: cfg.ls_candidate_fraction,
        metric: cfg.metric,
        seed: cfg.seed ^ MAZZETTO_LS_SEED,
    };
    let coresets_ref = &coresets;
    let ls_ref = &ls_cfg;
    let (centers, trimmed) = cluster.run_leader_round(
        "mazzetto: compose + weighted local search",
        leader_mem,
        move || {
            let merged = CoverageSummary::compose_all(coresets_ref.iter().cloned())
                .unwrap_or_else(|| {
                    CoverageSummary::from_weighted(WeightedSet::with_capacity(dim, 0), 0.0)
                });
            // Trim up to z suspected outliers — the lightest entries, ties
            // resolved by the canonical order so the trim is deterministic
            // — but never below k survivors (same discipline as
            // `super::robust::solve_summary_kmedian`).
            let reps = merged.reps();
            let m = reps.len();
            let trimmed = z.min(m.saturating_sub(k));
            let mut order: Vec<usize> = (0..m).collect();
            order.sort_by(|&a, &b| reps.weight(a).total_cmp(&reps.weight(b)).then(a.cmp(&b)));
            let mut keep: Vec<usize> = order[trimmed..].to_vec();
            keep.sort_unstable(); // back to canonical order for local search
            let survivors = reps.gather(&keep);
            (local_search_weighted(&survivors, ls_ref).centers, trimmed)
        },
    )?;

    Ok(MazzettoResult {
        centers,
        coreset_size,
        trimmed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::metrics::kmedian_cost;
    use crate::runtime::NativeBackend;

    fn blobs(n: usize, k: usize, contamination: f64, seed: u64) -> crate::data::Dataset {
        DataGenConfig {
            n,
            k,
            sigma: 0.05,
            contamination,
            seed,
            ..Default::default()
        }
        .generate()
    }

    fn cluster(machines: usize) -> MrCluster {
        MrCluster::new(MrConfig {
            n_machines: machines,
            ..Default::default()
        })
    }

    #[test]
    fn two_rounds_and_quality_on_clean_data() {
        let data = blobs(4000, 8, 0.0, 71);
        let cfg = ClusterConfig {
            k: 8,
            machines: 8,
            seed: 71,
            ls_max_swaps: 40,
            ..Default::default()
        };
        let mut c = cluster(8);
        let res = mr_mazzetto_kmedian(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(c.stats.n_rounds(), 2, "coreset + leader solve");
        assert_eq!(res.centers.len(), 8);
        assert_eq!(res.trimmed, 0, "z defaults to 0");
        assert!(res.coreset_size <= MAX_SUMMARY_REPS);
        let cost = kmedian_cost(&data.points, &res.centers);
        let planted = data.planted_cost_median();
        assert!(cost < planted * 2.0, "cost {cost} vs planted {planted}");
    }

    #[test]
    fn accuracy_sizing_grows_the_coreset_beyond_the_robust_summaries() {
        // The whole point of the rival: at the same config its composed
        // coreset is at least as large as the robust pipeline's 4k + z
        // summaries (both under the shared cap), buying accuracy.
        let (_, robust_tau) = {
            // Mirror robust.rs's shape at the same knobs.
            let k = 5usize;
            let machines = 8usize;
            let n = 4000usize;
            let max_parts = (MAX_SUMMARY_REPS / k.max(1)).max(1);
            let n_parts = machines.min(n).min(max_parts).max(1);
            (n_parts, (4 * k).min(MAX_SUMMARY_REPS / n_parts).max(1))
        };
        let (_, mazzetto_tau) = coreset_shape(8, 4000, 5, 0.1);
        assert!(
            mazzetto_tau >= robust_tau,
            "mazzetto tau {mazzetto_tau} < robust tau {robust_tau}"
        );
    }

    #[test]
    fn trims_suspected_outliers_when_z_is_set() {
        let data = blobs(2000, 5, 0.01, 72);
        let z = data.n_outliers();
        assert!(z > 0, "contamination must have produced outliers");
        let cfg = ClusterConfig {
            k: 5,
            machines: 8,
            z,
            seed: 72,
            ls_max_swaps: 40,
            ..Default::default()
        };
        let mut c = cluster(8);
        let res = mr_mazzetto_kmedian(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(res.trimmed, z.min(res.coreset_size.saturating_sub(5)));
        assert!(res.trimmed > 0, "outlier budget must have trimmed entries");
        assert_eq!(res.centers.len(), 5);
    }

    #[test]
    fn replays_identically_at_any_machine_count() {
        let data = blobs(1000, 4, 0.0, 73);
        for machines in [4usize, 9] {
            let cfg = ClusterConfig {
                k: 4,
                machines,
                seed: 73,
                ls_max_swaps: 20,
                ..Default::default()
            };
            let a = mr_mazzetto_kmedian(&mut cluster(machines), &data.points, &cfg, &NativeBackend)
                .unwrap();
            let b = mr_mazzetto_kmedian(&mut cluster(machines), &data.points, &cfg, &NativeBackend)
                .unwrap();
            assert_eq!(a.centers, b.centers, "same config must replay identically");
        }
    }

    #[test]
    fn coreset_shape_invariants_hold_across_the_knob_space() {
        for machines in [1usize, 4, 100, 1000, 5000] {
            for n in [1usize, 100, 10_000, 1_000_000] {
                for k in [1usize, 5, 25, 400] {
                    for eps in [0.0f64, 0.05, 0.1, 0.5, 1.0] {
                        let (n_parts, tau) = coreset_shape(machines, n, k, eps);
                        assert!(
                            n_parts * tau <= MAX_SUMMARY_REPS,
                            "cap violated: machines={machines} n={n} k={k} eps={eps} \
                             -> {n_parts} x {tau}"
                        );
                        assert!(n_parts >= 1 && tau >= 1);
                        assert!(n_parts <= machines.min(n.max(1)));
                    }
                }
            }
        }
    }

    #[test]
    fn file_backed_run_is_bit_identical_to_resident() {
        let gen = DataGenConfig {
            n: 1500,
            k: 4,
            sigma: 0.05,
            seed: 74,
            ..Default::default()
        };
        let data = gen.generate();
        let dir = std::env::temp_dir().join("mrcluster_mazzetto_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let store = PointStore::from(gen.generate_stream(&dir.join("mazz_ooc.mrc")).unwrap());
        let cfg = ClusterConfig {
            k: 4,
            machines: 6,
            seed: 74,
            ls_max_swaps: 20,
            ..Default::default()
        };
        let mem = mr_mazzetto_kmedian(&mut cluster(6), &data.points, &cfg, &NativeBackend).unwrap();
        let ooc =
            mr_mazzetto_kmedian_store(&mut cluster(6), &store, &cfg, &NativeBackend).unwrap();
        assert_eq!(mem.centers, ooc.centers, "file-backed centers diverged");
        assert_eq!(mem.coreset_size, ooc.coreset_size);
        let meter = store.meter().expect("file store is metered");
        assert_eq!(meter.current(), 0, "every resident window must be dropped");
        assert!(meter.peak() > 0, "the run must have streamed something");
    }

    #[test]
    fn single_machine_degenerate_case() {
        let data = blobs(100, 3, 0.0, 75);
        let cfg = ClusterConfig {
            k: 3,
            machines: 1,
            seed: 75,
            ls_max_swaps: 20,
            ..Default::default()
        };
        let res =
            mr_mazzetto_kmedian(&mut cluster(1), &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(res.centers.len(), 3);
    }
}
