//! `MapReduce-kMedian` (Algorithm 5): Iterative-Sample, then weight every
//! sampled point by the unsampled points it represents, then run a weighted
//! k-median algorithm `A` on the weighted sample on one machine.
//!
//! Theorem 3.11: with an α-approximate weighted `A` this is a
//! (10α + 3)-approximation w.h.p. — `A` = local search gives the constant
//! guarantee (Sampling-LocalSearch); `A` = Lloyd is the fast heuristic the
//! experiments favor (Sampling-Lloyd).

use super::mr_iterative_sample::mr_iterative_sample;
use super::InnerAlgo;
use crate::algorithms::lloyd::{lloyd, LloydConfig};
use crate::algorithms::local_search::{local_search, LocalSearchConfig};
use crate::config::ClusterConfig;
use crate::geometry::PointSet;
use crate::mapreduce::{MrCluster, MrError};
use crate::runtime::ComputeBackend;

/// Result of MapReduce-kMedian.
#[derive(Clone, Debug)]
pub struct MrKMedianResult {
    /// The k centers.
    pub centers: PointSet,
    /// Size of the weighted sample the final `A` ran on.
    pub sample_size: usize,
    /// Iterations the distributed sampler ran.
    pub sample_iterations: usize,
}

/// Run Algorithm 5 on `cluster` with `A = inner`.
pub fn mr_kmedian(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    inner: InnerAlgo,
    backend: &dyn ComputeBackend,
) -> Result<MrKMedianResult, MrError> {
    // ---- Step 1: C <- MapReduce-Iterative-Sample ----
    let sres = mr_iterative_sample(cluster, points, cfg, backend)?;
    let sample = sres.sample;
    log::debug!(
        "kmedian: sample |C| = {} after {} iterations",
        sample.len(),
        sres.iterations
    );

    // ---- Steps 2–4: weight phase. Partition V (zero-copy views),
    // broadcast C, each machine computes w^i(y) = |{x in V^i \ C : x^C = y}|
    // in a single assign pass (one machine round). ----
    let parts = points.chunks(cfg.machines.min(points.len()).max(1));
    let bcast = sample.mem_bytes();
    let metric = cfg.metric;
    let sample_ref = &sample;
    let hists: Vec<Vec<f64>> = cluster.run_machine_round(
        "kmedian: weight histogram",
        &parts,
        bcast,
        move |_m, part: &PointSet| backend.weight_histogram_metric(part, sample_ref, metric).0,
    )?;

    // ---- Steps 5–7: leader sums weights (+1 for the sample point itself)
    // and runs the weighted clustering algorithm A on (C, w). ----
    let hist_bytes: usize = hists.iter().map(|h| h.len() * 8).sum();
    let leader_mem = hist_bytes + sample.mem_bytes();
    let sample_ref = &sample;
    let centers = cluster.run_leader_round("kmedian: weighted A on sample", leader_mem, || {
        let m = sample_ref.len();
        let mut w = vec![1.0f32; m]; // the +1 of Algorithm 5 step 6
        for h in &hists {
            debug_assert_eq!(h.len(), m);
            for (j, v) in h.iter().enumerate() {
                w[j] += *v as f32;
            }
        }
        run_weighted_inner(sample_ref, &w, cfg, inner)
    })?;

    Ok(MrKMedianResult {
        centers,
        sample_size: sample.len(),
        sample_iterations: sres.iterations,
    })
}

/// The weighted sequential `A` (shared with Divide).
pub(crate) fn run_weighted_inner(
    points: &PointSet,
    weights: &[f32],
    cfg: &ClusterConfig,
    inner: InnerAlgo,
) -> PointSet {
    match inner {
        InnerAlgo::Lloyd => lloyd(
            points,
            Some(weights),
            &LloydConfig {
                k: cfg.k,
                max_iters: cfg.lloyd_max_iters,
                tol: cfg.lloyd_tol,
                metric: cfg.metric,
                // Weighted runs silently fall back to the unpruned scan
                // (see `algorithms/lloyd.rs`); threaded for uniformity.
                prune: cfg.prune,
                seed: cfg.seed ^ 0xA11CE,
                ..Default::default()
            },
            &crate::runtime::NativeBackend,
        )
        .centers,
        InnerAlgo::LocalSearch => local_search(
            points,
            Some(weights),
            &LocalSearchConfig {
                k: cfg.k,
                min_rel_gain: cfg.ls_min_rel_gain,
                max_swaps: cfg.ls_max_swaps,
                candidate_fraction: cfg.ls_candidate_fraction,
                metric: cfg.metric,
                seed: cfg.seed ^ 0xB0B,
            },
        )
        .centers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::metrics::kmedian_cost;
    use crate::runtime::NativeBackend;

    fn run(inner: InnerAlgo, seed: u64) -> (f64, f64, MrKMedianResult) {
        let data = DataGenConfig {
            n: 20_000,
            k: 10,
            sigma: 0.05,
            seed,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 10,
            epsilon: 0.2,
            machines: 16,
            seed,
            ..Default::default()
        };
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 16,
            ..Default::default()
        });
        let res = mr_kmedian(&mut cluster, &data.points, &cfg, inner, &NativeBackend).unwrap();
        let cost = kmedian_cost(&data.points, &res.centers);
        let planted = data.planted_cost_median();
        (cost, planted, res)
    }

    #[test]
    fn sampling_lloyd_near_planted_cost() {
        let (cost, planted, res) = run(InnerAlgo::Lloyd, 11);
        assert_eq!(res.centers.len(), 10);
        // The planted centers are near-optimal; a constant-factor algorithm
        // on well-separated blobs should land within 2x.
        assert!(
            cost < planted * 2.0,
            "cost {cost} vs planted {planted} (sample {})",
            res.sample_size
        );
    }

    #[test]
    fn sampling_local_search_near_planted_cost() {
        let (cost, planted, res) = run(InnerAlgo::LocalSearch, 12);
        assert_eq!(res.centers.len(), 10);
        assert!(
            cost < planted * 2.0,
            "cost {cost} vs planted {planted} (sample {})",
            res.sample_size
        );
    }

    #[test]
    fn sample_much_smaller_than_input() {
        let (_, _, res) = run(InnerAlgo::Lloyd, 13);
        assert!(res.sample_size < 20_000 / 4, "sample {}", res.sample_size);
    }
}
