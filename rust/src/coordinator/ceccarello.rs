//! Rival coordinator: Ceccarello, Pietracaprina & Pucci's MapReduce
//! k-center with outliers (arXiv:1802.09205), behind the same driver
//! registry as the paper's own pipelines (E17 arena).
//!
//! The round shape is deliberately *flatter* than the repo's three-round
//! [`super::robust`] pipeline — two rounds, trading a bigger leader-side
//! union for one less synchronization barrier:
//!
//! 1. **skeletonize** (machine round, [`StoreBlock`] descriptors): every
//!    machine runs a Gonzalez farthest-point traversal over its block and
//!    ships a [`CoverageSummary`] — τ = k + z + √(n/m) weighted
//!    representatives plus the block's coverage radius. The √(n/m) slack
//!    is the paper's accuracy term: more representatives per machine means
//!    a smaller coverage radius, which is the only term the final
//!    approximation factor pays beyond the sequential greedy's 3x.
//! 2. **union + outlier-aware greedy** (leader round): the leader takes
//!    the canonical multiset union of the skeletons
//!    ([`CoverageSummary::compose_all`] — associative and commutative
//!    bit-for-bit, so shuffle order and lineage replay cannot change a
//!    byte) and runs the weighted Charikar greedy with outlier budget `z`
//!    ([`kcenter_with_outliers_metric`]) over the union.
//!
//! Both the per-machine size and the partition count are clamped so the
//! union never exceeds [`MAX_SUMMARY_REPS`] representatives — the same
//! guard rail as the robust pipeline, for the same reason: an uncapped
//! `z` or machine count must not degenerate the "summary" back into the
//! dataset. The skeleton round streams [`StoreBlock`]s, so the pipeline
//! runs file-backed with bit-identical output.

use crate::algorithms::outliers::kcenter_with_outliers_metric;
use crate::config::ClusterConfig;
use crate::geometry::{PointSet, PointStore, StoreBlock};
use crate::mapreduce::{MemSize, MrCluster, MrError};
use crate::runtime::ComputeBackend;
use crate::summaries::{CoverageSummary, WeightedSet};

use super::robust::MAX_SUMMARY_REPS;

/// Seed-stream separator: the skeleton round draws from
/// `cfg.seed ^ CECCARELLO_SEED ^ machine`, so this pipeline's traversals
/// never collide with the robust pipeline's summaries on the same config.
const CECCARELLO_SEED: u64 = 0xCECA_2018;

/// Result of the Ceccarello-style k-center-with-outliers pipeline.
#[derive(Clone, Debug)]
pub struct CeccarelloResult {
    /// The k centers.
    pub centers: PointSet,
    /// Representatives in the union skeleton the leader greedy ran on.
    pub skeleton_size: usize,
    /// Skeleton weight the greedy left uncovered (≤ the `z` budget).
    pub dropped_weight: f64,
    /// Max coverage radius over the per-machine skeletons (the
    /// decomposition's contribution to the approximation error).
    pub skeleton_radius: f64,
}

/// The skeleton round's shape under the [`MAX_SUMMARY_REPS`] cap:
/// `(n_parts, tau)` with `n_parts · tau ≤ MAX_SUMMARY_REPS` always. The
/// requested per-machine size is the paper's τ = k + z + √(n/m); the
/// partition count is first bounded so every machine affords ≥ k
/// representatives, then τ is bounded by the remainder.
fn skeleton_shape(machines: usize, n: usize, k: usize, z: usize) -> (usize, usize) {
    let max_parts = (MAX_SUMMARY_REPS / k.max(1)).max(1);
    let n_parts = machines.min(n).min(max_parts).max(1);
    let per_block = n.div_ceil(n_parts).max(1);
    let tau_request = k
        .saturating_add(z)
        .saturating_add((per_block as f64).sqrt().ceil() as usize);
    let tau = tau_request.min(MAX_SUMMARY_REPS / n_parts).max(1);
    (n_parts, tau)
}

/// Ceccarello et al.'s 2-round MapReduce k-center with `z` outliers:
/// per-machine Gonzalez skeletons of τ = k + z + √(n/m) representatives
/// with coverage radii, outlier-aware greedy over the union at the
/// leader. Resident-input wrapper over
/// [`mr_ceccarello_kcenter_store`].
pub fn mr_ceccarello_kcenter(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<CeccarelloResult, MrError> {
    mr_ceccarello_kcenter_store(cluster, &PointStore::from(points.clone()), cfg, backend)
}

/// [`mr_ceccarello_kcenter`] over any [`PointStore`] backing. With a
/// file-backed store each skeleton machine streams only its own block
/// into memory; the result is bit-identical to the resident run on the
/// same seed and config.
pub fn mr_ceccarello_kcenter_store(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<CeccarelloResult, MrError> {
    let (n_parts, tau) = skeleton_shape(cfg.machines, store.len(), cfg.k, cfg.z);
    let blocks = store.blocks(n_parts);

    // ---- Round 1: per-machine Gonzalez skeletons over blocks ----
    let seed = cfg.seed ^ CECCARELLO_SEED;
    let metric = cfg.metric;
    let skeletons: Vec<CoverageSummary> = cluster.run_machine_round(
        "ceccarello: Gonzalez skeletons",
        &blocks,
        0,
        move |m, block: &StoreBlock| {
            let part = block.load();
            CoverageSummary::build_metric(
                part.points(),
                tau.min(part.len()).max(1),
                seed ^ (m as u64),
                backend,
                metric,
            )
        },
    )?;

    // ---- Round 2: union + outlier-aware greedy on the leader ----
    // Composition is a canonical multiset union (no entries are merged
    // arithmetically), so the union size is exactly the sum of the
    // skeleton sizes — known before composing, which lets the leader's
    // memory charge include the greedy's cached |union|² distance matrix
    // up front. The summary cap keeps the union under MAX_MATRIX here;
    // the zero-charge branch only matters for direct library callers.
    let union_size: usize = skeletons.iter().map(CoverageSummary::len).sum();
    let matrix_bytes = if union_size <= crate::algorithms::outliers::MAX_MATRIX {
        union_size * union_size * 4
    } else {
        0
    };
    let leader_mem = skeletons.iter().map(MemSize::mem_bytes).sum::<usize>() + matrix_bytes;
    let k = cfg.k;
    let z = cfg.z as f64;
    let dim = store.dim();
    let skeletons_ref = &skeletons;
    let (result, skeleton_radius) = cluster.run_leader_round(
        "ceccarello: union + outlier greedy",
        leader_mem,
        move || {
            let merged = CoverageSummary::compose_all(skeletons_ref.iter().cloned())
                .unwrap_or_else(|| {
                    CoverageSummary::from_weighted(WeightedSet::with_capacity(dim, 0), 0.0)
                });
            (
                kcenter_with_outliers_metric(merged.reps(), k, z, metric),
                merged.radius(),
            )
        },
    )?;

    Ok(CeccarelloResult {
        centers: result.centers,
        skeleton_size: union_size,
        dropped_weight: result.dropped_weight,
        skeleton_radius,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::metrics::kcenter_cost_with_outliers;
    use crate::runtime::NativeBackend;

    fn contaminated(n: usize, k: usize, contamination: f64, seed: u64) -> crate::data::Dataset {
        DataGenConfig {
            n,
            k,
            sigma: 0.05,
            contamination,
            seed,
            ..Default::default()
        }
        .generate()
    }

    fn cluster(machines: usize) -> MrCluster {
        MrCluster::new(MrConfig {
            n_machines: machines,
            ..Default::default()
        })
    }

    #[test]
    fn two_rounds_and_shapes() {
        let data = contaminated(2000, 5, 0.01, 61);
        let z = data.n_outliers();
        let cfg = ClusterConfig {
            k: 5,
            machines: 8,
            z,
            seed: 61,
            ..Default::default()
        };
        let mut c = cluster(8);
        let res = mr_ceccarello_kcenter(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(c.stats.n_rounds(), 2, "skeletonize + leader greedy");
        assert_eq!(res.centers.len(), 5);
        assert!(res.skeleton_size <= MAX_SUMMARY_REPS);
        assert!(res.dropped_weight <= z as f64 + 1e-9);
        assert!(res.skeleton_radius >= 0.0);
    }

    #[test]
    fn shrugs_off_contamination() {
        let data = contaminated(2000, 5, 0.01, 62);
        let z = data.n_outliers();
        assert!(z > 0, "contamination must have produced outliers");
        let cfg = ClusterConfig {
            k: 5,
            machines: 8,
            z,
            seed: 62,
            ..Default::default()
        };
        let mut c = cluster(8);
        let res = mr_ceccarello_kcenter(&mut c, &data.points, &cfg, &NativeBackend).unwrap();
        let robust_cost = kcenter_cost_with_outliers(&data.points, &res.centers, z);
        // Same calibration as the robust pipeline's test: planted centers
        // with z dropped are the reference; the pipeline pays the skeleton
        // radius plus the greedy's 3x, so 4x is a conservative envelope —
        // and the √(n/m) skeleton slack keeps the radius term small.
        let reference = kcenter_cost_with_outliers(&data.points, &data.planted_centers, z);
        assert!(
            robust_cost <= reference * 4.0 + 1e-6,
            "ceccarello {robust_cost} vs reference {reference}"
        );
    }

    #[test]
    fn replays_identically_at_any_machine_count() {
        let data = contaminated(1000, 4, 0.02, 63);
        let z = data.n_outliers();
        for machines in [4usize, 9] {
            let cfg = ClusterConfig {
                k: 4,
                machines,
                z,
                seed: 63,
                ..Default::default()
            };
            let a =
                mr_ceccarello_kcenter(&mut cluster(machines), &data.points, &cfg, &NativeBackend)
                    .unwrap();
            let b =
                mr_ceccarello_kcenter(&mut cluster(machines), &data.points, &cfg, &NativeBackend)
                    .unwrap();
            assert_eq!(a.centers, b.centers, "same config must replay identically");
            assert_eq!(a.dropped_weight.to_bits(), b.dropped_weight.to_bits());
        }
    }

    #[test]
    fn skeleton_shape_invariants_hold_across_the_knob_space() {
        for machines in [1usize, 4, 100, 1000, 5000] {
            for n in [1usize, 100, 10_000, 1_000_000] {
                for k in [1usize, 5, 25, 400] {
                    for z in [0usize, 10, 1000, 100_000] {
                        let (n_parts, tau) = skeleton_shape(machines, n, k, z);
                        assert!(
                            n_parts * tau <= MAX_SUMMARY_REPS,
                            "cap violated: machines={machines} n={n} k={k} z={z} \
                             -> {n_parts} x {tau}"
                        );
                        assert!(n_parts >= 1 && tau >= 1);
                        assert!(n_parts <= machines.min(n.max(1)));
                    }
                }
            }
        }
        // The union always fits the greedy's distance-matrix cache.
        assert!(MAX_SUMMARY_REPS <= crate::algorithms::outliers::MAX_MATRIX);
    }

    #[test]
    fn file_backed_run_is_bit_identical_to_resident() {
        let gen = DataGenConfig {
            n: 1500,
            k: 4,
            sigma: 0.05,
            contamination: 0.02,
            seed: 64,
            ..Default::default()
        };
        let data = gen.generate();
        let z = data.n_outliers();
        let dir = std::env::temp_dir().join("mrcluster_ceccarello_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let store = PointStore::from(gen.generate_stream(&dir.join("cecc_ooc.mrc")).unwrap());
        let cfg = ClusterConfig {
            k: 4,
            machines: 6,
            z,
            seed: 64,
            ..Default::default()
        };
        let mem =
            mr_ceccarello_kcenter(&mut cluster(6), &data.points, &cfg, &NativeBackend).unwrap();
        let ooc =
            mr_ceccarello_kcenter_store(&mut cluster(6), &store, &cfg, &NativeBackend).unwrap();
        assert_eq!(mem.centers, ooc.centers, "file-backed centers diverged");
        assert_eq!(mem.skeleton_size, ooc.skeleton_size);
        assert_eq!(mem.dropped_weight.to_bits(), ooc.dropped_weight.to_bits());
        let meter = store.meter().expect("file store is metered");
        assert_eq!(meter.current(), 0, "every resident window must be dropped");
        assert!(meter.peak() > 0, "the run must have streamed something");
    }

    #[test]
    fn single_machine_degenerate_case() {
        let data = contaminated(100, 3, 0.0, 65);
        let cfg = ClusterConfig {
            k: 3,
            machines: 1,
            seed: 65,
            ..Default::default()
        };
        let res =
            mr_ceccarello_kcenter(&mut cluster(1), &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(res.centers.len(), 3);
    }
}
