//! `MapReduce-kCenter` (Algorithm 4): Iterative-Sample, then run an
//! α-approximate k-center algorithm on the sample on one machine.
//!
//! Theorem 3.7: (4α + 2)-approximation w.h.p.; with Gonzalez (α = 2) that
//! is a 10-approximation. The paper's own experiments note the k-center
//! objective is sensitive to sampling (a missed outlier directly shows up
//! in the max), which experiment E3 (`kcenter-compare`) reproduces.

use super::mr_iterative_sample::{mr_iterative_sample, mr_iterative_sample_store, MrSampleResult};
use crate::algorithms::gonzalez::gonzalez_metric;
use crate::config::ClusterConfig;
use crate::geometry::{PointSet, PointStore};
use crate::mapreduce::{MrCluster, MrError};
use crate::runtime::ComputeBackend;
use crate::util::rng::Rng;

/// Result of MapReduce-kCenter.
#[derive(Clone, Debug)]
pub struct MrKCenterResult {
    /// The k centers.
    pub centers: PointSet,
    /// Size of the Iterative-Sample output the final `A` ran on.
    pub sample_size: usize,
    /// Iterations the distributed sampler ran.
    pub sample_iterations: usize,
}

/// Run Algorithm 4 on `cluster` with `A` = Gonzalez's 2-approximation.
pub fn mr_kcenter(
    cluster: &mut MrCluster,
    points: &PointSet,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<MrKCenterResult, MrError> {
    let sres = mr_iterative_sample(cluster, points, cfg, backend)?;
    finish_on_sample(cluster, cfg, sres)
}

/// [`mr_kcenter`] over any [`PointStore`] backing: the sampling rounds
/// stream each machine's window of the backing file
/// ([`mr_iterative_sample_store`]); the final leader round is unchanged,
/// since it only ever sees the sample. Bit-identical to the resident run
/// on the same seed and config.
pub fn mr_kcenter_store(
    cluster: &mut MrCluster,
    store: &PointStore,
    cfg: &ClusterConfig,
    backend: &dyn ComputeBackend,
) -> Result<MrKCenterResult, MrError> {
    let sres = mr_iterative_sample_store(cluster, store, cfg, backend)?;
    finish_on_sample(cluster, cfg, sres)
}

/// The shared final round: Algorithm 4 maps C (and conceptually its
/// pairwise distances — O(|C|² log n) bits, the memory bound of Theorem
/// 1.1) to one reducer running Gonzalez.
fn finish_on_sample(
    cluster: &mut MrCluster,
    cfg: &ClusterConfig,
    sres: MrSampleResult,
) -> Result<MrKCenterResult, MrError> {
    let sample = sres.sample;
    let leader_mem = sample.mem_bytes() + sample.len() * sample.len() * 4;
    let k = cfg.k;
    let seed = cfg.seed;
    let metric = cfg.metric;
    let sample_ref = &sample;
    let centers = cluster.run_leader_round("kcenter: A on sample", leader_mem, || {
        let mut rng = Rng::new(seed ^ 0xCE47E5);
        gonzalez_metric(sample_ref, k, &mut rng, metric).centers
    })?;

    Ok(MrKCenterResult {
        centers,
        sample_size: sample.len(),
        sample_iterations: sres.iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::mapreduce::MrConfig;
    use crate::metrics::kcenter_cost;
    use crate::runtime::NativeBackend;

    #[test]
    fn radius_within_constant_of_gonzalez_full() {
        let data = DataGenConfig {
            n: 20_000,
            k: 10,
            sigma: 0.05,
            seed: 21,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 10,
            epsilon: 0.2,
            machines: 16,
            seed: 21,
            ..Default::default()
        };
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 16,
            ..Default::default()
        });
        let res = mr_kcenter(&mut cluster, &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(res.centers.len(), 10);
        let sampled_radius = kcenter_cost(&data.points, &res.centers);

        // Full-data Gonzalez as the reference (2-approx of OPT).
        let mut rng = crate::util::rng::Rng::new(99);
        let full = crate::algorithms::gonzalez::gonzalez(&data.points, 10, &mut rng);
        // Theorem 3.7 bound vs 2-approx reference: ratio <= (4*2+2)/1 = 10x
        // in the worst case; the paper observed ~4x. Allow 8x here.
        assert!(
            sampled_radius <= full.radius * 8.0 + 1e-6,
            "sampled {} vs full {}",
            sampled_radius,
            full.radius
        );
    }

    #[test]
    fn works_on_tiny_input() {
        let data = DataGenConfig {
            n: 200,
            k: 4,
            seed: 22,
            ..Default::default()
        }
        .generate();
        let cfg = ClusterConfig {
            k: 4,
            machines: 4,
            seed: 22,
            ..Default::default()
        };
        let mut cluster = MrCluster::new(MrConfig {
            n_machines: 4,
            ..Default::default()
        });
        let res = mr_kcenter(&mut cluster, &data.points, &cfg, &NativeBackend).unwrap();
        assert_eq!(res.centers.len(), 4);
    }
}
