//! Configuration system: typed config structs, a TOML-subset file format,
//! and dotted-key CLI overrides (`--set cluster.k=50`).
//!
//! Precedence: defaults < config file < command-line overrides — the usual
//! launcher layering (compare Megatron/MaxText-style config systems, scaled
//! to this project).

pub mod toml;

use crate::algorithms::lloyd::PruneKind;
use crate::data::DataGenConfig;
use crate::geometry::MetricKind;
use crate::runtime::{AssignPath, Precision};
use crate::sampling::SampleConstants;
use crate::sim::{Heterogeneity, NetworkKind, Placement, SimConfig};
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Which compute backend serves the numeric hot loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuntimeBackendKind {
    /// Pure-rust kernels.
    Native,
    /// AOT HLO artifacts through PJRT; falls back to native per-call when no
    /// bucket fits.
    Xla,
}

/// Which Iterative-Sample constants profile to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConstantsProfile {
    /// Algorithm 1's literal constants (for the theory checks).
    Theory,
    /// log-free practical constants (the experiment default).
    Practical,
}

impl ConstantsProfile {
    /// The concrete coefficient set this profile names.
    pub fn constants(self) -> SampleConstants {
        match self {
            ConstantsProfile::Theory => SampleConstants::theory(),
            ConstantsProfile::Practical => SampleConstants::practical(),
        }
    }
}

/// Everything the clustering drivers need.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of centers.
    pub k: usize,
    /// The metric space every layer runs in — kernels, sequential `A`
    /// subroutines, coordinators, summaries, and cost reporting
    /// (`cluster.metric`: `l2sq` | `l2` | `l1` | `cosine` | `chebyshev`).
    /// The default `l2sq` reproduces the pre-metric pipeline bit-for-bit.
    pub metric: MetricKind,
    /// Iterative-Sample ε (paper experiments: 0.1).
    pub epsilon: f64,
    /// Which Iterative-Sample constants profile to use.
    pub profile: ConstantsProfile,
    /// Simulated machines (paper: 100).
    pub machines: usize,
    /// Per-machine memory budget in bytes (None = unenforced).
    pub mem_limit: Option<usize>,
    /// Run simulated machines on worker threads.
    pub parallel: bool,
    /// Worker threads (0 = all cores).
    pub threads: usize,
    /// Which compute backend serves the numeric hot loop.
    pub backend: RuntimeBackendKind,
    /// Which assign kernel serves the Euclidean family
    /// (`cluster.kernel`: `exact` | `gemm`). `exact` (default) is
    /// bit-identical to the scalar reference; `gemm` is the norm-expanded
    /// ε-equivalent fast path — rung (a) of the kernel speed ladder.
    pub kernel: AssignPath,
    /// Lloyd-accumulator precision (`cluster.precision`: `f64` | `f32`).
    /// `f64` (default) is the bit-exact path; `f32` accumulates per fixed
    /// block in single precision — rung (b) of the ladder.
    pub precision: Precision,
    /// Lloyd assign-phase pruning (`cluster.prune`: `none` | `hamerly`).
    /// `hamerly` skips provably-redundant distance evaluations under
    /// triangle-valid metrics — rung (c) of the ladder,
    /// assignment-identical per iteration to the unpruned path.
    pub prune: PruneKind,
    /// Directory holding manifest.json + *.hlo.txt.
    pub artifact_dir: PathBuf,
    /// Lloyd iteration cap.
    pub lloyd_max_iters: usize,
    /// Lloyd relative-improvement stopping tolerance.
    pub lloyd_tol: f64,
    /// Local-search swap cap (safety net; the gain threshold terminates).
    pub ls_max_swaps: usize,
    /// Local-search minimum relative gain for a swap to be applied.
    pub ls_min_rel_gain: f64,
    /// Fraction of points evaluated as swap-in candidates (1.0 = all).
    pub ls_candidate_fraction: f64,
    /// Fault-injection knob (real lose-output-and-replay semantics with
    /// bounded retries, optional speculative backups for stragglers, and
    /// round-granularity checkpoint accounting; see `mapreduce::MrConfig`
    /// and `mapreduce::recovery`): probability any task attempt fails.
    /// Default 0 (injection disabled).
    pub fail_prob: f64,
    /// Probability a machine-task runs slow (see `mapreduce::MrConfig`).
    pub straggler_prob: f64,
    /// Simulated-time multiplier for straggling tasks (≥ 1.0).
    pub straggler_factor: f64,
    /// Failed attempts tolerated per task before the job aborts.
    pub max_task_retries: usize,
    /// Launch speculative backup copies for straggling tasks.
    pub speculative: bool,
    /// Charge round-granularity checkpoint writes to the recovery log.
    pub checkpoint: bool,
    /// Outlier budget `z` for the robust pipelines
    /// ([`crate::coordinator::robust`]): Robust-kCenter may leave up to
    /// `z` total weight uncovered; Coreset-kMedian trims up to `z`
    /// suspected-outlier summary entries. Ignored by the paper's own
    /// (non-robust) algorithms. Default 0.
    pub z: usize,
    /// Root PRNG seed for the whole run.
    pub seed: u64,
    /// Discrete-event timing simulation (`[sim]` section / `sim.*` keys):
    /// contended network, heterogeneous hosts, rack topology. Off by
    /// default; enabling it adds a `sim_wallclock` column to every round
    /// without changing any output (see `crate::sim`).
    pub sim: SimConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            k: 25,
            metric: MetricKind::L2Sq,
            epsilon: 0.1,
            profile: ConstantsProfile::Practical,
            machines: 100,
            mem_limit: None,
            parallel: true,
            threads: 0,
            backend: RuntimeBackendKind::Native,
            kernel: AssignPath::Exact,
            precision: Precision::F64,
            prune: PruneKind::None,
            artifact_dir: PathBuf::from("artifacts"),
            // High cap: convergence is governed by lloyd_tol; big inputs
            // legitimately take many more iterations than small samples —
            // that asymmetry is where the paper's speedups come from.
            lloyd_max_iters: 100,
            lloyd_tol: 1e-4,
            ls_max_swaps: 200,
            ls_min_rel_gain: 1e-4,
            ls_candidate_fraction: 1.0,
            fail_prob: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            max_task_retries: 16,
            speculative: false,
            checkpoint: false,
            z: 0,
            seed: 42,
            sim: SimConfig::default(),
        }
    }
}

/// Where the dataset's coordinates live during a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataBacking {
    /// Fully resident [`crate::geometry::PointSet`] (the default).
    Mem,
    /// Out-of-core v2 store file (`crate::geometry::store`): the
    /// streaming coordinators make one sequential pass per round over
    /// fixed windows of the backing file and keep only O(chunk) bytes of
    /// coordinates resident. Bit-identical results to `mem` on the same
    /// seed and config.
    File,
}

/// Dataset storage settings (`[data] path | backing | chunk_points`).
#[derive(Clone, Debug, PartialEq)]
pub struct StorageConfig {
    /// Dataset file to load instead of generating synthetically: the v2
    /// store format (`.mrc`, with header provenance), the legacy resident
    /// binary, or CSV — distinguished by the file's own magic/extension.
    pub path: Option<PathBuf>,
    /// Where coordinates live during the run (`mem` | `file`).
    pub backing: DataBacking,
    /// Streaming window size in points for out-of-core passes that are
    /// not already partitioned by machine (e.g. the final cost sweep).
    /// Rounded up to the fixed reduction block, so the windowing cannot
    /// perturb the bit-deterministic block structure.
    pub chunk_points: usize,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig {
            path: None,
            backing: DataBacking::Mem,
            chunk_points: 64 * 1024,
        }
    }
}

/// Serving-layer settings (`[serve]` section / `serve.*` keys); see
/// `crate::serve`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeConfig {
    /// Per-batch ingest compression size (`serve.tau`). `0` (default) is
    /// the lossless mode: every ingested point becomes a unit-weight
    /// sketch entry and epoch re-solves are bit-identical to the one-shot
    /// batch pipeline. `> 0` compresses each batch to at most `tau`
    /// weighted representatives before folding — bounded memory, sketch
    /// invariant to batch arrival order but ε-equivalent under re-splits.
    pub tau: usize,
    /// Auto-close the epoch after this many ingested batches
    /// (`serve.epoch_batches`). `0` (default) = close manually.
    pub epoch_batches: usize,
}

/// Top-level launcher configuration.
#[derive(Clone, Debug, Default)]
pub struct AppConfig {
    /// Synthetic-dataset generation settings (`[data]`).
    pub data: DataGenConfig,
    /// Dataset storage settings (`[data] path | backing | chunk_points`).
    pub storage: StorageConfig,
    /// Clustering/engine settings (`[cluster]`).
    pub cluster: ClusterConfig,
    /// Serving-layer settings (`[serve]`).
    pub serve: ServeConfig,
}

impl AppConfig {
    /// Load from a TOML file and/or apply `section.key=value` overrides.
    pub fn load(file: Option<&std::path::Path>, overrides: &[(String, String)]) -> Result<Self> {
        let mut cfg = AppConfig::default();
        if let Some(path) = file {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?;
            let doc = toml::parse(&text).context("parsing config file")?;
            for (section, kvs) in &doc {
                for (key, value) in kvs {
                    cfg.apply(section, key, value).with_context(|| {
                        format!("config file key [{section}] {key} = {value}")
                    })?;
                }
            }
        }
        for (dotted, value) in overrides {
            let (section, key) = dotted
                .split_once('.')
                .with_context(|| format!("override '{dotted}' must be section.key"))?;
            cfg.apply(section, key, value)
                .with_context(|| format!("override {dotted}={value}"))?;
        }
        Ok(cfg)
    }

    /// Apply one `[section] key = value` setting.
    pub fn apply(&mut self, section: &str, key: &str, value: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(v: &str) -> Result<T>
        where
            T::Err: std::fmt::Display,
        {
            v.parse::<T>()
                .map_err(|e| anyhow::anyhow!("bad value {v:?}: {e}"))
        }
        match (section, key) {
            ("data", "n") => self.data.n = p(value)?,
            ("data", "k") => self.data.k = p(value)?,
            ("data", "dim") => self.data.dim = p(value)?,
            ("data", "sigma") => self.data.sigma = p(value)?,
            ("data", "alpha") => self.data.alpha = p(value)?,
            ("data", "contamination") => self.data.contamination = p(value)?,
            ("data", "seed") => self.data.seed = p(value)?,
            ("data", "path") => self.storage.path = Some(PathBuf::from(value)),
            ("data", "backing") => {
                self.storage.backing = match value {
                    "mem" => DataBacking::Mem,
                    "file" => DataBacking::File,
                    other => anyhow::bail!("unknown backing {other:?} (expected: mem, file)"),
                }
            }
            ("data", "chunk_points") => {
                self.storage.chunk_points = p(value)?;
                anyhow::ensure!(
                    self.storage.chunk_points > 0,
                    "chunk_points must be positive"
                );
            }
            ("cluster", "k") => self.cluster.k = p(value)?,
            ("cluster", "metric") => {
                self.cluster.metric = MetricKind::parse(value).with_context(|| {
                    format!(
                        "unknown metric {value:?} (expected one of: {})",
                        MetricKind::ALL.map(|m| m.name()).join(", ")
                    )
                })?
            }
            ("cluster", "epsilon") => self.cluster.epsilon = p(value)?,
            ("cluster", "profile") => {
                self.cluster.profile = match value {
                    "theory" => ConstantsProfile::Theory,
                    "practical" => ConstantsProfile::Practical,
                    other => anyhow::bail!("unknown profile {other:?}"),
                }
            }
            ("cluster", "machines") => self.cluster.machines = p(value)?,
            ("cluster", "mem_limit") => {
                self.cluster.mem_limit = if value == "none" {
                    None
                } else {
                    Some(p(value)?)
                }
            }
            ("cluster", "parallel") => self.cluster.parallel = p(value)?,
            ("cluster", "threads") => self.cluster.threads = p(value)?,
            ("cluster", "backend") => {
                self.cluster.backend = match value {
                    "native" => RuntimeBackendKind::Native,
                    "xla" => RuntimeBackendKind::Xla,
                    other => anyhow::bail!("unknown backend {other:?}"),
                }
            }
            ("cluster", "kernel") => {
                self.cluster.kernel = AssignPath::parse(value).with_context(|| {
                    format!("unknown kernel {value:?} (expected: exact, gemm)")
                })?
            }
            ("cluster", "precision") => {
                self.cluster.precision = Precision::parse(value).with_context(|| {
                    format!("unknown precision {value:?} (expected: f64, f32)")
                })?
            }
            ("cluster", "prune") => {
                self.cluster.prune = PruneKind::parse(value).with_context(|| {
                    format!("unknown prune mode {value:?} (expected: none, hamerly)")
                })?
            }
            ("cluster", "artifact_dir") => self.cluster.artifact_dir = PathBuf::from(value),
            ("cluster", "lloyd_max_iters") => self.cluster.lloyd_max_iters = p(value)?,
            ("cluster", "lloyd_tol") => self.cluster.lloyd_tol = p(value)?,
            ("cluster", "ls_max_swaps") => self.cluster.ls_max_swaps = p(value)?,
            ("cluster", "ls_min_rel_gain") => self.cluster.ls_min_rel_gain = p(value)?,
            ("cluster", "ls_candidate_fraction") => {
                self.cluster.ls_candidate_fraction = p(value)?
            }
            ("cluster", "fail_prob") => self.cluster.fail_prob = p(value)?,
            ("cluster", "straggler_prob") => self.cluster.straggler_prob = p(value)?,
            ("cluster", "straggler_factor") => self.cluster.straggler_factor = p(value)?,
            ("cluster", "max_task_retries") => self.cluster.max_task_retries = p(value)?,
            ("cluster", "speculative") => self.cluster.speculative = p(value)?,
            ("cluster", "checkpoint") => self.cluster.checkpoint = p(value)?,
            ("cluster", "z") => self.cluster.z = p(value)?,
            ("cluster", "seed") => self.cluster.seed = p(value)?,
            ("sim", "enabled") => self.cluster.sim.enabled = p(value)?,
            ("sim", "network") => {
                self.cluster.sim.network =
                    NetworkKind::parse(value).map_err(|e| anyhow::anyhow!(e))?
            }
            ("sim", "racks") => {
                self.cluster.sim.racks = p(value)?;
                anyhow::ensure!(self.cluster.sim.racks > 0, "sim.racks must be positive");
            }
            ("sim", "oversub") => {
                self.cluster.sim.oversub = p(value)?;
                anyhow::ensure!(self.cluster.sim.oversub >= 1.0, "sim.oversub must be >= 1");
            }
            ("sim", "nic_mbps") => {
                self.cluster.sim.nic_mbps = p(value)?;
                anyhow::ensure!(self.cluster.sim.nic_mbps > 0.0, "sim.nic_mbps must be > 0");
            }
            ("sim", "compute_mbps") => {
                self.cluster.sim.compute_mbps = p(value)?;
                anyhow::ensure!(
                    self.cluster.sim.compute_mbps > 0.0,
                    "sim.compute_mbps must be > 0"
                );
            }
            ("sim", "latency_us") => {
                self.cluster.sim.latency_us = p(value)?;
                anyhow::ensure!(
                    self.cluster.sim.latency_us >= 0.0,
                    "sim.latency_us must be >= 0"
                );
            }
            ("sim", "hetero") => {
                self.cluster.sim.hetero =
                    Heterogeneity::parse(value).map_err(|e| anyhow::anyhow!(e))?
            }
            ("sim", "placement") => {
                self.cluster.sim.placement =
                    Placement::parse(value).map_err(|e| anyhow::anyhow!(e))?
            }
            ("sim", "seed") => self.cluster.sim.seed = p(value)?,
            ("serve", "tau") => self.serve.tau = p(value)?,
            ("serve", "epoch_batches") => self.serve.epoch_batches = p(value)?,
            (s, k) => anyhow::bail!("unknown config key [{s}] {k}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = AppConfig::default();
        assert_eq!(c.cluster.k, 25);
        assert_eq!(c.cluster.machines, 100);
        assert!((c.cluster.epsilon - 0.1).abs() < 1e-12);
        assert!((c.data.sigma - 0.1).abs() < 1e-12);
        assert_eq!(c.data.alpha, 0.0);
    }

    #[test]
    fn overrides_apply() {
        let cfg = AppConfig::load(
            None,
            &[
                ("data.n".into(), "5000".into()),
                ("cluster.k".into(), "7".into()),
                ("cluster.backend".into(), "xla".into()),
                ("cluster.profile".into(), "theory".into()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.data.n, 5000);
        assert_eq!(cfg.cluster.k, 7);
        assert_eq!(cfg.cluster.backend, RuntimeBackendKind::Xla);
        assert_eq!(cfg.cluster.profile, ConstantsProfile::Theory);
    }

    #[test]
    fn fault_keys_apply() {
        let cfg = AppConfig::load(
            None,
            &[
                ("cluster.fail_prob".into(), "0.3".into()),
                ("cluster.max_task_retries".into(), "5".into()),
                ("cluster.speculative".into(), "true".into()),
                ("cluster.checkpoint".into(), "true".into()),
            ],
        )
        .unwrap();
        assert!((cfg.cluster.fail_prob - 0.3).abs() < 1e-12);
        assert_eq!(cfg.cluster.max_task_retries, 5);
        assert!(cfg.cluster.speculative);
        assert!(cfg.cluster.checkpoint);
    }

    #[test]
    fn outlier_keys_apply() {
        let cfg = AppConfig::load(
            None,
            &[
                ("cluster.z".into(), "12".into()),
                ("data.contamination".into(), "0.02".into()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.cluster.z, 12);
        assert!((cfg.data.contamination - 0.02).abs() < 1e-12);
        // Defaults: robustness knobs off.
        let d = AppConfig::default();
        assert_eq!(d.cluster.z, 0);
        assert_eq!(d.data.contamination, 0.0);
    }

    #[test]
    fn metric_key_applies_with_aliases() {
        let cfg = AppConfig::load(None, &[("cluster.metric".into(), "l1".into())]).unwrap();
        assert_eq!(cfg.cluster.metric, MetricKind::L1);
        let cfg =
            AppConfig::load(None, &[("cluster.metric".into(), "angular".into())]).unwrap();
        assert_eq!(cfg.cluster.metric, MetricKind::Cosine);
        // Default is the paper's squared-Euclidean fast path.
        assert_eq!(AppConfig::default().cluster.metric, MetricKind::L2Sq);
        // Unknown metric names fail with the valid list.
        let err = AppConfig::load(None, &[("cluster.metric".into(), "hamming".into())])
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown metric"), "{err:#}");
    }

    #[test]
    fn ladder_keys_apply_and_default_off() {
        let cfg = AppConfig::load(
            None,
            &[
                ("cluster.kernel".into(), "gemm".into()),
                ("cluster.precision".into(), "f32".into()),
                ("cluster.prune".into(), "hamerly".into()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.cluster.kernel, AssignPath::Gemm);
        assert_eq!(cfg.cluster.precision, Precision::F32);
        assert_eq!(cfg.cluster.prune, PruneKind::Hamerly);
        // The fast paths are strictly opt-in: defaults keep the exact,
        // bit-identical pipeline.
        let d = AppConfig::default();
        assert_eq!(d.cluster.kernel, AssignPath::Exact);
        assert_eq!(d.cluster.precision, Precision::F64);
        assert_eq!(d.cluster.prune, PruneKind::None);
        // Unknown values fail with the valid list.
        let err = AppConfig::load(None, &[("cluster.kernel".into(), "blas".into())])
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown kernel"), "{err:#}");
        let err = AppConfig::load(None, &[("cluster.precision".into(), "f16".into())])
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown precision"), "{err:#}");
        let err = AppConfig::load(None, &[("cluster.prune".into(), "elkan".into())])
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown prune mode"), "{err:#}");
    }

    #[test]
    fn storage_keys_apply_and_default_resident() {
        let cfg = AppConfig::load(
            None,
            &[
                ("data.path".into(), "pts.mrc".into()),
                ("data.backing".into(), "file".into()),
                ("data.chunk_points".into(), "4096".into()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.storage.path.as_deref(), Some(std::path::Path::new("pts.mrc")));
        assert_eq!(cfg.storage.backing, DataBacking::File);
        assert_eq!(cfg.storage.chunk_points, 4096);
        // Defaults: fully resident, no input file.
        let d = AppConfig::default();
        assert_eq!(d.storage.backing, DataBacking::Mem);
        assert!(d.storage.path.is_none());
        assert!(d.storage.chunk_points > 0);
        // Bad values fail loudly.
        let err = AppConfig::load(None, &[("data.backing".into(), "disk".into())]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown backing"), "{err:#}");
        assert!(AppConfig::load(None, &[("data.chunk_points".into(), "0".into())]).is_err());
    }

    #[test]
    fn sim_keys_apply_and_default_off() {
        let cfg = AppConfig::load(
            None,
            &[
                ("sim.enabled".into(), "true".into()),
                ("sim.network".into(), "topology".into()),
                ("sim.racks".into(), "4".into()),
                ("sim.oversub".into(), "3.5".into()),
                ("sim.nic_mbps".into(), "10000".into()),
                ("sim.compute_mbps".into(), "800".into()),
                ("sim.latency_us".into(), "250".into()),
                ("sim.hetero".into(), "bimodal:0.2:3".into()),
                ("sim.placement".into(), "rackaware".into()),
                ("sim.seed".into(), "99".into()),
            ],
        )
        .unwrap();
        let s = &cfg.cluster.sim;
        assert!(s.enabled);
        assert_eq!(s.network, NetworkKind::Topology);
        assert_eq!(s.racks, 4);
        assert!((s.oversub - 3.5).abs() < 1e-12);
        assert!((s.nic_mbps - 10000.0).abs() < 1e-9);
        assert!((s.compute_mbps - 800.0).abs() < 1e-9);
        assert!((s.latency_us - 250.0).abs() < 1e-9);
        assert_eq!(s.hetero, Heterogeneity::Bimodal { slow_frac: 0.2, slow_factor: 3.0 });
        assert_eq!(s.placement, Placement::RackAware);
        assert_eq!(s.seed, 99);
        // The simulation is strictly opt-in.
        let d = AppConfig::default();
        assert!(!d.cluster.sim.enabled);
        assert_eq!(d.cluster.sim, SimConfig::default());
        // Bad values fail loudly.
        assert!(AppConfig::load(None, &[("sim.network".into(), "mesh".into())]).is_err());
        assert!(AppConfig::load(None, &[("sim.oversub".into(), "0.5".into())]).is_err());
        assert!(AppConfig::load(None, &[("sim.racks".into(), "0".into())]).is_err());
        assert!(AppConfig::load(None, &[("sim.hetero".into(), "gamma".into())]).is_err());
        assert!(AppConfig::load(None, &[("sim.placement".into(), "random".into())]).is_err());
    }

    #[test]
    fn serve_keys_apply_and_default_lossless_manual() {
        let cfg = AppConfig::load(
            None,
            &[
                ("serve.tau".into(), "64".into()),
                ("serve.epoch_batches".into(), "16".into()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.serve.tau, 64);
        assert_eq!(cfg.serve.epoch_batches, 16);
        // Defaults: lossless ingest, manual epoch close.
        let d = AppConfig::default();
        assert_eq!(d.serve, ServeConfig::default());
        assert_eq!(d.serve.tau, 0);
        assert_eq!(d.serve.epoch_batches, 0);
        // Bad values fail loudly.
        assert!(AppConfig::load(None, &[("serve.tau".into(), "-1".into())]).is_err());
        assert!(AppConfig::load(None, &[("serve.nope".into(), "1".into())]).is_err());
    }

    #[test]
    fn bad_key_rejected() {
        assert!(AppConfig::load(None, &[("cluster.nope".into(), "1".into())]).is_err());
        assert!(AppConfig::load(None, &[("nodot".into(), "1".into())]).is_err());
        assert!(AppConfig::load(None, &[("cluster.k".into(), "abc".into())]).is_err());
    }

    #[test]
    fn file_then_overrides_precedence() {
        let dir = std::env::temp_dir().join("mrcluster_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.toml");
        std::fs::write(
            &path,
            "[data]\nn = 1000\nk = 10\n\n[cluster]\nk = 10\nepsilon = 0.2\n",
        )
        .unwrap();
        let cfg = AppConfig::load(
            Some(&path),
            &[("cluster.k".into(), "99".into())],
        )
        .unwrap();
        assert_eq!(cfg.data.n, 1000);
        assert_eq!(cfg.cluster.k, 99, "override beats file");
        assert!((cfg.cluster.epsilon - 0.2).abs() < 1e-12);
    }
}
