//! Minimal TOML-subset parser for config files (offline build — no `toml`
//! crate). Supports:
//!
//! ```toml
//! # comment
//! [section]
//! key = 123          # integers / floats
//! name = "string"    # basic strings
//! flag = true        # booleans
//! ```
//!
//! Values are kept as raw strings; typing happens in `AppConfig::apply`.
//! Not supported (rejected, not silently ignored): arrays, inline tables,
//! multi-line strings, dotted keys.

use anyhow::{bail, Result};

/// Parsed document: ordered (section, [(key, value)]) pairs.
pub type Doc = Vec<(String, Vec<(String, String)>)>;

/// Parse TOML-subset text.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc: Doc = Vec::new();
    let mut current: Option<usize> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            let name = name.trim();
            if name.is_empty() || name.contains('[') || name.contains('.') {
                bail!("line {}: bad section name {name:?}", lineno + 1);
            }
            doc.push((name.to_string(), Vec::new()));
            current = Some(doc.len() - 1);
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected key = value", lineno + 1);
        };
        let key = key.trim();
        if key.is_empty() || key.contains(' ') || key.contains('.') {
            bail!("line {}: bad key {key:?}", lineno + 1);
        }
        let value = parse_value(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let Some(idx) = current else {
            bail!("line {}: key outside of any [section]", lineno + 1);
        };
        doc[idx].1.push((key.to_string(), value));
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<String> {
    if v.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = v.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string {v:?}");
        };
        if inner.contains('"') {
            bail!("embedded quote in {v:?}");
        }
        return Ok(inner.to_string());
    }
    if v.starts_with('[') || v.starts_with('{') {
        bail!("arrays/inline tables are not supported: {v:?}");
    }
    Ok(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = "# top comment\n[data]\nn = 1000\nsigma = 0.1 # trailing\n\n\
                    [cluster]\nbackend = \"xla\"\nparallel = true\n";
        let doc = parse(text).unwrap();
        assert_eq!(doc.len(), 2);
        assert_eq!(doc[0].0, "data");
        assert_eq!(doc[0].1, vec![("n".into(), "1000".into()), ("sigma".into(), "0.1".into())]);
        assert_eq!(doc[1].1[0], ("backend".into(), "xla".into()));
        assert_eq!(doc[1].1[1], ("parallel".into(), "true".into()));
    }

    #[test]
    fn rejects_key_outside_section() {
        assert!(parse("k = 1\n").is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(parse("[data\nn = 1\n").is_err());
        assert!(parse("[data]\ns = \"abc\n").is_err());
    }

    #[test]
    fn rejects_arrays() {
        assert!(parse("[a]\nx = [1, 2]\n").is_err());
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse("[a]\nx = \"a#b\"\n").unwrap();
        assert_eq!(doc[0].1[0].1, "a#b");
    }

    #[test]
    fn empty_doc_ok() {
        assert!(parse("\n# nothing\n").unwrap().is_empty());
    }
}
