//! Explicit `Θ(n²)` distance representation — the input model the paper's
//! theory section assumes ("we are given the distance function explicitly as
//! a set of Θ(n²) distances"). Practical only for small n; used by the
//! graph-metric tests and the k-center demo on non-embeddable metrics.

use crate::geometry::PointSet;

/// A dense symmetric distance matrix with zero diagonal.
#[derive(Clone, Debug)]
pub struct DistanceMatrix {
    n: usize,
    d: Vec<f32>, // row-major n x n
}

impl DistanceMatrix {
    /// Build from an explicit full matrix. Validates metric axioms
    /// (symmetry, zero diagonal, non-negativity); triangle inequality is
    /// checked only in debug builds (O(n³)).
    pub fn new(n: usize, d: Vec<f32>) -> anyhow::Result<Self> {
        anyhow::ensure!(d.len() == n * n, "matrix must be n*n");
        for i in 0..n {
            anyhow::ensure!(d[i * n + i] == 0.0, "diagonal must be zero at {i}");
            for j in 0..i {
                let dij = d[i * n + j];
                let dji = d[j * n + i];
                anyhow::ensure!(dij >= 0.0, "negative distance at ({i},{j})");
                anyhow::ensure!(
                    (dij - dji).abs() <= 1e-5 * (1.0 + dij.abs()),
                    "asymmetric at ({i},{j}): {dij} vs {dji}"
                );
            }
        }
        #[cfg(debug_assertions)]
        {
            for i in 0..n {
                for j in 0..n {
                    for l in 0..n {
                        debug_assert!(
                            d[i * n + j] <= d[i * n + l] + d[l * n + j] + 1e-3,
                            "triangle inequality violated at ({i},{j},{l})"
                        );
                    }
                }
            }
        }
        Ok(DistanceMatrix { n, d })
    }

    /// Build by evaluating Euclidean distances between the rows of a
    /// [`PointSet`] (handy for tests comparing matrix vs coordinate paths).
    pub fn from_points(ps: &PointSet) -> Self {
        let n = ps.len();
        let mut d = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = crate::geometry::metric::sq_dist(ps.row(i), ps.row(j)).sqrt();
                d[i * n + j] = v;
                d[j * n + i] = v;
            }
        }
        DistanceMatrix { n, d }
    }

    /// Build shortest-path distances of a weighted undirected graph given as
    /// an edge list (Floyd–Warshall; the "sparse graph" input the paper's
    /// intro discusses, made explicit). Disconnected pairs get a large
    /// finite distance so the result is still a (pseudo-)metric.
    pub fn from_graph(n: usize, edges: &[(usize, usize, f32)]) -> Self {
        const INF: f32 = 1e12;
        let mut d = vec![INF; n * n];
        for i in 0..n {
            d[i * n + i] = 0.0;
        }
        for &(u, v, w) in edges {
            assert!(u < n && v < n);
            assert!(w >= 0.0, "edge weights must be non-negative");
            let cur = d[u * n + v];
            if w < cur {
                d[u * n + v] = w;
                d[v * n + u] = w;
            }
        }
        for k in 0..n {
            for i in 0..n {
                let dik = d[i * n + k];
                if dik >= INF {
                    continue;
                }
                for j in 0..n {
                    let via = dik + d[k * n + j];
                    if via < d[i * n + j] {
                        d[i * n + j] = via;
                    }
                }
            }
        }
        // Clamp disconnected pairs to the largest finite distance * 2 so
        // that the triangle inequality still holds.
        let maxfin = d
            .iter()
            .copied()
            .filter(|&x| x < INF)
            .fold(0.0f32, f32::max);
        let cap = (maxfin * 2.0).max(1.0);
        for x in d.iter_mut() {
            if *x >= INF {
                *x = cap;
            }
        }
        DistanceMatrix { n, d }
    }

    /// Number of points the matrix covers.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the matrix covers no points.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Distance between points `i` and `j`.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f32 {
        self.d[i * self.n + j]
    }

    /// Minimum distance from `i` to any index in `set`.
    pub fn dist_to_set(&self, i: usize, set: &[usize]) -> f32 {
        set.iter()
            .map(|&j| self.dist(i, j))
            .fold(f32::INFINITY, f32::min)
    }

    /// k-center cost of `centers` over all points.
    pub fn kcenter_cost(&self, centers: &[usize]) -> f32 {
        (0..self.n)
            .map(|i| self.dist_to_set(i, centers))
            .fold(0.0, f32::max)
    }

    /// k-median cost of `centers` over all points.
    pub fn kmedian_cost(&self, centers: &[usize]) -> f64 {
        (0..self.n)
            .map(|i| self.dist_to_set(i, centers) as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_points_roundtrip() {
        let ps = PointSet::from_flat(2, vec![0.0, 0.0, 3.0, 4.0, 0.0, 1.0]);
        let m = DistanceMatrix::from_points(&ps);
        assert_eq!(m.len(), 3);
        assert!((m.dist(0, 1) - 5.0).abs() < 1e-5);
        assert_eq!(m.dist(0, 0), 0.0);
        assert!((m.dist(1, 0) - m.dist(0, 1)).abs() < 1e-6);
    }

    #[test]
    fn rejects_asymmetric() {
        let d = vec![0.0, 1.0, 2.0, 0.0];
        assert!(DistanceMatrix::new(2, d).is_err());
    }

    #[test]
    fn rejects_nonzero_diagonal() {
        let d = vec![1.0, 1.0, 1.0, 0.0];
        assert!(DistanceMatrix::new(2, d).is_err());
    }

    #[test]
    fn graph_shortest_paths() {
        // Path graph 0-1-2 with weights 1, 2: d(0,2) = 3.
        let m = DistanceMatrix::from_graph(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        assert!((m.dist(0, 2) - 3.0).abs() < 1e-6);
        assert!((m.dist(2, 0) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn graph_disconnected_capped() {
        let m = DistanceMatrix::from_graph(3, &[(0, 1, 5.0)]);
        assert!(m.dist(0, 2) > 5.0);
        assert!(m.dist(0, 2).is_finite());
        // Still symmetric.
        assert_eq!(m.dist(0, 2), m.dist(2, 0));
    }

    #[test]
    fn costs() {
        let ps = PointSet::from_flat(1, vec![0.0, 1.0, 2.0, 10.0]);
        let m = DistanceMatrix::from_points(&ps);
        assert!((m.kcenter_cost(&[0]) - 10.0).abs() < 1e-5);
        assert!((m.kmedian_cost(&[0]) - 13.0).abs() < 1e-4);
        assert!((m.kcenter_cost(&[1, 3]) - 1.0).abs() < 1e-5);
    }
}
