//! Metric-space substrate: point storage, distance functions, and the
//! explicit distance-matrix representation the paper's theory section
//! assumes (`Θ(n²)` edges) for small instances.

pub mod matrix;
pub mod metric;
pub mod point;
pub mod store;

pub use matrix::DistanceMatrix;
pub use metric::{EuclideanSq, Metric, MetricKind};
pub use point::{chunk_spans, PointSet};
pub use store::{
    DatasetHeader, FileStore, PointStore, Resident, ResidentMeter, StoreBlock, StoreWriter,
};
