//! Distance functions.
//!
//! The paper's algorithms only require the triangle inequality; all our
//! k-median / k-center machinery is written against the [`Metric`] trait.
//! The experiments (§4.2) use Euclidean distance in `R^3`; the squared
//! Euclidean form is the hot-path primitive (monotone in the true distance,
//! so argmins are unaffected, and it avoids the sqrt until cost reporting —
//! the same trick the L1 Pallas kernel uses).

/// A distance function over coordinate rows.
pub trait Metric: Send + Sync {
    /// The true metric distance d(a, b).
    fn dist(&self, a: &[f32], b: &[f32]) -> f32;

    /// A monotone surrogate of `dist` (defaults to `dist` itself). Argmin /
    /// comparisons may use this; costs must go through [`Metric::dist`] or
    /// [`Metric::to_dist`].
    #[inline]
    fn surrogate(&self, a: &[f32], b: &[f32]) -> f32 {
        self.dist(a, b)
    }

    /// Map a surrogate value back to the true distance.
    #[inline]
    fn to_dist(&self, surrogate: f32) -> f32 {
        surrogate
    }
}

/// Squared-Euclidean surrogate for the Euclidean metric. This is the metric
/// every paper experiment runs under.
#[derive(Debug, Default, Clone, Copy)]
pub struct EuclideanSq;

/// Squared Euclidean distance between two coordinate rows, with an
/// unrolled fast path for the paper's `d = 3`.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match a.len() {
        3 => {
            let d0 = a[0] - b[0];
            let d1 = a[1] - b[1];
            let d2 = a[2] - b[2];
            d0 * d0 + d1 * d1 + d2 * d2
        }
        2 => {
            let d0 = a[0] - b[0];
            let d1 = a[1] - b[1];
            d0 * d0 + d1 * d1
        }
        _ => {
            let mut acc = 0.0f32;
            for i in 0..a.len() {
                let d = a[i] - b[i];
                acc += d * d;
            }
            acc
        }
    }
}

impl Metric for EuclideanSq {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        sq_dist(a, b).sqrt()
    }

    #[inline]
    fn surrogate(&self, a: &[f32], b: &[f32]) -> f32 {
        sq_dist(a, b)
    }

    #[inline]
    fn to_dist(&self, surrogate: f32) -> f32 {
        surrogate.max(0.0).sqrt()
    }
}

/// Manhattan (L1) metric — included to demonstrate the library is not tied
/// to Euclidean geometry (the paper's guarantees only need the triangle
/// inequality).
#[derive(Debug, Default, Clone, Copy)]
pub struct Manhattan;

impl Metric for Manhattan {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }
}

/// Chebyshev (L∞) metric.
#[derive(Debug, Default, Clone, Copy)]
pub struct Chebyshev;

impl Metric for Chebyshev {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_matches_definition_various_dims() {
        for d in [1usize, 2, 3, 4, 8, 17] {
            let a: Vec<f32> = (0..d).map(|i| i as f32 * 0.5).collect();
            let b: Vec<f32> = (0..d).map(|i| 2.0 - i as f32).collect();
            let want: f32 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            assert!((sq_dist(&a, &b) - want).abs() < 1e-5, "dim {d}");
        }
    }

    #[test]
    fn euclidean_consistency() {
        let m = EuclideanSq;
        let a = [0.0, 3.0, 0.0];
        let b = [4.0, 0.0, 0.0];
        assert!((m.dist(&a, &b) - 5.0).abs() < 1e-6);
        assert!((m.surrogate(&a, &b) - 25.0).abs() < 1e-5);
        assert!((m.to_dist(m.surrogate(&a, &b)) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn identity_and_symmetry() {
        let metrics: Vec<Box<dyn Metric>> =
            vec![Box::new(EuclideanSq), Box::new(Manhattan), Box::new(Chebyshev)];
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 4.0, 2.5];
        for m in &metrics {
            assert_eq!(m.dist(&a, &a), 0.0);
            assert!((m.dist(&a, &b) - m.dist(&b, &a)).abs() < 1e-6);
            assert!(m.dist(&a, &b) > 0.0);
        }
    }

    #[test]
    fn triangle_inequality_randomized() {
        let mut rng = crate::util::rng::Rng::new(99);
        let metrics: Vec<Box<dyn Metric>> =
            vec![Box::new(EuclideanSq), Box::new(Manhattan), Box::new(Chebyshev)];
        for _ in 0..200 {
            let p: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..3).map(|_| rng.f32() * 10.0 - 5.0).collect())
                .collect();
            for m in &metrics {
                let ab = m.dist(&p[0], &p[1]);
                let bc = m.dist(&p[1], &p[2]);
                let ac = m.dist(&p[0], &p[2]);
                assert!(ac <= ab + bc + 1e-4, "triangle violated");
            }
        }
    }
}
