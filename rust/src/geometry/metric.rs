//! Distance functions and the pluggable metric-space abstraction.
//!
//! The paper's algorithms (Iterative-Sample, MapReduce-kCenter/kMedian) are
//! stated for *general metric spaces* — the proofs only use the triangle
//! inequality. This module is what makes the reproduction honor that: every
//! layer (backend kernels, sequential `A` subroutines, coordinators, cost
//! oracles) is parameterized by a [`MetricKind`], selected at run time via
//! the `cluster.metric` config key (see the README configuration table).
//!
//! Two representations coexist:
//!
//! * [`MetricKind`] — a `Copy` enum naming the registered metrics. This is
//!   the currency the whole pipeline threads around: it is cheap to store
//!   in configs, trivially serializable (`name`/`parse`), and lets the hot
//!   kernels dispatch once per tile instead of per distance
//!   (see `runtime/native.rs`).
//! * the [`Metric`] trait — the open-ended object-safe interface, kept for
//!   library users who want to experiment with metrics the enum does not
//!   register. [`MetricKind`] implements it, as do the standalone structs
//!   ([`EuclideanSq`], [`Manhattan`], [`Chebyshev`]).
//!
//! ## Surrogates
//!
//! Each metric may expose a cheap *surrogate*: a monotone stand-in for the
//! true distance that argmin comparisons can use directly. The Euclidean
//! fast path ([`MetricKind::L2Sq`], the default — and the metric every
//! paper experiment runs under) uses the squared distance and defers the
//! `sqrt` to cost reporting; the angular metric ([`MetricKind::Cosine`])
//! uses `1 − cos θ` and defers the `acos`. Costs always go through
//! [`MetricKind::to_dist_f32`] / [`MetricKind::to_dist_f64`], so reported
//! objectives are true metric distances for every kind.
//!
//! # Examples
//!
//! The same assignment under two metrics — Euclidean geometry picks the
//! *near* center, angular geometry the *aligned* one:
//!
//! ```
//! use mrcluster::geometry::{MetricKind, PointSet};
//! use mrcluster::runtime::{ComputeBackend, NativeBackend};
//!
//! let p = PointSet::from_flat(2, vec![3.0, 1.0]);
//! let c = PointSet::from_flat(2, vec![10.0, 0.0, 0.0, 1.0]);
//! // Euclidean: (3,1) is far from (10,0), close to (0,1).
//! assert_eq!(NativeBackend.assign_metric(&p, &c, MetricKind::L2Sq).idx, vec![1]);
//! // Angular: (3,1) points almost along (10,0).
//! assert_eq!(NativeBackend.assign_metric(&p, &c, MetricKind::Cosine).idx, vec![0]);
//! ```

/// A distance function over coordinate rows.
///
/// Implementations must be symmetric, zero on identical rows, and satisfy
/// the triangle inequality — the only properties the paper's analysis
/// uses. [`MetricKind`] is the registered-metric implementation the
/// pipeline threads around; the standalone structs below demonstrate the
/// open-ended form.
pub trait Metric: Send + Sync {
    /// The true metric distance d(a, b).
    fn dist(&self, a: &[f32], b: &[f32]) -> f32;

    /// A monotone surrogate of `dist` (defaults to `dist` itself). Argmin /
    /// comparisons may use this; costs must go through [`Metric::dist`] or
    /// [`Metric::to_dist`].
    #[inline]
    fn surrogate(&self, a: &[f32], b: &[f32]) -> f32 {
        self.dist(a, b)
    }

    /// Map a surrogate value back to the true distance.
    #[inline]
    fn to_dist(&self, surrogate: f32) -> f32 {
        surrogate
    }
}

/// The registered metric spaces the pipeline can run under.
///
/// Selected via `cluster.metric` (TOML / `--set cluster.metric=…` /
/// `mrcluster cluster --metric …`). [`MetricKind::L2Sq`] is the default
/// and reproduces the pre-metric pipeline bit-for-bit: its kernels are the
/// original squared-Euclidean fast path, dispatched unchanged
/// (property-tested in `rust/tests/prop_metrics.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum MetricKind {
    /// Euclidean distance through the squared-distance surrogate — the
    /// specialized fast path (no `sqrt` until cost reporting) and the
    /// metric of every paper experiment. The default.
    #[default]
    L2Sq,
    /// Euclidean distance computed directly (the surrogate *is* the
    /// distance). Same geometry as [`MetricKind::L2Sq`]; exists to exercise
    /// the generic path and as the reference for float-rounding contrasts.
    L2,
    /// Manhattan / taxicab distance `Σ |aᵢ − bᵢ|`.
    L1,
    /// Angular distance `acos(cos θ)` through the `1 − cos θ` surrogate.
    /// Unlike raw cosine *dissimilarity*, the angle is a true metric
    /// (triangle inequality holds on the sphere; the maximum distance is
    /// π, for anti-parallel rows). Zero-norm rows are treated as at
    /// distance 0 from other zero-norm rows and at a right angle
    /// (θ = π/2, surrogate 1) to everything else.
    Cosine,
    /// Chebyshev / L∞ distance `max |aᵢ − bᵢ|`.
    Chebyshev,
}

impl MetricKind {
    /// Every registered metric, in display order (the E13 sweep order).
    pub const ALL: [MetricKind; 5] = [
        MetricKind::L2Sq,
        MetricKind::L2,
        MetricKind::L1,
        MetricKind::Cosine,
        MetricKind::Chebyshev,
    ];

    /// Canonical config/CLI name (`cluster.metric` value).
    pub fn name(self) -> &'static str {
        match self {
            MetricKind::L2Sq => "l2sq",
            MetricKind::L2 => "l2",
            MetricKind::L1 => "l1",
            MetricKind::Cosine => "cosine",
            MetricKind::Chebyshev => "chebyshev",
        }
    }

    /// Parse a config/CLI name (aliases accepted, case-insensitive).
    pub fn parse(s: &str) -> Option<MetricKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "l2sq" | "squared-l2" | "euclidean-sq" | "sqeuclidean" => MetricKind::L2Sq,
            "l2" | "euclidean" => MetricKind::L2,
            "l1" | "manhattan" | "taxicab" => MetricKind::L1,
            "cosine" | "angular" => MetricKind::Cosine,
            "chebyshev" | "linf" | "max" => MetricKind::Chebyshev,
            _ => return None,
        })
    }

    /// True when the coordinate-wise (weighted) mean minimizes the summed
    /// distance objective well enough for Lloyd's classical update — the
    /// Euclidean family. Non-Euclidean metrics route Lloyd's update to the
    /// medoid step instead (`algorithms/lloyd.rs`).
    #[inline]
    pub fn mean_is_minimizer(self) -> bool {
        matches!(self, MetricKind::L2Sq | MetricKind::L2)
    }

    /// True when triangle-inequality bound pruning (Hamerly-style; see
    /// `algorithms/lloyd.rs`) is valid: the distances obtained through
    /// [`MetricKind::to_dist_f32`] / [`MetricKind::dist`] form a true
    /// metric. Holds for `l2`, `l1`, and `chebyshev` directly, and for
    /// `l2sq` because its bounds are routed through the `l2` distance
    /// (the sqrt of the surrogate). The `cosine` surrogate `1 − cos θ` is
    /// not a metric (its `to_dist` arc-length conversion is, but the
    /// kernels compare surrogates), so pruning is skipped there.
    #[inline]
    pub fn supports_triangle_pruning(self) -> bool {
        !matches!(self, MetricKind::Cosine)
    }

    /// The comparison surrogate s(a, b) — monotone in the true distance.
    ///
    /// Scalar reference implementation; the tiled kernels in
    /// `runtime/native.rs` replicate these op sequences plane-major so
    /// kernel and scalar surrogates agree bit-for-bit.
    #[inline]
    pub fn surrogate(self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            MetricKind::L2Sq => sq_dist(a, b),
            MetricKind::L2 => sq_dist(a, b).max(0.0).sqrt(),
            MetricKind::L1 => a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum(),
            MetricKind::Cosine => cosine_surrogate(a, b),
            MetricKind::Chebyshev => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f32::max),
        }
    }

    /// Surrogate → true distance, in `f32` (the flavor the hot paths use:
    /// `min_dist`, the kernels' per-point cost shares).
    #[inline]
    pub fn to_dist_f32(self, s: f32) -> f32 {
        match self {
            MetricKind::L2Sq => s.max(0.0).sqrt(),
            MetricKind::Cosine => (1.0 - s).clamp(-1.0, 1.0).acos(),
            MetricKind::L2 | MetricKind::L1 | MetricKind::Chebyshev => s.max(0.0),
        }
    }

    /// Surrogate → true distance, in `f64` (the flavor the exact cost
    /// evaluators use; under [`MetricKind::L2Sq`] this is the `f64` sqrt
    /// the pre-metric `eval_costs` applied, preserving bit-identity).
    #[inline]
    pub fn to_dist_f64(self, s: f32) -> f64 {
        match self {
            MetricKind::L2Sq => (s.max(0.0) as f64).sqrt(),
            MetricKind::Cosine => ((1.0 - s) as f64).clamp(-1.0, 1.0).acos(),
            MetricKind::L2 | MetricKind::L1 | MetricKind::Chebyshev => s.max(0.0) as f64,
        }
    }

    /// Surrogate → squared true distance, in `f64` — the k-means objective
    /// share. Under [`MetricKind::L2Sq`] the surrogate *is* the squared
    /// distance (bit-identical to the pre-metric accumulation); other
    /// metrics square their `f64` distance.
    #[inline]
    pub fn means_share_f64(self, s: f32) -> f64 {
        match self {
            MetricKind::L2Sq => s.max(0.0) as f64,
            _ => {
                let d = self.to_dist_f64(s);
                d * d
            }
        }
    }

    /// The true metric distance d(a, b) in `f32`.
    #[inline]
    pub fn dist(self, a: &[f32], b: &[f32]) -> f32 {
        self.to_dist_f32(self.surrogate(a, b))
    }

    /// The true metric distance d(a, b) in `f64` (cost-evaluation flavor).
    #[inline]
    pub fn dist_f64(self, a: &[f32], b: &[f32]) -> f64 {
        self.to_dist_f64(self.surrogate(a, b))
    }
}

impl std::fmt::Display for MetricKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl Metric for MetricKind {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        MetricKind::dist(*self, a, b)
    }

    #[inline]
    fn surrogate(&self, a: &[f32], b: &[f32]) -> f32 {
        MetricKind::surrogate(*self, a, b)
    }

    #[inline]
    fn to_dist(&self, surrogate: f32) -> f32 {
        self.to_dist_f32(surrogate)
    }
}

/// Squared Euclidean distance between two coordinate rows, with an
/// unrolled fast path for the paper's `d = 3`.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match a.len() {
        3 => {
            let d0 = a[0] - b[0];
            let d1 = a[1] - b[1];
            let d2 = a[2] - b[2];
            d0 * d0 + d1 * d1 + d2 * d2
        }
        2 => {
            let d0 = a[0] - b[0];
            let d1 = a[1] - b[1];
            d0 * d0 + d1 * d1
        }
        _ => {
            let mut acc = 0.0f32;
            for i in 0..a.len() {
                let d = a[i] - b[i];
                acc += d * d;
            }
            acc
        }
    }
}

/// The `1 − cos θ` surrogate of the angular metric, with the zero-norm
/// convention of [`MetricKind::Cosine`]. Accumulates dot product and both
/// squared norms coordinate-by-coordinate in index order — the same op
/// sequence the tiled kernel replays plane-major, so scalar and kernel
/// surrogates agree bit-for-bit.
#[inline]
pub fn cosine_surrogate(a: &[f32], b: &[f32]) -> f32 {
    let mut dot = 0.0f32;
    let mut na2 = 0.0f32;
    let mut nb2 = 0.0f32;
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na2 += a[i] * a[i];
        nb2 += b[i] * b[i];
    }
    let denom = (na2 * nb2).sqrt();
    if denom > 0.0 {
        1.0 - dot / denom
    } else if na2 == 0.0 && nb2 == 0.0 {
        0.0
    } else {
        1.0
    }
}

/// Squared-Euclidean surrogate for the Euclidean metric. This is the metric
/// every paper experiment runs under (the struct form of
/// [`MetricKind::L2Sq`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct EuclideanSq;

impl Metric for EuclideanSq {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        sq_dist(a, b).sqrt()
    }

    #[inline]
    fn surrogate(&self, a: &[f32], b: &[f32]) -> f32 {
        sq_dist(a, b)
    }

    #[inline]
    fn to_dist(&self, surrogate: f32) -> f32 {
        surrogate.max(0.0).sqrt()
    }
}

/// Manhattan (L1) metric — the struct form of [`MetricKind::L1`].
#[derive(Debug, Default, Clone, Copy)]
pub struct Manhattan;

impl Metric for Manhattan {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }
}

/// Chebyshev (L∞) metric — the struct form of [`MetricKind::Chebyshev`].
#[derive(Debug, Default, Clone, Copy)]
pub struct Chebyshev;

impl Metric for Chebyshev {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_matches_definition_various_dims() {
        for d in [1usize, 2, 3, 4, 8, 17] {
            let a: Vec<f32> = (0..d).map(|i| i as f32 * 0.5).collect();
            let b: Vec<f32> = (0..d).map(|i| 2.0 - i as f32).collect();
            let want: f32 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            assert!((sq_dist(&a, &b) - want).abs() < 1e-5, "dim {d}");
        }
    }

    #[test]
    fn euclidean_consistency() {
        let m = EuclideanSq;
        let a = [0.0, 3.0, 0.0];
        let b = [4.0, 0.0, 0.0];
        assert!((m.dist(&a, &b) - 5.0).abs() < 1e-6);
        assert!((m.surrogate(&a, &b) - 25.0).abs() < 1e-5);
        assert!((m.to_dist(m.surrogate(&a, &b)) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn kind_l2sq_matches_struct_euclidean() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 4.0, 2.5];
        let k = MetricKind::L2Sq;
        assert_eq!(k.surrogate(&a, &b).to_bits(), EuclideanSq.surrogate(&a, &b).to_bits());
        assert!((MetricKind::dist(k, &a, &b) - EuclideanSq.dist(&a, &b)).abs() < 1e-6);
        // L2 computes the same geometry directly.
        assert!((MetricKind::dist(MetricKind::L2, &a, &b) - EuclideanSq.dist(&a, &b)).abs() < 1e-6);
    }

    #[test]
    fn known_hand_values_per_kind() {
        let a = [1.0f32, 2.0];
        let b = [4.0f32, -2.0];
        assert!((MetricKind::dist(MetricKind::L2, &a, &b) - 5.0).abs() < 1e-6);
        assert!((MetricKind::dist(MetricKind::L1, &a, &b) - 7.0).abs() < 1e-6);
        assert!((MetricKind::dist(MetricKind::Chebyshev, &a, &b) - 4.0).abs() < 1e-6);
        // Orthogonal vectors: angular distance π/2.
        let e0 = [1.0f32, 0.0];
        let e1 = [0.0f32, 3.0];
        let ang = MetricKind::dist(MetricKind::Cosine, &e0, &e1);
        assert!((ang - std::f32::consts::FRAC_PI_2).abs() < 1e-5, "{ang}");
        // Parallel vectors of different magnitude: angular distance 0.
        let p = [2.0f32, 2.0];
        let q = [5.0f32, 5.0];
        assert!(MetricKind::dist(MetricKind::Cosine, &p, &q).abs() < 1e-3);
    }

    #[test]
    fn cosine_zero_norm_convention() {
        let z = [0.0f32, 0.0];
        let x = [1.0f32, 0.0];
        assert_eq!(MetricKind::surrogate(MetricKind::Cosine, &z, &z), 0.0);
        assert_eq!(MetricKind::surrogate(MetricKind::Cosine, &z, &x), 1.0);
        assert_eq!(MetricKind::surrogate(MetricKind::Cosine, &x, &z), 1.0);
    }

    #[test]
    fn names_roundtrip_and_aliases() {
        for m in MetricKind::ALL {
            assert_eq!(MetricKind::parse(m.name()), Some(m), "{m}");
            assert_eq!(format!("{m}"), m.name());
        }
        assert_eq!(MetricKind::parse("euclidean"), Some(MetricKind::L2));
        assert_eq!(MetricKind::parse("Manhattan"), Some(MetricKind::L1));
        assert_eq!(MetricKind::parse("angular"), Some(MetricKind::Cosine));
        assert_eq!(MetricKind::parse("linf"), Some(MetricKind::Chebyshev));
        assert_eq!(MetricKind::parse("squared-l2"), Some(MetricKind::L2Sq));
        assert_eq!(MetricKind::parse("nope"), None);
        assert_eq!(MetricKind::default(), MetricKind::L2Sq);
    }

    #[test]
    fn identity_and_symmetry() {
        let metrics: Vec<Box<dyn Metric>> =
            vec![Box::new(EuclideanSq), Box::new(Manhattan), Box::new(Chebyshev)];
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 4.0, 2.5];
        for m in &metrics {
            assert_eq!(m.dist(&a, &a), 0.0);
            assert!((m.dist(&a, &b) - m.dist(&b, &a)).abs() < 1e-6);
            assert!(m.dist(&a, &b) > 0.0);
        }
        for k in MetricKind::ALL {
            assert!(MetricKind::dist(k, &a, &a).abs() < 1e-6, "{k}");
            assert!(
                (MetricKind::dist(k, &a, &b) - MetricKind::dist(k, &b, &a)).abs() < 1e-6,
                "{k}"
            );
            assert!(MetricKind::dist(k, &a, &b) > 0.0, "{k}");
        }
    }

    #[test]
    fn triangle_inequality_randomized() {
        let mut rng = crate::util::rng::Rng::new(99);
        for _ in 0..200 {
            let p: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..3).map(|_| rng.f32() * 10.0 - 5.0).collect())
                .collect();
            for k in MetricKind::ALL {
                let ab = MetricKind::dist(k, &p[0], &p[1]);
                let bc = MetricKind::dist(k, &p[1], &p[2]);
                let ac = MetricKind::dist(k, &p[0], &p[2]);
                assert!(ac <= ab + bc + 1e-4, "{k}: triangle violated");
            }
        }
    }

    #[test]
    fn surrogate_is_monotone_in_distance() {
        let mut rng = crate::util::rng::Rng::new(7);
        let a: Vec<f32> = (0..4).map(|_| rng.f32() * 2.0 - 1.0).collect();
        for _ in 0..100 {
            let b: Vec<f32> = (0..4).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let c: Vec<f32> = (0..4).map(|_| rng.f32() * 2.0 - 1.0).collect();
            for k in MetricKind::ALL {
                let (sb, sc) = (k.surrogate(&a, &b), k.surrogate(&a, &c));
                let (db, dc) = (MetricKind::dist(k, &a, &b), MetricKind::dist(k, &a, &c));
                if sb < sc {
                    assert!(db <= dc + 1e-5, "{k}: surrogate order disagrees with dist");
                }
                // to_dist inverts the surrogate to the true distance.
                assert!((k.to_dist_f32(sb) - db).abs() < 1e-6, "{k}");
                assert!((k.to_dist_f64(sb) - db as f64).abs() < 1e-5, "{k}");
            }
        }
    }
}
