//! Flat structure-of-arrays point storage.
//!
//! All hot loops in the system iterate over contiguous `f32` coordinate
//! rows, so points are stored as one flat `Vec<f32>` of length `n * dim`
//! (row-major). This is also exactly the layout the PJRT artifacts take as
//! input, so handing a block to the XLA backend is a memcpy, not a gather.

use std::fmt;

/// A set of `n` points in `R^dim`, stored row-major.
#[derive(Clone, PartialEq)]
pub struct PointSet {
    dim: usize,
    coords: Vec<f32>,
}

impl fmt::Debug for PointSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PointSet(n={}, dim={})", self.len(), self.dim)
    }
}

impl PointSet {
    /// Build from a flat row-major coordinate buffer.
    pub fn from_flat(dim: usize, coords: Vec<f32>) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert!(
            coords.len() % dim == 0,
            "flat buffer length {} not divisible by dim {}",
            coords.len(),
            dim
        );
        PointSet { dim, coords }
    }

    /// An empty set with capacity for `cap` points.
    pub fn with_capacity(dim: usize, cap: usize) -> Self {
        assert!(dim > 0);
        PointSet {
            dim,
            coords: Vec::with_capacity(cap * dim),
        }
    }

    pub fn len(&self) -> usize {
        self.coords.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Coordinates of point `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.dim;
        &self.coords[i * d..(i + 1) * d]
    }

    /// The whole flat buffer (row-major).
    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.coords
    }

    /// Append one point.
    pub fn push(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim, "row has wrong dimension");
        self.coords.extend_from_slice(row);
    }

    /// Append all points of `other` (must agree on dim).
    pub fn extend(&mut self, other: &PointSet) {
        assert_eq!(self.dim, other.dim);
        self.coords.extend_from_slice(&other.coords);
    }

    /// New set containing the rows at `indices` (in order).
    pub fn gather(&self, indices: &[usize]) -> PointSet {
        let mut out = PointSet::with_capacity(self.dim, indices.len());
        for &i in indices {
            out.push(self.row(i));
        }
        out
    }

    /// Split into `parts` nearly-equal contiguous chunks (last may be
    /// shorter). Used by the MapReduce partitioners.
    pub fn chunks(&self, parts: usize) -> Vec<PointSet> {
        assert!(parts > 0);
        let n = self.len();
        let per = crate::util::div_ceil(n, parts);
        let mut out = Vec::new();
        let mut start = 0;
        while start < n {
            let end = (start + per).min(n);
            out.push(PointSet::from_flat(
                self.dim,
                self.coords[start * self.dim..end * self.dim].to_vec(),
            ));
            start = end;
        }
        out
    }

    /// In-place Fisher–Yates shuffle of the rows ("the mappers arbitrarily
    /// partition" — we realize arbitrariness as a seeded shuffle).
    pub fn shuffle(&mut self, rng: &mut crate::util::rng::Rng) {
        let n = self.len();
        let d = self.dim;
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            if i != j {
                for c in 0..d {
                    self.coords.swap(i * d + c, j * d + c);
                }
            }
        }
    }

    /// Memory footprint in bytes (used by the engine's memory accounting).
    pub fn mem_bytes(&self) -> usize {
        self.coords.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ps(rows: &[&[f32]]) -> PointSet {
        let dim = rows[0].len();
        let mut p = PointSet::with_capacity(dim, rows.len());
        for r in rows {
            p.push(r);
        }
        p
    }

    #[test]
    fn construction_and_access() {
        let p = ps(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(p.len(), 3);
        assert_eq!(p.dim(), 2);
        assert_eq!(p.row(1), &[3.0, 4.0]);
        assert_eq!(p.flat().len(), 6);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn bad_flat_panics() {
        PointSet::from_flat(3, vec![1.0; 7]);
    }

    #[test]
    fn gather_preserves_order() {
        let p = ps(&[&[0.0], &[1.0], &[2.0], &[3.0]]);
        let g = p.gather(&[3, 1]);
        assert_eq!(g.row(0), &[3.0]);
        assert_eq!(g.row(1), &[1.0]);
    }

    #[test]
    fn chunks_cover_everything() {
        let p = PointSet::from_flat(1, (0..10).map(|i| i as f32).collect());
        let cs = p.chunks(3);
        assert_eq!(cs.len(), 3);
        let total: usize = cs.iter().map(|c| c.len()).sum();
        assert_eq!(total, 10);
        // Order preserved across chunk boundaries.
        assert_eq!(cs[0].row(0), &[0.0]);
        assert_eq!(cs[2].row(cs[2].len() - 1), &[9.0]);
    }

    #[test]
    fn chunks_more_parts_than_points() {
        let p = PointSet::from_flat(1, vec![1.0, 2.0]);
        let cs = p.chunks(5);
        let total: usize = cs.iter().map(|c| c.len()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = PointSet::from_flat(1, (0..100).map(|i| i as f32).collect());
        let mut rng = Rng::new(1);
        p.shuffle(&mut rng);
        let mut vals: Vec<f32> = p.flat().to_vec();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let expect: Vec<f32> = (0..100).map(|i| i as f32).collect();
        assert_eq!(vals, expect);
        // And it actually moved something.
        assert_ne!(p.flat()[..10], expect[..10]);
    }

    #[test]
    fn extend_appends() {
        let mut a = ps(&[&[1.0, 1.0]]);
        let b = ps(&[&[2.0, 2.0]]);
        a.extend(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.row(1), &[2.0, 2.0]);
    }
}
