//! Flat structure-of-arrays point storage with a zero-copy data plane.
//!
//! All hot loops in the system iterate over contiguous `f32` coordinate
//! rows, so points are stored as one flat buffer of length `n * dim`
//! (row-major). This is also exactly the layout the PJRT artifacts take as
//! input, so handing a block to the XLA backend is a memcpy, not a gather.
//!
//! Since the zero-copy refactor, a [`PointSet`] is a cheap *view* over
//! `Arc`-shared storage: [`PointSet::chunks`], [`PointSet::view`], and
//! contiguous-range [`PointSet::gather`]s alias the parent's allocation in
//! O(1) instead of copying coordinates, which turns the per-round
//! partitioning of the simulated cluster from an O(n·d) memcpy into
//! metadata. Mutation (`push`/`extend`/`shuffle`) is copy-on-write: it
//! first materializes a private buffer when the storage is shared, so a
//! previously-taken view is never affected by later writes to its parent.
//!
//! Two byte measures intentionally coexist (see `mapreduce/kv.rs`):
//! [`PointSet::mem_bytes`] is the *logical* footprint of the view — what a
//! simulated machine "holds", which is what `MrConfig::mem_limit` must
//! charge even when the host process shares one allocation across all
//! partitions — while [`PointSet::owned_bytes`] reports the bytes this set
//! uniquely owns on the host (0 for borrowed views), which is what the
//! zero-copy tests assert on.

use std::fmt;
use std::sync::Arc;

/// The `(lo, hi)` row ranges [`PointSet::chunks`] splits `len` rows into.
///
/// Shared with the file-backed store (`geometry/store.rs`) so an
/// out-of-core dataset is partitioned on *exactly* the boundaries the
/// in-memory partitioner would use — a precondition for the file-backed
/// coordinator runs being bit-identical to in-memory runs.
pub fn chunk_spans(len: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts > 0);
    let per = crate::util::div_ceil(len, parts);
    let mut out = Vec::new();
    let mut start = 0;
    while start < len {
        let end = (start + per).min(len);
        out.push((start, end));
        start = end;
    }
    out
}

/// A set of `n` points in `R^dim`, stored row-major; possibly a borrowed
/// view into storage shared with other sets.
#[derive(Clone)]
pub struct PointSet {
    dim: usize,
    /// Shared row-major storage; mutation copies-on-write.
    storage: Arc<Vec<f32>>,
    /// View start within `storage`, in floats (always a multiple of `dim`).
    start: usize,
    /// View length, in floats (always a multiple of `dim`).
    len: usize,
}

impl fmt::Debug for PointSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PointSet(n={}, dim={})", self.len(), self.dim)
    }
}

/// Views compare by contents, not by storage identity.
impl PartialEq for PointSet {
    fn eq(&self, other: &Self) -> bool {
        self.dim == other.dim && self.flat() == other.flat()
    }
}

impl PointSet {
    /// Build from a flat row-major coordinate buffer (takes ownership).
    pub fn from_flat(dim: usize, coords: Vec<f32>) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert!(
            coords.len() % dim == 0,
            "flat buffer length {} not divisible by dim {}",
            coords.len(),
            dim
        );
        let len = coords.len();
        PointSet {
            dim,
            storage: Arc::new(coords),
            start: 0,
            len,
        }
    }

    /// An empty set with capacity for `cap` points.
    pub fn with_capacity(dim: usize, cap: usize) -> Self {
        assert!(dim > 0);
        PointSet {
            dim,
            storage: Arc::new(Vec::with_capacity(cap * dim)),
            start: 0,
            len: 0,
        }
    }

    /// Number of points in this view.
    pub fn len(&self) -> usize {
        self.len / self.dim
    }

    /// True when the view holds no points.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Point dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Coordinates of point `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.dim;
        &self.flat()[i * d..(i + 1) * d]
    }

    /// The whole flat buffer of this view (row-major).
    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.storage[self.start..self.start + self.len]
    }

    /// O(1) zero-copy view of rows `lo..hi` (aliases this set's storage).
    ///
    /// # Examples
    ///
    /// ```
    /// use mrcluster::geometry::PointSet;
    ///
    /// let p = PointSet::from_flat(2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    /// let v = p.view(1, 3);
    /// assert_eq!(v.len(), 2);
    /// assert_eq!(v.row(0), &[2.0, 3.0]);
    /// assert!(v.shares_storage(&p)); // no coordinates were copied
    /// ```
    pub fn view(&self, lo: usize, hi: usize) -> PointSet {
        assert!(
            lo <= hi && hi <= self.len(),
            "view range {lo}..{hi} out of bounds for {} points",
            self.len()
        );
        PointSet {
            dim: self.dim,
            storage: Arc::clone(&self.storage),
            start: self.start + lo * self.dim,
            len: (hi - lo) * self.dim,
        }
    }

    /// True when this set shares its storage allocation with `other`.
    pub fn shares_storage(&self, other: &PointSet) -> bool {
        Arc::ptr_eq(&self.storage, &other.storage)
    }

    /// True when this set is a borrowed view: other sets reference the same
    /// allocation, or it spans a strict subrange of it.
    pub fn is_view(&self) -> bool {
        self.start != 0 || self.len != self.storage.len() || Arc::strong_count(&self.storage) > 1
    }

    /// Host bytes uniquely owned by this set — 0 for borrowed views. The
    /// simulated-cluster accounting uses [`PointSet::mem_bytes`] instead: a
    /// simulated machine holds every byte of its partition even when the
    /// host process shares one allocation across partitions.
    pub fn owned_bytes(&self) -> usize {
        if self.is_view() {
            0
        } else {
            self.storage.capacity() * std::mem::size_of::<f32>()
        }
    }

    /// Ensure unique full-span ownership of the underlying buffer, copying
    /// the viewed range once if it is shared (copy-on-write).
    fn make_owned(&mut self) {
        let spans = self.start == 0 && self.len == self.storage.len();
        let unique = Arc::get_mut(&mut self.storage).is_some();
        if !(spans && unique) {
            let copied: Vec<f32> = self.flat().to_vec();
            self.storage = Arc::new(copied);
            self.start = 0;
        }
    }

    /// Mutable access to the (uniquely owned) backing buffer.
    fn coords_mut(&mut self) -> &mut Vec<f32> {
        self.make_owned();
        Arc::get_mut(&mut self.storage).expect("storage unique after make_owned")
    }

    /// Append one point.
    pub fn push(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim, "row has wrong dimension");
        self.coords_mut().extend_from_slice(row);
        self.len += self.dim;
    }

    /// Append all points of `other` (must agree on dim). `other` may alias
    /// this set's storage: copy-on-write detaches us first, while `other`
    /// keeps borrowing the original allocation.
    pub fn extend(&mut self, other: &PointSet) {
        assert_eq!(self.dim, other.dim);
        self.coords_mut().extend_from_slice(other.flat());
        self.len += other.len;
    }

    /// New set containing the rows at `indices` (in order). A contiguous
    /// ascending run — the common case: partition blocks, prune steps that
    /// drop nothing — returns an O(1) view instead of copying.
    pub fn gather(&self, indices: &[usize]) -> PointSet {
        if !indices.is_empty() && indices.windows(2).all(|w| w[1] == w[0] + 1) {
            let lo = indices[0];
            let hi = lo + indices.len();
            if hi <= self.len() {
                return self.view(lo, hi);
            }
        }
        let mut out = PointSet::with_capacity(self.dim, indices.len());
        for &i in indices {
            out.push(self.row(i));
        }
        out
    }

    /// Split into `parts` nearly-equal contiguous chunks (last may be
    /// shorter). Used by the MapReduce partitioners. Zero-copy: every chunk
    /// is a view aliasing this set's storage.
    ///
    /// # Examples
    ///
    /// ```
    /// use mrcluster::geometry::PointSet;
    ///
    /// let p = PointSet::from_flat(1, (0..10).map(|i| i as f32).collect());
    /// let chunks = p.chunks(3);
    /// assert_eq!(chunks.len(), 3);
    /// assert_eq!(chunks.iter().map(PointSet::len).sum::<usize>(), 10);
    /// assert!(chunks.iter().all(|c| c.shares_storage(&p))); // all views
    /// ```
    pub fn chunks(&self, parts: usize) -> Vec<PointSet> {
        chunk_spans(self.len(), parts)
            .into_iter()
            .map(|(lo, hi)| self.view(lo, hi))
            .collect()
    }

    /// In-place Fisher–Yates shuffle of the rows ("the mappers arbitrarily
    /// partition" — we realize arbitrariness as a seeded shuffle).
    pub fn shuffle(&mut self, rng: &mut crate::util::rng::Rng) {
        let n = self.len();
        let d = self.dim;
        let coords = self.coords_mut();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            if i != j {
                for c in 0..d {
                    coords.swap(i * d + c, j * d + c);
                }
            }
        }
    }

    /// Logical memory footprint of this view in bytes (what a simulated
    /// machine holding this partition is charged by the engine).
    pub fn mem_bytes(&self) -> usize {
        self.len * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ps(rows: &[&[f32]]) -> PointSet {
        let dim = rows[0].len();
        let mut p = PointSet::with_capacity(dim, rows.len());
        for r in rows {
            p.push(r);
        }
        p
    }

    #[test]
    fn construction_and_access() {
        let p = ps(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(p.len(), 3);
        assert_eq!(p.dim(), 2);
        assert_eq!(p.row(1), &[3.0, 4.0]);
        assert_eq!(p.flat().len(), 6);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn bad_flat_panics() {
        PointSet::from_flat(3, vec![1.0; 7]);
    }

    #[test]
    fn gather_preserves_order() {
        let p = ps(&[&[0.0], &[1.0], &[2.0], &[3.0]]);
        let g = p.gather(&[3, 1]);
        assert_eq!(g.row(0), &[3.0]);
        assert_eq!(g.row(1), &[1.0]);
    }

    #[test]
    fn chunks_cover_everything() {
        let p = PointSet::from_flat(1, (0..10).map(|i| i as f32).collect());
        let cs = p.chunks(3);
        assert_eq!(cs.len(), 3);
        let total: usize = cs.iter().map(|c| c.len()).sum();
        assert_eq!(total, 10);
        // Order preserved across chunk boundaries.
        assert_eq!(cs[0].row(0), &[0.0]);
        assert_eq!(cs[2].row(cs[2].len() - 1), &[9.0]);
    }

    #[test]
    fn chunk_spans_match_chunks() {
        for (n, parts) in [(10usize, 3usize), (2, 5), (1, 1), (100, 7), (16, 16)] {
            let p = PointSet::from_flat(1, (0..n).map(|i| i as f32).collect());
            let cs = p.chunks(parts);
            let spans = chunk_spans(n, parts);
            assert_eq!(cs.len(), spans.len());
            for (c, &(lo, hi)) in cs.iter().zip(&spans) {
                assert_eq!(c.len(), hi - lo);
                assert_eq!(c.row(0), p.row(lo));
            }
        }
        assert!(chunk_spans(0, 4).is_empty(), "no empty spans for len 0");
    }

    #[test]
    fn chunks_more_parts_than_points() {
        let p = PointSet::from_flat(1, vec![1.0, 2.0]);
        let cs = p.chunks(5);
        let total: usize = cs.iter().map(|c| c.len()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn chunks_are_zero_copy_views() {
        let p = PointSet::from_flat(2, (0..40).map(|i| i as f32).collect());
        for c in p.chunks(4) {
            assert!(c.shares_storage(&p), "chunk must alias the parent");
            assert!(c.is_view());
            assert_eq!(c.owned_bytes(), 0, "a view owns no bytes");
        }
        // The logical charge is unchanged: chunk bytes sum to the parent's.
        let total: usize = p.chunks(4).iter().map(|c| c.mem_bytes()).sum();
        assert_eq!(total, p.mem_bytes());
    }

    #[test]
    fn view_survives_parent_mutation() {
        let mut p = PointSet::from_flat(1, vec![0.0, 1.0, 2.0, 3.0]);
        let v = p.view(1, 3);
        p.push(&[9.0]); // copy-on-write: must not touch the view
        p.shuffle(&mut Rng::new(3));
        assert_eq!(v.flat(), &[1.0, 2.0]);
        assert!(!v.shares_storage(&p), "mutation must have detached parent");
    }

    #[test]
    fn gather_contiguous_is_view_noncontiguous_copies() {
        let p = PointSet::from_flat(1, (0..8).map(|i| i as f32).collect());
        let run = p.gather(&[2, 3, 4]);
        assert!(run.shares_storage(&p));
        assert_eq!(run.flat(), &[2.0, 3.0, 4.0]);
        let scattered = p.gather(&[0, 2, 4]);
        assert!(!scattered.shares_storage(&p));
        assert_eq!(scattered.flat(), &[0.0, 2.0, 4.0]);
    }

    #[test]
    fn clone_is_cow() {
        let p = PointSet::from_flat(1, vec![1.0, 2.0]);
        let mut c = p.clone();
        assert!(c.shares_storage(&p), "clone is O(1) until mutated");
        c.push(&[3.0]);
        assert_eq!(p.len(), 2);
        assert_eq!(c.len(), 3);
        assert!(!c.shares_storage(&p));
    }

    #[test]
    fn view_of_view_and_equality() {
        let p = PointSet::from_flat(2, (0..12).map(|i| i as f32).collect());
        let v = p.view(1, 5);
        let vv = v.view(1, 3);
        assert_eq!(vv.len(), 2);
        assert_eq!(vv.row(0), p.row(2));
        assert_eq!(vv, p.view(2, 4), "equality is by contents");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = PointSet::from_flat(1, (0..100).map(|i| i as f32).collect());
        let mut rng = Rng::new(1);
        p.shuffle(&mut rng);
        let mut vals: Vec<f32> = p.flat().to_vec();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let expect: Vec<f32> = (0..100).map(|i| i as f32).collect();
        assert_eq!(vals, expect);
        // And it actually moved something.
        assert_ne!(p.flat()[..10], expect[..10]);
    }

    #[test]
    fn extend_appends() {
        let mut a = ps(&[&[1.0, 1.0]]);
        let b = ps(&[&[2.0, 2.0]]);
        a.extend(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.row(1), &[2.0, 2.0]);
    }

    #[test]
    fn extend_from_own_view_is_safe() {
        let mut a = PointSet::from_flat(1, vec![0.0, 1.0, 2.0]);
        let tail = a.view(1, 3);
        a.extend(&tail);
        assert_eq!(a.flat(), &[0.0, 1.0, 2.0, 1.0, 2.0]);
        assert_eq!(tail.flat(), &[1.0, 2.0]);
    }
}
