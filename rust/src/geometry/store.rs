//! Out-of-core point storage: a file-backed sibling of [`PointSet`].
//!
//! [`PointSet`] is an in-RAM view over `Arc`-shared storage; this module
//! adds the storage variant that lets `n ≫ RAM` datasets flow through the
//! same partition boundaries. A [`PointStore`] is either resident
//! (`Mem`, wrapping a [`PointSet`]) or file-backed (`File`, wrapping a
//! [`FileStore`] over the on-disk dataset format), and exposes one
//! chunk-iterator surface: [`PointStore::blocks`] splits the set on
//! *exactly* the row ranges [`PointSet::chunks`] would produce (shared
//! [`chunk_spans`] arithmetic), and each [`StoreBlock`] materializes its
//! rows on demand with [`StoreBlock::load`] — an O(1) zero-copy view for
//! resident data, a bounded read that is dropped after use for file-backed
//! data.
//!
//! Because partition boundaries, row order, and the `f32` little-endian
//! round-trip are all exact, a coordinator run over a `File` store is
//! bit-identical to the same run over a `Mem` store of the same data
//! (property-tested in `rust/tests/prop_ooc.rs`).
//!
//! # Dataset format (v2, `MRCLSTO2`)
//!
//! ```text
//! magic "MRCLSTO2" (8) | version u32 LE | dim u32 LE | n u64 LE |
//! seed u64 LE | n·dim f32 LE row-major coordinates
//! ```
//!
//! The 32-byte header carries provenance (`seed`: the generator seed that
//! produced the payload, 0 for imported data) and is validated on open —
//! magic, version, plausible `dim`, and the exact file length implied by
//! `n·dim` — so a truncated or mislabeled file fails loudly instead of
//! feeding garbage coordinates to a multi-hour run. The legacy headerless
//! `MRCLPTS1` format (`data/loader.rs`) remains readable for resident
//! loads; only this format supports out-of-core runs.
//!
//! # Resident accounting
//!
//! The simulated-cluster charge (`MemSize`, `MRC^0` audits) stays the
//! *logical* partition size — a real machine holds every byte of its
//! block whether the host streamed it or not, and file-backed runs must
//! reproduce the in-memory engine ledger bit-for-bit. What out-of-core
//! execution changes is the *host* side: [`ResidentMeter`] tracks the
//! bytes actually materialized from disk at any instant (loads add,
//! drops subtract), so tests and the E14 experiment can assert that peak
//! host residency stays O(chunk) while the logical dataset is orders of
//! magnitude larger.

use crate::geometry::point::{chunk_spans, PointSet};
use anyhow::{Context, Result};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Magic bytes opening the v2 dataset-store format.
pub const STORE_MAGIC: &[u8; 8] = b"MRCLSTO2";

/// Current dataset-store format version (the only one readable).
pub const STORE_VERSION: u32 = 2;

/// Fixed size of the v2 header preceding the coordinate payload.
pub const STORE_HEADER_BYTES: u64 = 32;

/// The validated header of a v2 dataset file: shape plus provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DatasetHeader {
    /// Point dimensionality.
    pub dim: u32,
    /// Number of points in the payload.
    pub n: u64,
    /// Provenance: the generator seed that produced the payload
    /// (0 for datasets imported from elsewhere).
    pub seed: u64,
}

impl DatasetHeader {
    /// Bytes of coordinate payload this header declares (`n · dim · 4`).
    pub fn payload_bytes(&self) -> u64 {
        self.n * self.dim as u64 * 4
    }

    /// Serialize the 32-byte header.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        w.write_all(STORE_MAGIC)?;
        w.write_all(&STORE_VERSION.to_le_bytes())?;
        w.write_all(&self.dim.to_le_bytes())?;
        w.write_all(&self.n.to_le_bytes())?;
        w.write_all(&self.seed.to_le_bytes())?;
        Ok(())
    }

    /// Read and validate a 32-byte header: magic, version, plausible dim.
    pub fn read_from(r: &mut impl Read) -> Result<DatasetHeader> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("reading dataset magic")?;
        anyhow::ensure!(
            &magic == STORE_MAGIC,
            "bad magic {:?}: not a {} dataset store",
            String::from_utf8_lossy(&magic),
            String::from_utf8_lossy(STORE_MAGIC),
        );
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4).context("reading store version")?;
        let version = u32::from_le_bytes(b4);
        anyhow::ensure!(
            version == STORE_VERSION,
            "unsupported dataset-store version {version} (this build reads {STORE_VERSION})"
        );
        r.read_exact(&mut b4).context("reading dim")?;
        let dim = u32::from_le_bytes(b4);
        anyhow::ensure!(dim > 0 && dim < 1 << 16, "implausible dim {dim}");
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8).context("reading n")?;
        let n = u64::from_le_bytes(b8);
        r.read_exact(&mut b8).context("reading seed")?;
        let seed = u64::from_le_bytes(b8);
        Ok(DatasetHeader { dim, n, seed })
    }
}

/// Host-side residency ledger for a file-backed store: how many payload
/// bytes are materialized in RAM right now, and the worst case seen.
///
/// Loads add their byte count on materialization and subtract it when the
/// [`Resident`] guard drops; `Mem` loads are zero-copy views and charge
/// nothing. This is the *host* measure (the analogue of
/// [`PointSet::owned_bytes`]) — the simulated-machine charge is
/// unchanged, see the module docs.
#[derive(Debug, Default)]
pub struct ResidentMeter {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl ResidentMeter {
    /// Bytes materialized from this store right now.
    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    /// High-water mark of [`ResidentMeter::current`] since the last
    /// [`ResidentMeter::reset_peak`].
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Restart the high-water mark at the current residency.
    pub fn reset_peak(&self) {
        self.peak.store(self.current(), Ordering::Relaxed);
    }

    fn add(&self, bytes: usize) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn sub(&self, bytes: usize) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// A materialized range of store rows; dereferences to [`PointSet`].
///
/// For `Mem` stores this is a zero-copy view; for `File` stores it owns
/// the freshly-read coordinates and its `Drop` returns the bytes to the
/// store's [`ResidentMeter`] — the load/process/drop discipline the
/// out-of-core coordinators follow.
pub struct Resident {
    pts: PointSet,
    meter: Option<Arc<ResidentMeter>>,
    bytes: usize,
}

impl Resident {
    /// The materialized points.
    pub fn points(&self) -> &PointSet {
        &self.pts
    }
}

impl std::ops::Deref for Resident {
    type Target = PointSet;

    fn deref(&self) -> &PointSet {
        &self.pts
    }
}

impl Drop for Resident {
    fn drop(&mut self) {
        if let Some(m) = &self.meter {
            m.sub(self.bytes);
        }
    }
}

/// A file-backed dataset in the v2 store format: a validated header plus
/// the path to re-read ranges from. Cheap to clone; reads open the file
/// per call, so the handle is `Send + Sync` without holding descriptors.
#[derive(Clone, Debug)]
pub struct FileStore {
    path: PathBuf,
    header: DatasetHeader,
    meter: Arc<ResidentMeter>,
}

impl FileStore {
    /// Open and validate a v2 dataset file: header fields plus the exact
    /// file length the header implies (truncation fails here, not mid-run).
    pub fn open(path: &Path) -> Result<FileStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let header = DatasetHeader::read_from(&mut f)
            .with_context(|| format!("reading header of {}", path.display()))?;
        let expect = STORE_HEADER_BYTES + header.payload_bytes();
        let actual = f.metadata()?.len();
        anyhow::ensure!(
            actual == expect,
            "{}: file is {actual} bytes but the header (n = {}, dim = {}) implies {expect}",
            path.display(),
            header.n,
            header.dim,
        );
        Ok(FileStore {
            path: path.to_path_buf(),
            header,
            meter: Arc::new(ResidentMeter::default()),
        })
    }

    /// The validated header.
    pub fn header(&self) -> &DatasetHeader {
        &self.header
    }

    /// The backing file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of points in the store.
    pub fn len(&self) -> usize {
        self.header.n as usize
    }

    /// True when the store holds no points.
    pub fn is_empty(&self) -> bool {
        self.header.n == 0
    }

    /// Point dimensionality.
    pub fn dim(&self) -> usize {
        self.header.dim as usize
    }

    /// The residency ledger shared by every clone of this handle.
    pub fn meter(&self) -> &Arc<ResidentMeter> {
        &self.meter
    }

    /// Read rows `lo..hi` into a fresh owned [`PointSet`] (exact `f32`
    /// little-endian round-trip: the values are bit-identical to what the
    /// writer was handed).
    pub fn read_rows(&self, lo: usize, hi: usize) -> Result<PointSet> {
        assert!(
            lo <= hi && hi <= self.len(),
            "read range {lo}..{hi} out of bounds for {} points",
            self.len()
        );
        let d = self.dim();
        let mut f = std::fs::File::open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;
        f.seek(SeekFrom::Start(STORE_HEADER_BYTES + (lo * d * 4) as u64))?;
        let mut bytes = vec![0u8; (hi - lo) * d * 4];
        f.read_exact(&mut bytes)
            .with_context(|| format!("reading rows {lo}..{hi} of {}", self.path.display()))?;
        let mut coords = Vec::with_capacity((hi - lo) * d);
        for c in bytes.chunks_exact(4) {
            coords.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(PointSet::from_flat(d, coords))
    }
}

/// Incremental writer for the v2 dataset format: create with the declared
/// shape, push rows, finish. Never holds more than the `BufWriter` buffer,
/// so arbitrarily large datasets can be produced in O(1) memory.
pub struct StoreWriter {
    w: BufWriter<std::fs::File>,
    path: PathBuf,
    header: DatasetHeader,
    written: u64,
}

impl StoreWriter {
    /// Create the file and write the header; `n` rows must follow.
    pub fn create(path: &Path, dim: usize, n: usize, seed: u64) -> Result<StoreWriter> {
        assert!(dim > 0, "dim must be positive");
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        let header = DatasetHeader {
            dim: dim as u32,
            n: n as u64,
            seed,
        };
        header.write_to(&mut w)?;
        Ok(StoreWriter {
            w,
            path: path.to_path_buf(),
            header,
            written: 0,
        })
    }

    /// Append one row (must match the declared `dim`).
    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        assert_eq!(row.len(), self.header.dim as usize, "row has wrong dimension");
        for v in row {
            self.w.write_all(&v.to_le_bytes())?;
        }
        self.written += 1;
        Ok(())
    }

    /// Flush, verify the declared row count was written, and reopen the
    /// result as a validated [`FileStore`].
    pub fn finish(mut self) -> Result<FileStore> {
        anyhow::ensure!(
            self.written == self.header.n,
            "{}: wrote {} rows but the header declares {}",
            self.path.display(),
            self.written,
            self.header.n,
        );
        self.w.flush()?;
        drop(self.w);
        FileStore::open(&self.path)
    }
}

/// Storage-variant handle the out-of-core data plane is written against:
/// resident points or a file-backed store, one partitioning surface.
///
/// Coordinators that accept a `&PointStore` run unchanged over both
/// variants; the `Mem` arm costs nothing over a plain [`PointSet`]
/// (loads are zero-copy views), which is how file-backed runs stay
/// bit-identical to in-memory runs — they are the same code path.
#[derive(Clone, Debug)]
pub enum PointStore {
    /// Fully resident points (every load is an O(1) view).
    Mem(PointSet),
    /// File-backed points (loads read, process, drop).
    File(FileStore),
}

impl From<PointSet> for PointStore {
    fn from(ps: PointSet) -> PointStore {
        PointStore::Mem(ps)
    }
}

impl From<FileStore> for PointStore {
    fn from(fs: FileStore) -> PointStore {
        PointStore::File(fs)
    }
}

impl PointStore {
    /// Number of points in the store.
    pub fn len(&self) -> usize {
        match self {
            PointStore::Mem(ps) => ps.len(),
            PointStore::File(fs) => fs.len(),
        }
    }

    /// True when the store holds no points.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            PointStore::Mem(ps) => ps.dim(),
            PointStore::File(fs) => fs.dim(),
        }
    }

    /// Logical bytes of the whole dataset (`len · dim · 4`) — the
    /// `check_mrc0` input-size `N`, independent of what is resident.
    pub fn total_bytes(&self) -> usize {
        self.len() * self.dim() * 4
    }

    /// The residency ledger (`File` stores only; `Mem` loads are views
    /// and there is nothing to meter).
    pub fn meter(&self) -> Option<&Arc<ResidentMeter>> {
        match self {
            PointStore::Mem(_) => None,
            PointStore::File(fs) => Some(fs.meter()),
        }
    }

    /// Materialize rows `lo..hi`: an O(1) view for `Mem`, a metered read
    /// for `File`.
    ///
    /// # Panics
    ///
    /// Panics on an I/O error mid-read (the store was validated on open,
    /// so this means the file changed underneath the run — there is no
    /// sane way to continue a deterministic round from that).
    pub fn load(&self, lo: usize, hi: usize) -> Resident {
        match self {
            PointStore::Mem(ps) => Resident {
                pts: ps.view(lo, hi),
                meter: None,
                bytes: 0,
            },
            PointStore::File(fs) => {
                let pts = fs
                    .read_rows(lo, hi)
                    .expect("out-of-core read failed mid-run");
                let bytes = pts.mem_bytes();
                fs.meter.add(bytes);
                Resident {
                    pts,
                    meter: Some(Arc::clone(&fs.meter)),
                    bytes,
                }
            }
        }
    }

    /// Materialize the whole store (small sets, leader-side baselines).
    pub fn load_all(&self) -> Resident {
        self.load(0, self.len())
    }

    /// Split into `parts` nearly-equal contiguous blocks on *exactly* the
    /// boundaries [`PointSet::chunks`] uses (shared [`chunk_spans`]).
    /// Blocks are descriptors: no coordinates move until
    /// [`StoreBlock::load`].
    pub fn blocks(&self, parts: usize) -> Vec<StoreBlock> {
        chunk_spans(self.len(), parts)
            .into_iter()
            .map(|(lo, hi)| StoreBlock {
                store: self.clone(),
                lo,
                hi,
            })
            .collect()
    }
}

/// One contiguous partition of a [`PointStore`]: the unit a simulated
/// machine holds. Carries only `(store handle, lo, hi)` until loaded.
#[derive(Clone, Debug)]
pub struct StoreBlock {
    store: PointStore,
    /// First row of the block (inclusive).
    pub lo: usize,
    /// One past the last row of the block.
    pub hi: usize,
}

impl StoreBlock {
    /// Number of points in the block.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// True when the block holds no points.
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    /// The owning store.
    pub fn store(&self) -> &PointStore {
        &self.store
    }

    /// Logical bytes a simulated machine holding this partition is
    /// charged — identical to [`PointSet::mem_bytes`] of the same rows,
    /// whether or not the host has them materialized.
    pub fn mem_bytes(&self) -> usize {
        self.len() * self.store.dim() * 4
    }

    /// Materialize the block's rows (view for `Mem`, metered read for
    /// `File`); drop the result to release the residency.
    pub fn load(&self) -> Resident {
        self.store.load(self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("mrcluster_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_ps(n: usize, d: usize) -> PointSet {
        let mut rng = crate::util::rng::Rng::new(7);
        PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
    }

    fn write_ps(path: &Path, ps: &PointSet, seed: u64) -> FileStore {
        let mut w = StoreWriter::create(path, ps.dim(), ps.len(), seed).unwrap();
        for i in 0..ps.len() {
            w.push_row(ps.row(i)).unwrap();
        }
        w.finish().unwrap()
    }

    #[test]
    fn header_roundtrip() {
        let h = DatasetHeader {
            dim: 5,
            n: 1234,
            seed: 99,
        };
        let mut buf = Vec::new();
        h.write_to(&mut buf).unwrap();
        assert_eq!(buf.len() as u64, STORE_HEADER_BYTES);
        let back = DatasetHeader::read_from(&mut &buf[..]).unwrap();
        assert_eq!(back, h);
    }

    #[test]
    fn open_rejects_bad_magic_version_dim_and_truncation() {
        // Wrong magic.
        let p = tmpfile("badmagic.mrc");
        let mut buf = b"NOTMAGIC".to_vec();
        buf.extend_from_slice(&[0u8; 24]);
        std::fs::write(&p, &buf).unwrap();
        assert!(FileStore::open(&p).is_err());

        // Wrong version.
        let p = tmpfile("badver.mrc");
        let mut buf = Vec::new();
        DatasetHeader { dim: 2, n: 1, seed: 0 }.write_to(&mut buf).unwrap();
        buf[8] = 9; // version -> 9
        buf.extend_from_slice(&[0u8; 8]); // 1 row of dim 2
        std::fs::write(&p, &buf).unwrap();
        let e = FileStore::open(&p).unwrap_err();
        assert!(format!("{e:#}").contains("version"), "{e:#}");

        // Zero dim.
        let p = tmpfile("zerodim.mrc");
        let mut buf = Vec::new();
        buf.extend_from_slice(STORE_MAGIC);
        buf.extend_from_slice(&STORE_VERSION.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, &buf).unwrap();
        assert!(FileStore::open(&p).is_err());

        // Truncated payload: header declares 4 rows, file carries 2.
        let p = tmpfile("trunc.mrc");
        let mut buf = Vec::new();
        DatasetHeader { dim: 3, n: 4, seed: 0 }.write_to(&mut buf).unwrap();
        buf.extend_from_slice(&[0u8; 2 * 3 * 4]);
        std::fs::write(&p, &buf).unwrap();
        let e = FileStore::open(&p).unwrap_err();
        assert!(format!("{e:#}").contains("implies"), "{e:#}");
    }

    #[test]
    fn writer_roundtrip_is_bit_exact() {
        let ps = sample_ps(257, 3);
        let fs = write_ps(&tmpfile("rt.mrc"), &ps, 41);
        assert_eq!(fs.len(), 257);
        assert_eq!(fs.dim(), 3);
        assert_eq!(fs.header().seed, 41);
        let back = fs.read_rows(0, 257).unwrap();
        assert_eq!(back, ps, "f32 LE round-trip must be exact");
        // Range reads match the same rows.
        let mid = fs.read_rows(100, 130).unwrap();
        assert_eq!(mid, ps.view(100, 130));
    }

    #[test]
    fn writer_rejects_short_write() {
        let p = tmpfile("short.mrc");
        let mut w = StoreWriter::create(&p, 2, 3, 0).unwrap();
        w.push_row(&[1.0, 2.0]).unwrap();
        assert!(w.finish().is_err(), "1 of 3 declared rows written");
    }

    #[test]
    fn blocks_match_pointset_chunks() {
        let ps = sample_ps(103, 2);
        let fs = write_ps(&tmpfile("blocks.mrc"), &ps, 0);
        for parts in [1usize, 3, 7, 103, 200] {
            let chunks = ps.chunks(parts);
            let blocks = PointStore::from(fs.clone()).blocks(parts);
            assert_eq!(chunks.len(), blocks.len());
            for (c, b) in chunks.iter().zip(&blocks) {
                assert_eq!(b.len(), c.len());
                assert_eq!(b.mem_bytes(), c.mem_bytes());
                assert_eq!(*b.load(), *c, "block rows must equal chunk rows");
            }
        }
    }

    #[test]
    fn mem_loads_are_zero_copy_and_unmetered() {
        let ps = sample_ps(64, 3);
        let store = PointStore::from(ps.clone());
        assert!(store.meter().is_none());
        let blocks = store.blocks(4);
        let r = blocks[1].load();
        assert!(r.points().shares_storage(&ps), "Mem load must be a view");
        assert_eq!(r.points().owned_bytes(), 0);
    }

    #[test]
    fn meter_tracks_load_and_drop() {
        let ps = sample_ps(100, 3);
        let fs = write_ps(&tmpfile("meter.mrc"), &ps, 0);
        let store = PointStore::from(fs);
        let meter = Arc::clone(store.meter().unwrap());
        assert_eq!(meter.current(), 0);
        {
            let a = store.load(0, 50);
            assert_eq!(meter.current(), 50 * 3 * 4);
            let b = store.load(50, 100);
            assert_eq!(meter.current(), 100 * 3 * 4);
            drop(a);
            assert_eq!(meter.current(), 50 * 3 * 4);
            drop(b);
        }
        assert_eq!(meter.current(), 0);
        assert_eq!(meter.peak(), 100 * 3 * 4, "peak saw both chunks resident");
        meter.reset_peak();
        assert_eq!(meter.peak(), 0);
    }
}
