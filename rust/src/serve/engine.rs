//! [`ServeEngine`]: ties the ingest log, the epoch re-solve, and the
//! published-model slot into one long-lived service object.

use super::ingest::IngestLog;
use super::model::{Model, ModelSlot};
use super::query::{QueryEngine, QueryResponse};
use crate::config::{ClusterConfig, ServeConfig};
use crate::coordinator::driver::{make_backend, mr_config};
use crate::coordinator::robust::{mr_coreset_kmedian, solve_summary_kmedian};
use crate::geometry::PointSet;
use crate::mapreduce::MrCluster;
use crate::runtime::ComputeBackend;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What one [`ServeEngine::close_epoch`] call did.
#[derive(Clone, Debug)]
pub struct EpochClose {
    /// The published model (already visible to queries when this returns).
    pub model: Arc<Model>,
    /// Batches the closed epoch had ingested.
    pub batches: u64,
    /// Points the closed epoch had ingested.
    pub points: u64,
    /// Representatives in the epoch sketch the re-solve ran on.
    pub sketch_len: usize,
    /// Sketch entries trimmed as suspected outliers before the final step.
    pub trimmed: usize,
    /// MapReduce rounds the re-solve spent.
    pub rounds: usize,
    /// Wall-clock time of the re-solve + publish.
    pub wall: Duration,
}

/// The serving engine: single-writer ingest, epoch close through the batch
/// coordinator machinery, lock-free-for-readers model publication.
///
/// Concurrency contract: [`ServeEngine::ingest`] and
/// [`ServeEngine::close_epoch`] serialize on the internal ingest lock;
/// queries ([`ServeEngine::query`], or any number of cloned
/// [`QueryEngine`] handles) touch only the [`ModelSlot`] and the shared
/// compute kernels, so they never block ingestion and never observe a torn
/// model. The engine is `Send + Sync`; share it behind an `Arc` to serve
/// from many threads.
pub struct ServeEngine {
    cfg: ClusterConfig,
    serve: ServeConfig,
    backend: Arc<dyn ComputeBackend>,
    ingest: Mutex<IngestLog>,
    slot: Arc<ModelSlot>,
}

impl ServeEngine {
    /// An engine for `dim`-dimensional points, with the compute backend
    /// resolved from `cfg` (kernel-ladder routing included: `exact`/`gemm`
    /// kernels and f64/f32 precision all serve).
    pub fn new(dim: usize, cfg: &ClusterConfig, serve: &ServeConfig) -> ServeEngine {
        ServeEngine::with_backend(dim, cfg, serve, make_backend(cfg))
    }

    /// [`ServeEngine::new`] with an explicit backend (shared across
    /// engines in benches and tests).
    pub fn with_backend(
        dim: usize,
        cfg: &ClusterConfig,
        serve: &ServeConfig,
        backend: Arc<dyn ComputeBackend>,
    ) -> ServeEngine {
        // Constant per-batch compression seed: a compressed batch summary
        // must be a pure function of the batch contents (never of its
        // arrival index) or order invariance would break.
        let log = IngestLog::new(dim, cfg.metric, serve.tau, cfg.seed ^ 0xB47C1);
        ServeEngine {
            cfg: cfg.clone(),
            serve: serve.clone(),
            backend,
            ingest: Mutex::new(log),
            slot: Arc::new(ModelSlot::new()),
        }
    }

    /// Fold one batch into the current epoch. When `serve.epoch_batches`
    /// is non-zero and the batch count reaches it, the epoch closes
    /// automatically and the close report is returned.
    pub fn ingest(&self, batch: &PointSet) -> anyhow::Result<Option<EpochClose>> {
        let auto_close = {
            let mut log = self.ingest.lock().unwrap_or_else(|e| e.into_inner());
            log.ingest(batch, self.backend.as_ref());
            self.serve.epoch_batches > 0 && log.batches() >= self.serve.epoch_batches as u64
        };
        if auto_close {
            return self.close_epoch().map(Some);
        }
        Ok(None)
    }

    /// Close the current epoch: take its sketch, re-solve through the
    /// coordinator machinery, and publish the model by snapshot swap.
    ///
    /// Lossless mode (`serve.tau == 0`) runs the literal one-shot
    /// coreset-k-median pipeline on the epoch's canonical point
    /// arrangement — centers are bit-identical to a batch run on the same
    /// data. Compressed mode re-solves the folded sketch through the same
    /// trim + weighted-local-search leader round the pipeline's round 3
    /// uses. Errors if the epoch is empty.
    pub fn close_epoch(&self) -> anyhow::Result<EpochClose> {
        let (sketch, epoch, batches, points) = {
            let mut log = self.ingest.lock().unwrap_or_else(|e| e.into_inner());
            anyhow::ensure!(
                !log.is_empty(),
                "epoch {} has no ingested points",
                log.epoch()
            );
            log.take_epoch()
        };
        let t0 = Instant::now();
        let mut cluster = MrCluster::new(mr_config(&self.cfg));
        let result = if self.serve.tau == 0 {
            let epoch_points = sketch.reps().points().clone();
            mr_coreset_kmedian(&mut cluster, &epoch_points, &self.cfg, self.backend.as_ref())?
        } else {
            solve_summary_kmedian(&mut cluster, &sketch, &self.cfg)?
        };
        let model = self.slot.publish(Model {
            epoch,
            centers: result.centers,
            metric: self.cfg.metric,
            summary_size: sketch.len(),
            total_weight: crate::summaries::Coreset::total_weight(&sketch),
        });
        Ok(EpochClose {
            model,
            batches,
            points,
            sketch_len: sketch.len(),
            trimmed: result.trimmed,
            rounds: cluster.stats.n_rounds(),
            wall: t0.elapsed(),
        })
    }

    /// Answer one batched query against the current snapshot (`None`
    /// until the first epoch publishes). Shorthand for
    /// [`ServeEngine::query_engine`]`.query(batch)`.
    pub fn query(&self, batch: &PointSet) -> Option<QueryResponse> {
        self.query_engine().query(batch)
    }

    /// A cloneable query handle sharing this engine's model slot and
    /// compute backend — hand one to each serving thread.
    pub fn query_engine(&self) -> QueryEngine {
        QueryEngine::new(Arc::clone(&self.slot), Arc::clone(&self.backend))
    }

    /// The currently published model, if any epoch has closed.
    pub fn snapshot(&self) -> Option<Arc<Model>> {
        self.slot.snapshot()
    }

    /// Batches folded into the open epoch so far.
    pub fn pending_batches(&self) -> u64 {
        self.ingest
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .batches()
    }

    /// Points folded into the open epoch so far.
    pub fn pending_points(&self) -> u64 {
        self.ingest
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .points()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::MetricKind;

    fn tiny_cfg() -> ClusterConfig {
        ClusterConfig {
            k: 3,
            machines: 4,
            ls_max_swaps: 20,
            seed: 11,
            ..Default::default()
        }
    }

    fn stream(n: usize, seed: u64) -> PointSet {
        crate::data::DataGenConfig {
            n,
            k: 3,
            dim: 2,
            sigma: 0.1,
            seed,
            ..Default::default()
        }
        .generate()
        .points
    }

    #[test]
    fn close_on_empty_epoch_errors() {
        let engine = ServeEngine::new(2, &tiny_cfg(), &ServeConfig::default());
        let err = engine.close_epoch().unwrap_err();
        assert!(format!("{err:#}").contains("no ingested points"), "{err:#}");
        assert!(engine.snapshot().is_none());
    }

    #[test]
    fn ingest_close_query_round_trip() {
        let engine = ServeEngine::new(2, &tiny_cfg(), &ServeConfig::default());
        let data = stream(300, 5);
        for chunk in data.chunks(3) {
            engine.ingest(&chunk).unwrap();
        }
        assert_eq!(engine.pending_batches(), 3);
        assert_eq!(engine.pending_points(), 300);
        let close = engine.close_epoch().unwrap();
        assert_eq!(close.model.epoch, 1);
        assert_eq!(close.model.centers.len(), 3);
        assert_eq!(close.points, 300);
        assert_eq!(close.rounds, 3, "summarize + compose + leader solve");
        assert_eq!(engine.pending_points(), 0, "epoch reset");
        let r = engine.query(&data.view(0, 10)).unwrap();
        assert_eq!(r.epoch, 1);
        assert_eq!(r.assign.len(), 10);
        assert!(r.cost.is_finite());
    }

    #[test]
    fn auto_close_fires_on_epoch_batches() {
        let serve = ServeConfig {
            epoch_batches: 2,
            ..Default::default()
        };
        let engine = ServeEngine::new(2, &tiny_cfg(), &serve);
        let data = stream(200, 6);
        assert!(engine.ingest(&data.view(0, 100)).unwrap().is_none());
        let close = engine
            .ingest(&data.view(100, 200))
            .unwrap()
            .expect("second batch must close the epoch");
        assert_eq!(close.model.epoch, 1);
        assert_eq!(close.batches, 2);
        assert_eq!(engine.snapshot().unwrap().epoch, 1);
    }

    #[test]
    fn compressed_mode_serves_with_bounded_sketch() {
        let serve = ServeConfig {
            tau: 8,
            ..Default::default()
        };
        let cfg = ClusterConfig {
            metric: MetricKind::L1,
            ..tiny_cfg()
        };
        let engine = ServeEngine::new(2, &cfg, &serve);
        let data = stream(400, 7);
        for chunk in data.chunks(4) {
            engine.ingest(&chunk).unwrap();
        }
        let close = engine.close_epoch().unwrap();
        assert!(close.sketch_len <= 4 * 8, "tau bound per batch");
        assert_eq!(close.model.metric, MetricKind::L1);
        assert!((close.model.total_weight - 400.0).abs() < 1e-9);
        assert!(engine.query(&data.view(0, 5)).is_some());
    }
}
