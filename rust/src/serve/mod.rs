//! Serving layer: incremental coreset epochs + a concurrent query path.
//!
//! The batch pipelines end at a one-shot solve, but
//! [`Coreset::compose`](crate::summaries::Coreset::compose) is an
//! associative, commutative, bit-deterministic merge — exactly the
//! primitive the composable-coreset line (Ceccarello et al.) uses to turn
//! batch clustering into streaming maintenance. This module builds the
//! long-lived service on it:
//!
//! * [`IngestLog`] folds incoming point batches into the current epoch's
//!   [`CoverageSummary`](crate::summaries::CoverageSummary) sketch. The
//!   fold is compose-shaped but canonicalizes **once per publish**
//!   ([`CoverageSummary::compose_all`](crate::summaries::CoverageSummary::compose_all)),
//!   so a long ingest chain never pays a per-batch re-sort.
//! * [`ServeEngine::close_epoch`] re-solves the sketch through the
//!   existing coordinator machinery (the one-shot coreset-k-median
//!   pipeline in lossless mode, the shared weighted-local-search leader
//!   round in compressed mode) and publishes a [`Model`] by atomic `Arc`
//!   snapshot swap ([`ModelSlot`]).
//! * [`QueryEngine`] answers batched assign/cost queries on the existing
//!   compute kernels against whichever snapshot it captured. Queries never
//!   take the ingest lock and never observe a torn model: a captured
//!   snapshot is an immutable `Arc<Model>`.
//!
//! # Epoch lifecycle
//!
//! ```text
//! ingest(b₁) … ingest(bₙ) ──► close_epoch() ──► publish(Arc<Model>) ──► epoch+1
//!        │                        │                     │
//!   fold into sketch        re-solve sketch      queries swap to the
//!   (no canonicalize)      (coordinator rounds)  new snapshot atomically
//! ```
//!
//! # Bit-identical vs ε-equivalent
//!
//! | `serve.tau` | epoch sketch | re-solved centers |
//! |---|---|---|
//! | `0` (lossless, default) | bit-identical under **any** batch split, arrival order, or regrouping — the sketch is the canonical multiset of the epoch's points | bit-identical to the one-shot batch pipeline on the epoch's canonical point arrangement |
//! | `> 0` (compressed) | bit-identical under batch *reordering* (compose commutativity); ε-equivalent under re-*splitting* (each batch is lossily summarized before folding) | deterministic per batch partition; ε-equivalent across partitions |
//!
//! `rust/tests/prop_serve.rs` property-tests the lossless column and the
//! compressed column's order invariance; the concurrent stress test there
//! proves snapshot isolation (every answer maps to exactly one published
//! epoch).

mod engine;
mod ingest;
mod model;
mod query;

pub use engine::{EpochClose, ServeEngine};
pub use ingest::IngestLog;
pub use model::{Model, ModelSlot};
pub use query::{QueryEngine, QueryResponse};
