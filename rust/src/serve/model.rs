//! [`Model`] and [`ModelSlot`]: the immutable published model and its
//! atomic snapshot-swap cell.

use crate::geometry::{MetricKind, PointSet};
use std::sync::{Arc, RwLock};

/// One published epoch's model: the re-solved centers plus enough
/// provenance to interpret an answer. A `Model` is immutable after
/// publication — queries hold it through an `Arc`, so no field can change
/// underneath an in-flight batch.
#[derive(Clone, Debug)]
pub struct Model {
    /// Epoch id this model was solved from (first epoch is 1).
    pub epoch: u64,
    /// The k centers.
    pub centers: PointSet,
    /// Metric the centers were solved under; queries answer in the same
    /// geometry.
    pub metric: MetricKind,
    /// Representatives in the epoch sketch the re-solve ran on.
    pub summary_size: usize,
    /// Total input weight the sketch represented (= the epoch's point
    /// count in lossless mode).
    pub total_weight: f64,
}

/// The snapshot-swap cell between the epoch-close writer and concurrent
/// query readers.
///
/// The **snapshot-swap contract**: [`ModelSlot::publish`] replaces the
/// slot's `Arc<Model>` under a write lock; [`ModelSlot::snapshot`] clones
/// the `Arc` under a read lock held only for the pointer copy. A reader
/// therefore pays O(1) synchronization per *batch* (not per point), never
/// blocks ingestion (the slot is the only shared state), and can never
/// observe a torn model: whatever `Arc` it captured points at one fully
/// published, immutable epoch — before or after any concurrent swap, never
/// between. `rust/tests/prop_serve.rs` stress-tests the contract under
/// contention.
#[derive(Debug, Default)]
pub struct ModelSlot {
    slot: RwLock<Option<Arc<Model>>>,
}

impl ModelSlot {
    /// An empty slot (no model published yet).
    pub fn new() -> ModelSlot {
        ModelSlot::default()
    }

    /// Atomically swap in a new model; returns the published `Arc`.
    pub fn publish(&self, model: Model) -> Arc<Model> {
        let arc = Arc::new(model);
        let mut slot = self.slot.write().unwrap_or_else(|e| e.into_inner());
        *slot = Some(Arc::clone(&arc));
        arc
    }

    /// Capture the current snapshot, if any epoch has been published.
    pub fn snapshot(&self) -> Option<Arc<Model>> {
        self.slot
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Epoch id of the current snapshot, if any.
    pub fn epoch(&self) -> Option<u64> {
        self.snapshot().map(|m| m.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(epoch: u64) -> Model {
        Model {
            epoch,
            centers: PointSet::from_flat(1, vec![epoch as f32]),
            metric: MetricKind::L2Sq,
            summary_size: 1,
            total_weight: 1.0,
        }
    }

    #[test]
    fn empty_slot_has_no_snapshot() {
        let slot = ModelSlot::new();
        assert!(slot.snapshot().is_none());
        assert!(slot.epoch().is_none());
    }

    #[test]
    fn publish_swaps_and_old_snapshots_stay_valid() {
        let slot = ModelSlot::new();
        slot.publish(model(1));
        let old = slot.snapshot().unwrap();
        slot.publish(model(2));
        // The captured snapshot still reads epoch 1 — immutable under swap.
        assert_eq!(old.epoch, 1);
        assert_eq!(old.centers.row(0), &[1.0]);
        assert_eq!(slot.epoch(), Some(2));
    }
}
