//! [`QueryEngine`]: batched assign/cost queries against a captured model
//! snapshot.

use super::model::{Model, ModelSlot};
use crate::geometry::PointSet;
use crate::runtime::ComputeBackend;
use std::sync::Arc;

/// The answer to one batched query, computed entirely against a single
/// captured snapshot — the whole batch reflects exactly one published
/// epoch (`epoch` says which), never a mix.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    /// Epoch id of the snapshot this batch was answered against.
    pub epoch: u64,
    /// Nearest-center index per query point.
    pub assign: Vec<u32>,
    /// Per-point distance surrogate to the assigned center (same
    /// semantics as [`crate::runtime::AssignOut::sqdist`]: squared
    /// distance for `l2sq`, the true distance for `l2`/`l1`/`chebyshev`,
    /// the `1 − cos θ` surrogate for `cosine`).
    pub dist: Vec<f32>,
    /// Batch cost: the sum of true metric distances (not surrogates) from
    /// each point to its center, accumulated serially in point order —
    /// bit-deterministic at any thread count.
    pub cost: f64,
}

/// A cloneable handle answering batched queries against whichever
/// [`Model`] snapshot each call captures.
///
/// Each [`QueryEngine::query`] call captures the snapshot once, then runs
/// the batch through the configured compute kernel (the same l2sq fast
/// paths, general-metric kernels, and GEMM/f32 ladder rungs the batch
/// pipelines use; large batches parallelize over the shared worker pool).
/// Queries never take the ingest lock, so they never block — and are never
/// blocked by — ingestion; concurrent epoch closes only swap the slot,
/// which the already-captured snapshot is immune to.
#[derive(Clone)]
pub struct QueryEngine {
    slot: Arc<ModelSlot>,
    backend: Arc<dyn ComputeBackend>,
}

impl QueryEngine {
    /// A handle over `slot` answering through `backend`.
    pub fn new(slot: Arc<ModelSlot>, backend: Arc<dyn ComputeBackend>) -> QueryEngine {
        QueryEngine { slot, backend }
    }

    /// Answer one batch against the current snapshot; `None` until the
    /// first epoch publishes.
    pub fn query(&self, batch: &PointSet) -> Option<QueryResponse> {
        let model = self.slot.snapshot()?;
        Some(QueryEngine::answer(&model, self.backend.as_ref(), batch))
    }

    /// The pure per-batch answer function: assign `batch` to `model`'s
    /// centers under `model`'s metric. Public so consistency tests can
    /// serially replay a concurrent run's answers against a pinned model
    /// through the *identical* code path.
    pub fn answer(model: &Model, backend: &dyn ComputeBackend, batch: &PointSet) -> QueryResponse {
        let out = backend.assign_metric(batch, &model.centers, model.metric);
        let cost = out
            .sqdist
            .iter()
            .map(|&s| model.metric.to_dist_f64(s))
            .sum();
        QueryResponse {
            epoch: model.epoch,
            assign: out.idx,
            dist: out.sqdist,
            cost,
        }
    }

    /// Epoch id of the snapshot a query issued now would capture.
    pub fn current_epoch(&self) -> Option<u64> {
        self.slot.epoch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::MetricKind;
    use crate::runtime::NativeBackend;

    fn publish(slot: &ModelSlot, centers: &[f32]) {
        slot.publish(Model {
            epoch: 1,
            centers: PointSet::from_flat(1, centers.to_vec()),
            metric: MetricKind::L2Sq,
            summary_size: centers.len(),
            total_weight: centers.len() as f64,
        });
    }

    #[test]
    fn query_before_first_publish_is_none() {
        let q = QueryEngine::new(Arc::new(ModelSlot::new()), Arc::new(NativeBackend));
        assert!(q.query(&PointSet::from_flat(1, vec![1.0])).is_none());
        assert!(q.current_epoch().is_none());
    }

    #[test]
    fn query_assigns_and_costs_against_the_snapshot() {
        let slot = Arc::new(ModelSlot::new());
        publish(&slot, &[0.0, 10.0]);
        let q = QueryEngine::new(Arc::clone(&slot), Arc::new(NativeBackend));
        let r = q.query(&PointSet::from_flat(1, vec![1.0, 9.0])).unwrap();
        assert_eq!(r.epoch, 1);
        assert_eq!(r.assign, vec![0, 1]);
        // l2sq surrogate is the squared distance; cost is the true metric.
        assert_eq!(r.dist, vec![1.0, 1.0]);
        assert!((r.cost - 2.0).abs() < 1e-9);
    }
}
