//! [`IngestLog`]: fold incoming point batches into the current epoch's
//! coverage-summary sketch.

use crate::geometry::{MetricKind, PointSet};
use crate::runtime::ComputeBackend;
use crate::summaries::{CoverageSummary, WeightedSet};

/// The write side of the serving layer: batches arrive, each is embedded
/// (or compressed) into weighted representatives, and the representatives
/// accumulate into the current epoch's sketch.
///
/// The accumulation is exactly a [`Coreset::compose`] fold of per-batch
/// summaries, with the canonicalization deferred to [`IngestLog::sketch`] —
/// one sort per publish instead of one per batch
/// ([`CoverageSummary::compose_all`] proves the deferral byte-identical to
/// the eager fold). Two regimes:
///
/// * **lossless** (`tau == 0`, the default): every batch point becomes a
///   unit-weight representative. The epoch sketch is then the canonical
///   multiset of all points ingested this epoch — a pure function of the
///   data multiset, so *any* partition, permutation, or regrouping of the
///   stream into batches yields bit-identical sketch bytes.
/// * **compressed** (`tau > 0`): each batch is first summarized down to at
///   most `tau` weighted representatives
///   ([`CoverageSummary::build_metric`], fixed seed). Memory stays bounded
///   by `tau · batches`; the sketch is invariant to batch *arrival order*
///   (composition is commutative) but only ε-equivalent under
///   re-splitting, since the per-batch compression sees different blocks.
///
/// The log is single-writer: [`crate::serve::ServeEngine`] wraps it in a
/// `Mutex` that queries never take.
///
/// [`Coreset::compose`]: crate::summaries::Coreset::compose
#[derive(Clone, Debug)]
pub struct IngestLog {
    metric: MetricKind,
    /// Per-batch compression size; `0` = lossless unit-weight embedding.
    tau: usize,
    /// Seed for the per-batch compression skeleton. Constant across
    /// batches, so a compressed batch summary is a pure function of the
    /// batch contents — the property order invariance rests on.
    seed: u64,
    /// Current epoch id (first epoch is 1).
    epoch: u64,
    batches: u64,
    points: u64,
    /// Accumulated representatives, in arrival order (canonicalized only
    /// when the sketch is taken).
    raw: WeightedSet,
    /// Running max of the per-batch coverage radii (0 while lossless).
    radius: f64,
}

impl IngestLog {
    /// An empty log for `dim`-dimensional points under `metric`, with the
    /// given per-batch compression size (`tau == 0` = lossless) and
    /// compression seed.
    pub fn new(dim: usize, metric: MetricKind, tau: usize, seed: u64) -> IngestLog {
        IngestLog {
            metric,
            tau,
            seed,
            epoch: 1,
            batches: 0,
            points: 0,
            raw: WeightedSet::with_capacity(dim, 0),
            radius: 0.0,
        }
    }

    /// Fold one batch into the current epoch. Lossless mode appends every
    /// point at unit weight; compressed mode first summarizes the batch to
    /// at most `tau` representatives through `backend`'s assignment kernel.
    pub fn ingest(&mut self, batch: &PointSet, backend: &dyn ComputeBackend) {
        assert_eq!(batch.dim(), self.raw.dim(), "ingest batch dim mismatch");
        self.batches += 1;
        self.points += batch.len() as u64;
        if batch.is_empty() {
            return;
        }
        if self.tau == 0 {
            self.raw.extend(&WeightedSet::unit(batch.clone()));
        } else {
            let summary = CoverageSummary::build_metric(
                batch,
                self.tau.min(batch.len()),
                self.seed,
                backend,
                self.metric,
            );
            self.radius = self.radius.max(summary.radius());
            self.raw.extend(summary.reps());
        }
    }

    /// The current epoch's sketch: the accumulated representatives,
    /// canonicalized now (the once-per-publish sort), with the running max
    /// coverage radius. Does not reset the log.
    pub fn sketch(&self) -> CoverageSummary {
        CoverageSummary::from_weighted(self.raw.clone(), self.radius)
    }

    /// Close the current epoch: return `(sketch, epoch id, batches,
    /// points)` and reset the log for the next epoch (epoch id advances by
    /// one; counters and accumulator clear).
    pub fn take_epoch(&mut self) -> (CoverageSummary, u64, u64, u64) {
        let sketch = self.sketch();
        let closed = (sketch, self.epoch, self.batches, self.points);
        self.epoch += 1;
        self.batches = 0;
        self.points = 0;
        self.raw = WeightedSet::with_capacity(self.raw.dim(), 0);
        self.radius = 0.0;
        closed
    }

    /// Current epoch id (the id the *next* close will publish).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Batches folded into the current epoch so far.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Points ingested into the current epoch so far.
    pub fn points(&self) -> u64 {
        self.points
    }

    /// Representatives currently accumulated (pre-canonicalization).
    pub fn pending_reps(&self) -> usize {
        self.raw.len()
    }

    /// True when nothing has been ingested this epoch.
    pub fn is_empty(&self) -> bool {
        self.points == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::summaries::Coreset;

    fn batch(coords: &[f32]) -> PointSet {
        PointSet::from_flat(1, coords.to_vec())
    }

    #[test]
    fn lossless_sketch_is_the_canonical_point_multiset() {
        let mut log = IngestLog::new(1, MetricKind::L2Sq, 0, 7);
        log.ingest(&batch(&[3.0, 1.0]), &NativeBackend);
        log.ingest(&batch(&[2.0]), &NativeBackend);
        let s = log.sketch();
        assert_eq!(s.len(), 3);
        assert_eq!(s.total_weight(), 3.0);
        assert_eq!(s.radius(), 0.0, "lossless sketch has no coverage error");
        assert_eq!(s.reps().row(0), &[1.0]);
        assert_eq!(s.reps().row(2), &[3.0]);
        assert_eq!((log.batches(), log.points()), (2, 3));
    }

    #[test]
    fn take_epoch_resets_and_advances() {
        let mut log = IngestLog::new(1, MetricKind::L2Sq, 0, 7);
        log.ingest(&batch(&[1.0]), &NativeBackend);
        let (s, epoch, batches, points) = log.take_epoch();
        assert_eq!((s.len(), epoch, batches, points), (1, 1, 1, 1));
        assert!(log.is_empty());
        assert_eq!(log.epoch(), 2);
        assert_eq!(log.pending_reps(), 0);
    }

    #[test]
    fn compressed_mode_bounds_reps_and_tracks_radius() {
        let mut log = IngestLog::new(1, MetricKind::L2Sq, 2, 7);
        log.ingest(&batch(&[0.0, 0.1, 0.2, 5.0]), &NativeBackend);
        log.ingest(&batch(&[9.0, 9.1, 9.2]), &NativeBackend);
        assert!(log.pending_reps() <= 4, "2 reps per batch max");
        let s = log.sketch();
        assert_eq!(s.total_weight(), 7.0, "weights still cover every point");
        assert!(s.radius() > 0.0, "compression has coverage error");
    }

    #[test]
    fn empty_batches_count_but_add_nothing() {
        let mut log = IngestLog::new(2, MetricKind::L1, 3, 7);
        log.ingest(&PointSet::with_capacity(2, 0), &NativeBackend);
        assert_eq!(log.batches(), 1);
        assert!(log.is_empty());
        assert_eq!(log.sketch().len(), 0);
    }
}
