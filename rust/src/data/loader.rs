//! Dataset I/O: CSV (human-readable, small data) and a raw little-endian
//! f32 binary format (fast cache for the multi-million-point Figure 2
//! runs). Both load fully resident; the out-of-core v2 store format with
//! a provenance header lives in `geometry/store.rs`.

use crate::geometry::PointSet;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Load a headerless CSV of floats; every row must have the same width.
/// Lines starting with `#` and blank lines are skipped.
///
/// The parse is buffered and line-at-a-time: values append straight into
/// one flat coordinate buffer (no per-row allocation), pre-sized from the
/// file length and the first data line. A ragged row fails with the file
/// and 1-based line number.
pub fn load_csv(path: &Path) -> Result<PointSet> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let file_bytes = f.metadata().map(|m| m.len() as usize).unwrap_or(0);
    let reader = BufReader::new(f);
    let mut dim: Option<usize> = None;
    let mut coords: Vec<f32> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line =
            line.with_context(|| format!("{}, line {}: read error", path.display(), lineno + 1))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let before = coords.len();
        for s in t.split(',') {
            coords.push(s.trim().parse::<f32>().with_context(|| {
                format!("{}, line {}: bad float {s:?}", path.display(), lineno + 1)
            })?);
        }
        let width = coords.len() - before;
        match dim {
            None => {
                dim = Some(width);
                // Pre-size the output from the file length and the first
                // data line (line.len() + 1 counts its newline); later rows
                // are the same width, so this lands within a few percent.
                let per_line = line.len() + 1;
                coords.reserve((file_bytes / per_line + 1) * width);
            }
            Some(d) => anyhow::ensure!(
                width == d,
                "{}, line {}: ragged row — {} values, expected {}",
                path.display(),
                lineno + 1,
                width,
                d
            ),
        }
    }
    let dim = dim.with_context(|| format!("{}: empty csv", path.display()))?;
    Ok(PointSet::from_flat(dim, coords))
}

/// Write points as CSV.
pub fn save_csv(path: &Path, ps: &PointSet) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..ps.len() {
        let row = ps.row(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                w.write_all(b",")?;
            }
            write!(w, "{v}")?;
        }
        w.write_all(b"\n")?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"MRCLPTS1";

/// Write points in the raw binary format:
/// magic(8) | dim u32 LE | n u64 LE | n*dim f32 LE.
pub fn save_f32_bin(path: &Path, ps: &PointSet) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(ps.dim() as u32).to_le_bytes())?;
    w.write_all(&(ps.len() as u64).to_le_bytes())?;
    for v in ps.flat() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary format written by [`save_f32_bin`].
///
/// The header is validated before any payload is trusted: magic prefix,
/// format version (the trailing magic byte), a positive plausible `dim`,
/// and the exact file length the declared `(n, dim)` implies — so a
/// truncated download or a file whose payload disagrees with its header
/// fails with a precise message instead of a short-read panic or silent
/// garbage.
pub fn load_f32_bin(path: &Path) -> Result<PointSet> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let total = f
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{}: reading magic", path.display()))?;
    anyhow::ensure!(
        magic[..7] == BIN_MAGIC[..7],
        "{}: bad magic {:?} — not a mrcluster points file",
        path.display(),
        String::from_utf8_lossy(&magic),
    );
    anyhow::ensure!(
        magic[7] == BIN_MAGIC[7],
        "{}: unsupported points-format version {:?} (this build reads version {})",
        path.display(),
        magic[7] as char,
        BIN_MAGIC[7] as char,
    );
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)
        .with_context(|| format!("{}: reading dim", path.display()))?;
    let dim = u32::from_le_bytes(b4) as usize;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)
        .with_context(|| format!("{}: reading n", path.display()))?;
    let n = u64::from_le_bytes(b8);
    anyhow::ensure!(dim > 0, "{}: header declares zero dim", path.display());
    anyhow::ensure!(dim < 1 << 16, "{}: implausible dim {dim}", path.display());
    let payload = n
        .checked_mul(dim as u64)
        .and_then(|v| v.checked_mul(4))
        .with_context(|| {
            format!("{}: header shape n = {n}, dim = {dim} overflows", path.display())
        })?;
    let expect = 8 + 4 + 8 + payload;
    anyhow::ensure!(
        total == expect,
        "{}: file is {total} bytes but the header (n = {n}, dim = {dim}) implies {expect} — \
         truncated or dim/payload mismatch",
        path.display(),
    );
    let n = n as usize;
    let mut bytes = vec![0u8; n * dim * 4];
    r.read_exact(&mut bytes)?;
    let mut coords = Vec::with_capacity(n * dim);
    for c in bytes.chunks_exact(4) {
        coords.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(PointSet::from_flat(dim, coords))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mrcluster_loader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip() {
        // Rust's f32 Display prints the shortest representation that
        // parses back to the same bits, so save/load round-trips exactly.
        let ps = PointSet::from_flat(3, vec![1.0, 2.5, -3.0, 0.0, 1e-4, 9.0]);
        let p = tmpfile("rt.csv");
        save_csv(&p, &ps).unwrap();
        let back = load_csv(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.dim(), 3);
        assert_eq!(back, ps, "csv round-trip must be value-exact");
    }

    #[test]
    fn csv_roundtrip_random_values() {
        let mut rng = crate::util::rng::Rng::new(17);
        let ps = PointSet::from_flat(4, (0..4 * 100).map(|_| rng.f32() * 2e3 - 1e3).collect());
        let p = tmpfile("rt_rand.csv");
        save_csv(&p, &ps).unwrap();
        assert_eq!(load_csv(&p).unwrap(), ps);
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let p = tmpfile("comments.csv");
        std::fs::write(&p, "# header\n\n1,2\n3,4\n").unwrap();
        let ps = load_csv(&p).unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps.dim(), 2);
    }

    #[test]
    fn csv_rejects_ragged_rows_naming_file_and_line() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "1,2\n3,4,5\n").unwrap();
        let e = format!("{:#}", load_csv(&p).unwrap_err());
        assert!(e.contains("ragged"), "{e}");
        assert!(e.contains("line 2"), "must name the offending line: {e}");
        assert!(e.contains("ragged.csv"), "must name the file: {e}");
    }

    #[test]
    fn csv_rejects_bad_float() {
        let p = tmpfile("bad.csv");
        std::fs::write(&p, "1,abc\n").unwrap();
        assert!(load_csv(&p).is_err());
    }

    #[test]
    fn bin_roundtrip() {
        let ps = PointSet::from_flat(2, (0..64).map(|i| i as f32 * 0.25).collect());
        let p = tmpfile("rt.bin");
        save_f32_bin(&p, &ps).unwrap();
        let back = load_f32_bin(&p).unwrap();
        assert_eq!(back, ps);
    }

    #[test]
    fn bin_rejects_bad_magic() {
        let p = tmpfile("badmagic.bin");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        assert!(load_f32_bin(&p).is_err());
    }

    #[test]
    fn bin_rejects_unknown_version() {
        let ps = PointSet::from_flat(1, vec![1.0]);
        let p = tmpfile("badver.bin");
        save_f32_bin(&p, &ps).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[7] = b'9'; // MRCLPTS1 -> MRCLPTS9
        std::fs::write(&p, &bytes).unwrap();
        let e = format!("{:#}", load_f32_bin(&p).unwrap_err());
        assert!(e.contains("version"), "{e}");
    }

    #[test]
    fn bin_rejects_truncated_payload() {
        let ps = PointSet::from_flat(2, (0..32).map(|i| i as f32).collect());
        let p = tmpfile("trunc.bin");
        save_f32_bin(&p, &ps).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 12]).unwrap();
        let e = format!("{:#}", load_f32_bin(&p).unwrap_err());
        assert!(e.contains("truncated"), "{e}");
    }

    #[test]
    fn bin_rejects_dim_payload_mismatch() {
        // Header says dim = 3, payload carries dim = 2 rows: the implied
        // length disagrees with the file and the loader must say so
        // instead of misparsing the coordinates.
        let ps = PointSet::from_flat(2, (0..20).map(|i| i as f32).collect());
        let p = tmpfile("dimmismatch.bin");
        save_f32_bin(&p, &ps).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8..12].copy_from_slice(&3u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let e = format!("{:#}", load_f32_bin(&p).unwrap_err());
        assert!(e.contains("implies"), "{e}");
    }

    #[test]
    fn bin_rejects_zero_dim() {
        let ps = PointSet::from_flat(1, vec![1.0, 2.0]);
        let p = tmpfile("zerodim.bin");
        save_f32_bin(&p, &ps).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8..12].copy_from_slice(&0u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let e = format!("{:#}", load_f32_bin(&p).unwrap_err());
        assert!(e.contains("zero dim"), "{e}");
    }
}
