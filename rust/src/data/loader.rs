//! Dataset I/O: CSV (human-readable, small data) and a raw little-endian
//! f32 binary format (fast cache for the multi-million-point Figure 2 runs).

use crate::geometry::PointSet;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Load a headerless CSV of floats; every row must have the same width.
/// Lines starting with `#` and blank lines are skipped.
pub fn load_csv(path: &Path) -> Result<PointSet> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut dim: Option<usize> = None;
    let mut coords: Vec<f32> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let row: Vec<f32> = t
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f32>()
                    .with_context(|| format!("line {}: bad float {s:?}", lineno + 1))
            })
            .collect::<Result<_>>()?;
        match dim {
            None => dim = Some(row.len()),
            Some(d) => anyhow::ensure!(
                row.len() == d,
                "line {}: width {} != {}",
                lineno + 1,
                row.len(),
                d
            ),
        }
        coords.extend_from_slice(&row);
    }
    let dim = dim.context("empty csv")?;
    Ok(PointSet::from_flat(dim, coords))
}

/// Write points as CSV.
pub fn save_csv(path: &Path, ps: &PointSet) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..ps.len() {
        let row = ps.row(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                w.write_all(b",")?;
            }
            write!(w, "{v}")?;
        }
        w.write_all(b"\n")?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"MRCLPTS1";

/// Write points in the raw binary format:
/// magic(8) | dim u32 LE | n u64 LE | n*dim f32 LE.
pub fn save_f32_bin(path: &Path, ps: &PointSet) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(ps.dim() as u32).to_le_bytes())?;
    w.write_all(&(ps.len() as u64).to_le_bytes())?;
    for v in ps.flat() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary format written by [`save_f32_bin`].
pub fn load_f32_bin(path: &Path) -> Result<PointSet> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == BIN_MAGIC, "bad magic: not a mrcluster points file");
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let dim = u32::from_le_bytes(b4) as usize;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    anyhow::ensure!(dim > 0 && dim < 1 << 16, "implausible dim {dim}");
    let mut bytes = vec![0u8; n * dim * 4];
    r.read_exact(&mut bytes)?;
    let mut coords = Vec::with_capacity(n * dim);
    for c in bytes.chunks_exact(4) {
        coords.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(PointSet::from_flat(dim, coords))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mrcluster_loader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip() {
        let ps = PointSet::from_flat(3, vec![1.0, 2.5, -3.0, 0.0, 1e-4, 9.0]);
        let p = tmpfile("rt.csv");
        save_csv(&p, &ps).unwrap();
        let back = load_csv(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.dim(), 3);
        for i in 0..2 {
            for j in 0..3 {
                assert!((back.row(i)[j] - ps.row(i)[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let p = tmpfile("comments.csv");
        std::fs::write(&p, "# header\n\n1,2\n3,4\n").unwrap();
        let ps = load_csv(&p).unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps.dim(), 2);
    }

    #[test]
    fn csv_rejects_ragged_rows() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "1,2\n3,4,5\n").unwrap();
        assert!(load_csv(&p).is_err());
    }

    #[test]
    fn csv_rejects_bad_float() {
        let p = tmpfile("bad.csv");
        std::fs::write(&p, "1,abc\n").unwrap();
        assert!(load_csv(&p).is_err());
    }

    #[test]
    fn bin_roundtrip() {
        let ps = PointSet::from_flat(2, (0..64).map(|i| i as f32 * 0.25).collect());
        let p = tmpfile("rt.bin");
        save_f32_bin(&p, &ps).unwrap();
        let back = load_f32_bin(&p).unwrap();
        assert_eq!(back, ps);
    }

    #[test]
    fn bin_rejects_bad_magic() {
        let p = tmpfile("badmagic.bin");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        assert!(load_f32_bin(&p).is_err());
    }
}
