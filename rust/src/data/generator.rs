//! The paper's synthetic workload generator (§4.2), exactly:
//!
//! * `k` planted centers positioned uniformly at random in the unit cube;
//! * each point is assigned to cluster `i` with probability proportional to
//!   a Zipf weight (`alpha = 0` ⇒ uniform sizes — the Figure 1/2 setting;
//!   larger `alpha` ⇒ more skewed sizes);
//! * a point is its planted center plus a `N(0, sigma²)` offset per
//!   coordinate (global standard deviation `sigma = 0.1` in the paper).
//!
//! Beyond the paper, [`DataGenConfig::contamination`] replaces a fraction
//! of points with far-away uniform outliers (the adversary of the robust
//! pipelines, labeled [`OUTLIER_LABEL`]); `contamination = 0` reproduces
//! the paper's generator bit-for-bit.
//!
//! The planted centers and per-point cluster labels are kept so experiments
//! can report "ground-truth" costs alongside algorithm costs.

use crate::geometry::store::{FileStore, StoreWriter};
use crate::geometry::PointSet;
use crate::util::rng::{Rng, Zipf};
use std::path::Path;

/// Configuration for [`DataGenConfig::generate`].
#[derive(Clone, Debug)]
pub struct DataGenConfig {
    /// Number of points (the paper sweeps 10^4 .. 10^7).
    pub n: usize,
    /// Number of planted clusters (paper: 25).
    pub k: usize,
    /// Dimensionality (paper: 3).
    pub dim: usize,
    /// Global std-dev of the point spread around its center (paper: 0.1).
    pub sigma: f64,
    /// Zipf skew of cluster sizes (paper: 0 in the reported figures).
    pub alpha: f64,
    /// Fraction of points replaced by uniform far outliers in
    /// `[-OUTLIER_SPREAD, 1 + OUTLIER_SPREAD]^dim` (labeled
    /// [`OUTLIER_LABEL`]). 0 (the default) reproduces the paper's clean
    /// generator bit-for-bit — the contamination coin is only flipped when
    /// this is positive, so existing seeds replay unchanged.
    pub contamination: f64,
    /// PRNG seed.
    pub seed: u64,
}

/// Label marking a contaminated (outlier) point in [`Dataset::labels`].
pub const OUTLIER_LABEL: u32 = u32::MAX;

/// Half-width of the outlier box beyond the unit cube: contaminated
/// coordinates are uniform in `[-OUTLIER_SPREAD, 1 + OUTLIER_SPREAD]`, an
/// order of magnitude outside the planted-blob geometry.
pub const OUTLIER_SPREAD: f32 = 5.0;

impl Default for DataGenConfig {
    fn default() -> Self {
        DataGenConfig {
            n: 10_000,
            k: 25,
            dim: 3,
            sigma: 0.1,
            alpha: 0.0,
            contamination: 0.0,
            seed: 42,
        }
    }
}

/// A generated dataset: points plus planting metadata.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// The generated points.
    pub points: PointSet,
    /// Planted cluster centers (k x dim).
    pub planted_centers: PointSet,
    /// Planted cluster label of each point ([`OUTLIER_LABEL`] for
    /// contaminated points).
    pub labels: Vec<u32>,
    /// The configuration that generated this dataset.
    pub config: DataGenConfig,
}

impl DataGenConfig {
    /// The single RNG-draw core shared by [`DataGenConfig::generate`] and
    /// [`DataGenConfig::generate_stream`]: draws the planted centers, then
    /// streams each point `(row, label)` to `emit` in seed-determined
    /// order. One code path means the two writers cannot drift — a
    /// streamed file is bit-identical to the in-memory points by
    /// construction (and property-tested in the module tests).
    ///
    /// The per-point draws do not depend on `n`, so a longer run is
    /// prefix-identical to a shorter one with the same seed.
    fn run_core<E>(&self, mut emit: E) -> anyhow::Result<PointSet>
    where
        E: FnMut(&[f32], u32) -> anyhow::Result<()>,
    {
        assert!(self.k >= 1, "need at least one cluster");
        assert!(self.n >= 1, "need at least one point");
        assert!(
            (0.0..1.0).contains(&self.contamination),
            "contamination must be in [0, 1)"
        );
        let mut rng = Rng::new(self.seed);

        // Planted centers: uniform in the unit cube.
        let mut centers = PointSet::with_capacity(self.dim, self.k);
        let mut row = vec![0.0f32; self.dim];
        for _ in 0..self.k {
            for c in row.iter_mut() {
                *c = rng.f32();
            }
            centers.push(&row);
        }

        // Cluster sizes: Zipf-weighted categorical per point.
        let zipf = Zipf::new(self.k, self.alpha);
        let box_width = 1.0 + 2.0 * OUTLIER_SPREAD;
        for _ in 0..self.n {
            // Short-circuit keeps the clean (contamination = 0) RNG stream
            // identical to the paper-faithful generator.
            if self.contamination > 0.0 && rng.bernoulli(self.contamination) {
                for r in row.iter_mut() {
                    *r = rng.f32() * box_width - OUTLIER_SPREAD;
                }
                emit(&row, OUTLIER_LABEL)?;
                continue;
            }
            let c = zipf.sample(&mut rng);
            let center = centers.row(c);
            for (j, r) in row.iter_mut().enumerate() {
                *r = center[j] + (self.sigma * rng.normal()) as f32;
            }
            emit(&row, c as u32)?;
        }
        Ok(centers)
    }

    /// Generate the dataset this configuration describes (deterministic in
    /// the seed).
    pub fn generate(&self) -> Dataset {
        let mut points = PointSet::with_capacity(self.dim, self.n);
        let mut labels = Vec::with_capacity(self.n);
        let centers = self
            .run_core(|row, label| {
                labels.push(label);
                points.push(row);
                Ok(())
            })
            .expect("in-memory emit cannot fail");

        Dataset {
            points,
            planted_centers: centers,
            labels,
            config: self.clone(),
        }
    }

    /// Generate straight to a v2 dataset-store file (`geometry/store.rs`)
    /// without ever materializing the point set: O(1) memory at any `n`,
    /// so datasets far beyond RAM can be produced. Same seed ⇒ the file
    /// payload is bit-identical to [`DataGenConfig::generate`]'s points
    /// (and a larger `n` is prefix-identical to a smaller one). The
    /// header records `self.seed` as provenance. Labels and planted
    /// centers are not stored — re-derive them by re-running the
    /// generator at the recorded seed.
    pub fn generate_stream(&self, path: &Path) -> anyhow::Result<FileStore> {
        let mut w = StoreWriter::create(path, self.dim, self.n, self.seed)?;
        self.run_core(|row, _label| w.push_row(row))?;
        w.finish()
    }
}

impl Dataset {
    /// Number of contaminated (outlier) points the generator produced —
    /// the natural `z` budget for the robust pipelines.
    pub fn n_outliers(&self) -> usize {
        self.labels.iter().filter(|&&l| l == OUTLIER_LABEL).count()
    }

    /// The k-median cost of the *planted* centers — a handy (not optimal)
    /// reference line for experiment reports. With contamination, the
    /// outliers' (large) distances are included; use
    /// [`crate::metrics::kmedian_cost_with_outliers`] to exclude them.
    pub fn planted_cost_median(&self) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..self.points.len() {
            let mut best = f32::INFINITY;
            for c in 0..self.planted_centers.len() {
                let d = crate::geometry::metric::sq_dist(
                    self.points.row(i),
                    self.planted_centers.row(c),
                );
                if d < best {
                    best = d;
                }
            }
            acc += (best.max(0.0) as f64).sqrt();
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let cfg = DataGenConfig {
            n: 1000,
            k: 10,
            ..Default::default()
        };
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.points.len(), 1000);
        assert_eq!(a.planted_centers.len(), 10);
        assert_eq!(a.labels.len(), 1000);
        assert_eq!(a.points, b.points, "same seed must replay identically");
    }

    #[test]
    fn different_seed_different_data() {
        let a = DataGenConfig { seed: 1, ..Default::default() }.generate();
        let b = DataGenConfig { seed: 2, ..Default::default() }.generate();
        assert_ne!(a.points, b.points);
    }

    #[test]
    fn uniform_alpha_balances_clusters() {
        let cfg = DataGenConfig {
            n: 50_000,
            k: 5,
            alpha: 0.0,
            seed: 3,
            ..Default::default()
        };
        let d = cfg.generate();
        let mut counts = vec![0usize; 5];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        for &c in &counts {
            assert!(
                (c as f64 - 10_000.0).abs() < 600.0,
                "alpha=0 should balance: {counts:?}"
            );
        }
    }

    #[test]
    fn skewed_alpha_unbalances_clusters() {
        let cfg = DataGenConfig {
            n: 50_000,
            k: 5,
            alpha: 1.5,
            seed: 3,
            ..Default::default()
        };
        let d = cfg.generate();
        let mut counts = vec![0usize; 5];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts[0] > 2 * counts[4], "zipf skew expected: {counts:?}");
    }

    #[test]
    fn points_near_their_planted_center() {
        let cfg = DataGenConfig {
            n: 2000,
            k: 4,
            sigma: 0.01,
            seed: 7,
            ..Default::default()
        };
        let d = cfg.generate();
        for i in 0..d.points.len() {
            let c = d.labels[i] as usize;
            let dist = crate::geometry::metric::sq_dist(
                d.points.row(i),
                d.planted_centers.row(c),
            )
            .sqrt();
            // 3 coords * sigma=0.01 each: distances beyond 0.1 are ~10 sigma.
            assert!(dist < 0.1, "point {i} too far from its center: {dist}");
        }
    }

    #[test]
    fn contamination_plants_far_outliers() {
        let cfg = DataGenConfig {
            n: 5000,
            k: 5,
            sigma: 0.05,
            contamination: 0.02,
            seed: 9,
            ..Default::default()
        };
        let d = cfg.generate();
        let z = d.n_outliers();
        // ~100 expected; Bernoulli spread is tight at n = 5000.
        assert!((60..=140).contains(&z), "outlier count {z}");
        let mut outside = 0usize;
        for i in 0..d.points.len() {
            let is_outlier = d.labels[i] == OUTLIER_LABEL;
            let row = d.points.row(i);
            let far = row.iter().any(|&c| !(-0.5..=1.5).contains(&c));
            if is_outlier && far {
                outside += 1;
            }
            if !is_outlier {
                assert!(!far, "clean point {i} escaped the blob geometry");
            }
        }
        // The outlier box is 11 units wide vs the unit cube: the vast
        // majority of outliers must land clearly outside.
        assert!(outside * 10 >= z * 7, "{outside}/{z} outliers far");
    }

    #[test]
    fn zero_contamination_is_bit_identical_to_clean_generator() {
        let clean = DataGenConfig {
            n: 2000,
            k: 6,
            seed: 31,
            ..Default::default()
        };
        let explicit = DataGenConfig {
            contamination: 0.0,
            ..clean.clone()
        };
        assert_eq!(clean.generate().points, explicit.generate().points);
        assert_eq!(clean.generate().n_outliers(), 0);
    }

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mrcluster_generator_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn stream_matches_in_memory_bit_for_bit() {
        // Contamination > 0 exercises both emit arms of the shared core.
        let cfg = DataGenConfig {
            n: 2000,
            k: 7,
            contamination: 0.05,
            alpha: 0.8,
            seed: 23,
            ..Default::default()
        };
        let fs = cfg.generate_stream(&tmpfile("stream.mrc")).unwrap();
        assert_eq!(fs.len(), 2000);
        assert_eq!(fs.header().seed, 23, "header must carry provenance");
        let back = fs.read_rows(0, fs.len()).unwrap();
        assert_eq!(back, cfg.generate().points, "streamed file must be bit-identical");
    }

    #[test]
    fn stream_is_prefix_identical_across_n() {
        let long = DataGenConfig {
            n: 1500,
            k: 9,
            seed: 5,
            ..Default::default()
        };
        let short = DataGenConfig { n: 400, ..long.clone() };
        let fs = long.generate_stream(&tmpfile("prefix.mrc")).unwrap();
        let prefix = fs.read_rows(0, 400).unwrap();
        assert_eq!(
            prefix,
            short.generate().points,
            "per-point draws must not depend on n"
        );
    }

    #[test]
    fn planted_cost_is_reasonable() {
        let cfg = DataGenConfig {
            n: 5000,
            k: 8,
            sigma: 0.05,
            seed: 11,
            ..Default::default()
        };
        let d = cfg.generate();
        let per_point = d.planted_cost_median() / 5000.0;
        // E[|N(0, sigma^2 I_3)|] ~ sigma * sqrt(8/pi) ~ 1.6 sigma; planted
        // centers are near-optimal so the per-point cost should be close.
        assert!(per_point > 0.02 && per_point < 0.2, "per-point {per_point}");
    }
}
