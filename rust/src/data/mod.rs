//! Dataset generation and I/O.

pub mod generator;
pub mod loader;

pub use generator::{DataGenConfig, Dataset, OUTLIER_LABEL, OUTLIER_SPREAD};
pub use loader::{load_csv, load_f32_bin, save_csv, save_f32_bin};
