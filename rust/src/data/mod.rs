//! Dataset generation and I/O.
//!
//! [`DataGenConfig::generate`] materializes the §4.2 workload in RAM;
//! [`DataGenConfig::generate_stream`] writes the identical point stream to
//! the out-of-core v2 dataset format (`crate::geometry::store`) in O(1)
//! memory. The loaders here cover the resident CSV / legacy-binary
//! formats.

pub mod generator;
pub mod loader;

pub use generator::{DataGenConfig, Dataset, OUTLIER_LABEL, OUTLIER_SPREAD};
pub use loader::{load_csv, load_f32_bin, save_csv, save_f32_bin};
