//! `Iterative-Sample` (Algorithm 1) — sequential form.
//!
//! Loop until few points remain (|R| below a threshold):
//!   1. add each remaining point to the sample `S` independently with
//!      probability `c_s · k · n^ε · log n / |R|`;
//!   2. add each remaining point to a witness set `H` with probability
//!      `c_h · n^ε · log n / |R|`;
//!   3. pick the pivot `v` = the `(c_p · log n)`-th farthest point of `H`
//!      from `S` (Algorithm 2);
//!   4. drop from `R` every point closer to `S` than `v`.
//! Return `C = S ∪ R`.
//!
//! Propositions 2.1/2.2: w.h.p. `O(1/ε)` iterations and
//! `|C| = O(k · n^ε · log n / ε)`.
//!
//! ## Constants profiles
//!
//! The paper's proofs use constants (9, 4, 8, 4) *with* the `log n` factors
//! — chosen to make the Chernoff bounds go through, not to be run. (With
//! n = 10⁷, k = 25, ε = 0.1 they would sample ≈ 80k points while the
//! paper's own experiments cluster samples in seconds.) We therefore ship
//! two profiles:
//!
//! * [`SampleConstants::theory`] — the literal Algorithm 1 constants;
//!   used by the property tests that verify Propositions 2.1/2.2.
//! * [`SampleConstants::practical`] — same structure with the `log n`
//!   factors dropped and unit coefficients, matching the sample sizes the
//!   paper's experiment section implies. This is the Figure 1/2 default.

use crate::geometry::{MetricKind, PointSet};
use crate::runtime::ComputeBackend;
use crate::sampling::select::select_pivot;
use crate::util::{log_n, rng::Rng};

/// Coefficients of Algorithm 1 (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct SampleConstants {
    /// Coefficient of the S-sample probability (paper: 9).
    pub c_sample: f64,
    /// Coefficient of the H-sample probability (paper: 4).
    pub c_witness: f64,
    /// Coefficient of the pivot rank (paper: 8).
    pub c_pivot: f64,
    /// Coefficient of the loop threshold (paper: 4/ε with the ε applied
    /// separately — here just the constant 4).
    pub c_threshold: f64,
    /// Multiply the `log n` factors in (true for the paper's theory form).
    pub use_log_n: bool,
}

impl SampleConstants {
    /// The literal constants of Algorithm 1.
    pub fn theory() -> Self {
        SampleConstants {
            c_sample: 9.0,
            c_witness: 4.0,
            c_pivot: 8.0,
            c_threshold: 4.0,
            use_log_n: true,
        }
    }

    /// Practical profile: drops the `log n` factors (see module docs).
    pub fn practical() -> Self {
        SampleConstants {
            c_sample: 2.0,
            c_witness: 2.0,
            c_pivot: 2.0,
            c_threshold: 2.0,
            use_log_n: false,
        }
    }

    fn logn(&self, n: usize) -> f64 {
        if self.use_log_n {
            log_n(n)
        } else {
            1.0
        }
    }

    /// S-inclusion probability at remaining-set size `r` (clamped to 1).
    pub fn p_sample(&self, n: usize, k: usize, eps: f64, r: usize) -> f64 {
        let p = self.c_sample * k as f64 * (n as f64).powf(eps) * self.logn(n) / r as f64;
        p.min(1.0)
    }

    /// H-inclusion probability at remaining-set size `r` (clamped to 1).
    pub fn p_witness(&self, n: usize, eps: f64, r: usize) -> f64 {
        let p = self.c_witness * (n as f64).powf(eps) * self.logn(n) / r as f64;
        p.min(1.0)
    }

    /// Pivot rank (≥ 1).
    pub fn pivot_rank(&self, n: usize) -> usize {
        (self.c_pivot * self.logn(n)).ceil().max(1.0) as usize
    }

    /// Loop threshold: stop when `|R| ≤ threshold`.
    pub fn threshold(&self, n: usize, k: usize, eps: f64) -> usize {
        let t = self.c_threshold / eps * k as f64 * (n as f64).powf(eps) * self.logn(n);
        t.ceil() as usize
    }
}

/// Configuration of one Iterative-Sample run.
#[derive(Clone, Debug)]
pub struct IterativeSampleConfig {
    /// Number of centers the downstream algorithm will pick.
    pub k: usize,
    /// The paper's ε parameter (0 < ε < δ/2); experiments use 0.1.
    pub epsilon: f64,
    /// Constants profile (theory-literal or practical).
    pub constants: SampleConstants,
    /// The metric space `d(x, S)` is maintained in. The sampler's analysis
    /// (Propositions 2.1/2.2) is metric-free — only the pivot *threshold*
    /// semantics need a metric, and any registered one works.
    pub metric: MetricKind,
    /// PRNG seed.
    pub seed: u64,
    /// Safety cap on loop iterations (the theory says O(1/ε)).
    pub max_iters: usize,
}

impl Default for IterativeSampleConfig {
    fn default() -> Self {
        IterativeSampleConfig {
            k: 25,
            epsilon: 0.1,
            constants: SampleConstants::practical(),
            metric: MetricKind::L2Sq,
            seed: 0,
            max_iters: 200,
        }
    }
}

/// Per-iteration diagnostics (used by the sample-stats experiment, E4).
#[derive(Clone, Debug)]
pub struct IterationStats {
    /// |R| entering the iteration.
    pub remaining_before: usize,
    /// Points Bernoulli-sampled into the batch.
    pub sampled: usize,
    /// Witness points drawn for the pivot choice.
    pub witnesses: usize,
    /// The chosen pivot distance (0 when no pivot was selected).
    pub pivot_dist: f32,
    /// Points pruned (sampled or well-represented).
    pub dropped: usize,
}

/// Output of Iterative-Sample.
#[derive(Clone, Debug)]
pub struct SampleResult {
    /// The sample `C = S ∪ R` as points.
    pub sample: PointSet,
    /// Indices of `C` into the input set.
    pub indices: Vec<usize>,
    /// While-loop iterations executed.
    pub iterations: usize,
    /// Per-iteration diagnostics, one entry per iteration.
    pub iter_stats: Vec<IterationStats>,
}

/// Run sequential Iterative-Sample over `points`.
///
/// `backend` computes the d(x, S) updates (the hot loop); distances are
/// maintained incrementally against each new sample batch, so the total
/// work is O(Σ_iters |R_iter| · |ΔS_iter| · d).
pub fn iterative_sample(
    points: &PointSet,
    cfg: &IterativeSampleConfig,
    backend: &dyn ComputeBackend,
) -> SampleResult {
    let n = points.len();
    let mut rng = Rng::new(cfg.seed);
    let threshold = cfg.constants.threshold(n, cfg.k, cfg.epsilon).max(1);

    // Remaining points and their current distance to S (∞ until S exists).
    let mut alive: Vec<usize> = (0..n).collect();
    let mut dist: Vec<f32> = vec![f32::INFINITY; n];
    let mut sample_indices: Vec<usize> = Vec::new();
    let mut iter_stats = Vec::new();
    let mut iterations = 0usize;

    while alive.len() > threshold && iterations < cfg.max_iters {
        iterations += 1;
        let r = alive.len();
        let ps = cfg.constants.p_sample(n, cfg.k, cfg.epsilon, r);
        let ph = cfg.constants.p_witness(n, cfg.epsilon, r);

        // Step 1+2: independent Bernoulli sampling of S-batch and H.
        let mut batch_idx: Vec<usize> = Vec::new();
        let mut h_idx: Vec<usize> = Vec::new();
        for &i in &alive {
            if rng.bernoulli(ps) {
                batch_idx.push(i);
            }
            if rng.bernoulli(ph) {
                h_idx.push(i);
            }
        }
        if batch_idx.is_empty() {
            // Extremely unlikely unless probabilities underflow; force one
            // sample so the loop always progresses.
            batch_idx.push(alive[rng.below(alive.len())]);
        }

        // Update d(x, S) for remaining points against the new batch only.
        let batch = points.gather(&batch_idx);
        let alive_ps = points.gather(&alive);
        let nd = backend.min_dist_metric(&alive_ps, &batch, cfg.metric);
        for (pos, &i) in alive.iter().enumerate() {
            if nd[pos] < dist[i] {
                dist[i] = nd[pos];
            }
        }
        sample_indices.extend_from_slice(&batch_idx);

        // Step 3: pivot from H's distances to S.
        let h_dists: Vec<f32> = h_idx.iter().map(|&i| dist[i]).collect();
        let rank = cfg.constants.pivot_rank(n);
        let pivot = match select_pivot(&h_dists, rank) {
            Some(p) => p,
            None => {
                // Empty H: skip the prune (keep only removing sampled pts).
                let in_batch: std::collections::HashSet<usize> =
                    batch_idx.iter().copied().collect();
                alive.retain(|i| !in_batch.contains(i));
                iter_stats.push(IterationStats {
                    remaining_before: r,
                    sampled: batch_idx.len(),
                    witnesses: 0,
                    pivot_dist: f32::NAN,
                    dropped: 0,
                });
                continue;
            }
        };

        // Step 4: drop well-represented points (d(x,S) < pivot) and all
        // newly sampled points (they are in S now).
        let before = alive.len();
        let in_batch: std::collections::HashSet<usize> =
            batch_idx.iter().copied().collect();
        alive.retain(|&i| dist[i] >= pivot && !in_batch.contains(&i));
        let dropped = before - alive.len();

        iter_stats.push(IterationStats {
            remaining_before: r,
            sampled: batch_idx.len(),
            witnesses: h_idx.len(),
            pivot_dist: pivot,
            dropped,
        });
    }

    // C = S ∪ R.
    let mut indices = sample_indices;
    indices.extend_from_slice(&alive);
    // Dedup while preserving order (a point can be sampled once only — the
    // retain above removes batch members — but be defensive).
    let mut seen = std::collections::HashSet::new();
    indices.retain(|&i| seen.insert(i));

    SampleResult {
        sample: points.gather(&indices),
        indices,
        iterations,
        iter_stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::runtime::NativeBackend;

    fn run(n: usize, k: usize, eps: f64, constants: SampleConstants, seed: u64) -> SampleResult {
        let data = DataGenConfig {
            n,
            k,
            seed,
            ..Default::default()
        }
        .generate();
        let cfg = IterativeSampleConfig {
            k,
            epsilon: eps,
            constants,
            seed: seed + 1,
            ..Default::default()
        };
        iterative_sample(&data.points, &cfg, &NativeBackend)
    }

    #[test]
    fn returns_valid_indices_no_dups() {
        let res = run(5000, 10, 0.2, SampleConstants::practical(), 1);
        let mut sorted = res.indices.clone();
        sorted.sort_unstable();
        let len = sorted.len();
        sorted.dedup();
        assert_eq!(sorted.len(), len, "duplicate indices in sample");
        assert!(sorted.iter().all(|&i| i < 5000));
        assert_eq!(res.sample.len(), res.indices.len());
    }

    #[test]
    fn sample_is_sublinear_with_practical_constants() {
        let n = 20_000;
        let res = run(n, 10, 0.2, SampleConstants::practical(), 2);
        assert!(
            res.sample.len() < n / 4,
            "sample {} out of {n} is not sublinear",
            res.sample.len()
        );
        assert!(res.sample.len() >= 10, "sample must be at least k");
    }

    #[test]
    fn iterations_bounded_by_o_one_over_eps() {
        // Proposition 2.1: O(1/ε) iterations w.h.p. Allow a 4x constant.
        for (eps, seed) in [(0.2, 3u64), (0.4, 4u64)] {
            let res = run(30_000, 5, eps, SampleConstants::theory(), seed);
            let bound = (4.0 / eps).ceil() as usize + 2;
            assert!(
                res.iterations <= bound,
                "eps={eps}: {} iterations > bound {bound}",
                res.iterations
            );
        }
    }

    #[test]
    fn theory_sample_size_matches_proposition_2_2() {
        // Proposition 2.2: |C| = O(k n^ε log n / ε).
        let n = 30_000usize;
        let k = 5;
        let eps = 0.3;
        let res = run(n, k, eps, SampleConstants::theory(), 5);
        let bound = 8.0 / eps * k as f64 * (n as f64).powf(eps) * (n as f64).ln();
        assert!(
            (res.sample.len() as f64) <= bound,
            "sample {} > bound {bound}",
            res.sample.len()
        );
    }

    #[test]
    fn remaining_shrinks_geometrically() {
        let res = run(50_000, 5, 0.3, SampleConstants::theory(), 6);
        for w in res.iter_stats.windows(2) {
            assert!(
                w[1].remaining_before < w[0].remaining_before,
                "R must shrink every iteration"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(8000, 8, 0.2, SampleConstants::practical(), 7);
        let b = run(8000, 8, 0.2, SampleConstants::practical(), 7);
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn tiny_input_returns_everything() {
        // n below the threshold: the loop never runs; C = V.
        let res = run(50, 10, 0.1, SampleConstants::theory(), 8);
        assert_eq!(res.sample.len(), 50);
        assert_eq!(res.iterations, 0);
    }
}
