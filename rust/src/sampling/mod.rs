//! The paper's core contribution: `Iterative-Sample` (Algorithms 1–2).
//!
//! This module is the *sequential* formulation (§2.1) — the logic shared by
//! the MapReduce version in [`crate::coordinator::mr_iterative_sample`],
//! which runs the identical iteration structure with the point set
//! partitioned across simulated machines.

pub mod iterative_sample;
pub mod select;

pub use iterative_sample::{
    iterative_sample, IterativeSampleConfig, SampleConstants, SampleResult,
};
pub use select::select_pivot;
