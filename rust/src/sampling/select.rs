//! `Select(H, S)` — Algorithm 2: the pivot choice.
//!
//! Order the witness sample `H` by distance to the current sample `S`
//! (farthest first) and return the element at the `(pivot_rank)`-th
//! position (the paper uses `8·log n`). Every remaining point closer to `S`
//! than the pivot is then considered "well represented" and dropped.
//!
//! Lemma 3.2: w.h.p. the pivot's rank among all remaining points lies in
//! `[|R|/n^ε, 4|R|/n^ε]`, so each iteration shrinks `R` by ~`n^ε`.

/// Given the distances `h_dists = d(h, S)` for each `h ∈ H`, return the
/// pivot *distance*: the `rank`-th largest (1-based; rank clamps to |H|).
/// Returns `None` when `H` is empty (callers then skip the prune step).
pub fn select_pivot(h_dists: &[f32], rank: usize) -> Option<f32> {
    if h_dists.is_empty() {
        return None;
    }
    let mut sorted: Vec<f32> = h_dists.to_vec();
    // Farthest first.
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    let idx = rank.max(1).min(sorted.len()) - 1;
    Some(sorted[idx])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_rank_th_farthest() {
        let d = vec![1.0, 5.0, 3.0, 4.0, 2.0];
        assert_eq!(select_pivot(&d, 1), Some(5.0));
        assert_eq!(select_pivot(&d, 2), Some(4.0));
        assert_eq!(select_pivot(&d, 5), Some(1.0));
    }

    #[test]
    fn rank_clamps() {
        let d = vec![1.0, 2.0];
        assert_eq!(select_pivot(&d, 100), Some(1.0));
        assert_eq!(select_pivot(&d, 0), Some(2.0));
    }

    #[test]
    fn empty_is_none() {
        assert_eq!(select_pivot(&[], 3), None);
    }

    #[test]
    fn handles_ties() {
        let d = vec![2.0, 2.0, 2.0];
        assert_eq!(select_pivot(&d, 2), Some(2.0));
    }
}
