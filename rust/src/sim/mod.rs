//! Discrete-event cluster simulation: simulated wall-clock for every
//! MapReduce round, with contended networks and heterogeneous hosts.
//!
//! The real engine (`mapreduce/`) executes rounds on actual threads and
//! measures them with `Instant` — numbers that vary run to run. This
//! module adds a *deterministic timing observer*: given the round's
//! deterministic facts (byte counts per task, pre-drawn attempt counts
//! from the fate stream, seeded host speeds), it replays the round as a
//! discrete-event simulation over a modeled cluster and reports a
//! simulated wall-clock that is a pure function of `(inputs, seed,
//! sim.* config)` — bit-identical across repeats, thread counts, and
//! machines.
//!
//! ## Determinism contract
//!
//! * **Observation, never control flow.** The simulation consumes the
//!   engine's byte counts and fates; nothing flows back. Clustering
//!   outputs, round counts, shuffle bytes, and MRC⁰ verdicts are
//!   bit-identical with `sim.enabled` on or off (asserted by the
//!   scenario matrix).
//! * **Own RNG stream.** Host speeds are drawn from `sim.seed` at
//!   cluster construction — the fault stream in `mapreduce/recovery.rs`
//!   and the data RNG are never touched.
//! * **No ambient nondeterminism.** No `Instant`, no wall clock, no
//!   `HashMap` anywhere under `sim/` (checked by a property test);
//!   events are totally ordered by `(time, seq)`; floating-point work
//!   happens in a fixed order.
//!
//! ## Round shapes
//!
//! * [`ClusterSim::machine_round`] — the engine's resident-partition
//!   round: an optional broadcast of the round's closure payload from
//!   the leader to every participating host, per-host FIFO execution of
//!   that host's tasks, then a gather flow per task output back to the
//!   leader. Gather incast at the leader's ingress link is where
//!   large-cluster rounds hurt.
//! * [`ClusterSim::shuffle_round`] — map compute, egress flows over the
//!   source uplinks (shuffle write), a barrier, ingress flows over the
//!   destination uplinks (shuffle read), reduce compute.
//! * [`ClusterSim::leader_round`] — sequential leader-side work.
//!
//! A task with `attempts = 1 + failures` simply computes `attempts`
//! times as long — lost attempts rerun serially on their host, so
//! injected faults stretch the simulated critical path exactly where
//! lineage replay stretches the real one. Stragglers are *emergent*:
//! a slow host (drawn from [`Heterogeneity`]) or a contended uplink
//! delays that host's chain and the round waits on it; the legacy
//! `straggler_factor` multiplier plays no part in `sim_wallclock`.

pub mod engine;
pub mod host;
pub mod network;
pub mod placement;

pub use engine::{EventQueue, SimTime, TraceEvent, TraceKind};
pub use host::Heterogeneity;
pub use network::{NetSim, NetworkKind, NetworkModel};
pub use placement::{Placement, Topology};

use std::collections::VecDeque;
use std::time::Duration;

/// The `sim.*` configuration block: everything the simulated cluster
/// needs, with `enabled: false` (no simulation, zero overhead) as the
/// default.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Master switch; when off, `MrCluster` records `sim_wallclock = 0`.
    pub enabled: bool,
    /// Contention model (`sim.network`): constant | shared | topology.
    pub network: NetworkKind,
    /// Rack count for the topology model (`sim.racks`).
    pub racks: usize,
    /// Fabric/uplink oversubscription factor (`sim.oversub`, >= 1.0).
    pub oversub: f64,
    /// Per-host NIC bandwidth in megabits/s (`sim.nic_mbps`).
    pub nic_mbps: f64,
    /// Per-host compute throughput in megabytes of task input processed
    /// per second at speed 1.0 (`sim.compute_mbps`).
    pub compute_mbps: f64,
    /// Flow start latency in microseconds (`sim.latency_us`) — charged
    /// once per flow, so it taxes round-heavy pipelines.
    pub latency_us: f64,
    /// Host speed distribution (`sim.hetero`).
    pub hetero: Heterogeneity,
    /// Task→host placement strategy (`sim.placement`).
    pub placement: Placement,
    /// Seed of the simulation's private RNG stream (`sim.seed`).
    pub seed: u64,
    /// Record per-round event traces (tests; off in production runs).
    pub record_trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            enabled: false,
            network: NetworkKind::Constant,
            racks: 1,
            oversub: 1.0,
            nic_mbps: 1000.0,
            compute_mbps: 500.0,
            latency_us: 500.0,
            hetero: Heterogeneity::None,
            placement: Placement::RoundRobin,
            seed: 0x51D0,
            record_trace: false,
        }
    }
}

impl SimConfig {
    /// NIC bandwidth in bytes/second.
    pub fn nic_bps(&self) -> f64 {
        self.nic_mbps * 1e6 / 8.0
    }

    /// Compute throughput in bytes/second at speed 1.0.
    pub fn compute_bps(&self) -> f64 {
        self.compute_mbps * 1e6
    }

    /// Flow start latency as simulated time.
    pub fn latency(&self) -> SimTime {
        SimTime::from_secs_f64(self.latency_us * 1e-6)
    }
}

/// One task's deterministic work description, as the engine reports it:
/// bytes in, bytes out, and the pre-drawn attempt count.
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskSpec {
    /// Input bytes one attempt processes.
    pub work_bytes: usize,
    /// Output bytes the surviving attempt ships (gather or shuffle).
    pub out_bytes: usize,
    /// Total attempts executed (`1 + failures` from the fate stream);
    /// 0 is treated as 1.
    pub attempts: usize,
}

impl TaskSpec {
    /// Convenience constructor.
    pub fn new(work_bytes: usize, out_bytes: usize, attempts: usize) -> TaskSpec {
        TaskSpec { work_bytes, out_bytes, attempts }
    }
}

/// The simulation's verdict on one round.
#[derive(Clone, Debug)]
pub struct RoundSim {
    /// Simulated wall-clock of the round (last event's timestamp).
    pub wallclock: Duration,
    /// Critical-path lower bound: no schedule could beat the slowest
    /// single host chain or the slowest uncontended flow (minus 1µs of
    /// rounding headroom).
    pub lower_bound: Duration,
    /// Serial upper bound: all compute plus all flows back to back
    /// (plus 1µs of rounding headroom). Fair sharing is work-conserving,
    /// so the simulated round can never exceed it.
    pub upper_bound: Duration,
    /// Event trace in processing order (empty unless
    /// `SimConfig::record_trace`).
    pub trace: Vec<TraceEvent>,
}

/// The simulated cluster: topology, link table, and per-host speeds.
/// Construction is pure; each round method replays one round and is
/// `&self` — the simulator carries no cross-round mutable state, so a
/// round's timing depends only on its own inputs.
#[derive(Clone, Debug)]
pub struct ClusterSim {
    cfg: SimConfig,
    topo: Topology,
    model: NetworkModel,
    speeds: Vec<f64>,
}

impl ClusterSim {
    /// Build the simulated cluster for `hosts` machines, drawing host
    /// speeds from `cfg.hetero` under `cfg.seed`.
    pub fn new(cfg: &SimConfig, hosts: usize) -> ClusterSim {
        let topo = Topology::new(hosts, cfg.racks);
        let speeds = cfg.hetero.draw_speeds(topo.hosts, cfg.seed);
        ClusterSim::with_speeds_topo(cfg, topo, speeds)
    }

    /// Build with explicit per-host speeds (host count = `speeds.len()`)
    /// — the hook the analytic oracle tests use.
    pub fn with_speeds(cfg: &SimConfig, speeds: Vec<f64>) -> ClusterSim {
        let topo = Topology::new(speeds.len(), cfg.racks);
        ClusterSim::with_speeds_topo(cfg, topo, speeds)
    }

    fn with_speeds_topo(cfg: &SimConfig, topo: Topology, speeds: Vec<f64>) -> ClusterSim {
        assert_eq!(speeds.len(), topo.hosts);
        let model = NetworkModel::new(cfg.network, topo, cfg.nic_bps(), cfg.oversub);
        ClusterSim { cfg: cfg.clone(), topo, model, speeds }
    }

    /// The drawn per-host speeds, in host order.
    pub fn speeds(&self) -> &[f64] {
        &self.speeds
    }

    /// The simulated cluster shape.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Seconds one task's whole attempt chain computes on `host`.
    fn compute_secs(&self, spec: &TaskSpec, host: usize) -> f64 {
        spec.work_bytes as f64 * spec.attempts.max(1) as f64
            / (self.cfg.compute_bps() * self.speeds[host])
    }

    /// Simulate a resident-partition ("machine") round: broadcast of
    /// `broadcast_bytes` to each participating host, per-host FIFO
    /// compute of `tasks` (task `i` placed by `sim.placement`), and a
    /// gather flow of each task's output back to the leader.
    pub fn machine_round(&self, tasks: &[TaskSpec], broadcast_bytes: usize) -> RoundSim {
        let mut run = Run::new(self);
        for (i, spec) in tasks.iter().enumerate() {
            let h = self.cfg.placement.host_for(i, &self.topo);
            run.tasks.push(TaskRt {
                host: h as u32,
                compute: SimTime::from_secs_f64(self.compute_secs(spec, h)),
                out_bytes: spec.out_bytes as f64,
                in_bytes: 0.0,
                kind: TaskKind::Gathered,
            });
            run.hosts[h].ready.push_back(i as u32);
        }
        run.outputs_pending = tasks.len();

        // Bounds, from the same primitives the event loop uses.
        let lat = self.cfg.latency_us * 1e-6;
        let mut per_host = vec![0.0f64; self.topo.hosts];
        let (mut lower, mut upper) = (0.0f64, 0.0f64);
        for (i, spec) in tasks.iter().enumerate() {
            let h = self.cfg.placement.host_for(i, &self.topo);
            per_host[h] += self.compute_secs(spec, h);
            if h != 0 && spec.out_bytes > 0 {
                let route = self.model.route_to_leader(h);
                let solo = lat + self.model.solo_secs(&route, spec.out_bytes as f64);
                lower = lower.max(solo);
                upper += solo;
            }
        }
        for h in 0..self.topo.hosts {
            lower = lower.max(per_host[h]);
            upper += per_host[h];
            if broadcast_bytes > 0 && h != 0 && !run.hosts[h].ready.is_empty() {
                let solo = lat
                    + self
                        .model
                        .solo_secs(&self.model.route_from_leader(h), broadcast_bytes as f64);
                lower = lower.max(solo);
                upper += solo;
            }
        }

        // t = 0: leader computes immediately; other hosts wait for the
        // broadcast (if there is one).
        for h in 0..self.topo.hosts {
            if run.hosts[h].ready.is_empty() {
                continue;
            }
            if broadcast_bytes > 0 && h != 0 {
                run.hosts[h].gate = true;
                let route = self.model.route_from_leader(h);
                run.launch_flow(route, broadcast_bytes as f64, FlowTag::Broadcast(h as u32));
            } else {
                run.open_gate(h);
            }
        }
        run.finish(lower, upper)
    }

    /// Simulate a shuffle round: `map` tasks compute and write their
    /// outputs over the source uplinks; when the last byte lands, each
    /// `reduce` task's input crosses the destination uplink and its
    /// compute runs. Reduce task `r`'s transfer and compute are both
    /// sized by its `work_bytes` (the bytes it receives).
    pub fn shuffle_round(&self, map: &[TaskSpec], reduce: &[TaskSpec]) -> RoundSim {
        let mut run = Run::new(self);
        for (i, spec) in map.iter().enumerate() {
            let h = self.cfg.placement.host_for(i, &self.topo);
            run.tasks.push(TaskRt {
                host: h as u32,
                compute: SimTime::from_secs_f64(self.compute_secs(spec, h)),
                out_bytes: spec.out_bytes as f64,
                in_bytes: 0.0,
                kind: TaskKind::Map,
            });
            run.hosts[h].ready.push_back(i as u32);
        }
        for (r, spec) in reduce.iter().enumerate() {
            let h = self.cfg.placement.host_for(r, &self.topo);
            let id = run.tasks.len() as u32;
            run.tasks.push(TaskRt {
                host: h as u32,
                compute: SimTime::from_secs_f64(self.compute_secs(spec, h)),
                out_bytes: 0.0,
                in_bytes: spec.work_bytes as f64,
                kind: TaskKind::Reduce,
            });
            run.reduce_ids.push(id);
        }
        run.map_out_pending = map.len();
        run.reduces_pending = reduce.len();

        let lat = self.cfg.latency_us * 1e-6;
        let mut per_host = vec![0.0f64; self.topo.hosts];
        let (mut lower, mut upper) = (0.0f64, 0.0f64);
        for (i, spec) in map.iter().enumerate() {
            let h = self.cfg.placement.host_for(i, &self.topo);
            per_host[h] += self.compute_secs(spec, h);
            if spec.out_bytes > 0 {
                let solo = lat
                    + self
                        .model
                        .solo_secs(&self.model.route_shuffle_out(h), spec.out_bytes as f64);
                lower = lower.max(solo);
                upper += solo;
            }
        }
        for (r, spec) in reduce.iter().enumerate() {
            let h = self.cfg.placement.host_for(r, &self.topo);
            per_host[h] += self.compute_secs(spec, h);
            if spec.work_bytes > 0 {
                let solo = lat
                    + self
                        .model
                        .solo_secs(&self.model.route_shuffle_in(h), spec.work_bytes as f64);
                lower = lower.max(solo);
                upper += solo;
            }
        }
        for v in &per_host {
            lower = lower.max(*v);
            upper += *v;
        }

        if map.is_empty() {
            run.fire_barrier();
        } else {
            for h in 0..self.topo.hosts {
                if !run.hosts[h].ready.is_empty() {
                    run.open_gate(h);
                }
            }
        }
        run.finish(lower, upper)
    }

    /// Simulate a leader-only round: `work_bytes × attempts` of compute
    /// on host 0, no network.
    pub fn leader_round(&self, work_bytes: usize, attempts: usize) -> RoundSim {
        let spec = TaskSpec::new(work_bytes, 0, attempts);
        let secs = self.compute_secs(&spec, 0);
        let t = SimTime::from_secs_f64(secs);
        let mut trace = Vec::new();
        if self.cfg.record_trace {
            trace.push(TraceEvent { time: SimTime::ZERO, kind: TraceKind::TaskStart, a: 0, b: 0 });
            trace.push(TraceEvent { time: t, kind: TraceKind::TaskDone, a: 0, b: 0 });
        }
        RoundSim {
            wallclock: t.as_duration(),
            lower_bound: Duration::from_nanos(t.0.saturating_sub(SLACK_NS)),
            upper_bound: Duration::from_nanos(t.0 + SLACK_NS),
            trace,
        }
    }
}

/// Rounding headroom on the analytic bounds: bound arithmetic and event
/// arithmetic round to nanoseconds at different points, so give the
/// comparison a microsecond of slack each way.
const SLACK_NS: u64 = 1_000;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// A flow's start latency elapsed: it enters the network now.
    FlowJoin(u32),
    /// A task's attempt chain finished computing.
    TaskDone(u32),
}

#[derive(Clone, Copy, Debug)]
enum FlowTag {
    /// Round payload reaching a host; opens its gate.
    Broadcast(u32),
    /// A task output reaching the leader.
    Gather,
    /// A map task's shuffle write landing in the fabric.
    MapOut,
    /// A reduce task's shuffle read arriving; readies that task.
    ReduceIn(u32),
}

#[derive(Clone, Debug)]
struct Flow {
    route: Vec<usize>,
    bytes: f64,
    tag: FlowTag,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TaskKind {
    /// Output gathers to the leader (machine round).
    Gathered,
    /// Output shuffles out (map side).
    Map,
    /// Consumes a shuffle input (reduce side).
    Reduce,
}

#[derive(Clone, Copy, Debug)]
struct TaskRt {
    host: u32,
    compute: SimTime,
    out_bytes: f64,
    in_bytes: f64,
    kind: TaskKind,
}

#[derive(Clone, Debug, Default)]
struct HostSched {
    /// Blocked until the round broadcast arrives.
    gate: bool,
    /// A task is computing right now.
    busy: bool,
    /// Tasks ready to run, FIFO.
    ready: VecDeque<u32>,
}

/// One round's event-loop state. Freshly built per round.
struct Run {
    model: NetworkModel,
    record: bool,
    net: NetSim,
    q: EventQueue<Ev>,
    flows: Vec<Flow>,
    tasks: Vec<TaskRt>,
    hosts: Vec<HostSched>,
    reduce_ids: Vec<u32>,
    trace: Vec<TraceEvent>,
    latency: SimTime,
    now: SimTime,
    outputs_pending: usize,
    map_out_pending: usize,
    reduces_pending: usize,
    barrier_fired: bool,
}

impl Run {
    fn new(sim: &ClusterSim) -> Run {
        Run {
            model: sim.model.clone(),
            record: sim.cfg.record_trace,
            net: NetSim::new(&sim.model),
            q: EventQueue::new(),
            flows: Vec::new(),
            tasks: Vec::new(),
            hosts: vec![HostSched::default(); sim.topo.hosts],
            reduce_ids: Vec::new(),
            trace: Vec::new(),
            latency: sim.cfg.latency(),
            now: SimTime::ZERO,
            outputs_pending: 0,
            map_out_pending: 0,
            reduces_pending: 0,
            barrier_fired: false,
        }
    }

    fn push_trace(&mut self, kind: TraceKind, a: u32, b: u32) {
        if self.record {
            self.trace.push(TraceEvent { time: self.now, kind, a, b });
        }
    }

    /// Create a flow starting now: it joins the network after the start
    /// latency.
    fn launch_flow(&mut self, route: Vec<usize>, bytes: f64, tag: FlowTag) {
        let fid = self.flows.len() as u32;
        self.flows.push(Flow { route, bytes, tag });
        self.q.push(SimTime(self.now.0 + self.latency.0), Ev::FlowJoin(fid));
    }

    /// Mark a host ready to compute (its broadcast arrived, or there was
    /// none) and start its first task.
    fn open_gate(&mut self, h: usize) {
        self.hosts[h].gate = false;
        self.push_trace(TraceKind::HostReady, h as u32, 0);
        self.try_start(h);
    }

    /// A task became runnable; queue it on its host.
    fn ready_task(&mut self, t: u32) {
        let h = self.tasks[t as usize].host as usize;
        self.hosts[h].ready.push_back(t);
        self.try_start(h);
    }

    fn try_start(&mut self, h: usize) {
        if self.hosts[h].gate || self.hosts[h].busy {
            return;
        }
        let Some(t) = self.hosts[h].ready.pop_front() else {
            return;
        };
        self.hosts[h].busy = true;
        self.push_trace(TraceKind::TaskStart, t, h as u32);
        let compute = self.tasks[t as usize].compute;
        self.q.push(SimTime(self.now.0 + compute.0), Ev::TaskDone(t));
    }

    /// One map output fully landed (or had no bytes); when all have, the
    /// shuffle barrier fires and the reduce inputs start flowing.
    fn map_out_landed(&mut self) {
        self.map_out_pending -= 1;
        if self.map_out_pending == 0 && !self.barrier_fired {
            self.fire_barrier();
        }
    }

    fn fire_barrier(&mut self) {
        self.barrier_fired = true;
        let ids = std::mem::take(&mut self.reduce_ids);
        for &r in &ids {
            let task = self.tasks[r as usize];
            if task.in_bytes > 0.0 {
                let route = self.model.route_shuffle_in(task.host as usize);
                self.launch_flow(route, task.in_bytes, FlowTag::ReduceIn(r));
            } else {
                self.ready_task(r);
            }
        }
        self.reduce_ids = ids;
    }

    fn handle_task_done(&mut self, t: u32) {
        let task = self.tasks[t as usize];
        let h = task.host as usize;
        self.push_trace(TraceKind::TaskDone, t, task.host);
        self.hosts[h].busy = false;
        match task.kind {
            TaskKind::Gathered => {
                if h == 0 || task.out_bytes <= 0.0 {
                    self.outputs_pending -= 1;
                } else {
                    let route = self.model.route_to_leader(h);
                    self.launch_flow(route, task.out_bytes, FlowTag::Gather);
                }
            }
            TaskKind::Map => {
                if task.out_bytes <= 0.0 {
                    self.map_out_landed();
                } else {
                    let route = self.model.route_shuffle_out(h);
                    self.launch_flow(route, task.out_bytes, FlowTag::MapOut);
                }
            }
            TaskKind::Reduce => {
                self.reduces_pending -= 1;
            }
        }
        self.try_start(h);
    }

    fn handle_flow_done(&mut self, fid: u32) {
        self.push_trace(TraceKind::FlowDone, fid, 0);
        let tag = self.flows[fid as usize].tag;
        match tag {
            FlowTag::Broadcast(h) => self.open_gate(h as usize),
            FlowTag::Gather => self.outputs_pending -= 1,
            FlowTag::MapOut => self.map_out_landed(),
            FlowTag::ReduceIn(r) => self.ready_task(r),
        }
    }

    /// Drain the event queue and the network, interleaved in time order
    /// (heap first on ties), then package the verdict.
    fn finish(mut self, lower_secs: f64, upper_secs: f64) -> RoundSim {
        let mut done: Vec<u32> = Vec::new();
        loop {
            let t_heap = self.q.peek_time();
            let t_net = self.net.next_finish();
            let take_heap = match (t_heap, t_net) {
                (None, None) => break,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(th), Some((tn, _))) => th <= tn,
            };
            if take_heap {
                let (t, ev) = self.q.pop().unwrap();
                self.now = t;
                match ev {
                    Ev::FlowJoin(fid) => {
                        self.push_trace(TraceKind::FlowStart, fid, 0);
                        let Flow { route, bytes, .. } = self.flows[fid as usize].clone();
                        self.net.join(t, &route, bytes, fid);
                    }
                    Ev::TaskDone(t_id) => self.handle_task_done(t_id),
                }
            } else {
                let (t, cid) = t_net.unwrap();
                self.now = t;
                done.clear();
                self.net.complete(t, cid, &mut done);
                for &fid in &done {
                    self.handle_flow_done(fid);
                }
            }
        }
        debug_assert_eq!(self.outputs_pending, 0);
        debug_assert_eq!(self.reduces_pending, 0);
        debug_assert!(self.net.is_idle());
        RoundSim {
            wallclock: self.now.as_duration(),
            lower_bound: Duration::from_nanos(
                SimTime::from_secs_f64(lower_secs).0.saturating_sub(SLACK_NS),
            ),
            upper_bound: Duration::from_nanos(SimTime::from_secs_f64(upper_secs).0 + SLACK_NS),
            trace: self.trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oracle_cfg() -> SimConfig {
        SimConfig {
            enabled: true,
            network: NetworkKind::Topology,
            racks: 2,
            oversub: 1.0,
            nic_mbps: 800.0,    // 1e8 bytes/s
            compute_mbps: 100.0, // 1e8 bytes/s
            latency_us: 0.0,
            record_trace: true,
            ..SimConfig::default()
        }
    }

    /// 2 racks × 2 hosts, hand-computed machine round (see prop_sim.rs
    /// for the full derivation): slow host 2 finishes compute at 2.0s,
    /// its gather lands at 2.4s.
    #[test]
    fn machine_round_matches_hand_computation() {
        let sim = ClusterSim::with_speeds(&oracle_cfg(), vec![1.0, 1.0, 0.5, 1.0]);
        let tasks = vec![TaskSpec::new(100_000_000, 40_000_000, 1); 4];
        let r = sim.machine_round(&tasks, 0);
        assert_eq!(r.wallclock, Duration::from_nanos(2_400_000_000));
        assert!(r.lower_bound <= r.wallclock && r.wallclock <= r.upper_bound);
    }

    #[test]
    fn attempts_scale_compute_and_broadcast_gates_hosts() {
        let cfg = oracle_cfg();
        let sim = ClusterSim::with_speeds(&cfg, vec![1.0; 4]);
        // One task per host, 1e8 work: 1s clean. Host 1's task carries a
        // failed attempt: 2s. No outputs, no broadcast => wallclock 2s.
        let mut tasks = vec![TaskSpec::new(100_000_000, 0, 1); 4];
        tasks[1].attempts = 2;
        let r = sim.machine_round(&tasks, 0);
        assert_eq!(r.wallclock, Duration::from_secs(2));
        // With a 2e7 broadcast the three non-leader hosts share the
        // leader egress link (cap 1e8, load 3) ... all gates open at
        // 0.6s, so the straggling host now ends at 2.6s.
        let r = sim.machine_round(&tasks, 20_000_000);
        assert_eq!(r.wallclock, Duration::from_nanos(2_600_000_000));
        assert!(r.lower_bound <= r.wallclock && r.wallclock <= r.upper_bound);
    }

    #[test]
    fn shuffle_round_matches_hand_computation() {
        // Oversub 2 => uplink caps 1e8. 4 maps (1s compute, 5e7 out):
        // egress 2 flows/uplink at 5e7 => barrier at 2.0s. 4 reduces of
        // 6e7: ingress 1.2s, compute 0.6s => 3.8s total.
        let cfg = SimConfig { oversub: 2.0, ..oracle_cfg() };
        let sim = ClusterSim::with_speeds(&cfg, vec![1.0; 4]);
        let map = vec![TaskSpec::new(100_000_000, 50_000_000, 1); 4];
        let reduce = vec![TaskSpec::new(60_000_000, 0, 1); 4];
        let r = sim.shuffle_round(&map, &reduce);
        assert_eq!(r.wallclock, Duration::from_nanos(3_800_000_000));
        assert!(r.lower_bound <= r.wallclock && r.wallclock <= r.upper_bound);
    }

    #[test]
    fn leader_round_is_pure_compute() {
        let sim = ClusterSim::with_speeds(&oracle_cfg(), vec![2.0, 1.0]);
        // 1e8 bytes × 3 attempts at 2e8 B/s = 1.5s.
        let r = sim.leader_round(100_000_000, 3);
        assert_eq!(r.wallclock, Duration::from_nanos(1_500_000_000));
        assert_eq!(r.trace.len(), 2);
    }

    #[test]
    fn rounds_replay_bit_identically() {
        let cfg = SimConfig {
            enabled: true,
            network: NetworkKind::Topology,
            racks: 4,
            oversub: 3.0,
            hetero: Heterogeneity::LogNormal(0.5),
            record_trace: true,
            ..SimConfig::default()
        };
        let mk = || ClusterSim::new(&cfg, 16);
        let tasks: Vec<TaskSpec> =
            (0..24).map(|i| TaskSpec::new(1000 + i * 37, 100 + i * 11, 1 + i % 3)).collect();
        let reduce: Vec<TaskSpec> = (0..16).map(|i| TaskSpec::new(500 + i * 13, 0, 1)).collect();
        let (a, b) = (mk(), mk());
        assert_eq!(a.speeds(), b.speeds());
        let (ra, rb) = (a.machine_round(&tasks, 4096), b.machine_round(&tasks, 4096));
        assert_eq!(ra.wallclock, rb.wallclock);
        assert_eq!(ra.trace, rb.trace);
        let (sa, sb) = (a.shuffle_round(&tasks, &reduce), b.shuffle_round(&tasks, &reduce));
        assert_eq!(sa.wallclock, sb.wallclock);
        assert_eq!(sa.trace, sb.trace);
    }

    #[test]
    fn wallclock_within_bounds_across_models() {
        for kind in [NetworkKind::Constant, NetworkKind::Shared, NetworkKind::Topology] {
            for racks in [1usize, 3] {
                let cfg = SimConfig {
                    enabled: true,
                    network: kind,
                    racks,
                    oversub: 2.5,
                    hetero: Heterogeneity::Bimodal { slow_frac: 0.3, slow_factor: 4.0 },
                    ..SimConfig::default()
                };
                let sim = ClusterSim::new(&cfg, 9);
                let tasks: Vec<TaskSpec> = (0..13)
                    .map(|i| TaskSpec::new(10_000 + i * 997, 900 + i * 53, 1 + i % 2))
                    .collect();
                let r = sim.machine_round(&tasks, 2048);
                assert!(r.lower_bound <= r.wallclock, "{kind} racks {racks}: {r:?}");
                assert!(r.wallclock <= r.upper_bound, "{kind} racks {racks}: {r:?}");
                let s = sim.shuffle_round(&tasks, &tasks[..9]);
                assert!(s.lower_bound <= s.wallclock && s.wallclock <= s.upper_bound);
            }
        }
    }

    #[test]
    fn empty_round_is_instant() {
        let sim = ClusterSim::new(&SimConfig::default(), 4);
        let r = sim.machine_round(&[], 0);
        assert_eq!(r.wallclock, Duration::ZERO);
    }
}
