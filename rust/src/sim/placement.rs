//! Cluster shape (hosts grouped into racks) and task-to-host placement.
//!
//! Placement only decides *where* a task's bytes and compute land in the
//! simulated cluster — it never reorders the engine's tasks or touches
//! their outputs, so it is pure timing observation. The default
//! `RoundRobin` mirrors the real engine's `i % n_machines` partition
//! assignment; `RackAware` spreads consecutive tasks across racks first,
//! trading intra-rack locality for balanced uplink load.

use std::fmt;

/// Shape of the simulated cluster: `hosts` machines packed into `racks`
/// racks of (up to) `rack_width()` hosts each; the trailing rack may be
/// short. Host 0 doubles as the coordinator ("leader").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Simulated machine count.
    pub hosts: usize,
    /// Configured rack count (clamped to `[1, hosts]`).
    pub racks: usize,
}

impl Topology {
    /// Build a topology, clamping `racks` into `[1, hosts]`.
    pub fn new(hosts: usize, racks: usize) -> Topology {
        let hosts = hosts.max(1);
        Topology { hosts, racks: racks.clamp(1, hosts) }
    }

    /// Hosts per full rack (ceiling division; the last rack may be short).
    pub fn rack_width(&self) -> usize {
        self.hosts.div_ceil(self.racks)
    }

    /// The rack a host lives in.
    pub fn rack_of(&self, host: usize) -> usize {
        host / self.rack_width()
    }

    /// Number of hosts actually in `rack` (0 for trailing empty racks
    /// that the clamped ceiling split leaves unused).
    pub fn rack_size(&self, rack: usize) -> usize {
        let w = self.rack_width();
        self.hosts.saturating_sub(rack * w).min(w)
    }

    /// Racks that actually contain hosts.
    pub fn occupied_racks(&self) -> usize {
        self.hosts.div_ceil(self.rack_width())
    }
}

/// Strategy mapping task index → host index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// `task % hosts` — mirrors the engine's partition assignment.
    RoundRobin,
    /// Stripe tasks across occupied racks first, then round-robin within
    /// each rack: consecutive tasks land in different racks.
    RackAware,
}

impl Placement {
    /// Parse the `sim.placement` config value: `roundrobin` | `rackaware`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "roundrobin" => Ok(Placement::RoundRobin),
            "rackaware" => Ok(Placement::RackAware),
            other => Err(format!(
                "unknown placement {other:?} (roundrobin | rackaware)"
            )),
        }
    }

    /// The host that task `task` runs on. Pure and total: every task
    /// maps to a real host for every topology.
    pub fn host_for(&self, task: usize, topo: &Topology) -> usize {
        match self {
            Placement::RoundRobin => task % topo.hosts,
            Placement::RackAware => {
                let nr = topo.occupied_racks();
                let rack = task % nr;
                let slot = task / nr;
                let base = rack * topo.rack_width();
                base + slot % topo.rack_size(rack)
            }
        }
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Placement::RoundRobin => write!(f, "roundrobin"),
            Placement::RackAware => write!(f, "rackaware"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_clamps_and_splits() {
        let t = Topology::new(5, 3);
        assert_eq!(t.rack_width(), 2);
        assert_eq!((t.rack_size(0), t.rack_size(1), t.rack_size(2)), (2, 2, 1));
        assert_eq!(t.occupied_racks(), 3);
        assert_eq!(t.rack_of(0), 0);
        assert_eq!(t.rack_of(3), 1);
        assert_eq!(t.rack_of(4), 2);
        // racks > hosts clamps; hosts = 0 clamps to 1.
        assert_eq!(Topology::new(2, 10).racks, 2);
        assert_eq!(Topology::new(0, 1).hosts, 1);
        // 4 hosts / 3 racks: width 2, rack 2 is empty.
        let t = Topology::new(4, 3);
        assert_eq!(t.rack_size(2), 0);
        assert_eq!(t.occupied_racks(), 2);
    }

    #[test]
    fn round_robin_matches_engine_partitioning() {
        let t = Topology::new(4, 2);
        for task in 0..16 {
            assert_eq!(Placement::RoundRobin.host_for(task, &t), task % 4);
        }
    }

    #[test]
    fn rack_aware_stripes_racks_and_stays_total() {
        let t = Topology::new(6, 3); // racks {0,1} {2,3} {4,5}
        let hosts: Vec<usize> =
            (0..6).map(|i| Placement::RackAware.host_for(i, &t)).collect();
        assert_eq!(hosts, vec![0, 2, 4, 1, 3, 5]);
        // Totality incl. an empty trailing rack and task >> hosts.
        let odd = Topology::new(4, 3);
        for task in 0..64 {
            for p in [Placement::RoundRobin, Placement::RackAware] {
                assert!(p.host_for(task, &odd) < odd.hosts);
            }
        }
    }

    #[test]
    fn parse_roundtrips() {
        for s in ["roundrobin", "rackaware"] {
            assert_eq!(Placement::parse(s).unwrap().to_string(), s);
        }
        assert!(Placement::parse("random").is_err());
    }
}
