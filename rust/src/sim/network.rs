//! Contended-bandwidth network models with deterministic fair sharing.
//!
//! A *flow* is one logical transfer (a broadcast payload, a gathered task
//! output, a shuffle segment) traversing a fixed *route* — a sorted list
//! of link ids. While active, a flow transfers at
//!
//! ```text
//! rate = min(NIC, min over links l of  capacity_l / active_flows_l)
//! ```
//!
//! i.e. every link splits its capacity equally among the flows crossing
//! it, and no flow exceeds its endpoint NIC. (This is equal-share
//! splitting, not full max-min water-filling: capacity a NIC-capped flow
//! leaves on a link is *not* redistributed — a deliberately simple law
//! that a test can reproduce by hand.) When a flow joins or finishes,
//! every rate is recomputed; between such events rates are constant, so
//! completion times are exact closed forms.
//!
//! Flows with identical routes form a *class* and always share one rate,
//! which makes the simulation cheap at 10k-host scale: a class advances a
//! single `depleted` byte counter, each member stores its constant
//! virtual finish depth (`depleted`-at-join + bytes) in a `BTreeMap`, and
//! the next completion is the minimum depth — O(classes) per event
//! instead of O(flows), with class count bounded by the number of
//! distinct routes (a handful per round: one per rack plus the leader
//! links).
//!
//! Three models share this machinery:
//! * [`NetworkKind::Constant`] — no links at all: every flow runs at NIC
//!   rate, the uncontended baseline.
//! * [`NetworkKind::Shared`] — one fabric link of capacity
//!   `NIC × hosts / oversub`, plus dedicated leader ingress/egress links
//!   of capacity NIC (so gather incast at the coordinator is modeled).
//! * [`NetworkKind::Topology`] — one uplink per rack of capacity
//!   `NIC × rack_size / oversub`; cross-rack flows traverse both racks'
//!   uplinks, intra-rack flows touch none, and the leader keeps its
//!   ingress/egress links.

use super::engine::SimTime;
use super::placement::Topology;
use std::collections::BTreeMap;
use std::fmt;

/// Which contention model shapes transfer times.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetworkKind {
    /// Uncontended: every flow transfers at full NIC rate.
    Constant,
    /// A single shared fabric link (capacity `NIC × hosts / oversub`)
    /// plus leader ingress/egress links.
    Shared,
    /// Per-rack uplinks (capacity `NIC × rack_size / oversub`) plus
    /// leader ingress/egress links.
    Topology,
}

impl NetworkKind {
    /// Parse the `sim.network` config value: `constant` | `shared` |
    /// `topology`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "constant" => Ok(NetworkKind::Constant),
            "shared" => Ok(NetworkKind::Shared),
            "topology" => Ok(NetworkKind::Topology),
            other => Err(format!(
                "unknown network model {other:?} (constant | shared | topology)"
            )),
        }
    }
}

impl fmt::Display for NetworkKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetworkKind::Constant => write!(f, "constant"),
            NetworkKind::Shared => write!(f, "shared"),
            NetworkKind::Topology => write!(f, "topology"),
        }
    }
}

/// Static network description: link capacities and routing. Built once
/// per simulated cluster; the per-round dynamic state lives in
/// [`NetSim`].
#[derive(Clone, Debug)]
pub struct NetworkModel {
    kind: NetworkKind,
    topo: Topology,
    nic: f64,
    caps: Vec<f64>,
    leader_in: usize,
    leader_out: usize,
}

impl NetworkModel {
    /// Build the link table for `kind` over `topo`. `nic_bps` is the
    /// per-host NIC bandwidth in bytes/second; `oversub` divides the
    /// aggregate fabric/uplink capacity (1.0 = non-blocking).
    pub fn new(kind: NetworkKind, topo: Topology, nic_bps: f64, oversub: f64) -> NetworkModel {
        let oversub = oversub.max(1.0);
        let (caps, leader_in, leader_out) = match kind {
            NetworkKind::Constant => (Vec::new(), usize::MAX, usize::MAX),
            NetworkKind::Shared => {
                let fabric = nic_bps * topo.hosts as f64 / oversub;
                (vec![fabric, nic_bps, nic_bps], 1, 2)
            }
            NetworkKind::Topology => {
                let mut caps: Vec<f64> = (0..topo.racks)
                    .map(|r| nic_bps * topo.rack_size(r) as f64 / oversub)
                    .collect();
                let leader_in = caps.len();
                caps.push(nic_bps);
                let leader_out = caps.len();
                caps.push(nic_bps);
                (caps, leader_in, leader_out)
            }
        };
        NetworkModel { kind, topo, nic: nic_bps, caps, leader_in, leader_out }
    }

    /// Per-flow NIC cap in bytes/second.
    pub fn nic_bps(&self) -> f64 {
        self.nic
    }

    /// Route of a gather flow `host → leader` (host 0): the host's rack
    /// uplink and the leader rack's uplink if they differ, plus the
    /// leader ingress link. Sorted ascending.
    pub fn route_to_leader(&self, host: usize) -> Vec<usize> {
        match self.kind {
            NetworkKind::Constant => Vec::new(),
            NetworkKind::Shared => vec![0, self.leader_in],
            NetworkKind::Topology => {
                let r = self.topo.rack_of(host);
                if r == 0 {
                    vec![self.leader_in]
                } else {
                    vec![0, r, self.leader_in]
                }
            }
        }
    }

    /// Route of a broadcast flow `leader → host`: mirror of
    /// [`NetworkModel::route_to_leader`] through the leader egress link.
    pub fn route_from_leader(&self, host: usize) -> Vec<usize> {
        match self.kind {
            NetworkKind::Constant => Vec::new(),
            NetworkKind::Shared => vec![0, self.leader_out],
            NetworkKind::Topology => {
                let r = self.topo.rack_of(host);
                if r == 0 {
                    vec![self.leader_out]
                } else {
                    vec![0, r, self.leader_out]
                }
            }
        }
    }

    /// Route of a shuffle segment leaving `host` toward the fabric
    /// (map-side write). All-to-all traffic is modeled disaggregated:
    /// egress crosses the source uplink, ingress the destination uplink.
    pub fn route_shuffle_out(&self, host: usize) -> Vec<usize> {
        match self.kind {
            NetworkKind::Constant => Vec::new(),
            NetworkKind::Shared => vec![0],
            NetworkKind::Topology => vec![self.topo.rack_of(host)],
        }
    }

    /// Route of a shuffle segment arriving at `host` (reduce-side read).
    pub fn route_shuffle_in(&self, host: usize) -> Vec<usize> {
        self.route_shuffle_out(host)
    }

    /// Uncontended transfer time for `bytes` over `route`, in seconds —
    /// the rate a lone flow would get. Used for the critical-path bounds.
    pub fn solo_secs(&self, route: &[usize], bytes: f64) -> f64 {
        let rate = route
            .iter()
            .fold(self.nic, |r, &l| r.min(self.caps[l]));
        bytes / rate
    }
}

/// Dynamic fair-share state of one round's flows. Created fresh per
/// round so class ids are a deterministic function of the round alone.
#[derive(Clone, Debug)]
pub struct NetSim {
    nic: f64,
    caps: Vec<f64>,
    link_load: Vec<usize>,
    classes: Vec<ClassState>,
    class_ids: BTreeMap<Vec<usize>, usize>,
    active: usize,
}

#[derive(Clone, Debug)]
struct ClassState {
    route: Vec<usize>,
    /// Current per-flow rate (bytes/second); constant between events.
    rate: f64,
    /// Bytes every still-active member has transferred since it joined
    /// the class epoch (members join at the current depth).
    depleted: f64,
    /// When `depleted` was last advanced.
    last: SimTime,
    /// Members keyed by `(virtual finish depth bits, join seq)` — the
    /// depth is `depleted`-at-join + bytes, constant for the flow's
    /// lifetime, and nonnegative f64 bits order exactly like the values.
    q: BTreeMap<(u64, u64), u32>,
    seq: u64,
}

impl NetSim {
    /// Fresh round state over `model`'s links.
    pub fn new(model: &NetworkModel) -> NetSim {
        NetSim {
            nic: model.nic,
            caps: model.caps.clone(),
            link_load: vec![0; model.caps.len()],
            classes: Vec::new(),
            class_ids: BTreeMap::new(),
            active: 0,
        }
    }

    /// True when no flow is in transfer.
    pub fn is_idle(&self) -> bool {
        self.active == 0
    }

    /// A flow of `bytes` enters the network at `now` over `route`
    /// (sorted link ids). `token` is returned by the completion that
    /// finishes it.
    pub fn join(&mut self, now: SimTime, route: &[usize], bytes: f64, token: u32) {
        self.advance(now);
        let cid = match self.class_ids.get(route) {
            Some(&cid) => cid,
            None => {
                let cid = self.classes.len();
                self.class_ids.insert(route.to_vec(), cid);
                self.classes.push(ClassState {
                    route: route.to_vec(),
                    rate: 0.0,
                    depleted: 0.0,
                    last: now,
                    q: BTreeMap::new(),
                    seq: 0,
                });
                cid
            }
        };
        let class = &mut self.classes[cid];
        let depth = class.depleted + bytes.max(0.0);
        let seq = class.seq;
        class.seq += 1;
        class.q.insert((depth.to_bits(), seq), token);
        for &l in route {
            self.link_load[l] += 1;
        }
        self.active += 1;
        self.refresh_rates();
    }

    /// The earliest pending completion: `(time, class)`, ties resolved
    /// toward the lower class id (classes are created in deterministic
    /// order, so this is a total order).
    pub fn next_finish(&self) -> Option<(SimTime, usize)> {
        let mut best: Option<(SimTime, usize)> = None;
        for (cid, class) in self.classes.iter().enumerate() {
            let Some((&(depth_bits, _), _)) = class.q.iter().next() else {
                continue;
            };
            let depth = f64::from_bits(depth_bits);
            let secs = (depth - class.depleted).max(0.0) / class.rate;
            let t = class.last + SimTime::from_secs_f64(secs);
            let better = match best {
                None => true,
                Some((bt, _)) => t < bt,
            };
            if better {
                best = Some((t, cid));
            }
        }
        best
    }

    /// Complete the front flow of `class` at `now` (as returned by
    /// [`NetSim::next_finish`]), plus any class members that reach their
    /// depth at the same instant; their tokens are appended to `done` in
    /// deterministic (depth, join-seq) order.
    pub fn complete(&mut self, now: SimTime, class: usize, done: &mut Vec<u32>) {
        self.advance(now);
        let removed_at = done.len();
        let c = &mut self.classes[class];
        // Pop the triggering flow unconditionally: nanosecond rounding of
        // the event timestamp may leave `depleted` a whisker short of the
        // stored depth, and popping by depth alone would then stall.
        if let Some((&(depth_bits, seq), _)) = c.q.iter().next() {
            let depth = f64::from_bits(depth_bits);
            c.depleted = c.depleted.max(depth);
            done.push(c.q.remove(&(depth_bits, seq)).unwrap());
        }
        while let Some((&(depth_bits, seq), _)) = c.q.iter().next() {
            if f64::from_bits(depth_bits) > c.depleted {
                break;
            }
            done.push(c.q.remove(&(depth_bits, seq)).unwrap());
        }
        let removed = done.len() - removed_at;
        let route = self.classes[class].route.clone();
        for &l in &route {
            self.link_load[l] -= removed;
        }
        self.active -= removed;
        self.refresh_rates();
    }

    /// Advance every class's depletion counter to `now` at its current
    /// rate. Classes are independent, so per-class order cannot matter;
    /// iteration is in class-id order regardless.
    fn advance(&mut self, now: SimTime) {
        for class in &mut self.classes {
            if now > class.last {
                if !class.q.is_empty() {
                    let dt = (now.0 - class.last.0) as f64 * 1e-9;
                    class.depleted += class.rate * dt;
                }
                class.last = now;
            }
        }
    }

    /// Recompute every class's equal-share rate from current link loads.
    fn refresh_rates(&mut self) {
        for class in &mut self.classes {
            if class.q.is_empty() {
                class.rate = 0.0;
                continue;
            }
            let mut rate = self.nic;
            for &l in &class.route {
                rate = rate.min(self.caps[l] / self.link_load[l] as f64);
            }
            class.rate = rate;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared_model(hosts: usize, nic: f64, oversub: f64) -> NetworkModel {
        NetworkModel::new(NetworkKind::Shared, Topology::new(hosts, 1), nic, oversub)
    }

    #[test]
    fn constant_model_has_no_links() {
        let m = NetworkModel::new(NetworkKind::Constant, Topology::new(8, 2), 1e8, 4.0);
        assert!(m.route_to_leader(5).is_empty());
        assert!(m.route_shuffle_out(5).is_empty());
        assert_eq!(m.solo_secs(&[], 1e8), 1.0);
    }

    #[test]
    fn topology_routes_cross_racks() {
        let m = NetworkModel::new(NetworkKind::Topology, Topology::new(4, 2), 1e8, 1.0);
        // Racks {0,1}, {2,3}; links: 0,1 = uplinks, 2 = leader-in, 3 = leader-out.
        assert_eq!(m.route_to_leader(1), vec![2]); // same rack as leader
        assert_eq!(m.route_to_leader(3), vec![0, 1, 2]); // cross-rack
        assert_eq!(m.route_from_leader(2), vec![0, 1, 3]);
        assert_eq!(m.route_shuffle_out(3), vec![1]);
        // Uplink capacity = nic * rack_size / oversub = 2e8.
        assert_eq!(m.solo_secs(&[0], 2e8), 2.0); // nic-capped at 1e8
    }

    #[test]
    fn lone_flow_runs_at_nic_rate() {
        let m = shared_model(4, 1e8, 1.0); // fabric 4e8 >> nic
        let mut net = NetSim::new(&m);
        net.join(SimTime::ZERO, &m.route_shuffle_out(1), 1e8, 7);
        let (t, cid) = net.next_finish().unwrap();
        assert_eq!(t, SimTime(1_000_000_000));
        let mut done = Vec::new();
        net.complete(t, cid, &mut done);
        assert_eq!(done, vec![7]);
        assert!(net.is_idle());
    }

    #[test]
    fn fabric_fair_share_halves_rates() {
        // nic 1e8, 2 hosts, oversub 2 => fabric cap 1e8: two flows get
        // 5e7 each and both finish at 2s (1e8 bytes each, same class).
        let m = shared_model(2, 1e8, 2.0);
        let mut net = NetSim::new(&m);
        net.join(SimTime::ZERO, &m.route_shuffle_out(0), 1e8, 0);
        net.join(SimTime::ZERO, &m.route_shuffle_out(1), 1e8, 1);
        let (t, cid) = net.next_finish().unwrap();
        assert_eq!(t, SimTime(2_000_000_000));
        let mut done = Vec::new();
        net.complete(t, cid, &mut done);
        assert_eq!(done, vec![0, 1]); // same depth: join order
        assert!(net.is_idle());
    }

    #[test]
    fn survivor_speeds_up_after_completion() {
        // Same fabric (cap 1e8), flows of 1e8 and 2e8 bytes. Fair share
        // 5e7 each; the small flow ends at 2s, then the big one runs at
        // nic (1e8) for its remaining 1e8 bytes: done at 3s.
        let m = shared_model(2, 1e8, 2.0);
        let mut net = NetSim::new(&m);
        net.join(SimTime::ZERO, &m.route_shuffle_out(0), 1e8, 0);
        net.join(SimTime::ZERO, &m.route_shuffle_out(1), 2e8, 1);
        let mut done = Vec::new();
        let (t1, c1) = net.next_finish().unwrap();
        assert_eq!(t1, SimTime(2_000_000_000));
        net.complete(t1, c1, &mut done);
        assert_eq!(done, vec![0]);
        let (t2, c2) = net.next_finish().unwrap();
        assert_eq!(t2, SimTime(3_000_000_000));
        net.complete(t2, c2, &mut done);
        assert_eq!(done, vec![0, 1]);
        assert!(net.is_idle());
    }

    #[test]
    fn late_join_shares_from_arrival() {
        // Flow A (1e8 bytes) alone for 0.5s at nic 1e8 (fabric ample),
        // then B joins on the same route; both run at 5e7 (leader-in cap
        // 1e8 shared). A has 5e7 left -> done at 1.5s.
        let m = shared_model(4, 1e8, 1.0);
        let mut net = NetSim::new(&m);
        net.join(SimTime::ZERO, &m.route_to_leader(1), 1e8, 0);
        net.join(SimTime(500_000_000), &m.route_to_leader(2), 1e8, 1);
        let (t1, c1) = net.next_finish().unwrap();
        assert_eq!(t1, SimTime(1_500_000_000));
        let mut done = Vec::new();
        net.complete(t1, c1, &mut done);
        assert_eq!(done, vec![0]);
        // B joined at depth 5e7 (depth 1.5e8); at 1.5s depletion is 1e8,
        // and the remaining 5e7 bytes run at full nic => done at 2.0s.
        let (t2, _) = net.next_finish().unwrap();
        assert_eq!(t2, SimTime(2_000_000_000));
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let m = shared_model(2, 1e8, 1.0);
        let mut net = NetSim::new(&m);
        net.join(SimTime(42), &m.route_shuffle_out(0), 0.0, 9);
        let (t, cid) = net.next_finish().unwrap();
        assert_eq!(t, SimTime(42));
        let mut done = Vec::new();
        net.complete(t, cid, &mut done);
        assert_eq!(done, vec![9]);
        assert!(net.is_idle());
    }

    #[test]
    fn replay_is_bit_identical() {
        let run = || {
            let m = NetworkModel::new(
                NetworkKind::Topology,
                Topology::new(8, 2),
                1.25e8,
                3.0,
            );
            let mut net = NetSim::new(&m);
            let mut log = Vec::new();
            for h in 0..8usize {
                net.join(
                    SimTime(h as u64 * 1_000),
                    &m.route_to_leader(h),
                    (h as f64 + 1.0) * 1e7,
                    h as u32,
                );
            }
            let mut done = Vec::new();
            while let Some((t, cid)) = net.next_finish() {
                done.clear();
                net.complete(t, cid, &mut done);
                log.push((t, done.clone()));
            }
            log
        };
        assert_eq!(run(), run());
    }
}
