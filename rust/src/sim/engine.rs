//! Deterministic discrete-event core: simulated time, a stable event
//! queue, and the trace record the property tests diff bit-for-bit.
//!
//! Nothing in this module (or anywhere under `sim/`) reads a wall clock:
//! there is no `Instant`, no thread timing, no `HashMap` whose iteration
//! order could leak into event order. Simulated time is an integer
//! nanosecond counter, events are totally ordered by `(time, seq)` where
//! `seq` is the global scheduling index, and every floating-point quantity
//! is derived from the same deterministic inputs in the same order on
//! every run — so two runs with the same seed produce bit-identical
//! traces regardless of how the *real* cluster engine scheduled its
//! threads.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Duration;

/// A point in simulated time: integer nanoseconds since round start.
///
/// Integer time (not `f64`) makes event ordering exact; fractional
/// quantities (transfer times, compute durations) are rounded to the
/// nearest nanosecond exactly once, when they become an event timestamp.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    /// The round's origin.
    pub const ZERO: SimTime = SimTime(0);

    /// Nearest-nanosecond conversion from (nonnegative) seconds.
    pub fn from_secs_f64(secs: f64) -> SimTime {
        SimTime((secs.max(0.0) * 1e9).round() as u64)
    }

    /// As a standard `Duration` (what `RoundStats` stores).
    pub fn as_duration(self) -> Duration {
        Duration::from_nanos(self.0)
    }

    /// As floating-point seconds (reporting only — never fed back into
    /// event arithmetic).
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 * 1e-9
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

/// Binary-heap event queue with a *stable total order*: events pop in
/// `(time, seq)` order, where `seq` is the insertion index. Two events
/// scheduled for the same instant therefore pop in the order they were
/// scheduled — never in heap-internal or hash order — which is what makes
/// the event trace a deterministic function of the round's inputs.
#[derive(Clone, Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(SimTime, u64, E)>>,
    seq: u64,
}

impl<E: Ord> EventQueue<E> {
    /// An empty queue; `seq` starts at zero.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `ev` at absolute simulated time `at`.
    pub fn push(&mut self, at: SimTime, ev: E) {
        let s = self.seq;
        self.seq += 1;
        self.heap.push(Reverse((at, s, ev)));
    }

    /// Timestamp of the next event, if any.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    /// Pop the next event in `(time, seq)` order.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t, e))
    }

    /// True when no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E: Ord> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// What happened at a trace point.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceKind {
    /// A host received the round's broadcast payload (or had nothing to
    /// wait for) and may start computing.
    HostReady,
    /// A task's attempt chain began computing on its host.
    TaskStart,
    /// A task's attempt chain finished computing.
    TaskDone,
    /// A flow entered the network (its start latency has elapsed).
    FlowStart,
    /// A flow's last byte arrived.
    FlowDone,
}

/// One entry of a round's event trace, recorded in processing order.
///
/// `a` and `b` identify the subject: for task events `a` is the task
/// index and `b` its host; for flow events `a` is the flow id; for
/// `HostReady` `a` is the host. Property tests compare whole traces with
/// `==` — bit-identical across repeats and thread modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// When it happened.
    pub time: SimTime,
    /// What happened.
    pub kind: TraceKind,
    /// Primary subject id (task index, flow id, or host).
    pub a: u32,
    /// Secondary subject id (host for task events; 0 otherwise).
    pub b: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_roundtrips() {
        assert_eq!(SimTime::from_secs_f64(0.3), SimTime(300_000_000));
        assert_eq!(SimTime::from_secs_f64(-1.0), SimTime::ZERO);
        assert_eq!(SimTime(1_500).as_duration(), Duration::from_nanos(1_500));
        let t = SimTime(2) + SimTime(3);
        assert_eq!(t, SimTime(5));
    }

    #[test]
    fn queue_pops_in_time_then_fifo_order() {
        let mut q = EventQueue::new();
        q.push(SimTime(10), 'b');
        q.push(SimTime(5), 'a');
        q.push(SimTime(10), 'c'); // same instant as 'b': FIFO on seq
        q.push(SimTime(10), 'd');
        assert_eq!(q.peek_time(), Some(SimTime(5)));
        let order: Vec<char> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!['a', 'b', 'c', 'd']);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn queue_replay_is_bit_identical() {
        let run = || {
            let mut q = EventQueue::new();
            for i in 0..100u32 {
                q.push(SimTime((i as u64 * 7919) % 97), i);
            }
            let mut out = Vec::new();
            while let Some((t, e)) = q.pop() {
                out.push((t, e));
            }
            out
        };
        assert_eq!(run(), run());
    }
}
