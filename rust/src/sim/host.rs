//! Per-host compute model: seeded heterogeneity distributions.
//!
//! Each simulated host gets a relative speed drawn once, at cluster
//! construction, from a seeded distribution in host-index order — the
//! draw never interleaves with the fault stream (`mapreduce/recovery.rs`)
//! or the data RNG, so enabling the simulation cannot perturb algorithm
//! outputs. Slow hosts are how stragglers *emerge* in the simulated
//! cluster: a task landing on a 4x-slow host simply takes 4x longer, and
//! the round's critical path stretches accordingly — no
//! `straggler_factor` multiplier involved.

use crate::util::rng::Rng;
use std::fmt;

/// Distribution of per-host relative compute speeds (1.0 = nominal).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Heterogeneity {
    /// Homogeneous cluster: every host runs at speed 1.0.
    None,
    /// Log-normal speeds: `speed = exp(sigma * z)` with `z` standard
    /// normal, clamped to `[0.1, 10.0]`. The classic long-tail model of
    /// mixed-generation fleets.
    LogNormal(f64),
    /// A two-population fleet: a `slow_frac` fraction of hosts run at
    /// `1 / slow_factor` speed, the rest at 1.0.
    Bimodal {
        /// Probability a host lands in the slow population.
        slow_frac: f64,
        /// Slowdown of the slow population (>= 1.0).
        slow_factor: f64,
    },
}

impl Heterogeneity {
    /// Parse the `sim.hetero` config value: `none`, `lognormal[:SIGMA]`
    /// (default sigma 0.5), or `bimodal[:FRAC[:FACTOR]]` (defaults
    /// 0.1 and 4.0).
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("");
        let num = |p: Option<&str>, default: f64| -> Result<f64, String> {
            match p {
                None => Ok(default),
                Some(v) => v
                    .parse::<f64>()
                    .map_err(|_| format!("bad heterogeneity parameter {v:?}")),
            }
        };
        match head {
            "none" => Ok(Heterogeneity::None),
            "lognormal" => Ok(Heterogeneity::LogNormal(num(parts.next(), 0.5)?)),
            "bimodal" => Ok(Heterogeneity::Bimodal {
                slow_frac: num(parts.next(), 0.1)?,
                slow_factor: num(parts.next(), 4.0)?,
            }),
            other => Err(format!(
                "unknown heterogeneity {other:?} \
                 (none | lognormal[:sigma] | bimodal[:frac[:factor]])"
            )),
        }
    }

    /// Draw the `n` host speeds, in host-index order, from a dedicated
    /// RNG stream derived from `seed`. Pure: same `(self, n, seed)` ⇒
    /// same speeds, bit-for-bit.
    pub fn draw_speeds(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed ^ 0x4057_5EED);
        (0..n)
            .map(|_| match *self {
                Heterogeneity::None => 1.0,
                Heterogeneity::LogNormal(sigma) => {
                    (sigma * rng.normal()).exp().clamp(0.1, 10.0)
                }
                Heterogeneity::Bimodal { slow_frac, slow_factor } => {
                    if rng.bernoulli(slow_frac) {
                        1.0 / slow_factor.max(1.0)
                    } else {
                        1.0
                    }
                }
            })
            .collect()
    }
}

impl fmt::Display for Heterogeneity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Heterogeneity::None => write!(f, "none"),
            Heterogeneity::LogNormal(sigma) => write!(f, "lognormal:{sigma}"),
            Heterogeneity::Bimodal { slow_frac, slow_factor } => {
                write!(f, "bimodal:{slow_frac}:{slow_factor}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_defaults() {
        assert_eq!(Heterogeneity::parse("none").unwrap(), Heterogeneity::None);
        assert_eq!(
            Heterogeneity::parse("lognormal").unwrap(),
            Heterogeneity::LogNormal(0.5)
        );
        assert_eq!(
            Heterogeneity::parse("lognormal:0.25").unwrap(),
            Heterogeneity::LogNormal(0.25)
        );
        assert_eq!(
            Heterogeneity::parse("bimodal:0.2:8").unwrap(),
            Heterogeneity::Bimodal { slow_frac: 0.2, slow_factor: 8.0 }
        );
        assert_eq!(
            Heterogeneity::parse("bimodal").unwrap(),
            Heterogeneity::Bimodal { slow_frac: 0.1, slow_factor: 4.0 }
        );
        assert!(Heterogeneity::parse("gauss").is_err());
        assert!(Heterogeneity::parse("lognormal:x").is_err());
        for s in ["none", "lognormal:0.5", "bimodal:0.1:4"] {
            let h = Heterogeneity::parse(s).unwrap();
            assert_eq!(h.to_string(), s);
        }
    }

    #[test]
    fn homogeneous_speeds_are_unit() {
        assert_eq!(Heterogeneity::None.draw_speeds(5, 9), vec![1.0; 5]);
    }

    #[test]
    fn draws_are_deterministic_and_seed_sensitive() {
        let h = Heterogeneity::LogNormal(0.5);
        assert_eq!(h.draw_speeds(64, 7), h.draw_speeds(64, 7));
        assert_ne!(h.draw_speeds(64, 7), h.draw_speeds(64, 8));
        assert!(h
            .draw_speeds(256, 7)
            .iter()
            .all(|&s| (0.1..=10.0).contains(&s)));
    }

    #[test]
    fn bimodal_hits_both_populations() {
        let h = Heterogeneity::Bimodal { slow_frac: 0.5, slow_factor: 4.0 };
        let speeds = h.draw_speeds(200, 3);
        assert!(speeds.iter().any(|&s| s == 1.0));
        assert!(speeds.iter().any(|&s| s == 0.25));
    }
}
