//! Plain-text table rendering for the experiment drivers — the Figure 1 /
//! Figure 2 reproductions print through this so the output reads like the
//! paper's tables.

/// A column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render the table as column-aligned text (trailing newline).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                // Right-align everything but the first column (labels).
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `p` decimals (helper for table cells).
pub fn fnum(x: f64, p: usize) -> String {
    format!("{x:.p$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["algo", "n=10k", "n=1M"]);
        t.row(vec!["Parallel-Lloyd", "1.000", "1.000"]);
        t.row(vec!["Sampling-LocalSearch", "1.018", "1.029"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width-ish: header and rows align on columns.
        assert!(lines[2].starts_with("Parallel-Lloyd"));
        assert!(lines[3].starts_with("Sampling-LocalSearch"));
        assert!(lines[2].trim_end().ends_with("1.000"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn wrong_width_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn fnum_decimals() {
        assert_eq!(fnum(1.23456, 3), "1.235");
        assert_eq!(fnum(2.0, 1), "2.0");
    }
}
