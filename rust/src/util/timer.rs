//! Wall-clock helpers used by the MapReduce engine's per-machine timing and
//! by the bench harness.

use std::time::{Duration, Instant};

/// Measure the wall-clock duration of `f`, returning (result, elapsed).
#[inline]
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Simple accumulating stopwatch (pause/resume semantics).
#[derive(Debug, Default, Clone, Copy)]
pub struct Stopwatch {
    total: Duration,
}

impl Stopwatch {
    /// Add an externally measured duration.
    pub fn add(&mut self, d: Duration) {
        self.total += d;
    }

    /// Run `f`, adding its wall-clock duration to the total.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let (out, d) = timed(f);
        self.total += d;
        out
    }

    /// Accumulated duration.
    pub fn total(&self) -> Duration {
        self.total
    }

    /// Accumulated duration in seconds.
    pub fn secs(&self) -> f64 {
        self.total.as_secs_f64()
    }
}

/// Human-friendly duration formatting for the report tables.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}m", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, d) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // non-negative by type
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::default();
        sw.add(Duration::from_millis(5));
        sw.add(Duration::from_millis(7));
        assert_eq!(sw.total(), Duration::from_millis(12));
        let x = sw.time(|| 1 + 1);
        assert_eq!(x, 2);
        assert!(sw.total() >= Duration::from_millis(12));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("us"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
        assert!(fmt_duration(Duration::from_secs(600)).ends_with('m'));
    }
}
