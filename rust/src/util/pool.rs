//! Persistent worker pool (vendored, std-only).
//!
//! The simulated cluster used to spawn a fresh set of scoped threads for
//! every MapReduce round, and the numeric kernels ran single-threaded
//! inside each machine task. This module replaces both with one
//! long-lived pool abstraction:
//!
//! * [`ThreadPool`] — a fixed set of workers created once and reused for
//!   every parallel-for batch (`MrCluster` owns one per cluster, so a
//!   whole multi-round algorithm run never spawns a thread after setup);
//! * [`global`] — a process-wide pool shared by `NativeBackend`'s blocked
//!   kernels and `metrics::cost::eval_costs`.
//!
//! The only primitive is a blocking parallel-for: [`ThreadPool::run`]
//! submits `total` indices, workers claim them from a shared counter
//! (work-stealing degenerates to counter-stealing because every batch is
//! an indexed range), and the submitter blocks until the batch drains.
//! Because `run` does not return while any claimed index is still
//! executing, the task closure may borrow the submitter's stack — the
//! same soundness argument as `std::thread::scope`.
//!
//! Nesting never deadlocks: a task that calls `run` again (e.g. a machine
//! task whose `NativeBackend::assign` wants the global pool) executes the
//! inner batch inline on the worker thread. This is detected with a
//! thread-local flag, so it also holds *across* pools. Determinism is the
//! caller's contract: every call site decomposes work into fixed-size
//! blocks merged in index order, so results do not depend on the worker
//! count or schedule (see `runtime/native.rs`).

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// True on pool worker threads (and inside [`with_serial`]): nested
    /// `run` calls execute inline instead of blocking on a pool.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with pool parallelism disabled on this thread: every
/// `ThreadPool::run` reached from `f` executes its batch inline. Used by
/// benches to measure the single-threaded kernel baseline, and by the
/// simulated cluster so an inline machine/leader task is timed as the one
/// machine it models. The flag is restored even if `f` unwinds.
pub fn with_serial<R>(f: impl FnOnce() -> R) -> R {
    struct Reset(bool);
    impl Drop for Reset {
        fn drop(&mut self) {
            IN_POOL_WORKER.with(|flag| flag.set(self.0));
        }
    }
    let prev = IN_POOL_WORKER.with(|flag| flag.replace(true));
    let _reset = Reset(prev);
    f()
}

/// Type-erased pointer to the batch closure. The lifetime is erased when a
/// batch is installed; `ThreadPool::run` keeps the referent alive until the
/// batch fully drains, so workers never dereference a dangling pointer.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the referent is Sync (shared calls are fine) and outlives every
// dereference (see `ThreadPool::run`).
unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

struct Batch {
    task: TaskPtr,
    total: usize,
    /// Next unclaimed index.
    next: usize,
    /// Claimed but not yet finished indices.
    active: usize,
    epoch: u64,
    /// First panic payload observed in this batch, if any.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct State {
    batch: Option<Batch>,
    shutdown: bool,
    /// Epoch of the most recently installed batch.
    next_epoch: u64,
    /// Epoch of the most recently completed batch.
    last_done: u64,
    /// Panic payloads of completed batches, keyed by epoch, waiting for
    /// their submitter to pick them up and resume unwinding.
    panics: Vec<(u64, Box<dyn std::any::Any + Send>)>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a batch with unclaimed indices.
    work: Condvar,
    /// Submitters wait here for a free slot / their batch's completion.
    done: Condvar,
}

/// A persistent fixed-size worker pool exposing a blocking parallel-for.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ThreadPool(workers={})", self.workers.len())
    }
}

impl ThreadPool {
    /// A pool of `threads` workers. `threads <= 1` spawns no OS threads:
    /// every `run` then executes inline on the caller.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                batch: None,
                shutdown: false,
                next_epoch: 0,
                last_done: 0,
                panics: Vec::new(),
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let n_workers = if threads <= 1 { 0 } else { threads };
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let sh = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("mr-pool-{i}"))
                .spawn(move || {
                    IN_POOL_WORKER.with(|flag| flag.set(true));
                    worker_loop(&sh);
                })
                .expect("failed to spawn pool worker");
            workers.push(handle);
        }
        ThreadPool { shared, workers }
    }

    /// Number of worker threads (0 means `run` is always inline).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Blocking parallel-for: calls `task(0..total)` exactly once each,
    /// spread over the workers, and returns when all calls finished. Runs
    /// inline when the pool has no workers, `total <= 1`, or the caller is
    /// itself a pool worker (nested parallelism).
    #[allow(clippy::transmutes_expressible_as_ptr_casts)]
    pub fn run(&self, total: usize, task: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        let inline =
            self.workers.is_empty() || total == 1 || IN_POOL_WORKER.with(|flag| flag.get());
        if inline {
            for i in 0..total {
                task(i);
            }
            return;
        }

        // SAFETY: the referent stays borrowed for the whole call, and this
        // function does not return until the batch is fully drained, so
        // erasing the lifetime cannot leave workers a dangling pointer.
        let ptr = TaskPtr(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(task)
        });

        let mut st = self.shared.state.lock().expect("pool state poisoned");
        // One batch at a time; concurrent submitters queue up here.
        while st.batch.is_some() {
            st = self.shared.done.wait(st).expect("pool state poisoned");
        }
        st.next_epoch += 1;
        let epoch = st.next_epoch;
        st.batch = Some(Batch {
            task: ptr,
            total,
            next: 0,
            active: 0,
            epoch,
            panic: None,
        });
        self.shared.work.notify_all();
        while st.last_done < epoch {
            st = self.shared.done.wait(st).expect("pool state poisoned");
        }
        if let Some(pos) = st.panics.iter().position(|(e, _)| *e == epoch) {
            let (_, payload) = st.panics.swap_remove(pos);
            drop(st);
            resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("pool state poisoned");
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        // Claim the next unclaimed index of the current batch.
        let (task, index, epoch) = {
            let mut st = shared.state.lock().expect("pool state poisoned");
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(b) = st.batch.as_mut() {
                    if b.next < b.total {
                        let i = b.next;
                        b.next += 1;
                        b.active += 1;
                        break (b.task, i, b.epoch);
                    }
                }
                st = shared.work.wait(st).expect("pool state poisoned");
            }
        };

        // Execute outside the lock; contain panics so the batch still
        // completes and the submitter can re-raise them.
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (&*task.0)(index) }));

        let mut st = shared.state.lock().expect("pool state poisoned");
        let finished = {
            let b = st
                .batch
                .as_mut()
                .expect("batch cleared while tasks were active");
            debug_assert_eq!(b.epoch, epoch);
            if let Err(payload) = result {
                if b.panic.is_none() {
                    b.panic = Some(payload);
                }
            }
            b.active -= 1;
            b.next >= b.total && b.active == 0
        };
        if finished {
            let b = st.batch.take().expect("batch vanished");
            st.last_done = b.epoch;
            if let Some(payload) = b.panic {
                st.panics.push((b.epoch, payload));
            }
            shared.done.notify_all();
        }
    }
}

/// The process-wide pool used by the numeric kernels ([`crate::runtime`])
/// and cost evaluation. Sized by the `MRCLUSTER_POOL_THREADS` env var
/// (unset or 0 → available cores).
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let threads = std::env::var("MRCLUSTER_POOL_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
            });
        ThreadPool::new(threads)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(100, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn single_thread_pool_is_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.worker_count(), 0);
        let sum = AtomicUsize::new(0);
        pool.run(10, &|i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn batches_reuse_workers() {
        let pool = ThreadPool::new(3);
        let ids = std::sync::Mutex::new(std::collections::HashSet::new());
        for _ in 0..20 {
            let count = AtomicUsize::new(0);
            pool.run(16, &|_| {
                count.fetch_add(1, Ordering::SeqCst);
                ids.lock().unwrap().insert(std::thread::current().id());
            });
            assert_eq!(count.load(Ordering::SeqCst), 16);
        }
        assert!(
            ids.lock().unwrap().len() <= 3,
            "batches must reuse the 3 persistent workers"
        );
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = ThreadPool::new(2);
        let count = AtomicUsize::new(0);
        pool.run(4, &|_| {
            // A worker resubmitting to its own pool must not deadlock.
            pool.run(8, &|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn with_serial_disables_parallelism() {
        let pool = ThreadPool::new(4);
        let main_thread = std::thread::current().id();
        let saw_other = std::sync::Mutex::new(false);
        with_serial(|| {
            pool.run(8, &|_| {
                if std::thread::current().id() != main_thread {
                    *saw_other.lock().unwrap() = true;
                }
            });
        });
        assert!(!*saw_other.lock().unwrap(), "serial scope must stay inline");
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "worker panic must reach the submitter");
        // The pool must still be usable afterwards.
        let count = AtomicUsize::new(0);
        pool.run(8, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn concurrent_submitters_both_complete() {
        let pool = ThreadPool::new(2);
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                pool.run(50, &|_| {
                    a.fetch_add(1, Ordering::SeqCst);
                });
            });
            scope.spawn(|| {
                pool.run(50, &|_| {
                    b.fetch_add(1, Ordering::SeqCst);
                });
            });
        });
        assert_eq!(a.load(Ordering::SeqCst), 50);
        assert_eq!(b.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn global_pool_exists() {
        let g = global();
        let count = AtomicUsize::new(0);
        g.run(4, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
    }
}
