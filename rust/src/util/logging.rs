//! Tiny stderr logger wired into the `log` facade.
//!
//! Level comes from `MRCLUSTER_LOG` (error|warn|info|debug|trace), default
//! `info`. Install once from `main()` / test setup via [`init`]. The logger
//! is a static (the vendored `log` crate has no `set_boxed_logger`).

use log::{Level, LevelFilter, Metadata, Record};
use std::io::Write;
use std::sync::{Once, OnceLock};
use std::time::Instant;

static INIT: Once = Once::new();
static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: StderrLogger = StderrLogger;

/// Process-relative time origin (first call wins; [`init`] pins it early).
fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = start().elapsed();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            let mut err = std::io::stderr().lock();
            let _ = writeln!(
                err,
                "[{:>9.3}s {} {}] {}",
                t.as_secs_f64(),
                lvl,
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        start();
        let filter = match std::env::var("MRCLUSTER_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        if log::set_logger(&LOGGER).is_ok() {
            log::set_max_level(filter);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
