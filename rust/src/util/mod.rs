//! Small self-contained substrates the rest of the crate builds on.
//!
//! Everything here is implemented in-tree because the build environment is
//! offline (see the dependency policy in the workspace `Cargo.toml`): a
//! deterministic RNG with the samplers the
//! paper's data generator needs, a minimal JSON reader for the AOT artifact
//! manifest, a stderr logger, wall-clock helpers, and table formatting for
//! the experiment drivers.

pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod table;
pub mod timer;

/// Ceiling division for usize (used all over the partitioning code).
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Natural logarithm of `n` clamped below at 1.0 — the paper's `log n`
/// factors; the clamp keeps tiny test instances from degenerating.
#[inline]
pub fn log_n(n: usize) -> f64 {
    (n.max(2) as f64).ln().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_exact_and_remainder() {
        assert_eq!(div_ceil(10, 5), 2);
        assert_eq!(div_ceil(11, 5), 3);
        assert_eq!(div_ceil(1, 1), 1);
        assert_eq!(div_ceil(0, 7), 0);
    }

    #[test]
    fn log_n_clamps() {
        assert_eq!(log_n(0), 1.0);
        assert_eq!(log_n(2), 1.0);
        assert!((log_n(1000) - (1000f64).ln()).abs() < 1e-12);
    }
}
