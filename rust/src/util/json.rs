//! Minimal JSON reader — just enough for `artifacts/manifest.json`.
//!
//! Offline build: no serde. This is a strict, recursive-descent parser for
//! the JSON subset the AOT exporter emits (objects, arrays, strings with
//! escapes, numbers, booleans, null). It rejects trailing garbage and is
//! fully covered by unit tests below.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing characters).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset the parser stopped at.
    pub offset: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed by the manifest;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "version": 1,
          "format": "hlo-text",
          "entries": [
            {"func": "assign", "b": 2048, "k": 32, "d": 3,
             "file": "assign_b2048_k32_d3.hlo.txt", "n_outputs": 2}
          ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("func").unwrap().as_str(), Some("assign"));
        assert_eq!(e.get("b").unwrap().as_usize(), Some(2048));
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("0").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn strings_and_escapes() {
        assert_eq!(
            Json::parse(r#""a\nb\t\"c\" A""#).unwrap(),
            Json::Str("a\nb\t\"c\" A".into())
        );
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"[1, [2, {"x": [3]}], []]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(
            a[1].as_arr().unwrap()[1].get("x").unwrap().as_arr().unwrap()[0],
            Json::Num(3.0)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(Json::parse(r#""héllo→""#).unwrap(), Json::Str("héllo→".into()));
    }
}
