//! Deterministic pseudo-random generation for reproducible experiments.
//!
//! Core generator is xoshiro256++ seeded through SplitMix64 (the standard
//! seeding recipe), plus the samplers the paper's experiment section needs:
//! uniform reals, Box–Muller normals (the point spread around each planted
//! center, §4.2), a Zipf-weighted categorical (cluster sizes), Bernoulli
//! (Iterative-Sample's inclusion probabilities) and Fisher–Yates selection.
//!
//! Every component of the system takes an explicit `Rng` (or a seed) — there
//! is no global RNG — so whole Figure-1 runs replay bit-identically.

/// xoshiro256++ PRNG. Not cryptographic; fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// An independent child stream (used to give each simulated machine its
    /// own generator so machine-parallel runs replay deterministically
    /// regardless of scheduling).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// The next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 128-bit multiply keeps the modulo bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — data generation is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates: choose `m` distinct indices out of [0, n).
    pub fn sample_distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} distinct from {n}");
        // Partial Fisher–Yates over an index map: O(m) memory when m << n
        // would need a hashmap; n is small whenever we call this (seeding).
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Sample from a discrete distribution given cumulative weights
    /// (strictly increasing, last element = total mass).
    pub fn categorical_cdf(&mut self, cdf: &[f64]) -> usize {
        let total = *cdf.last().expect("empty cdf");
        let u = self.f64() * total;
        // Binary search for the first cdf entry > u.
        match cdf.binary_search_by(|&c| {
            if c <= u {
                std::cmp::Ordering::Less
            } else {
                std::cmp::Ordering::Greater
            }
        }) {
            Ok(i) | Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Zipf-weighted categorical over `k` categories: weight of category `i`
/// (1-based) is `i^-alpha`. `alpha = 0` is uniform — the paper's Figure 1/2
/// setting; larger alpha skews cluster sizes (§4.2).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Precompute the CDF of a `k`-category Zipf(`alpha`) distribution.
    pub fn new(k: usize, alpha: f64) -> Self {
        assert!(k > 0);
        let mut cdf = Vec::with_capacity(k);
        let mut acc = 0.0;
        for i in 1..=k {
            acc += (i as f64).powf(-alpha);
            cdf.push(acc);
        }
        Zipf { cdf }
    }

    /// Draw one category index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        rng.categorical_cdf(&self.cdf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(13);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(100, 25);
        assert_eq!(s.len(), 25);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 25);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn zipf_uniform_when_alpha_zero() {
        let z = Zipf::new(4, 0.0);
        let mut r = Rng::new(9);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[z.sample(&mut r)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts {counts:?}");
        }
    }

    #[test]
    fn zipf_skew_orders_counts() {
        let z = Zipf::new(5, 1.5);
        let mut r = Rng::new(17);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        for w in counts.windows(2) {
            assert!(w[0] > w[1], "zipf counts must decrease: {counts:?}");
        }
    }

    #[test]
    fn fork_streams_are_unrelated() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn categorical_cdf_picks_correct_bucket() {
        let mut r = Rng::new(21);
        // Mass only on bucket 1.
        let cdf = vec![0.0, 1.0, 1.0];
        for _ in 0..100 {
            assert_eq!(r.categorical_cdf(&cdf), 1);
        }
    }
}
