//! # mrcluster — Fast Clustering using MapReduce
//!
//! A full reproduction of *Fast Clustering using MapReduce* (Ene, Im,
//! Moseley — KDD 2011) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — a simulated-cluster [`mapreduce`] engine
//!   (machines, rounds, shuffle, per-machine memory and time accounting,
//!   `MRC^0` constraint checks) and, on top of it, the paper's algorithms in
//!   [`coordinator`]: `MapReduce-Iterative-Sample` (Algorithm 3),
//!   `MapReduce-kCenter` (Algorithm 4), `MapReduce-kMedian` (Algorithm 5),
//!   `MapReduce-Divide-kMedian` (Algorithm 6) and `Parallel-Lloyd`, plus all
//!   sequential baselines in [`algorithms`]. Beyond the paper, the
//!   [`summaries`] layer adds composable weighted coresets, the
//!   outlier-robust pipelines live in [`coordinator::robust`], and every
//!   layer is parameterized over pluggable metric spaces
//!   ([`geometry::MetricKind`]: `l2sq`/`l2`/`l1`/`cosine`/`chebyshev`,
//!   selected via `cluster.metric`) — honoring the paper's general-metric
//!   statement of its algorithms. The [`serve`] layer turns the composable
//!   summaries into a long-lived serving mode: incremental coreset epochs
//!   with a concurrent, snapshot-isolated query path.
//! * **L2/L1 (python, build-time only)** — the numeric hot loop
//!   (blocked nearest-center assignment and Lloyd accumulation) written in
//!   JAX calling a Pallas kernel, AOT-lowered to HLO-text artifacts.
//! * **[`runtime`]** — loads those artifacts through the PJRT C API (`xla`
//!   crate) and exposes them behind [`runtime::ComputeBackend`], with a
//!   pure-rust [`runtime::NativeBackend`] fallback that shares the exact
//!   same semantics (cross-checked in tests).
//!
//! ## Quickstart
//!
//! ```no_run
//! use mrcluster::prelude::*;
//!
//! let data = DataGenConfig { n: 100_000, k: 25, ..Default::default() }
//!     .generate();
//! let cfg = ClusterConfig { k: 25, ..Default::default() };
//! let outcome = run_algorithm(Algorithm::SamplingLloyd, &data.points, &cfg)
//!     .expect("clustering failed");
//! println!("k-median cost = {:.4}", outcome.cost_median);
//! ```
//!
//! See `examples/` for end-to-end drivers and `ARCHITECTURE.md` (repo
//! root) for the paper-to-module map, the round-by-round pipeline
//! diagrams, and the determinism/recovery contract.

#![warn(missing_docs)]

pub mod algorithms;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod geometry;
pub mod mapreduce;
pub mod metrics;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod sim;
pub mod summaries;
pub mod util;

pub use config::{ClusterConfig, ConstantsProfile};
pub use coordinator::{run_algorithm, run_algorithm_store, Algorithm, Outcome};
pub use data::DataGenConfig;
pub use geometry::{MetricKind, PointSet, PointStore};

/// Convenient glob-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::{gonzalez, lloyd, local_search};
    pub use crate::config::{ClusterConfig, ConstantsProfile, RuntimeBackendKind};
    pub use crate::coordinator::{run_algorithm, Algorithm, Outcome};
    pub use crate::data::{DataGenConfig, Dataset};
    pub use crate::geometry::{FileStore, Metric, MetricKind, PointSet, PointStore};
    pub use crate::mapreduce::{MrCluster, MrConfig, RunStats};
    pub use crate::metrics::{
        kcenter_cost, kcenter_cost_metric, kcenter_cost_with_outliers, kmeans_cost,
        kmedian_cost, kmedian_cost_metric, kmedian_cost_with_outliers,
    };
    pub use crate::runtime::{ComputeBackend, NativeBackend};
    pub use crate::sampling::{IterativeSampleConfig, SampleConstants};
    pub use crate::serve::{IngestLog, Model, ModelSlot, QueryEngine, QueryResponse, ServeEngine};
    pub use crate::sim::{ClusterSim, Heterogeneity, NetworkKind, Placement, SimConfig};
    pub use crate::summaries::{Coreset, CoverageSummary, WeightedSet};
    pub use crate::util::rng::Rng;
}
