//! Weighted coreset summaries — the composable-summary layer under the
//! outlier-robust pipelines.
//!
//! The paper's pipelines (Iterative-Sample, Divide, Parallel-Lloyd) all
//! compress data before running an expensive sequential `A`, but each one
//! re-derives its own ad-hoc "points + weights" representation. This module
//! makes that representation first-class, following the *composable
//! coreset* structure of Mazzetto, Pietracaprina and Pucci (accurate
//! MapReduce k-median/k-means in general metric spaces) and the per-machine
//! coverage summaries of Ceccarello, Pietracaprina and Pucci (k-center with
//! outliers in MapReduce and streaming):
//!
//! * [`WeightedSet`] — points plus `f64` weights. The point block is a
//!   zero-copy [`crate::geometry::PointSet`] view, so building a summary
//!   over a machine's resident partition never copies coordinates.
//! * [`Coreset`] — the compositional contract: `compose(a, b)` merges two
//!   summaries **associatively and commutatively, bit-for-bit**, so
//!   summaries can meet in any order inside a reduce step (the engine's
//!   shuffle order is unspecified) without breaking the engine's
//!   bit-identical recovery guarantee.
//! * [`CoverageSummary`] — the concrete per-machine summary the robust
//!   coordinators use: a weighted farthest-point skeleton of the machine's
//!   block plus the coverage radius, composed across machines inside a
//!   reduce round and handed to the final sequential step
//!   ([`crate::algorithms::outliers`]).
//!
//! The bit-exactness requirement is why [`Coreset::compose`] is a
//! *canonical multiset union*: entries are kept in a canonical total order
//! and never arithmetically combined during composition (floating-point
//! addition is not associative), so any compose tree over the same
//! summaries yields the same bytes. `rust/tests/prop_summaries.rs`
//! property-tests exactly this.

pub mod coreset;
pub mod weighted;

pub use coreset::{Coreset, CoverageSummary};
pub use weighted::WeightedSet;
