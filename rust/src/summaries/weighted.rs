//! [`WeightedSet`]: points with `f64` weights, the currency of the
//! summary layer.
//!
//! A weighted set is the universal interface between the distributed
//! phases (which compress a partition down to few representatives, each
//! standing in for the input points it covers) and the sequential weighted
//! algorithms (`lloyd`, `local_search`, the outlier-robust k-center) that
//! consume them. The point block is an ordinary [`PointSet`], so a
//! weighted view over a machine's resident partition shares the partition's
//! `Arc` storage instead of copying coordinates.

use crate::geometry::PointSet;
use crate::mapreduce::MemSize;

/// A set of points in `R^dim`, each carrying a non-negative `f64` weight.
///
/// Weights mean "how many input points this entry represents" (they are
/// fractional-capable because downstream algorithms rescale them). The
/// entry order is significant: [`WeightedSet::canonicalize`] sorts entries
/// into a canonical total order so that two weighted sets holding the same
/// multiset of `(point, weight)` entries become bit-identical — the
/// property [`crate::summaries::Coreset::compose`] is built on.
#[derive(Clone, Debug)]
pub struct WeightedSet {
    points: PointSet,
    weights: Vec<f64>,
}

/// Equality is element-wise over points and weights (entry order matters;
/// canonicalize both sides first to compare as multisets).
impl PartialEq for WeightedSet {
    fn eq(&self, other: &Self) -> bool {
        self.points == other.points
            && self.weights.len() == other.weights.len()
            && self
                .weights
                .iter()
                .zip(&other.weights)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

impl WeightedSet {
    /// Pair `points` with explicit `weights` (must agree in length).
    pub fn new(points: PointSet, weights: Vec<f64>) -> Self {
        assert_eq!(
            points.len(),
            weights.len(),
            "weights/points length mismatch"
        );
        WeightedSet { points, weights }
    }

    /// Every point with unit weight — the embedding of an unweighted block.
    /// Zero-copy: the returned set borrows `points`' storage.
    pub fn unit(points: PointSet) -> Self {
        let n = points.len();
        WeightedSet {
            points,
            weights: vec![1.0; n],
        }
    }

    /// An empty set of the given dimensionality.
    pub fn with_capacity(dim: usize, cap: usize) -> Self {
        WeightedSet {
            points: PointSet::with_capacity(dim, cap),
            weights: Vec::with_capacity(cap),
        }
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when the set holds no entries.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Point dimensionality.
    pub fn dim(&self) -> usize {
        self.points.dim()
    }

    /// The underlying point block (a zero-copy view where possible).
    pub fn points(&self) -> &PointSet {
        &self.points
    }

    /// All weights, entry-aligned with [`WeightedSet::points`].
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Coordinates of entry `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        self.points.row(i)
    }

    /// Weight of entry `i`.
    pub fn weight(&self, i: usize) -> f64 {
        self.weights[i]
    }

    /// Total represented weight, summed in entry order (deterministic for a
    /// canonicalized set).
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }

    /// The weights narrowed to `f32`, for the weighted sequential
    /// algorithms ([`crate::algorithms::local_search`],
    /// [`crate::algorithms::lloyd`]) whose interface predates this module.
    pub fn weights_f32(&self) -> Vec<f32> {
        self.weights.iter().map(|&w| w as f32).collect()
    }

    /// Append one `(point, weight)` entry.
    pub fn push(&mut self, row: &[f32], weight: f64) {
        self.points.push(row);
        self.weights.push(weight);
    }

    /// Append all entries of `other` (must agree on dim).
    pub fn extend(&mut self, other: &WeightedSet) {
        self.points.extend(&other.points);
        self.weights.extend_from_slice(&other.weights);
    }

    /// New set holding the entries at `indices`, in that order.
    pub fn gather(&self, indices: &[usize]) -> WeightedSet {
        WeightedSet {
            points: self.points.gather(indices),
            weights: indices.iter().map(|&i| self.weights[i]).collect(),
        }
    }

    /// Indices of all entries in the canonical total order: rows compared
    /// lexicographically by `f32::total_cmp`, ties broken by the weight's
    /// bit pattern. The order depends only on entry *values*, never on the
    /// arrival order — the keystone of bit-identical composition.
    fn canonical_order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        idx.sort_by(|&a, &b| {
            for (x, y) in self.row(a).iter().zip(self.row(b)) {
                match x.total_cmp(y) {
                    std::cmp::Ordering::Equal => continue,
                    ord => return ord,
                }
            }
            self.weights[a].total_cmp(&self.weights[b])
        });
        idx
    }

    /// The same multiset of entries, rearranged into the canonical total
    /// order. Two sets holding equal entry multisets canonicalize to
    /// bit-identical sets regardless of how the entries arrived.
    pub fn canonicalize(&self) -> WeightedSet {
        self.gather(&self.canonical_order())
    }

    /// True when the entries are already in canonical order.
    pub fn is_canonical(&self) -> bool {
        self.canonical_order().windows(2).all(|w| w[0] < w[1])
    }
}

impl MemSize for WeightedSet {
    fn mem_bytes(&self) -> usize {
        self.points.mem_bytes() + self.weights.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wset(entries: &[(&[f32], f64)]) -> WeightedSet {
        let mut s = WeightedSet::with_capacity(entries[0].0.len(), entries.len());
        for (row, w) in entries {
            s.push(row, *w);
        }
        s
    }

    #[test]
    fn unit_embeds_unweighted_block_zero_copy() {
        let p = PointSet::from_flat(2, vec![0.0, 1.0, 2.0, 3.0]);
        let w = WeightedSet::unit(p.clone());
        assert_eq!(w.len(), 2);
        assert_eq!(w.total_weight(), 2.0);
        assert!(w.points().shares_storage(&p), "unit() must not copy");
    }

    #[test]
    fn push_extend_gather_roundtrip() {
        let mut a = wset(&[(&[1.0], 2.0)]);
        let b = wset(&[(&[3.0], 4.0), (&[5.0], 6.0)]);
        a.extend(&b);
        assert_eq!(a.len(), 3);
        let g = a.gather(&[2, 0]);
        assert_eq!(g.row(0), &[5.0]);
        assert_eq!(g.weight(1), 2.0);
    }

    #[test]
    fn canonicalize_is_arrival_order_insensitive() {
        let a = wset(&[(&[2.0, 0.0], 1.0), (&[1.0, 9.0], 3.0), (&[2.0, 0.0], 0.5)]);
        let b = wset(&[(&[2.0, 0.0], 0.5), (&[2.0, 0.0], 1.0), (&[1.0, 9.0], 3.0)]);
        assert_ne!(a, b);
        assert_eq!(a.canonicalize(), b.canonicalize());
        assert!(a.canonicalize().is_canonical());
    }

    #[test]
    fn canonical_order_breaks_coordinate_ties_by_weight() {
        let s = wset(&[(&[1.0], 5.0), (&[1.0], 2.0)]);
        let c = s.canonicalize();
        assert_eq!(c.weight(0), 2.0);
        assert_eq!(c.weight(1), 5.0);
    }

    #[test]
    fn weights_f32_narrow() {
        let s = wset(&[(&[0.0], 1.5), (&[1.0], 2.5)]);
        assert_eq!(s.weights_f32(), vec![1.5f32, 2.5]);
    }

    #[test]
    fn mem_bytes_counts_points_and_weights() {
        let s = wset(&[
            (&[0.0, 0.0], 1.0),
            (&[1.0, 0.0], 1.0),
            (&[0.0, 1.0], 1.0),
            (&[1.0, 1.0], 1.0),
        ]);
        assert!(s.mem_bytes() >= 4 * 2 * 4 + 4 * 8);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn new_rejects_mismatched_lengths() {
        WeightedSet::new(PointSet::from_flat(1, vec![1.0]), vec![1.0, 2.0]);
    }
}
