//! The [`Coreset`] composition contract and the concrete
//! [`CoverageSummary`] the robust coordinators ship between machines.

use super::weighted::WeightedSet;
use crate::geometry::{MetricKind, PointSet};
use crate::mapreduce::MemSize;
use crate::runtime::ComputeBackend;
use crate::util::rng::Rng;

/// A summary that composes associatively and commutatively, **bit-for-bit**.
///
/// `compose(a, b)` must satisfy, as exact byte equality (not approximate
/// equality):
///
/// * commutativity — `compose(a, b) == compose(b, a)`;
/// * associativity — `compose(compose(a, b), c) == compose(a, compose(b, c))`.
///
/// This is what lets per-machine summaries meet inside a reduce step in
/// *whatever order the shuffle delivers them* — and lets a replayed
/// (recovered) reduce task regenerate the identical bytes its failed
/// attempt lost — without weakening the engine's bit-identical recovery
/// guarantee. Implementations achieve it by keeping entries in a canonical
/// total order and never arithmetically combining them during composition
/// (see [`WeightedSet::canonicalize`]); `rust/tests/prop_summaries.rs`
/// property-tests the contract under random permutations and groupings.
///
/// # Examples
///
/// ```
/// use mrcluster::geometry::PointSet;
/// use mrcluster::runtime::NativeBackend;
/// use mrcluster::summaries::{Coreset, CoverageSummary};
///
/// // Two machines summarize their resident blocks independently...
/// let left = CoverageSummary::build(
///     &PointSet::from_flat(1, vec![0.0, 0.1, 5.0]), 2, 1, &NativeBackend);
/// let right = CoverageSummary::build(
///     &PointSet::from_flat(1, vec![9.0, 9.2]), 1, 2, &NativeBackend);
///
/// // ...and the merged summary is the same bytes in either merge order.
/// let ab = Coreset::compose(left.clone(), right.clone());
/// let ba = Coreset::compose(right, left);
/// assert_eq!(ab, ba);
/// assert_eq!(ab.total_weight(), 5.0); // every input point is represented
/// ```
pub trait Coreset: Sized {
    /// Merge two summaries into one covering the union of their inputs.
    fn compose(a: Self, b: Self) -> Self;

    /// Total input weight this summary represents.
    fn total_weight(&self) -> f64;
}

/// A per-machine *coverage summary* (Ceccarello et al. style): a
/// farthest-point skeleton of the machine's resident block in which every
/// representative is weighted by the number of block points it covers,
/// plus the coverage radius (the largest distance from a block point to
/// its representative).
///
/// Because a far outlier is, by construction of the farthest-point
/// traversal, selected as its *own* representative (with weight ≈ 1), the
/// summary preserves outliers as identifiable low-weight entries — which
/// is exactly what the final outlier-robust sequential step needs
/// ([`crate::algorithms::outliers::kcenter_with_outliers`]).
#[derive(Clone, Debug, PartialEq)]
pub struct CoverageSummary {
    /// Weighted representatives, always in canonical order.
    reps: WeightedSet,
    /// max over summarized points of d(point, its representative): the
    /// summary's proxy error. Composition takes the max.
    radius: f64,
}

impl CoverageSummary {
    /// Summarize `block` down to at most `size` weighted representatives
    /// via a farthest-point traversal seeded by `seed` (the traversal's
    /// start point is the only random choice, so a fixed seed makes the
    /// summary a pure function of the block — the property recovery replay
    /// relies on). The coverage counts run through `backend`'s assignment
    /// kernel. Squared-Euclidean form of [`CoverageSummary::build_metric`].
    pub fn build(
        block: &PointSet,
        size: usize,
        seed: u64,
        backend: &dyn ComputeBackend,
    ) -> CoverageSummary {
        CoverageSummary::build_metric(block, size, seed, backend, MetricKind::L2Sq)
    }

    /// [`CoverageSummary::build`] under an explicit metric: the
    /// farthest-point skeleton, the coverage counts, and the coverage
    /// radius are all taken in `metric`'s geometry (the radius is the true
    /// metric distance, not a surrogate).
    pub fn build_metric(
        block: &PointSet,
        size: usize,
        seed: u64,
        backend: &dyn ComputeBackend,
        metric: MetricKind,
    ) -> CoverageSummary {
        assert!(size >= 1, "summary size must be positive");
        if block.is_empty() {
            return CoverageSummary {
                reps: WeightedSet::with_capacity(block.dim(), 0),
                radius: 0.0,
            };
        }
        let mut rng = Rng::new(seed);
        let skeleton =
            crate::algorithms::gonzalez::gonzalez_metric(block, size, &mut rng, metric);
        let assign = backend.assign_metric(block, &skeleton.centers, metric);
        let mut weights = vec![0.0f64; skeleton.centers.len()];
        let mut max_s = 0.0f32;
        for (&c, &s) in assign.idx.iter().zip(&assign.sqdist) {
            weights[c as usize] += 1.0;
            if s > max_s {
                max_s = s;
            }
        }
        CoverageSummary {
            reps: WeightedSet::new(skeleton.centers, weights).canonicalize(),
            radius: metric.to_dist_f64(max_s),
        }
    }

    /// Wrap an existing weighted set as a summary (canonicalizing it) with
    /// a caller-supplied coverage radius.
    pub fn from_weighted(reps: WeightedSet, radius: f64) -> CoverageSummary {
        CoverageSummary {
            reps: reps.canonicalize(),
            radius,
        }
    }

    /// The canonical weighted representatives.
    pub fn reps(&self) -> &WeightedSet {
        &self.reps
    }

    /// Coverage radius: an upper bound on how far any summarized input
    /// point lies from its representative.
    pub fn radius(&self) -> f64 {
        self.radius
    }

    /// Number of representatives.
    pub fn len(&self) -> usize {
        self.reps.len()
    }

    /// True when the summary holds no representatives.
    pub fn is_empty(&self) -> bool {
        self.reps.is_empty()
    }

    /// Compose every summary in `summaries` with **one** canonicalization,
    /// byte-identical to folding them pairwise with [`Coreset::compose`]
    /// under *any* compose tree. Returns `None` for an empty iterator (the
    /// composition has no identity element carrying a dimensionality).
    ///
    /// A pairwise fold re-sorts the accumulated entries at every step —
    /// O(depth · m log m) gather work over a long ingest chain. Because
    /// composition never combines entries arithmetically, the fold's result
    /// is exactly `canonicalize(multiset union of all entries)` with the
    /// max radius, so concatenating everything first and canonicalizing
    /// once produces the identical bytes (entries that tie in the canonical
    /// order are themselves bitwise equal, so their mutual order cannot
    /// matter). `rust/tests/prop_serve.rs` pins the equivalence across fold
    /// depths and tree shapes. This is what the serving layer's epoch
    /// folding uses to canonicalize once per publish.
    pub fn compose_all<I>(summaries: I) -> Option<CoverageSummary>
    where
        I: IntoIterator<Item = CoverageSummary>,
    {
        let mut iter = summaries.into_iter();
        let first = iter.next()?;
        let empty_dim = first.reps.dim();
        let mut radius = first.radius;
        let mut parts: Vec<WeightedSet> = Vec::new();
        let mut entries = 0usize;
        if !first.reps.is_empty() {
            entries = first.reps.len();
            parts.push(first.reps);
        }
        for s in iter {
            radius = radius.max(s.radius);
            if s.reps.is_empty() {
                continue;
            }
            if let Some(head) = parts.first() {
                assert_eq!(s.reps.dim(), head.dim(), "summary dim mismatch");
            }
            entries += s.reps.len();
            parts.push(s.reps);
        }
        let reps = match parts.len() {
            // All inputs empty: the fold's empty-side shortcut would thread
            // the (empty) reps through unchanged.
            0 => WeightedSet::with_capacity(empty_dim, 0),
            // One non-empty input: its reps are already canonical and the
            // fold would return them untouched.
            1 => parts.pop().expect("len checked"),
            _ => {
                let mut merged =
                    WeightedSet::with_capacity(parts[0].dim(), entries);
                for p in &parts {
                    merged.extend(p);
                }
                merged.canonicalize()
            }
        };
        Some(CoverageSummary { reps, radius })
    }
}

impl Coreset for CoverageSummary {
    /// Canonical multiset union of the representatives; the radius is the
    /// max of the two. No weights are added during composition, so the
    /// result's bytes are independent of the compose tree.
    fn compose(a: Self, b: Self) -> Self {
        if a.reps.is_empty() {
            return CoverageSummary {
                radius: a.radius.max(b.radius),
                reps: b.reps,
            };
        }
        if b.reps.is_empty() {
            return CoverageSummary {
                radius: a.radius.max(b.radius),
                reps: a.reps,
            };
        }
        assert_eq!(a.reps.dim(), b.reps.dim(), "summary dim mismatch");
        let mut merged = WeightedSet::with_capacity(a.reps.dim(), a.len() + b.len());
        merged.extend(&a.reps);
        merged.extend(&b.reps);
        CoverageSummary {
            reps: merged.canonicalize(),
            radius: a.radius.max(b.radius),
        }
    }

    fn total_weight(&self) -> f64 {
        self.reps.total_weight()
    }
}

impl MemSize for CoverageSummary {
    fn mem_bytes(&self) -> usize {
        self.reps.mem_bytes() + std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn line(coords: &[f32]) -> PointSet {
        PointSet::from_flat(1, coords.to_vec())
    }

    #[test]
    fn build_covers_all_weight() {
        let block = line(&[0.0, 0.1, 0.2, 5.0, 5.1, 9.0]);
        let s = CoverageSummary::build(&block, 3, 7, &NativeBackend);
        assert_eq!(s.len(), 3);
        assert_eq!(s.total_weight(), 6.0, "every block point is represented");
        assert!(s.radius() > 0.0 && s.radius() < 0.3, "radius {}", s.radius());
    }

    #[test]
    fn outliers_become_their_own_light_representatives() {
        // 19 clustered points + 1 far outlier, 2 representatives: the
        // farthest-point skeleton must isolate the outlier at weight 1.
        let mut coords: Vec<f32> = (0..19).map(|i| i as f32 * 0.01).collect();
        coords.push(100.0);
        let s = CoverageSummary::build(&line(&coords), 2, 3, &NativeBackend);
        let weights = s.reps().weights();
        assert!(weights.contains(&1.0), "outlier weight: {weights:?}");
        assert!(weights.contains(&19.0), "bulk weight: {weights:?}");
    }

    #[test]
    fn compose_is_commutative_bitwise() {
        let a = CoverageSummary::build(&line(&[0.0, 0.3, 2.0]), 2, 1, &NativeBackend);
        let b = CoverageSummary::build(&line(&[7.0, 7.5]), 2, 2, &NativeBackend);
        assert_eq!(
            Coreset::compose(a.clone(), b.clone()),
            Coreset::compose(b, a)
        );
    }

    #[test]
    fn compose_is_associative_bitwise() {
        let a = CoverageSummary::build(&line(&[0.0, 0.3]), 2, 1, &NativeBackend);
        let b = CoverageSummary::build(&line(&[7.0]), 1, 2, &NativeBackend);
        let c = CoverageSummary::build(&line(&[3.0, 3.3, 3.4]), 2, 3, &NativeBackend);
        let left = Coreset::compose(Coreset::compose(a.clone(), b.clone()), c.clone());
        let right = Coreset::compose(a, Coreset::compose(b, c));
        assert_eq!(left, right);
    }

    #[test]
    fn compose_tracks_radius_and_weight() {
        let a = CoverageSummary::build(&line(&[0.0, 1.0]), 1, 1, &NativeBackend);
        let b = CoverageSummary::build(&line(&[5.0]), 1, 2, &NativeBackend);
        let ab = Coreset::compose(a.clone(), b.clone());
        assert_eq!(ab.total_weight(), 3.0);
        assert_eq!(ab.radius(), a.radius().max(b.radius()));
        assert_eq!(ab.len(), 2);
    }

    #[test]
    fn empty_blocks_compose_neutrally() {
        let e = CoverageSummary::build(&PointSet::with_capacity(1, 0), 1, 0, &NativeBackend);
        let a = CoverageSummary::build(&line(&[1.0, 2.0]), 2, 1, &NativeBackend);
        assert_eq!(Coreset::compose(e.clone(), a.clone()), a);
        assert_eq!(Coreset::compose(a.clone(), e), a);
    }

    #[test]
    fn summary_is_a_pure_function_of_the_block() {
        let block = line(&[0.0, 0.5, 4.0, 4.5, 9.0]);
        let a = CoverageSummary::build(&block, 3, 11, &NativeBackend);
        let b = CoverageSummary::build(&block, 3, 11, &NativeBackend);
        assert_eq!(a, b, "replay determinism");
    }

    #[test]
    fn build_metric_l2sq_is_bit_identical_to_build() {
        use crate::geometry::MetricKind;
        let block = line(&[0.0, 0.5, 4.0, 4.5, 9.0]);
        let a = CoverageSummary::build(&block, 3, 11, &NativeBackend);
        let b = CoverageSummary::build_metric(&block, 3, 11, &NativeBackend, MetricKind::L2Sq);
        assert_eq!(a, b);
        assert_eq!(a.radius().to_bits(), b.radius().to_bits());
    }

    #[test]
    fn compose_all_matches_pairwise_fold_bitwise() {
        let blocks: Vec<PointSet> = [
            &[0.0f32, 0.3, 2.0][..],
            &[7.0, 7.5],
            &[3.0, 3.3, 3.4],
            &[9.0],
        ]
        .iter()
        .map(|c| line(c))
        .collect();
        let summaries: Vec<CoverageSummary> = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| CoverageSummary::build(b, 2, i as u64 + 1, &NativeBackend))
            .collect();
        let folded = summaries
            .iter()
            .cloned()
            .reduce(Coreset::compose)
            .unwrap();
        let once = CoverageSummary::compose_all(summaries.clone()).unwrap();
        assert_eq!(folded, once);
        assert_eq!(folded.radius().to_bits(), once.radius().to_bits());
        // Single summary passes through untouched.
        let lone = CoverageSummary::compose_all(summaries[..1].to_vec()).unwrap();
        assert_eq!(lone, summaries[0]);
    }

    #[test]
    fn compose_all_handles_empty_inputs_like_the_fold() {
        let e = CoverageSummary::build(&PointSet::with_capacity(1, 0), 1, 0, &NativeBackend);
        let a = CoverageSummary::build(&line(&[1.0, 2.0]), 2, 1, &NativeBackend);
        let all = vec![e.clone(), a.clone(), e.clone()];
        let folded = all.iter().cloned().reduce(Coreset::compose).unwrap();
        let once = CoverageSummary::compose_all(all).unwrap();
        assert_eq!(folded, once);
        // All-empty and zero-length iterators.
        let empties = CoverageSummary::compose_all(vec![e.clone(), e.clone()]).unwrap();
        assert!(empties.is_empty());
        assert!(CoverageSummary::compose_all(std::iter::empty()).is_none());
    }

    #[test]
    fn metric_radius_covers_under_that_metric() {
        use crate::geometry::MetricKind;
        // 2-D block, one representative: the coverage radius must bound
        // every point's L1 distance to the (single) rep.
        let block = PointSet::from_flat(2, vec![0.0, 0.0, 1.0, 1.0, 2.0, 0.5]);
        let s = CoverageSummary::build_metric(&block, 1, 5, &NativeBackend, MetricKind::L1);
        assert_eq!(s.total_weight(), 3.0);
        let rep = s.reps().row(0).to_vec();
        for i in 0..block.len() {
            let d = MetricKind::L1.dist_f64(block.row(i), &rep);
            assert!(d <= s.radius() + 1e-6, "L1 point {i} escapes the radius");
        }
    }
}
