//! Clustering objective evaluation.
//!
//! Exact (non-sampled) cost computation for the three objectives the paper
//! touches: k-median (sum of distances), k-center (max distance) and
//! k-means (sum of squared distances). Evaluation is O(n·k·d); for the
//! multi-million-point Figure-2 runs it is chunked across worker threads.

pub mod cost;
pub mod report;

pub use cost::{
    assign_full, kcenter_cost, kcenter_cost_with_outliers, kmeans_cost, kmedian_cost,
    kmedian_cost_with_outliers, CostSummary,
};
