//! Clustering objective evaluation.
//!
//! Exact (non-sampled) cost computation for the three objectives the paper
//! touches: k-median (sum of distances), k-center (max distance) and
//! k-means (sum of squared distances) — each in a legacy squared-Euclidean
//! form and a [`crate::geometry::MetricKind`]-parameterized `*_metric`
//! form. Evaluation is O(n·k·d); for the multi-million-point Figure-2 runs
//! it is chunked across worker threads.

pub mod cost;
pub mod report;

pub use cost::{
    assign_full, assign_full_metric, kcenter_cost, kcenter_cost_metric,
    kcenter_cost_with_outliers, kcenter_cost_with_outliers_metric, kmeans_cost,
    kmeans_cost_metric, kmedian_cost, kmedian_cost_metric, kmedian_cost_with_outliers,
    kmedian_cost_with_outliers_metric, CostSummary,
};
