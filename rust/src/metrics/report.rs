//! Experiment result records and the normalized tables the paper reports
//! (costs normalized to Parallel-Lloyd, times in seconds) — Figures 1 and 2.

use crate::util::table::Table;
use std::time::Duration;

/// One algorithm run on one workload size.
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// Algorithm display name.
    pub algo: String,
    /// Workload size of this run.
    pub n: usize,
    /// k-median objective of the returned centers over ALL points.
    pub cost_median: f64,
    /// Simulated parallel time (Σ rounds max-machine), paper methodology.
    pub sim_time: Duration,
    /// Real wall-clock of the whole run (all machines on this host).
    pub wall_time: Duration,
    /// MapReduce rounds used.
    pub rounds: usize,
}

/// A Figure-1/2 style result matrix: rows = algorithms, columns = n values.
#[derive(Clone, Debug, Default)]
pub struct FigureReport {
    /// Every n value any record covers (sorted).
    pub ns: Vec<usize>,
    /// All collected records.
    pub records: Vec<RunRecord>,
}

impl FigureReport {
    /// Add one record, registering its n as a column.
    pub fn add(&mut self, rec: RunRecord) {
        if !self.ns.contains(&rec.n) {
            self.ns.push(rec.n);
            self.ns.sort_unstable();
        }
        self.records.push(rec);
    }

    fn find(&self, algo: &str, n: usize) -> Option<&RunRecord> {
        self.records.iter().find(|r| r.algo == algo && r.n == n)
    }

    fn algos(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for r in &self.records {
            if !seen.contains(&r.algo) {
                seen.push(r.algo.clone());
            }
        }
        seen
    }

    /// The paper's cost table: normalized to `baseline` (Parallel-Lloyd).
    pub fn cost_table(&self, baseline: &str) -> Table {
        let mut header = vec!["cost".to_string()];
        header.extend(self.ns.iter().map(|n| format!("n={n}")));
        let mut t = Table::new(header);
        for algo in self.algos() {
            let mut row = vec![algo.clone()];
            for &n in &self.ns {
                let cell = match (self.find(&algo, n), self.find(baseline, n)) {
                    (Some(r), Some(b)) if b.cost_median > 0.0 => {
                        format!("{:.3}", r.cost_median / b.cost_median)
                    }
                    (Some(r), _) => format!("{:.3}", r.cost_median),
                    _ => "N/A".to_string(),
                };
                row.push(cell);
            }
            t.row(row);
        }
        t
    }

    /// The paper's time table (simulated parallel seconds).
    pub fn time_table(&self) -> Table {
        let mut header = vec!["time".to_string()];
        header.extend(self.ns.iter().map(|n| format!("n={n}")));
        let mut t = Table::new(header);
        for algo in self.algos() {
            let mut row = vec![algo.clone()];
            for &n in &self.ns {
                let cell = match self.find(&algo, n) {
                    Some(r) => format!("{:.2}", r.sim_time.as_secs_f64()),
                    None => "N/A".to_string(),
                };
                row.push(cell);
            }
            t.row(row);
        }
        t
    }

    /// Machine-readable CSV (one row per record) for archiving bench runs.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("algo,n,cost_median,sim_time_s,wall_time_s,rounds\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.algo,
                r.n,
                r.cost_median,
                r.sim_time.as_secs_f64(),
                r.wall_time.as_secs_f64(),
                r.rounds
            ));
        }
        out
    }

    /// Speedup of `algo` over `other` at the largest n both ran.
    pub fn speedup(&self, algo: &str, other: &str) -> Option<f64> {
        let n = self
            .ns
            .iter()
            .rev()
            .find(|&&n| self.find(algo, n).is_some() && self.find(other, n).is_some())?;
        let a = self.find(algo, *n)?;
        let b = self.find(other, *n)?;
        let at = a.sim_time.as_secs_f64();
        if at <= 0.0 {
            return None;
        }
        Some(b.sim_time.as_secs_f64() / at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(algo: &str, n: usize, cost: f64, secs: f64) -> RunRecord {
        RunRecord {
            algo: algo.into(),
            n,
            cost_median: cost,
            sim_time: Duration::from_secs_f64(secs),
            wall_time: Duration::from_secs_f64(secs),
            rounds: 3,
        }
    }

    #[test]
    fn normalization_against_baseline() {
        let mut f = FigureReport::default();
        f.add(rec("Parallel-Lloyd", 1000, 10.0, 2.0));
        f.add(rec("Sampling-Lloyd", 1000, 11.0, 0.1));
        let t = f.cost_table("Parallel-Lloyd");
        let s = t.render();
        assert!(s.contains("1.000"), "{s}");
        assert!(s.contains("1.100"), "{s}");
    }

    #[test]
    fn missing_cells_are_na() {
        let mut f = FigureReport::default();
        f.add(rec("Parallel-Lloyd", 1000, 10.0, 2.0));
        f.add(rec("Parallel-Lloyd", 2000, 20.0, 4.0));
        f.add(rec("LocalSearch", 1000, 9.5, 600.0));
        let s = f.cost_table("Parallel-Lloyd").render();
        assert!(s.contains("N/A"), "{s}");
    }

    #[test]
    fn csv_roundtrips_fields() {
        let mut f = FigureReport::default();
        f.add(rec("Parallel-Lloyd", 1000, 10.0, 2.0));
        let csv = f.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("algo,n,"));
        assert!(lines[1].starts_with("Parallel-Lloyd,1000,10,2,"));
    }

    #[test]
    fn speedup_uses_largest_common_n() {
        let mut f = FigureReport::default();
        f.add(rec("Parallel-Lloyd", 1000, 10.0, 2.0));
        f.add(rec("Parallel-Lloyd", 4000, 10.0, 8.0));
        f.add(rec("Sampling-Lloyd", 1000, 10.0, 1.0));
        f.add(rec("Sampling-Lloyd", 4000, 10.0, 0.4));
        let s = f.speedup("Sampling-Lloyd", "Parallel-Lloyd").unwrap();
        assert!((s - 20.0).abs() < 1e-9);
    }
}
