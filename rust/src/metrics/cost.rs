//! Exact objective evaluation, threaded for large n over the shared
//! worker pool (no per-call thread spawns).
//!
//! Every evaluator exists in two forms: the plain functions are the
//! squared-Euclidean (`l2sq`) legacy surface, kept bit-identical to the
//! pre-metric pipeline; the `*_metric` forms take an explicit
//! [`MetricKind`] and are what the driver and the metric-aware tests use.
//! The plain forms are thin `l2sq` wrappers, so there is exactly one
//! implementation of each objective.

use crate::geometry::{MetricKind, PointSet, PointStore};
use crate::util::pool;
use std::sync::Mutex;

/// Points per parallel work item. Fixed (not derived from the thread
/// count) and merged in block order, so the f64 result is independent of
/// the worker count and schedule.
const COST_BLOCK: usize = 16 * 1024;

/// All three objectives of one center set over one point set.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostSummary {
    /// Σ d(x, C) — the k-median objective.
    pub median: f64,
    /// max d(x, C) — the k-center objective.
    pub center: f64,
    /// Σ d(x, C)² — the k-means objective.
    pub means: f64,
}

fn chunk_cost(
    points: &PointSet,
    lo: usize,
    hi: usize,
    centers: &PointSet,
    metric: MetricKind,
) -> CostSummary {
    let mut s = CostSummary::default();
    chunk_cost_into(&mut s, points, lo, hi, centers, metric);
    s
}

/// Accumulate rows `lo..hi` into a running summary. Accumulating window
/// after window into one `acc` performs *exactly* the f64 op sequence of a
/// single [`chunk_cost`] pass over the concatenated range — which is what
/// lets the out-of-core evaluator ([`eval_costs_store`]) stay bit-identical
/// to the in-memory one while never holding more than one window.
fn chunk_cost_into(
    s: &mut CostSummary,
    points: &PointSet,
    lo: usize,
    hi: usize,
    centers: &PointSet,
    metric: MetricKind,
) {
    for i in lo..hi {
        let row = points.row(i);
        let mut best = f32::INFINITY;
        for c in 0..centers.len() {
            let d = metric.surrogate(row, centers.row(c));
            if d < best {
                best = d;
            }
        }
        // Under l2sq this is the historical pair: d2 = best.max(0) as f64,
        // median += sqrt(d2), means += d2 — bit-identical.
        let d = metric.to_dist_f64(best);
        s.median += d;
        s.means += metric.means_share_f64(best);
        if d > s.center {
            s.center = d;
        }
    }
}

/// Evaluate all three objectives under `metric`. `threads = 1` forces a
/// single pass on the caller; any other value evaluates fixed blocks on
/// the shared worker pool (`util::pool::global`) and merges them in block
/// order, so the result does not depend on the actual worker count.
pub fn eval_costs_metric(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
    threads: usize,
) -> CostSummary {
    assert!(!centers.is_empty(), "no centers");
    assert_eq!(points.dim(), centers.dim(), "dim mismatch");
    let n = points.len();
    if threads == 1 || n < 10_000 {
        return chunk_cost(points, 0, n, centers, metric);
    }
    let n_blocks = crate::util::div_ceil(n, COST_BLOCK);
    let parts: Vec<Mutex<Option<CostSummary>>> = (0..n_blocks).map(|_| Mutex::new(None)).collect();
    pool::global().run(n_blocks, &|b| {
        let lo = b * COST_BLOCK;
        let hi = (lo + COST_BLOCK).min(n);
        *parts[b].lock().expect("cost slot poisoned") =
            Some(chunk_cost(points, lo, hi, centers, metric));
    });
    let mut out = CostSummary::default();
    for slot in parts {
        let p = slot
            .into_inner()
            .expect("cost slot poisoned")
            .expect("block not evaluated");
        out.median += p.median;
        out.means += p.means;
        out.center = out.center.max(p.center);
    }
    out
}

/// [`eval_costs_metric`] under the default squared-Euclidean metric.
pub fn eval_costs(points: &PointSet, centers: &PointSet, threads: usize) -> CostSummary {
    eval_costs_metric(points, centers, MetricKind::L2Sq, threads)
}

/// Out-of-core [`eval_costs_metric`]: one sequential pass over the store,
/// loading at most one I/O window (~`window_points` rows, rounded to a
/// `COST_BLOCK` multiple) at a time.
///
/// Bit-identical to `eval_costs_metric` on the same data, both branches:
/// the sequential branch accumulates every window into one running
/// summary (`chunk_cost_into` — the identical f64 op sequence as one
/// full pass), and the pooled branch keeps the window aligned to absolute
/// `COST_BLOCK` boundaries so the per-block partials *and their in-order
/// merge* are exactly the in-memory evaluator's. `Mem` stores simply
/// delegate.
pub fn eval_costs_store(
    store: &PointStore,
    centers: &PointSet,
    metric: MetricKind,
    threads: usize,
    window_points: usize,
) -> CostSummary {
    if let PointStore::Mem(ps) = store {
        return eval_costs_metric(ps, centers, metric, threads);
    }
    assert!(!centers.is_empty(), "no centers");
    assert_eq!(store.dim(), centers.dim(), "dim mismatch");
    let n = store.len();
    let window = (window_points.max(COST_BLOCK) / COST_BLOCK) * COST_BLOCK;
    let mut out = CostSummary::default();
    let sequential = threads == 1 || n < 10_000;
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + window).min(n);
        let pts = store.load(lo, hi);
        let w = hi - lo;
        if sequential {
            chunk_cost_into(&mut out, &pts, 0, w, centers, metric);
        } else {
            let n_blocks = crate::util::div_ceil(w, COST_BLOCK);
            let parts: Vec<Mutex<Option<CostSummary>>> =
                (0..n_blocks).map(|_| Mutex::new(None)).collect();
            pool::global().run(n_blocks, &|b| {
                let blo = b * COST_BLOCK;
                let bhi = (blo + COST_BLOCK).min(w);
                *parts[b].lock().expect("cost slot poisoned") =
                    Some(chunk_cost(&pts, blo, bhi, centers, metric));
            });
            for slot in parts {
                let p = slot
                    .into_inner()
                    .expect("cost slot poisoned")
                    .expect("block not evaluated");
                out.median += p.median;
                out.means += p.means;
                out.center = out.center.max(p.center);
            }
        }
        lo = hi;
    }
    out
}

/// k-median objective Σ d(x, C).
pub fn kmedian_cost(points: &PointSet, centers: &PointSet) -> f64 {
    eval_costs(points, centers, 0).median
}

/// k-median objective under an explicit metric.
pub fn kmedian_cost_metric(points: &PointSet, centers: &PointSet, metric: MetricKind) -> f64 {
    eval_costs_metric(points, centers, metric, 0).median
}

/// k-center objective max d(x, C).
pub fn kcenter_cost(points: &PointSet, centers: &PointSet) -> f64 {
    eval_costs(points, centers, 0).center
}

/// k-center objective under an explicit metric.
pub fn kcenter_cost_metric(points: &PointSet, centers: &PointSet, metric: MetricKind) -> f64 {
    eval_costs_metric(points, centers, metric, 0).center
}

/// k-means objective Σ d(x, C)².
pub fn kmeans_cost(points: &PointSet, centers: &PointSet) -> f64 {
    eval_costs(points, centers, 0).means
}

/// k-means objective under an explicit metric.
pub fn kmeans_cost_metric(points: &PointSet, centers: &PointSet, metric: MetricKind) -> f64 {
    eval_costs_metric(points, centers, metric, 0).means
}

/// All true nearest-center distances under `metric` (one
/// [`assign_full_metric`] pass; surrogates mapped through the metric).
fn nearest_dists_metric(points: &PointSet, centers: &PointSet, metric: MetricKind) -> Vec<f64> {
    assert!(!centers.is_empty(), "no centers");
    assert_eq!(points.dim(), centers.dim(), "dim mismatch");
    let (surr, _) = assign_full_metric(points, centers, metric);
    surr.into_iter().map(|s| metric.to_dist_f64(s)).collect()
}

/// k-center objective with `z` outliers: max d(x, C) after the `z`
/// farthest points are dropped. `z = 0` is [`kcenter_cost`]; `z >= n`
/// costs 0 (everything may be dropped).
pub fn kcenter_cost_with_outliers(points: &PointSet, centers: &PointSet, z: usize) -> f64 {
    kcenter_cost_with_outliers_metric(points, centers, z, MetricKind::L2Sq)
}

/// [`kcenter_cost_with_outliers`] under an explicit metric.
pub fn kcenter_cost_with_outliers_metric(
    points: &PointSet,
    centers: &PointSet,
    z: usize,
    metric: MetricKind,
) -> f64 {
    let mut d = nearest_dists_metric(points, centers, metric);
    let n = d.len();
    if z >= n {
        return 0.0;
    }
    let keep = n - z - 1;
    *d.select_nth_unstable_by(keep, f64::total_cmp).1
}

/// k-median objective with `z` outliers: Σ d(x, C) over all but the `z`
/// farthest points, summed in index order (deterministic).
pub fn kmedian_cost_with_outliers(points: &PointSet, centers: &PointSet, z: usize) -> f64 {
    kmedian_cost_with_outliers_metric(points, centers, z, MetricKind::L2Sq)
}

/// [`kmedian_cost_with_outliers`] under an explicit metric.
pub fn kmedian_cost_with_outliers_metric(
    points: &PointSet,
    centers: &PointSet,
    z: usize,
    metric: MetricKind,
) -> f64 {
    let d = nearest_dists_metric(points, centers, metric);
    let n = d.len();
    if z >= n {
        return 0.0;
    }
    let mut sorted = d.clone();
    let threshold = *sorted.select_nth_unstable_by(n - z - 1, f64::total_cmp).1;
    // Drop exactly z: everything strictly above the threshold plus enough
    // threshold-equal points to fill the budget (ties resolved by index).
    let mut budget = z - d.iter().filter(|&&x| x > threshold).count();
    let mut sum = 0.0f64;
    for &x in &d {
        if x > threshold {
            continue;
        }
        if x == threshold && budget > 0 {
            budget -= 1;
            continue;
        }
        sum += x;
    }
    sum
}

/// Full nearest-center assignment: (sq-distance, index) per point.
/// Single-threaded; used by the sequential baselines and tests.
pub fn assign_full(points: &PointSet, centers: &PointSet) -> (Vec<f32>, Vec<u32>) {
    assign_full_metric(points, centers, MetricKind::L2Sq)
}

/// [`assign_full`] under an explicit metric: (surrogate, index) per point.
/// The scalar reference the tiled kernels are checked against bit-for-bit.
pub fn assign_full_metric(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
) -> (Vec<f32>, Vec<u32>) {
    let n = points.len();
    let mut dist = vec![0.0f32; n];
    let mut idx = vec![0u32; n];
    for i in 0..n {
        let row = points.row(i);
        let mut best = f32::INFINITY;
        let mut bj = 0u32;
        for c in 0..centers.len() {
            let d = metric.surrogate(row, centers.row(c));
            if d < best {
                best = d;
                bj = c as u32;
            }
        }
        dist[i] = best.max(0.0);
        idx[i] = bj;
    }
    (dist, idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line_points() -> PointSet {
        PointSet::from_flat(1, vec![0.0, 1.0, 2.0, 10.0])
    }

    #[test]
    fn known_costs_single_center() {
        let p = line_points();
        let c = PointSet::from_flat(1, vec![0.0]);
        let s = eval_costs(&p, &c, 1);
        assert!((s.median - 13.0).abs() < 1e-6);
        assert!((s.center - 10.0).abs() < 1e-6);
        assert!((s.means - (1.0 + 4.0 + 100.0)).abs() < 1e-4);
    }

    #[test]
    fn known_costs_two_centers() {
        let p = line_points();
        let c = PointSet::from_flat(1, vec![1.0, 10.0]);
        let s = eval_costs(&p, &c, 1);
        assert!((s.median - 2.0).abs() < 1e-6); // 1 + 0 + 1 + 0
        assert!((s.center - 1.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_matches_sequential() {
        let mut rng = crate::util::rng::Rng::new(5);
        let n = 30_000;
        let coords: Vec<f32> = (0..n * 3).map(|_| rng.f32()).collect();
        let p = PointSet::from_flat(3, coords);
        let c = PointSet::from_flat(3, (0..30).map(|_| rng.f32()).collect());
        let seq = eval_costs(&p, &c, 1);
        let par = eval_costs(&p, &c, 4);
        assert!((seq.median - par.median).abs() / seq.median < 1e-9);
        assert_eq!(seq.center, par.center);
        // The metric-threaded path stays deterministic too.
        for m in MetricKind::ALL {
            let seq = eval_costs_metric(&p, &c, m, 1);
            let par = eval_costs_metric(&p, &c, m, 4);
            assert!((seq.median - par.median).abs() / seq.median.max(1e-12) < 1e-9, "{m}");
            assert_eq!(seq.center, par.center, "{m}");
        }
    }

    #[test]
    fn store_eval_is_bit_identical_to_in_memory() {
        use crate::geometry::StoreWriter;
        let mut rng = crate::util::rng::Rng::new(6);
        let n = 40_000;
        let p = PointSet::from_flat(3, (0..n * 3).map(|_| rng.f32()).collect());
        let c = PointSet::from_flat(3, (0..3 * 20).map(|_| rng.f32()).collect());
        let dir = std::env::temp_dir().join("mrcluster_cost_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("eval.mrc");
        let mut w = StoreWriter::create(&path, 3, n, 0).unwrap();
        for i in 0..n {
            w.push_row(p.row(i)).unwrap();
        }
        let store = PointStore::from(w.finish().unwrap());
        for threads in [1usize, 4] {
            let mem = eval_costs_metric(&p, &c, MetricKind::L2Sq, threads);
            // A window far below n forces many load/process/drop cycles.
            let ooc = eval_costs_store(&store, &c, MetricKind::L2Sq, threads, 16 * 1024);
            assert_eq!(mem.median.to_bits(), ooc.median.to_bits(), "threads={threads}");
            assert_eq!(mem.center.to_bits(), ooc.center.to_bits(), "threads={threads}");
            assert_eq!(mem.means.to_bits(), ooc.means.to_bits(), "threads={threads}");
        }
        // Residency stayed bounded by one window and drained fully.
        let meter = store.meter().unwrap();
        assert!(meter.peak() <= 16 * 1024 * 3 * 4, "peak {} over a window", meter.peak());
        assert_eq!(meter.current(), 0);
    }

    #[test]
    fn metric_costs_on_hand_instance() {
        // Points on two axes; one center at e0.
        let p = PointSet::from_flat(2, vec![3.0, 4.0, 2.0, 0.0]);
        let c = PointSet::from_flat(2, vec![1.0, 0.0]);
        let l2 = kmedian_cost_metric(&p, &c, MetricKind::L2);
        assert!((l2 - (20.0f64.sqrt() + 1.0)).abs() < 1e-5);
        assert!((kmedian_cost_metric(&p, &c, MetricKind::L1) - (6.0 + 1.0)).abs() < 1e-5);
        assert!((kcenter_cost_metric(&p, &c, MetricKind::Chebyshev) - 4.0).abs() < 1e-5);
        // (3,4) is at atan2(4,3) ≈ 0.9273 rad from e0; (2,0) is aligned.
        assert!((kcenter_cost_metric(&p, &c, MetricKind::Cosine) - 0.9273).abs() < 1e-3);
    }

    #[test]
    fn l2sq_wrappers_are_bit_identical_to_metric_form() {
        let mut rng = crate::util::rng::Rng::new(8);
        let p = PointSet::from_flat(3, (0..600).map(|_| rng.f32()).collect());
        let c = PointSet::from_flat(3, (0..15).map(|_| rng.f32()).collect());
        let legacy = eval_costs(&p, &c, 1);
        let metric = eval_costs_metric(&p, &c, MetricKind::L2Sq, 1);
        assert_eq!(legacy.median.to_bits(), metric.median.to_bits());
        assert_eq!(legacy.center.to_bits(), metric.center.to_bits());
        assert_eq!(legacy.means.to_bits(), metric.means.to_bits());
    }

    #[test]
    fn assign_full_picks_nearest() {
        let p = line_points();
        let c = PointSet::from_flat(1, vec![1.0, 10.0]);
        let (d, idx) = assign_full(&p, &c);
        assert_eq!(idx, vec![0, 0, 0, 1]);
        assert!((d[3] - 0.0).abs() < 1e-6);
        assert!((d[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_cost_when_centers_cover_points() {
        let p = line_points();
        let s = eval_costs(&p, &p, 1);
        assert_eq!(s.median, 0.0);
        assert_eq!(s.center, 0.0);
    }

    #[test]
    #[should_panic(expected = "no centers")]
    fn empty_centers_panics() {
        let p = line_points();
        eval_costs(&p, &PointSet::from_flat(1, vec![]), 1);
    }

    #[test]
    fn outlier_kcenter_drops_farthest() {
        let p = line_points(); // 0, 1, 2, 10
        let c = PointSet::from_flat(1, vec![0.0]);
        assert!((kcenter_cost_with_outliers(&p, &c, 0) - 10.0).abs() < 1e-9);
        assert!((kcenter_cost_with_outliers(&p, &c, 1) - 2.0).abs() < 1e-9);
        assert!((kcenter_cost_with_outliers(&p, &c, 3) - 0.0).abs() < 1e-9);
        assert_eq!(kcenter_cost_with_outliers(&p, &c, 99), 0.0);
    }

    #[test]
    fn outlier_kmedian_drops_farthest() {
        let p = line_points();
        let c = PointSet::from_flat(1, vec![0.0]);
        assert!((kmedian_cost_with_outliers(&p, &c, 0) - 13.0).abs() < 1e-9);
        assert!((kmedian_cost_with_outliers(&p, &c, 1) - 3.0).abs() < 1e-9);
        assert!((kmedian_cost_with_outliers(&p, &c, 4) - 0.0).abs() < 1e-9);
    }

    #[test]
    fn outlier_kmedian_tie_drop_is_exact() {
        // Three points at the same max distance; z = 2 must drop exactly 2.
        let p = PointSet::from_flat(1, vec![0.0, 5.0, 5.0, 5.0]);
        let c = PointSet::from_flat(1, vec![0.0]);
        assert!((kmedian_cost_with_outliers(&p, &c, 2) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn outlier_metric_variants_drop_under_their_own_geometry() {
        // Under L1 the point (3,3) is at distance 6; under Chebyshev 3.
        let p = PointSet::from_flat(2, vec![0.0, 0.0, 1.0, 0.0, 3.0, 3.0]);
        let c = PointSet::from_flat(2, vec![0.0, 0.0]);
        assert!(
            (kcenter_cost_with_outliers_metric(&p, &c, 0, MetricKind::L1) - 6.0).abs() < 1e-9
        );
        assert!(
            (kcenter_cost_with_outliers_metric(&p, &c, 1, MetricKind::L1) - 1.0).abs() < 1e-9
        );
        assert!(
            (kmedian_cost_with_outliers_metric(&p, &c, 1, MetricKind::Chebyshev) - 1.0).abs()
                < 1e-9
        );
    }
}
